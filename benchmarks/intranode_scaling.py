"""Threads-per-node scaling (paper Fig. 7).

The paper fixes 512 nodes and sweeps threads/rank, showing the hybrid
(task-based) component scales to all hardware threads. Analogue: fixed
4-rank decomposition of the clustered task graph, threads ∈ {1 … 32}.
"""

from __future__ import annotations

from repro.core import AsyncExecutorSim, decompose_with_comm
from .common import build_clustered_taskgraph, emit
from .strong_scaling import PHASES


def run(n_particles=12000, ranks=4, threads_list=(1, 2, 4, 8, 16, 32)):
    g, ncells, occupancy = build_clustered_taskgraph(n_particles)
    cell_bytes = [float(max(o, 1)) * 64.0 for o in occupancy]
    dist, _ = decompose_with_comm(g, ncells, ranks,
                                  cell_bytes=cell_bytes, phases=PHASES)
    rows = []
    t1 = None
    for th in threads_list:
        m = AsyncExecutorSim(dist, ranks=ranks, threads=th,
                             latency=1.5e-6, bandwidth=5e9).run()
        if t1 is None:
            t1 = m.makespan
        eff = t1 / (m.makespan * th)
        rows.append({
            "name": f"intranode/threads{th}",
            "us_per_call": round(m.makespan * 1e6, 1),
            "derived": f"efficiency={min(eff, 1.0):.3f}",
        })
    emit(rows, "intranode_scaling")
    return rows


if __name__ == "__main__":
    run()
