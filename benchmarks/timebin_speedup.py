"""Hierarchical time-bin speed-up on the Sedov blast (1807.01341).

Runs the same simulated time span twice over the point-explosion IC:

* **multi-dt** — :class:`~repro.sph.TimeBinSimulation`: per-particle
  power-of-two time bins, only due bins integrated each sub-step;
* **global-dt** — the reference :class:`~repro.sph.Simulation` stepping
  every particle at the global CFL minimum.

Reported per engine: particle-updates actually performed (the paper's
"work" axis), wall-clock, and energy drift. A third section replays the
activity pattern through the *task-graph* layer: per bin level,
``wave_schedule(active_only=True)`` over the activation-masked graph vs
the full graph — the simulated-schedule speed-up, summed over one cycle
with each level weighted by how often it fires.

Run:  PYTHONPATH=src python benchmarks/timebin_speedup.py [n_side]
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import AsyncExecutorSim, CostModel, wave_schedule
from repro.sph import (SPHConfig, SimulationSpec, build_simulation,
                       sedov_ic)
from repro.sph.engine import build_taskgraph
from repro.sph.timebins import cell_max_bins

try:                                    # runnable as module or script
    from .common import emit
except ImportError:                     # pragma: no cover
    from common import emit


def run(n_side=16, ncycles=3, dt_max=0.02, e0=1.0, seed=0,
        max_depth=10) -> list:
    ic = sedov_ic(n_side, e0=e0, seed=seed)
    n = len(ic["pos"])
    cfg = SPHConfig(alpha_visc=1.0, cfl=0.15)
    spec = SimulationSpec(
        scenario="sedov",
        scenario_params={"n_side": n_side, "e0": e0, "seed": seed},
        physics=cfg, dt_max=dt_max, max_depth=max_depth)

    # ---------------------------------------------------------- multi-dt
    tb = build_simulation(spec.with_(integrator="timebin"), ic=ic).engine
    e0_m, _ = tb.diagnostics()
    t0 = time.perf_counter()
    hist_tot = None
    for _ in range(ncycles):
        stats = tb.run_cycle()
        h = stats["bin_hist"]
        hist_tot = h if hist_tot is None else (
            np.pad(hist_tot, (0, max(0, len(h) - len(hist_tot))))
            + np.pad(h, (0, max(0, len(hist_tot) - len(h)))))
    wall_multi = time.perf_counter() - t0
    e1_m, _ = tb.diagnostics()
    t_span = float(tb.state.time)
    updates_multi = tb.particle_updates
    drift_multi = abs(e1_m - e0_m) / abs(e0_m)

    # --------------------------------------------------------- global-dt
    gl = build_simulation(spec.with_(integrator="global", rebin_every=4),
                          ic=ic).engine
    e0_g, _ = gl.diagnostics()
    t0 = time.perf_counter()
    steps = 0
    while float(gl.state.time) < t_span:
        gl.run(1)
        steps += 1
    wall_global = time.perf_counter() - t0
    e1_g, _ = gl.diagnostics()
    updates_global = steps * n
    drift_global = abs(e1_g - e0_g) / abs(e0_g)

    # ------------------------------------------- simulated schedule layer
    # replay the final bin assignment through the activation-masked task
    # graph: wave/simulated cost per level, weighted by firing frequency
    bins_h = np.asarray(tb.state.bins)
    mask_h = np.asarray(tb.state.cells.mask)
    cb = cell_max_bins(bins_h, mask_h)
    depth = max(int(cb.max()), 0)
    occ = (mask_h > 0).sum(axis=1)
    cm = CostModel(rates={})
    sched_active = 0.0
    sched_full = 0.0
    sim_active = 0.0
    sim_full = 0.0
    for level in range(depth + 1):
        # sub-steps per cycle whose lowest active bin is exactly `level`
        fires = 1 if level == 0 else 2 ** (level - 1)
        g = build_taskgraph(tb.spec, tb.pairs, occ, cm,
                            cell_bins=cb, level=level)
        for t in g.tasks.values():
            object.__setattr__(t, "rank", 0)
        waves = wave_schedule(g, active_only=True)
        active_cost = sum(g.tasks[t].cost for w in waves for t in w)
        full_cost = g.total_cost()
        sched_active += fires * active_cost
        sched_full += fires * full_cost
        sim_active += fires * AsyncExecutorSim(
            g, ranks=1, threads=4, active_only=True).run().makespan
        sim_full += fires * AsyncExecutorSim(
            g, ranks=1, threads=4).run().makespan

    rows = [
        {"name": "timebin/multi_dt/updates", "us_per_call": updates_multi,
         "derived": f"wall_s={wall_multi:.2f};dE={drift_multi:.3e};"
                    f"t={t_span:.3f}"},
        {"name": "timebin/global_dt/updates", "us_per_call": updates_global,
         "derived": f"wall_s={wall_global:.2f};dE={drift_global:.3e};"
                    f"steps={steps}"},
        {"name": "timebin/speedup",
         "us_per_call": round(updates_global / max(updates_multi, 1), 3),
         "derived": f"wall_speedup={wall_global / max(wall_multi, 1e-9):.2f};"
                    f"drift_ratio={drift_multi / max(drift_global, 1e-12):.2f}"},
        {"name": "timebin/schedule_speedup",
         "us_per_call": round(sched_full / max(sched_active, 1e-12), 3),
         "derived": f"sim_makespan_speedup="
                    f"{sim_full / max(sim_active, 1e-12):.2f};"
                    f"bin_hist={[int(x) for x in np.asarray(hist_tot)]}"},
    ]
    return rows


if __name__ == "__main__":
    import sys
    n_side = int(sys.argv[1]) if len(sys.argv) > 1 else 16
    emit(run(n_side=n_side), "timebin_speedup")
