"""Domain decomposition quality (paper Fig. 4 + §3.2).

The paper's partitions "follow the cells in the mesh but are not made of
regular cuts" — the point being that *work*, not data, is balanced. We
compare the multilevel graph partition against the traditional geometric
recursive-coordinate-bisection baseline on the clustered IC, over the
**recursively split** cell graph (§3.1 — without splitting, a single
overdense cell's O(occ²) self-task exceeds any per-rank budget and *no*
partitioner can balance it; that failure mode is also reported below).
"""

from __future__ import annotations

import numpy as np

from repro.core import Graph, evaluate, partition_geometric, partition_graph
from repro.sph import clustered_ic
from repro.sph.adaptive import refined_cell_graph, split_cells
from .common import emit


def run(n_particles=8000, ranks=32, seed=0, base_side=6, threshold=48):
    ic = clustered_ic(n_particles, seed=seed)
    box = ic["box"]

    # --- refined (split) cell graph: the paper's granularity
    node_w, edges, leaves = refined_cell_graph(
        ic["pos"], box, base_side, threshold=threshold, max_levels=5)
    g = Graph.from_edges(len(leaves), edges, np.maximum(node_w, 1e-9))
    ours = partition_graph(g, ranks, seed=0)

    centres = np.array([(np.array(l.idx) + 0.5) * box /
                        (base_side * 2 ** l.level) for l in leaves])
    geo = evaluate(g, partition_geometric(centres, ranks), ranks)
    geo_w = evaluate(g, partition_geometric(centres, ranks,
                                            weights=node_w), ranks)

    # --- unsplit graph: demonstrates why §3.1's splitting is needed
    node_u, edges_u, leaves_u = refined_cell_graph(
        ic["pos"], box, base_side, threshold=10 ** 9, max_levels=0)
    gu = Graph.from_edges(len(leaves_u), edges_u, np.maximum(node_u, 1e-9))
    ours_u = partition_graph(gu, ranks, seed=0)

    rows = [{
        "name": "partition/split_graph_multilevel",
        "us_per_call": "",
        "derived": f"imbalance={ours.imbalance:.3f} cut={ours.edge_cut:.3g} "
                   f"({len(leaves)} leaves)",
    }, {
        "name": "partition/split_geometric_unweighted",
        "us_per_call": "",
        "derived": f"imbalance={geo.imbalance:.3f} cut={geo.edge_cut:.3g}",
    }, {
        "name": "partition/split_geometric_work_weighted",
        "us_per_call": "",
        "derived": f"imbalance={geo_w.imbalance:.3f} cut={geo_w.edge_cut:.3g}",
    }, {
        "name": "partition/max_load_ratio_vs_geometric",
        "us_per_call": "",
        "derived": f"{geo.part_loads.max() / ours.part_loads.max():.2f}x "
                   f"(>1 ⇒ graph partition wins)",
    }, {
        "name": "partition/unsplit_graph (no §3.1 refinement)",
        "us_per_call": "",
        "derived": f"imbalance={ours_u.imbalance:.3f} "
                   f"({len(leaves_u)} cells) — splitting is load-bearing",
    }]
    emit(rows, "partition_quality")
    return rows


if __name__ == "__main__":
    run()
