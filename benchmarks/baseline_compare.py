"""Task-based engine vs traditional baseline (paper §5 GADGET-2 numbers).

    "The simulation setup … takes 2.9 s of wall-clock time per time-step
    on 256 cores using SWIFT whilst the default GADGET-2 code on exactly
    the same setup with the same number of cores requires 32 s."

GADGET-2 is not available here; the honest stand-in at test scale is the
bulk O(N²) masked evaluation (``ref_nsquared``) — the cost profile of
neighbour search without cell tasks. Both engines are jitted JAX on the
same CPU, so the ratio isolates the algorithmic effect of the cell/task
decomposition, which is the paper's comparison intent.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.sph import SPHConfig, clustered_ic
from repro.sph.cellgrid import bin_particles, build_pair_list, choose_grid
from repro.sph.engine import compute_accelerations
from repro.sph.ref_nsquared import nsq_density, nsq_forces
from .common import emit, timeit


def run(n_side=16, seed=0):
    # uniform occupancy: the controlled comparison of neighbour-search
    # algorithms (clustered cells are exercised by the partition/scaling
    # benchmarks; here they would only blow up the padded-block capacity)
    from repro.sph import uniform_ic
    ic = uniform_ic(n_side, seed=seed)
    n_particles = len(ic["pos"])
    pos, vel, mass, u, h, box = (ic[k] for k in
                                 ("pos", "vel", "mass", "u", "h", "box"))
    rng = np.random.default_rng(seed)
    vel = (vel + 0.1 * rng.standard_normal(vel.shape)).astype(np.float32)

    # --- task-based cell engine
    spec = choose_grid(box, float(h.max()), n_particles)
    cells, _ = bin_particles(spec, pos, vel, mass, u, h)
    pairs = build_pair_list(spec)
    cfg = SPHConfig(alpha_visc=0.8)
    cell_fn = jax.jit(lambda c: compute_accelerations(c, pairs, cfg))
    t_cell = timeit(cell_fn, cells, repeats=3)

    # --- bulk O(N²) baseline
    posj, velj, massj = jnp.asarray(pos), jnp.asarray(vel), jnp.asarray(mass)
    uj, hj = jnp.asarray(u), jnp.asarray(h)

    @jax.jit
    def nsq_fn(pos, vel, mass, u, h):
        rho, drho, _ = nsq_density(pos, mass, h, box)
        omega = 1.0 + (h / (3 * rho)) * drho
        return nsq_forces(pos, vel, mass, u, h, rho, omega, box,
                          alpha_visc=0.8)

    t_nsq = timeit(nsq_fn, posj, velj, massj, uj, hj, repeats=3)

    rows = [{
        "name": "baseline_compare/task_cell_engine",
        "us_per_call": round(t_cell * 1e6, 1),
        "derived": f"{n_particles} particles, {spec.ncells} cells",
    }, {
        "name": "baseline_compare/bulk_nsq_baseline",
        "us_per_call": round(t_nsq * 1e6, 1),
        "derived": f"speedup={t_nsq / t_cell:.1f}x "
                   f"(paper: 32s/2.9s = 11x vs GADGET-2)",
    }]
    emit(rows, "baseline_compare")
    return rows


if __name__ == "__main__":
    run()
