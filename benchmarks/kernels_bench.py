"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference.

NOTE: this container executes Pallas in interpret mode (Python), so
wall-times here validate *plumbing*, not TPU performance — TPU-side perf is
assessed structurally in §Roofline from the lowered artifacts. The jnp
reference timing is the honest CPU number.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.mamba_scan import selective_scan, selective_scan_ref
from .common import emit, timeit


def run():
    rows = []
    rng = np.random.default_rng(0)

    # flash attention, decode-ish block
    B, S, H, hd = 1, 512, 4, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    ref_fn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    t_ref = timeit(ref_fn, q, k, v, repeats=3)
    pal_fn = jax.jit(lambda q, k, v: flash_attention(
        q, k, v, causal=True, block_q=128, block_k=128, interpret=True))
    t_pal = timeit(pal_fn, q, k, v, repeats=3)
    rows.append({"name": "kernels/attention_ref_jnp",
                 "us_per_call": round(t_ref * 1e6, 1),
                 "derived": f"B{B} S{S} H{H} hd{hd}"})
    rows.append({"name": "kernels/flash_attention_interpret",
                 "us_per_call": round(t_pal * 1e6, 1),
                 "derived": "interpret-mode (correctness harness)"})

    # mamba scan
    B, S, dI, N = 1, 256, 64, 16
    u = jnp.asarray(rng.standard_normal((B, S, dI)).astype(np.float32))
    dt = jnp.asarray(0.1 * rng.random((B, S, dI)).astype(np.float32))
    A = jnp.asarray(-rng.random((dI, N)).astype(np.float32) - 0.1)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    D = jnp.asarray(rng.random(dI).astype(np.float32))
    ref2 = jax.jit(lambda *a: selective_scan_ref(*a))
    t_ref2 = timeit(ref2, u, dt, A, Bm, Cm, D, repeats=3)
    pal2 = jax.jit(lambda *a: selective_scan(*a, block_d=32,
                                             interpret=True))
    t_pal2 = timeit(pal2, u, dt, A, Bm, Cm, D, repeats=3)
    rows.append({"name": "kernels/mamba_scan_ref_jnp",
                 "us_per_call": round(t_ref2 * 1e6, 1),
                 "derived": f"B{B} S{S} dI{dI} N{N}"})
    rows.append({"name": "kernels/mamba_scan_interpret",
                 "us_per_call": round(t_pal2 * 1e6, 1),
                 "derived": "interpret-mode (correctness harness)"})

    emit(rows, "kernels_bench")
    return rows


if __name__ == "__main__":
    run()
