# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows; JSON copies land in benchmarks/results/, and a run index with
# per-module status/timing in benchmarks/results/summary.json.
#
# Modules whose optional dependencies or device requirements are absent
# (e.g. not enough addressable devices for a mesh, a kernel backend the
# container lacks) are *skipped*, not failed: a partial benchmark run on a
# laptop still produces every row it can.
from __future__ import annotations

import importlib
import json
import os
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# exception texts that mean "environment can't run this" rather than "the
# benchmark is broken" — matched case-insensitively
_SKIP_MARKERS = (
    "addressable devices",
    "host_platform_device_count",
    "requires jaxlib",
    "unavailable backend",
    "not supported on this platform",
)


def _classify(exc: BaseException) -> str:
    if isinstance(exc, ModuleNotFoundError):
        # a missing *external* module is the environment's fault; a repo
        # module failing to resolve is a bug and must fail the run
        missing = exc.name or ""
        return ("error" if missing.startswith(("repro", "benchmarks"))
                else "skipped")
    if isinstance(exc, NotImplementedError):
        return "skipped"
    text = str(exc).lower()
    if any(marker in text for marker in _SKIP_MARKERS):
        return "skipped"
    return "error"


def main() -> None:
    print("name,us_per_call,derived")
    # module names, imported lazily inside the try below: a missing
    # optional dependency at *import* time must classify as a skip of
    # that one module, not crash the whole run before any rows print
    modules = [
        ("strong_scaling (Figs 5/6/8)", "strong_scaling"),
        ("intranode_scaling (Fig 7)", "intranode_scaling"),
        ("comm_stats (§5 messages)", "comm_stats"),
        ("partition_quality (Fig 4)", "partition_quality"),
        ("baseline_compare (§5 GADGET-2)", "baseline_compare"),
        ("kernels_bench", "kernels_bench"),
        ("halo_transport (host vs collective vs fused wire)",
         "halo_transport"),
        ("fused_cycles (host- vs device-scheduled segments)",
         "fused_cycles"),
        ("observability (task plots)", "observability_bench"),
        ("fleet_throughput (batched serving)", "fleet_throughput"),
    ]
    summary = {}
    failures = []
    for label, modname in modules:
        t0 = time.time()
        try:
            mod = importlib.import_module(f".{modname}", __package__)
            mod.run()
        except Exception as e:
            status = _classify(e)
            summary[label] = {
                "status": status, "seconds": round(time.time() - t0, 1),
                "reason": f"{type(e).__name__}: {e}"}
            if status == "error":
                failures.append((label, e))
                print(f"{label},ERROR,{type(e).__name__}: {e}",
                      file=sys.stderr)
                traceback.print_exc()
            else:
                print(f"{label},SKIP,{type(e).__name__}: {e}",
                      file=sys.stderr)
        else:
            summary[label] = {"status": "ok",
                              "seconds": round(time.time() - t0, 1)}
            print(f"# {label} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
    # factored into benchmarks/common.py so they are standalone-runnable
    # (``python -m benchmarks.common``) and testable without a full run
    from .common import bench_trajectory, env_provenance
    summary["_env"] = env_provenance()
    summary["_bench_trajectory"] = bench_trajectory()
    bad = [e["file"] for e in summary["_bench_trajectory"]
           if not e["valid"]]
    if bad:
        print(f"# invalid BENCH artifacts: {bad}", file=sys.stderr)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark modules failed")


if __name__ == "__main__":
    main()
