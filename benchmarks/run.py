# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows; JSON copies land in benchmarks/results/.
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (baseline_compare, comm_stats, halo_transport,
                   intranode_scaling, kernels_bench, partition_quality,
                   strong_scaling)

    print("name,us_per_call,derived")
    modules = [
        ("strong_scaling (Figs 5/6/8)", strong_scaling.run),
        ("intranode_scaling (Fig 7)", intranode_scaling.run),
        ("comm_stats (§5 messages)", comm_stats.run),
        ("partition_quality (Fig 4)", partition_quality.run),
        ("baseline_compare (§5 GADGET-2)", baseline_compare.run),
        ("kernels_bench", kernels_bench.run),
        ("halo_transport (host vs collective wire)", halo_transport.run),
    ]
    failures = []
    for label, fn in modules:
        t0 = time.time()
        try:
            fn()
        except Exception as e:
            failures.append((label, e))
            print(f"{label},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            traceback.print_exc()
        else:
            print(f"# {label} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark modules failed")


if __name__ == "__main__":
    main()
