# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows; JSON copies land in benchmarks/results/, and a run index with
# per-module status/timing in benchmarks/results/summary.json.
#
# Modules whose optional dependencies or device requirements are absent
# (e.g. not enough addressable devices for a mesh, a kernel backend the
# container lacks) are *skipped*, not failed: a partial benchmark run on a
# laptop still produces every row it can.
from __future__ import annotations

import importlib
import json
import os
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# exception texts that mean "environment can't run this" rather than "the
# benchmark is broken" — matched case-insensitively
_SKIP_MARKERS = (
    "addressable devices",
    "host_platform_device_count",
    "requires jaxlib",
    "unavailable backend",
    "not supported on this platform",
)


def _classify(exc: BaseException) -> str:
    if isinstance(exc, ModuleNotFoundError):
        # a missing *external* module is the environment's fault; a repo
        # module failing to resolve is a bug and must fail the run
        missing = exc.name or ""
        return ("error" if missing.startswith(("repro", "benchmarks"))
                else "skipped")
    if isinstance(exc, NotImplementedError):
        return "skipped"
    text = str(exc).lower()
    if any(marker in text for marker in _SKIP_MARKERS):
        return "skipped"
    return "error"


def _env_provenance() -> dict:
    """What ran these numbers: versions, backend, devices, XLA flags."""
    env = {"python": sys.version.split()[0],
           "platform": sys.platform,
           "xla_flags": os.environ.get("XLA_FLAGS", ""),
           "jax_platforms": os.environ.get("JAX_PLATFORMS", "")}
    try:
        import jax
        import jaxlib
        env["jax"] = jax.__version__
        env["jaxlib"] = jaxlib.__version__
        env["backend"] = jax.default_backend()
        env["device_count"] = jax.device_count()
    except Exception as e:  # pragma: no cover - jax is a baked-in dep
        env["jax"] = f"unavailable: {type(e).__name__}"
    try:
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "src"))
        from repro.observability import METRICS_SCHEMA_VERSION
        env["metrics_schema_version"] = METRICS_SCHEMA_VERSION
    except Exception:  # pragma: no cover
        pass
    return env


def _bench_trajectory() -> list:
    """Validate the repo-root ``BENCH_*.json`` artifacts and list them.

    Each benchmark module leaves its headline artifact at the repo root;
    this collects them into one trajectory list in ``summary.json`` (the
    cross-run provenance record), checking every file parses, is a dict
    with a ``benchmark`` name, and does not claim a metrics schema newer
    than this tree understands. A malformed artifact is reported in the
    list (``valid: false``) rather than silently skipped."""
    root = os.path.join(os.path.dirname(__file__), "..")
    try:
        from repro.observability import METRICS_SCHEMA_VERSION
    except Exception:  # pragma: no cover
        METRICS_SCHEMA_VERSION = None
    out = []
    for fname in sorted(os.listdir(root)):
        if not (fname.startswith("BENCH_") and fname.endswith(".json")):
            continue
        path = os.path.join(root, fname)
        entry = {"file": fname, "valid": True, "problems": []}
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            entry["valid"] = False
            entry["problems"].append(f"unreadable: {e}")
            out.append(entry)
            continue
        if not isinstance(doc, dict):
            entry["valid"] = False
            entry["problems"].append("not a JSON object")
            out.append(entry)
            continue
        entry["benchmark"] = doc.get("benchmark")
        if not entry["benchmark"]:
            entry["valid"] = False
            entry["problems"].append("missing 'benchmark' name")
        ver = doc.get("metrics_schema_version")
        entry["metrics_schema_version"] = ver
        if ver is not None and METRICS_SCHEMA_VERSION is not None \
                and ver > METRICS_SCHEMA_VERSION:
            entry["valid"] = False
            entry["problems"].append(
                f"claims metrics schema {ver} > understood "
                f"{METRICS_SCHEMA_VERSION}")
        entry["mtime_unix"] = round(os.path.getmtime(path), 1)
        out.append(entry)
    return out


def main() -> None:
    print("name,us_per_call,derived")
    # module names, imported lazily inside the try below: a missing
    # optional dependency at *import* time must classify as a skip of
    # that one module, not crash the whole run before any rows print
    modules = [
        ("strong_scaling (Figs 5/6/8)", "strong_scaling"),
        ("intranode_scaling (Fig 7)", "intranode_scaling"),
        ("comm_stats (§5 messages)", "comm_stats"),
        ("partition_quality (Fig 4)", "partition_quality"),
        ("baseline_compare (§5 GADGET-2)", "baseline_compare"),
        ("kernels_bench", "kernels_bench"),
        ("halo_transport (host vs collective vs fused wire)",
         "halo_transport"),
        ("fused_cycles (host- vs device-scheduled segments)",
         "fused_cycles"),
        ("observability (task plots)", "observability_bench"),
        ("fleet_throughput (batched serving)", "fleet_throughput"),
    ]
    summary = {}
    failures = []
    for label, modname in modules:
        t0 = time.time()
        try:
            mod = importlib.import_module(f".{modname}", __package__)
            mod.run()
        except Exception as e:
            status = _classify(e)
            summary[label] = {
                "status": status, "seconds": round(time.time() - t0, 1),
                "reason": f"{type(e).__name__}: {e}"}
            if status == "error":
                failures.append((label, e))
                print(f"{label},ERROR,{type(e).__name__}: {e}",
                      file=sys.stderr)
                traceback.print_exc()
            else:
                print(f"{label},SKIP,{type(e).__name__}: {e}",
                      file=sys.stderr)
        else:
            summary[label] = {"status": "ok",
                              "seconds": round(time.time() - t0, 1)}
            print(f"# {label} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
    summary["_env"] = _env_provenance()
    summary["_bench_trajectory"] = _bench_trajectory()
    bad = [e["file"] for e in summary["_bench_trajectory"]
           if not e["valid"]]
    if bad:
        print(f"# invalid BENCH artifacts: {bad}", file=sys.stderr)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark modules failed")


if __name__ == "__main__":
    main()
