"""Per-phase timings of one traced Sedov run + tracer overhead (task plots).

Runs the distributed time-bin engine (4 emulated ranks, collective
transport, device residency) with ``observe=True`` for a few cycles and
reports the median per-span wall time of every traced phase — the numbers
behind the task-timeline plot — plus the cost of the tracer itself
(median seconds per recorded span, measured over 20k no-payload spans).

Results land in ``benchmarks/results/observability_bench.json`` and, as
the repo-level benchmark artifact, in ``BENCH_observability.json`` at the
repo root (per-phase medians, run provenance, metrics schema version).
Since schema v3 the artifact also carries the attribution column: the
per-cell work vectors' share of the single per-cycle metrics pull, the
cost-calibration fit residual, and the repartition advisor's
advised-vs-current imbalance (which must never regress).

The measurement runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the mesh exists
regardless of how the parent process configured jax.

Run:  PYTHONPATH=src python benchmarks/observability_bench.py [n_side] [ncycles]
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

try:                                    # runnable as module or script
    from .common import emit, env_provenance
except ImportError:                     # pragma: no cover
    from common import emit, env_provenance

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

_WORKER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(nranks)d"
import sys, json
sys.path.insert(0, %(src)r)
import numpy as np
import jax
jax.config.update("jax_default_matmul_precision", "float32")
from repro.sph import SimulationSpec, SPHConfig, build_simulation
from repro.observability import UMBRELLA_SPANS

spec = SimulationSpec(
    scenario="sedov",
    scenario_params={"n_side": %(n_side)d, "e0": 1.0, "seed": 0},
    physics=SPHConfig(alpha_visc=1.0, cfl=0.15),
    integrator="timebin", backend="distributed", ranks=%(nranks)d,
    dt_max=0.02, max_depth=4,
    transport="collective", residency="device", observe=True)
sim = build_simulation(spec)
for _ in range(%(warm)d):                         # compile + bucket settle
    sim.step()
mark = len(sim.observer.tracer.spans)
for _ in range(%(ncycles)d):
    sim.step()
spans = sim.observer.tracer.spans[mark:]

per = {}
for s in spans:
    if s.name in UMBRELLA_SPANS:
        continue
    per.setdefault(s.name, []).append(s.dur * 1e6)
rec = sim.observer.records[-1]
tstats = sim.engine.transfers.stats()
out = {
    "phases": {k: {"median_us": float(np.median(v)), "count": len(v)}
               for k, v in sorted(per.items())},
    "imbalance": rec.get("imbalance"),
    "dead_frac": rec.get("dead_frac"),
    "total_compiles": rec.get("total_compiles"),
    "force_substeps": rec.get("force_substeps"),
    "device_imbalance": rec.get("device_imbalance"),
    "device_phase_units": rec.get("device_phase_units"),
    "metrics_pulls": tstats["boundary_events"].get("metrics", 0),
    "metrics_pull_bytes": tstats["boundary_bytes"].get("metrics", 0),
    "cell_work": rec.get("cell_work"),
    "cost_calibration": rec.get("cost_calibration"),
    "advisor": rec.get("advisor"),
    "metrics_row_bytes": int(sum(np.asarray(a).nbytes
                                 for a in sim.engine.device_metrics_last)),
    "cycles_total": %(warm)d + %(ncycles)d,
    "backend": jax.default_backend(),
    "device_count": jax.device_count(),
    "jax": jax.__version__,
}
print("RESULT_JSON=" + json.dumps(out, default=str))
"""


def _tracer_overhead_us(n: int = 20000) -> float:
    """Median seconds per recorded span, enabled tracer, no payload."""
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.observability import Tracer
    tr = Tracer()
    samples = []
    for _ in range(20):
        t0 = time.perf_counter()
        for _ in range(n // 20):
            with tr.span("bench", rank=0):
                pass
        samples.append((time.perf_counter() - t0) / (n // 20))
    samples.sort()
    return 1e6 * samples[len(samples) // 2]


def run(n_side=6, ncycles=3, nranks=4, warm=2) -> list:
    script = _WORKER % {"nranks": nranks, "n_side": n_side,
                        "ncycles": ncycles, "warm": warm,
                        "src": os.path.join(ROOT, "src")}
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"observability_bench worker failed:\n{proc.stderr[-3000:]}")
    payload = next(line for line in proc.stdout.splitlines()
                   if line.startswith("RESULT_JSON="))
    res = json.loads(payload[len("RESULT_JSON="):])
    overhead_us = _tracer_overhead_us()

    rows = []
    for name, ph in res["phases"].items():
        rows.append({
            "name": f"observability/phase/{name}/median_us",
            "us_per_call": round(ph["median_us"], 1),
            "derived": f"count={ph['count']};nranks={nranks};"
                       f"n_side={n_side};ncycles={ncycles}"})
    rows.append({
        "name": "observability/tracer_span_overhead/median_us",
        "us_per_call": round(overhead_us, 3),
        "derived": "enabled tracer, empty span body"})
    rows.append({
        "name": "observability/run/imbalance",
        "us_per_call": round(res.get("imbalance") or 0.0, 4),
        "derived": f"dead_frac={res.get('dead_frac'):.4f};"
                   f"total_compiles={res.get('total_compiles')}"})
    # device telemetry pull cost: the contract is ONE host<->device
    # transfer per cycle, regardless of rank count or phase count
    cyc = res.get("cycles_total") or (ncycles + warm)
    pulls = res.get("metrics_pulls", 0)
    pulls_per_cycle = pulls / cyc if cyc else 0.0
    rows.append({
        "name": "observability/device_metrics/pulls_per_cycle",
        "us_per_call": round(pulls_per_cycle, 3),
        "derived": f"pulls={pulls};cycles={cyc};"
                   f"bytes={res.get('metrics_pull_bytes', 0)};"
                   f"device_imbalance={res.get('device_imbalance')}"})
    if pulls_per_cycle > 1.0:
        raise RuntimeError(
            f"device-metrics pull cost exceeds one transfer per cycle: "
            f"{pulls} pulls over {cyc} cycles")
    # attribution column (schema v3): the per-cell vectors' share of the
    # single metrics pull, the calibration fit residual, and the
    # repartition advisor's advised-vs-current imbalance
    adv = res.get("advisor") or {}
    cal = res.get("cost_calibration") or {}
    row_bytes = res.get("metrics_row_bytes") or 0
    cell_pull_bytes = (res.get("metrics_pull_bytes", 0) / pulls - row_bytes
                       if pulls else 0.0)
    resid = cal.get("residual")
    rows.append({
        "name": "observability/attribution/cell_pull_bytes_per_cycle",
        "us_per_call": round(cell_pull_bytes, 1),
        "derived": f"calibration_residual="
                   f"{'-' if resid is None else round(resid, 4)};"
                   f"advised={adv.get('advised_imbalance')};"
                   f"current={adv.get('current_imbalance')}"})
    if adv and adv.get("advised_imbalance", 0.0) \
            > adv.get("current_imbalance", 0.0) + 1e-9:
        raise RuntimeError(
            f"advisor regressed the partition: advised "
            f"{adv['advised_imbalance']} > current "
            f"{adv['current_imbalance']}")
    emit(rows, "observability_bench")

    from repro.observability import METRICS_SCHEMA_VERSION
    bench = {
        "benchmark": "observability",
        "scenario": "sedov",
        "nranks": nranks, "n_side": n_side,
        "ncycles": ncycles, "warmup_cycles": warm,
        "residency": "device", "transport": "collective",
        "metrics_schema_version": METRICS_SCHEMA_VERSION,
        "env": {"python": sys.version.split()[0],
                "jax": res.get("jax"),
                "backend": res.get("backend"),
                "device_count": res.get("device_count")},
        "phase_median_us": {k: v["median_us"]
                            for k, v in res["phases"].items()},
        "phase_counts": {k: v["count"] for k, v in res["phases"].items()},
        "tracer_span_overhead_us": overhead_us,
        "imbalance": res.get("imbalance"),
        "dead_frac": res.get("dead_frac"),
        "total_compiles": res.get("total_compiles"),
        "device_metrics": {
            "pulls": pulls,
            "cycles": cyc,
            "pulls_per_cycle": pulls_per_cycle,
            "pull_bytes": res.get("metrics_pull_bytes", 0),
            "device_imbalance": res.get("device_imbalance"),
            "device_phase_units": res.get("device_phase_units"),
        },
        "attribution": {
            "cell_pull_bytes_per_cycle": cell_pull_bytes,
            "cell_work": res.get("cell_work"),
            "cost_calibration": res.get("cost_calibration"),
            "advisor": res.get("advisor"),
        },
        "_env": env_provenance(),
    }
    with open(os.path.join(ROOT, "BENCH_observability.json"), "w") as f:
        json.dump(bench, f, indent=1, default=str)
    return rows


if __name__ == "__main__":
    n_side = int(sys.argv[1]) if len(sys.argv) > 1 else 6
    ncycles = int(sys.argv[2]) if len(sys.argv) > 2 else 3
    run(n_side=n_side, ncycles=ncycles)
