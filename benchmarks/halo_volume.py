"""Per-sub-step halo export volume: activity-aware vs full boundary.

Runs the distributed time-bin engine on the Sedov blast (the scenario with
the strongest bin contrast) twice — with activity-aware halo exchanges
(only cut cells whose bins are active at a sub-step ship data) and with
the full-boundary baseline (every cut cell ships at every force sub-step)
— and reports exported (cell, importer) slots per sub-step plus the
estimated byte volume. Both runs produce identical physics: the baseline
only re-ships data the replicas already hold.

Also replays the final bin assignment through the *static* schedule
(``halo_export_schedule``) — the planning-side accounting that the comm
planner's activation-frequency weights (``CostModel.timebin_units``)
approximate.

Run:  PYTHONPATH=src python benchmarks/halo_volume.py [n_side] [ncycles]
"""

from __future__ import annotations

import numpy as np

from repro.sph import SimulationSpec, SPHConfig, build_simulation
from repro.sph.dist_timebins import (_EX1_FIELDS, _EX2_FIELDS,
                                     build_rank_plan, halo_export_schedule)
from repro.sph.timebins import cell_max_bins

try:                                    # runnable as module or script
    from .common import emit
except ImportError:                     # pragma: no cover
    from common import emit


def _spec(n_side, nranks, activity_aware):
    return SimulationSpec(
        scenario="sedov",
        scenario_params={"n_side": n_side, "e0": 1.0, "seed": 0,
                         "n_target": 16.0, "r_inject": 0.5 / n_side},
        physics=SPHConfig(alpha_visc=1.0, cfl=0.15, n_target=16.0),
        integrator="timebin", backend="distributed", ranks=nranks,
        max_depth=8, activity_aware_halos=activity_aware)


def run(n_side=10, ncycles=2, nranks=4) -> list:
    rows = []
    results = {}
    for aware in (True, False):
        sim = build_simulation(_spec(n_side, nranks, aware))
        for _ in range(ncycles):
            sim.step()
        eng = sim.engine
        results[aware] = eng
        substeps = max(eng.substeps, 1)
        bytes_per_slot = (np.asarray(eng.state.cells.mass).shape[1]
                          * (_EX1_FIELDS + _EX2_FIELDS) * 4)
        name = "halo/activity_aware" if aware else "halo/full_boundary"
        rows.append({
            "name": f"{name}/slots_per_substep",
            "us_per_call": round(eng.halo_exported_slots / substeps, 3),
            "derived": f"total_slots={eng.halo_exported_slots};"
                       f"bytes_per_substep="
                       f"{eng.halo_exported_slots * bytes_per_slot / substeps:.0f};"
                       f"substeps={eng.substeps};"
                       f"updates={eng.particle_updates}"})
    aware, full = results[True], results[False]
    e_aware, _ = aware.diagnostics()
    e_full, _ = full.diagnostics()
    rows.append({
        "name": "halo/volume_saving",
        "us_per_call": round(1.0 - aware.halo_exported_slots
                             / max(full.halo_exported_slots, 1), 3),
        "derived": f"aware={aware.halo_exported_slots};"
                   f"full={full.halo_exported_slots};"
                   f"identical_physics={abs(e_aware - e_full) < 1e-12}"})

    # static schedule replay of the final bin assignment
    eng = results[True]
    cb = cell_max_bins(np.asarray(eng.state.bins),
                       np.asarray(eng.state.cells.mask))
    plan = build_rank_plan(eng._assignment, eng._ci, eng._cj,
                           nranks=eng.nranks)
    depth = max(int(cb.max()), 1)
    sched = halo_export_schedule(cb, plan, depth)
    rows.append({
        "name": "halo/static_schedule_saving",
        "us_per_call": round(1.0 - sched["active"].sum()
                             / max(sched["full"].sum(), 1), 3),
        "derived": f"active={int(sched['active'].sum())};"
                   f"full={int(sched['full'].sum())};depth={depth}"})
    return rows


if __name__ == "__main__":
    import sys
    n_side = int(sys.argv[1]) if len(sys.argv) > 1 else 10
    ncycles = int(sys.argv[2]) if len(sys.argv) > 2 else 2
    emit(run(n_side=n_side, ncycles=ncycles), "halo_volume")
