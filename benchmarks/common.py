"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def timeit(fn: Callable, *args, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    import jax
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(rows: List[Dict], name: str) -> None:
    """Print CSV rows (``name,us_per_call,derived``) and save JSON."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)
    for r in rows:
        us = r.get("us_per_call", "")
        derived = r.get("derived", "")
        print(f"{r['name']},{us},{derived}", flush=True)


def build_clustered_taskgraph(n_particles=4096, seed=0, *, base_side=6,
                              threshold=48, rate=2e-9):
    """Clustered-IC task graph over the §3.1-refined (split) cell set.

    Costs are seconds (``rate`` s/interaction — the measured-cost
    calibration of §3.2, ≈CPU pair-interaction throughput). Returns
    (task graph, n_leaves, per-leaf occupancy).
    """
    from repro.core import TaskGraph
    from repro.sph import clustered_ic
    from repro.sph.adaptive import refined_cell_graph
    import numpy as np

    ic = clustered_ic(n_particles, seed=seed)
    node_w, edges, leaves = refined_cell_graph(
        ic["pos"], ic["box"], base_side, threshold=threshold, max_levels=5)
    n_ngb = 48.0
    g = TaskGraph()
    occ = np.array([l.occupancy for l in leaves], dtype=np.int64)

    def self_cost(o):
        return rate * min(0.5 * o * o, n_ngb * o)

    def pair_cost(a, b):
        return rate * min(a * b, n_ngb * min(a, b))

    sort = [g.add_task("sort", resources=(c,), writes=(c,),
                       cost=max(rate * 2 * occ[c], 1e-9))
            for c in range(len(leaves))]
    ghost = [g.add_task("ghost", resources=(c,), writes=(c,),
                        cost=max(rate * occ[c], 1e-9))
             for c in range(len(leaves))]
    kick = [g.add_task("kick", resources=(c,), writes=(c,),
                       cost=max(rate * occ[c], 1e-9))
            for c in range(len(leaves))]
    for c in range(len(leaves)):
        d = g.add_task("density_self", resources=(c,), writes=(c,),
                       cost=max(self_cost(occ[c]), 1e-9))
        f = g.add_task("force_self", resources=(c,), writes=(c,),
                       cost=max(self_cost(occ[c]), 1e-9))
        g.add_dependency(d, sort[c])
        g.add_dependency(ghost[c], d)
        g.add_dependency(f, ghost[c])
        g.add_dependency(kick[c], f)
    for (a, b), _w in edges.items():
        d = g.add_task("density_pair", resources=(a, b), writes=(a, b),
                       cost=max(pair_cost(occ[a], occ[b]), 1e-9))
        f = g.add_task("force_pair", resources=(a, b), writes=(a, b),
                       cost=max(pair_cost(occ[a], occ[b]), 1e-9))
        for c in (a, b):
            g.add_dependency(d, sort[c])
            g.add_dependency(ghost[c], d)
            g.add_dependency(f, ghost[c])
            g.add_dependency(kick[c], f)
    return g, len(leaves), occ
