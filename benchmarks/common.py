"""Shared helpers for the benchmark harness.

Standalone-runnable: ``python -m benchmarks.common`` (or ``python
benchmarks/common.py``) validates the repo-root ``BENCH_*.json``
artifacts and prints the trajectory + environment provenance as JSON —
the same blocks ``benchmarks/run.py`` embeds in ``summary.json``.
"""

from __future__ import annotations

import json
import os
import sys
import time
from typing import Callable, Dict, List

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def timeit(fn: Callable, *args, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    import jax
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(rows: List[Dict], name: str) -> None:
    """Print CSV rows (``name,us_per_call,derived``) and save JSON."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)
    for r in rows:
        us = r.get("us_per_call", "")
        derived = r.get("derived", "")
        print(f"{r['name']},{us},{derived}", flush=True)


def env_provenance() -> dict:
    """What ran these numbers: versions, backend, devices, XLA flags."""
    env = {"python": sys.version.split()[0],
           "platform": sys.platform,
           "xla_flags": os.environ.get("XLA_FLAGS", ""),
           "jax_platforms": os.environ.get("JAX_PLATFORMS", "")}
    try:
        import jax
        import jaxlib
        env["jax"] = jax.__version__
        env["jaxlib"] = jaxlib.__version__
        env["backend"] = jax.default_backend()
        env["device_count"] = jax.device_count()
    except Exception as e:  # pragma: no cover - jax is a baked-in dep
        env["jax"] = f"unavailable: {type(e).__name__}"
    try:
        sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
        from repro.observability import METRICS_SCHEMA_VERSION
        env["metrics_schema_version"] = METRICS_SCHEMA_VERSION
    except Exception:  # pragma: no cover
        pass
    return env


def bench_trajectory(root: str = REPO_ROOT) -> List[Dict]:
    """Validate the repo-root ``BENCH_*.json`` artifacts and list them.

    Each benchmark module leaves its headline artifact at the repo root;
    this collects them into one trajectory list (embedded in
    ``summary.json`` as the cross-run provenance record), checking every
    file parses, is a dict with a ``benchmark`` name, and does not claim
    a metrics schema newer than this tree understands. A malformed
    artifact is reported in the list (``valid: false``) rather than
    silently skipped."""
    try:
        sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
        from repro.observability import METRICS_SCHEMA_VERSION
    except Exception:  # pragma: no cover
        METRICS_SCHEMA_VERSION = None
    out = []
    for fname in sorted(os.listdir(root)):
        if not (fname.startswith("BENCH_") and fname.endswith(".json")):
            continue
        path = os.path.join(root, fname)
        entry = {"file": fname, "valid": True, "problems": []}
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            entry["valid"] = False
            entry["problems"].append(f"unreadable: {e}")
            out.append(entry)
            continue
        if not isinstance(doc, dict):
            entry["valid"] = False
            entry["problems"].append("not a JSON object")
            out.append(entry)
            continue
        entry["benchmark"] = doc.get("benchmark")
        if not entry["benchmark"]:
            entry["valid"] = False
            entry["problems"].append("missing 'benchmark' name")
        ver = doc.get("metrics_schema_version")
        entry["metrics_schema_version"] = ver
        if ver is not None and METRICS_SCHEMA_VERSION is not None \
                and ver > METRICS_SCHEMA_VERSION:
            entry["valid"] = False
            entry["problems"].append(
                f"claims metrics schema {ver} > understood "
                f"{METRICS_SCHEMA_VERSION}")
        entry["mtime_unix"] = round(os.path.getmtime(path), 1)
        out.append(entry)
    return out


def build_clustered_taskgraph(n_particles=4096, seed=0, *, base_side=6,
                              threshold=48, rate=2e-9):
    """Clustered-IC task graph over the §3.1-refined (split) cell set.

    Costs are seconds (``rate`` s/interaction — the measured-cost
    calibration of §3.2, ≈CPU pair-interaction throughput). Returns
    (task graph, n_leaves, per-leaf occupancy).
    """
    from repro.core import TaskGraph
    from repro.sph import clustered_ic
    from repro.sph.adaptive import refined_cell_graph
    import numpy as np

    ic = clustered_ic(n_particles, seed=seed)
    node_w, edges, leaves = refined_cell_graph(
        ic["pos"], ic["box"], base_side, threshold=threshold, max_levels=5)
    n_ngb = 48.0
    g = TaskGraph()
    occ = np.array([l.occupancy for l in leaves], dtype=np.int64)

    def self_cost(o):
        return rate * min(0.5 * o * o, n_ngb * o)

    def pair_cost(a, b):
        return rate * min(a * b, n_ngb * min(a, b))

    sort = [g.add_task("sort", resources=(c,), writes=(c,),
                       cost=max(rate * 2 * occ[c], 1e-9))
            for c in range(len(leaves))]
    ghost = [g.add_task("ghost", resources=(c,), writes=(c,),
                        cost=max(rate * occ[c], 1e-9))
             for c in range(len(leaves))]
    kick = [g.add_task("kick", resources=(c,), writes=(c,),
                       cost=max(rate * occ[c], 1e-9))
            for c in range(len(leaves))]
    for c in range(len(leaves)):
        d = g.add_task("density_self", resources=(c,), writes=(c,),
                       cost=max(self_cost(occ[c]), 1e-9))
        f = g.add_task("force_self", resources=(c,), writes=(c,),
                       cost=max(self_cost(occ[c]), 1e-9))
        g.add_dependency(d, sort[c])
        g.add_dependency(ghost[c], d)
        g.add_dependency(f, ghost[c])
        g.add_dependency(kick[c], f)
    for (a, b), _w in edges.items():
        d = g.add_task("density_pair", resources=(a, b), writes=(a, b),
                       cost=max(pair_cost(occ[a], occ[b]), 1e-9))
        f = g.add_task("force_pair", resources=(a, b), writes=(a, b),
                       cost=max(pair_cost(occ[a], occ[b]), 1e-9))
        for c in (a, b):
            g.add_dependency(d, sort[c])
            g.add_dependency(ghost[c], d)
            g.add_dependency(f, ghost[c])
            g.add_dependency(kick[c], f)
    return g, len(leaves), occ


if __name__ == "__main__":
    print(json.dumps({"_env": env_provenance(),
                      "_bench_trajectory": bench_trajectory()}, indent=1))
    raise SystemExit(
        1 if any(not e["valid"] for e in bench_trajectory()) else 0)
