"""Fleet serving throughput: N batched requests vs N single runs.

The subsystem's reason to exist, measured: ≥64 concurrent small Sedov /
Kelvin–Helmholtz requests (heterogeneous in values, two signatures in
shape) served by :class:`repro.fleet.FleetRunner` as signature-grouped
stacked programs — against the baseline of running each request through
the single-simulation path back to back. Reported:

* aggregate per-particle throughput (particle-steps / second) for both
  strategies and the speed-up ratio;
* compile counts (the fleet's whole pitch: two signatures × one batch
  bucket ≈ 4 entry points for 64 requests, vs the baseline's per-signature
  engine programs);
* admission → completion latency distribution across the fleet.

On a multi-device process (``XLA_FLAGS=--xla_force_host_platform_
device_count=4``) the fleet axis shards across the mesh; on one device it
is pure vmap. Either way the numbers land in ``BENCH_fleet.json`` at the
repo root with ``_env`` provenance.

Run:  PYTHONPATH=src python benchmarks/fleet_throughput.py [requests] [steps]
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

try:                                    # runnable as module or script
    from .common import emit
except ImportError:                     # pragma: no cover
    from common import emit

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _specs(n_requests: int, n_side: int, rebin_every: int):
    from repro.sph import SimulationSpec
    specs = []
    for i in range(n_requests):
        if i % 2 == 0:
            specs.append(SimulationSpec(
                scenario="sedov", rebin_every=rebin_every,
                scenario_params={"n_side": n_side, "seed": i,
                                 "e0": 1.0 + 0.05 * (i % 8)}))
        else:
            specs.append(SimulationSpec(
                scenario="kelvin_helmholtz", rebin_every=rebin_every,
                scenario_params={"n_side": n_side, "seed": i,
                                 "v_shear": 0.3 + 0.02 * (i % 8)}))
    return specs


def run(n_requests: int = 64, n_steps: int = 8, n_side: int = 4) -> list:
    import jax
    from repro.fleet import FleetRunner, sequential_reference

    specs = _specs(n_requests, n_side, rebin_every=n_steps)
    n_particles = sum(
        (dict(s.scenario_params)["n_side"] ** 3) for s in specs)
    work = n_particles * n_steps                  # particle-steps total

    # ----------------------------------------------------------- batched
    runner = FleetRunner()
    t0 = time.perf_counter()
    reqs = [runner.submit(s, n_steps=n_steps) for s in specs]
    runner.drain()
    wall_fleet = time.perf_counter() - t0
    bad = [r for r in reqs if r.result is None]
    if bad:
        raise RuntimeError(f"{len(bad)} fleet requests failed: "
                           f"{bad[0].error!r}")
    latencies = np.array([r.latency for r in reqs])
    fleet_compiles = runner.probe.total_compiles()

    # ----------------------------------------- baseline: one run at a time
    t0 = time.perf_counter()
    for s in specs:
        sequential_reference(s, n_steps)
    wall_seq = time.perf_counter() - t0

    tput_fleet = work / wall_fleet
    tput_seq = work / wall_seq
    speedup = wall_seq / wall_fleet

    rows = [
        {"name": "fleet/throughput/particle_steps_per_s",
         "us_per_call": round(wall_fleet / n_requests * 1e6, 1),
         "derived": f"tput={tput_fleet:.0f}/s;requests={n_requests};"
                    f"steps={n_steps}"},
        {"name": "fleet/baseline/particle_steps_per_s",
         "us_per_call": round(wall_seq / n_requests * 1e6, 1),
         "derived": f"tput={tput_seq:.0f}/s"},
        {"name": "fleet/speedup_vs_sequential",
         "us_per_call": round(speedup, 3),
         "derived": f"compiles={fleet_compiles};"
                    f"entry_points={len(runner.programs.keys)};"
                    f"devices={runner.fleet_devices}"},
        {"name": "fleet/latency/p50_ms",
         "us_per_call": round(float(np.percentile(latencies, 50)) * 1e3, 2),
         "derived": f"p95={np.percentile(latencies, 95) * 1e3:.1f}ms"},
    ]
    emit(rows, "fleet_throughput")

    bench = {
        "benchmark": "fleet_throughput",
        "requests": n_requests,
        "steps": n_steps,
        "n_side": n_side,
        "particles_total": n_particles,
        "particle_steps": work,
        "signatures": len({s.signature_key() for s in specs}),
        "fleet": {
            "wall_s": wall_fleet,
            "particle_steps_per_s": tput_fleet,
            "compiles": fleet_compiles,
            "entry_points": len(runner.programs.keys),
            "batches": runner.batches_run,
            "buckets": {str(k): v for k, v
                        in runner.batcher.policy._bucket.items()},
            "fleet_devices": runner.fleet_devices,
            "latency_ms": {
                "p50": float(np.percentile(latencies, 50)) * 1e3,
                "p95": float(np.percentile(latencies, 95)) * 1e3,
                "max": float(latencies.max()) * 1e3},
            "pool": runner.pool.stats(),
        },
        "sequential": {
            "wall_s": wall_seq,
            "particle_steps_per_s": tput_seq,
        },
        "speedup": speedup,
        "_env": {
            "python": sys.version.split()[0],
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "xla_flags": os.environ.get("XLA_FLAGS", ""),
        },
    }
    with open(os.path.join(ROOT, "BENCH_fleet.json"), "w") as f:
        json.dump(bench, f, indent=1, default=str)
    return rows


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    n_requests = int(sys.argv[1]) if len(sys.argv) > 1 else 64
    n_steps = int(sys.argv[2]) if len(sys.argv) > 2 else 8
    run(n_requests=n_requests, n_steps=n_steps)
