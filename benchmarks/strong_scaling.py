"""Strong scaling (paper Figs. 5, 6, 8).

The paper's claim is a property of the *schedule*: with task-based
decomposition + work-balanced partitioning + asynchronous comm, parallel
efficiency stays >60% across a 512× scale-up, while the bulk-synchronous
baseline collapses. We reproduce it with the discrete-event executor
simulation over the real task graph of a clustered-IC SPH step, with
per-task costs calibrated in seconds and the paper-era network parameters
(FDR10-class: ~1–2 µs latency, ~5 GB/s).

Swept: ranks ∈ {1 … 256} (×2 threads) for async (SWIFT) and synchronous
(branch-and-bound baseline). Derived: parallel efficiency at each scale.
"""

from __future__ import annotations

import numpy as np

from repro.core import AsyncExecutorSim, decompose_with_comm
from .common import build_clustered_taskgraph, emit

PHASES = {"sort": "p0", "density_self": "p1", "density_pair": "p1",
          "ghost": "p2", "force_self": "p3", "force_pair": "p3",
          "kick": "p4", "send": "comm", "recv": "comm"}


def run(n_particles=20000, ranks_list=(1, 2, 4, 8, 16, 32, 64, 128),
        threads=2) -> list:
    g, ncells, occupancy = build_clustered_taskgraph(n_particles)
    cell_bytes = [float(max(o, 1)) * 64.0 for o in occupancy]  # ~64 B/particle
    rows = []
    t1 = None
    for ranks in ranks_list:
        if ranks == 1:
            dist = g
            for t in dist.tasks.values():
                object.__setattr__(t, "rank", 0)
        else:
            dist, dec = decompose_with_comm(g, ncells, ranks,
                                            cell_bytes=cell_bytes,
                                            phases=PHASES)
        kw = dict(ranks=ranks, threads=threads, latency=1.5e-6,
                  bandwidth=5e9)
        m_async = AsyncExecutorSim(dist, **kw).run()
        m_sync = AsyncExecutorSim(dist, synchronous=True, **kw).run()
        if t1 is None:
            t1 = m_async.makespan * ranks * threads / (1 * threads)
            t1 = m_async.makespan        # serial-ish reference at ranks=1
        eff_async = t1 / (m_async.makespan * ranks)
        eff_sync = t1 / (m_sync.makespan * ranks)
        rows.append({
            "name": f"strong_scaling/async/ranks{ranks}",
            "us_per_call": round(m_async.makespan * 1e6, 1),
            "derived": f"efficiency={min(eff_async, 1.0):.3f}",
        })
        rows.append({
            "name": f"strong_scaling/sync/ranks{ranks}",
            "us_per_call": round(m_sync.makespan * 1e6, 1),
            "derived": f"efficiency={min(eff_sync, 1.0):.3f}",
        })
    emit(rows, "strong_scaling")
    return rows


if __name__ == "__main__":
    run()
