"""Message statistics (paper §5).

    "on the SuperMUC machine with 32 nodes (512 cores), each MPI rank
    contains approximately 1.6e7 particles in 2.5e5 cells. SWIFT will
    generate around 58 000 point-to-point asynchronous MPI communications
    (a pair of send and recv tasks) per node and per time-step. Each one of
    these communications involves, on average, no more than 6 kB of data."

We measure the same quantities from the comm planner on a scaled-down grid
(the surface-to-volume accounting is scale-free) and extrapolate to the
paper's cells-per-rank with the measured boundary fraction.
"""

from __future__ import annotations

import numpy as np

from repro.core import decompose_with_comm
from .common import build_clustered_taskgraph, emit
from .strong_scaling import PHASES

PAPER_CELLS_PER_RANK = 2.5e5
PAPER_MSGS_PER_RANK = 58_000
PAPER_MEAN_KB = 6.0
PAPER_PARTICLES_PER_RANK = 1.6e7


def run(n_particles=8000, ranks=8):
    g, ncells, occupancy = build_clustered_taskgraph(n_particles)
    particle_bytes = 64.0
    cell_bytes = [float(max(o, 1)) * particle_bytes for o in occupancy]
    dist, dec = decompose_with_comm(g, ncells, ranks,
                                    cell_bytes=cell_bytes, phases=PHASES)
    stats = dec.comm
    cells_per_rank = ncells / ranks
    msgs_per_rank = stats.messages / ranks
    boundary_msgs_per_cell = msgs_per_rank / cells_per_rank

    # extrapolate: messages/rank ∝ boundary cells ∝ (cells/rank)^(2/3)·const
    scale = (PAPER_CELLS_PER_RANK / cells_per_rank) ** (2.0 / 3.0)
    extrapolated = msgs_per_rank * scale

    rows = [{
        "name": "comm_stats/messages_per_rank",
        "us_per_call": "",
        "derived": f"{msgs_per_rank:.0f} msgs/rank/step "
                   f"({ncells} cells, {ranks} ranks)",
    }, {
        "name": "comm_stats/mean_message_kB",
        "us_per_call": "",
        "derived": f"{stats.mean_message_bytes / 1024:.2f} kB "
                   f"(paper: ≤{PAPER_MEAN_KB} kB)",
    }, {
        "name": "comm_stats/extrapolated_paper_scale",
        "us_per_call": "",
        "derived": f"{extrapolated:.0f} msgs/rank at 2.5e5 cells/rank "
                   f"(paper: ~{PAPER_MSGS_PER_RANK})",
    }, {
        "name": "comm_stats/boundary_msgs_per_cell",
        "us_per_call": "",
        "derived": f"{boundary_msgs_per_cell:.3f}",
    }]
    emit(rows, "comm_stats")
    return rows


if __name__ == "__main__":
    run()
