"""Host vs collective vs fused-resident wire cost per force sub-step (Sedov).

The distributed time-bin engine runs the same physics over three execution
paths (bit-for-bit identical states, asserted below):

* ``transport="host"`` — numpy row copies between per-rank phase programs;
* ``transport="collective"`` — shard_map/ppermute exchange programs, but
  rank states still round-trip through host between the phase programs;
* ``transport="collective", residency="device"`` — the fused path: states
  stay resident on the mesh for the whole cycle and each force sub-step is
  one compiled program.

For each path the benchmark reports wall time per cycle / per force
sub-step and the **host-transfer bytes** per force sub-step: for the first
two, the full-field device→host→device round trips their wires pay
(``transport.stats()["host_bytes"]``); for the fused path, the transfer
probe's intra-cycle ledger — control tables and flags only, with
``state_bytes`` asserted 0.

Every path gets the same fixed warm-up (``max_warm`` cycles — enough for
the program caches to quiesce at the default size), so all paths are
measured at the same simulation epoch and the final states can be compared
bitwise; ``measure_compiles`` reports any compile residue in the measured
window.

The measurement runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the collective
paths have a 4-device mesh regardless of how the parent process configured
jax. Results land in ``benchmarks/results/halo_transport.json``.

Run:  PYTHONPATH=src python benchmarks/halo_transport.py [n_side] [ncycles]
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

try:                                    # runnable as module or script
    from .common import emit
except ImportError:                     # pragma: no cover
    from common import emit

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

_WORKER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(nranks)d"
import sys, time, json
sys.path.insert(0, %(src)r)
import numpy as np
import jax
jax.config.update("jax_default_matmul_precision", "float32")
from repro.sph import SimulationSpec, SPHConfig, build_simulation

base = SimulationSpec(
    scenario="sedov",
    scenario_params={"n_side": %(n_side)d, "e0": 1.0, "seed": 0,
                     "n_target": 16.0, "r_inject": 0.5 / %(n_side)d},
    physics=SPHConfig(alpha_visc=1.0, cfl=0.15, n_target=16.0),
    integrator="timebin", backend="distributed", ranks=%(nranks)d,
    max_depth=6)

PATHS = {
    "host": base,
    "collective": base.with_(transport="collective"),
    "fused": base.with_(transport="collective", residency="device"),
}

out = {}
states = {}
for label, spec in PATHS.items():
    sim = build_simulation(spec)
    eng = sim.engine
    # identical fixed warm-up for every path (the physics comparison needs
    # all paths at the same simulation epoch); long enough that the
    # program caches quiesce, so the measurement is steady-state reuse —
    # compiles_during_measurement reports any residue
    warm = %(max_warm)d
    for _ in range(warm):
        sim.step()
    compiles0 = eng.probe.total_compiles()
    host_bytes0 = eng._transport.stats().get("host_bytes", 0)
    intra0 = dict(eng.transfers.intra_bytes)
    walls, subs = [], 0
    for _ in range(%(ncycles)d):
        t0 = time.perf_counter()
        stats = sim.step()
        walls.append(time.perf_counter() - t0)
        subs += stats["force_substeps"]
    tstats = eng.transport_stats()
    host_bytes = tstats.get("host_bytes", 0) - host_bytes0
    intra = {k: v - intra0.get(k, 0)
             for k, v in eng.transfers.intra_bytes.items()}
    out[label] = {
        "wall_per_cycle_s": float(np.mean(walls)),
        "wall_per_force_substep_us": 1e6 * float(np.sum(walls)) / subs,
        "force_substeps": subs,
        "warmup_cycles": warm,
        "compiles_during_measurement":
            eng.probe.total_compiles() - compiles0,
        "exported_slots": int(eng.halo_exported_slots),
        "host_bytes_per_force_substep": host_bytes / subs,
        "intra_cycle_bytes_per_force_substep":
            sum(intra.values()) / subs,
        "intra_cycle_state_bytes": eng.transfers.stats()[
            "intra_state_bytes"],
        "transport": tstats,
    }
    states[label] = (np.asarray(eng.state.cells.pos),
                     np.asarray(eng.state.cells.u))
ref = states["host"]
for label in ("collective", "fused"):
    for a, b in zip(ref, states[label]):
        np.testing.assert_array_equal(a, b)
assert out["fused"]["intra_cycle_state_bytes"] == 0
out["identical_physics"] = True
print("RESULT_JSON=" + json.dumps(out, default=str))
"""


def run(n_side=8, ncycles=3, nranks=4, max_warm=8) -> list:
    script = _WORKER % {"nranks": nranks, "n_side": n_side,
                        "ncycles": ncycles, "max_warm": max_warm,
                        "src": os.path.join(ROOT, "src")}
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"halo_transport worker failed:\n{proc.stderr[-3000:]}")
    payload = next(line for line in proc.stdout.splitlines()
                   if line.startswith("RESULT_JSON="))
    res = json.loads(payload[len("RESULT_JSON="):])

    rows = []
    for label in ("host", "collective", "fused"):
        r = res[label]
        t = r["transport"]
        extra = ""
        if label != "host":
            extra = (f";mode={t['mode']};rounds={t['rounds']};"
                     f"programs={t['programs']}")
        if label == "fused":
            extra += (f";intra_state_bytes={r['intra_cycle_state_bytes']};"
                      f"bins_refreshes={t['bins_refreshes']}")
        rows.append({
            "name": f"transport/{label}/us_per_force_substep",
            "us_per_call": round(r["wall_per_force_substep_us"], 1),
            "derived": f"wall_per_cycle_s={r['wall_per_cycle_s']:.4f};"
                       f"force_substeps={r['force_substeps']};"
                       f"measure_compiles="
                       f"{r['compiles_during_measurement']};"
                       f"exported_slots={r['exported_slots']};"
                       f"host_B_per_substep="
                       f"{r['host_bytes_per_force_substep']:.0f};"
                       f"intra_B_per_substep="
                       f"{r['intra_cycle_bytes_per_force_substep']:.0f}"
                       f"{extra}"})
    for num, den, name in (("collective", "host",
                            "collective_over_host_ratio"),
                           ("fused", "collective",
                            "fused_over_collective_ratio")):
        ratio = (res[num]["wall_per_force_substep_us"]
                 / max(res[den]["wall_per_force_substep_us"], 1e-9))
        rows.append({
            "name": f"transport/{name}",
            "us_per_call": round(ratio, 3),
            "derived": f"identical_physics={res['identical_physics']};"
                       f"nranks={nranks};n_side={n_side};"
                       f"ncycles={ncycles}"})
    emit(rows, "halo_transport")
    return rows


if __name__ == "__main__":
    n_side = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    ncycles = int(sys.argv[2]) if len(sys.argv) > 2 else 3
    run(n_side=n_side, ncycles=ncycles)
