"""Host vs collective transport wall-time per force sub-step (Sedov).

The distributed time-bin engine runs the same physics over either wire
(``transport="host" | "collective"``, bit-for-bit identical states); this
microbenchmark measures what the wire costs: wall time per cycle and per
force sub-step for each transport, plus the collective side's compiled
exchange-program count (the bucket discipline keeps it flat as cycles
accumulate).

The measurement runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the collective
path has a 4-device mesh regardless of how the parent process configured
jax. Results land in ``benchmarks/results/halo_transport.json``.

Run:  PYTHONPATH=src python benchmarks/halo_transport.py [n_side] [ncycles]
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

try:                                    # runnable as module or script
    from .common import emit
except ImportError:                     # pragma: no cover
    from common import emit

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

_WORKER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(nranks)d"
import sys, time, json
sys.path.insert(0, %(src)r)
import numpy as np
import jax
jax.config.update("jax_default_matmul_precision", "float32")
from repro.sph import SimulationSpec, SPHConfig, build_simulation

base = SimulationSpec(
    scenario="sedov",
    scenario_params={"n_side": %(n_side)d, "e0": 1.0, "seed": 0,
                     "n_target": 16.0, "r_inject": 0.5 / %(n_side)d},
    physics=SPHConfig(alpha_visc=1.0, cfl=0.15, n_target=16.0),
    integrator="timebin", backend="distributed", ranks=%(nranks)d,
    max_depth=6)

out = {}
states = {}
for transport in ("host", "collective"):
    sim = build_simulation(base.with_(transport=transport))
    sim.step()                                   # warm-up: compiles
    walls, subs = [], 0
    for _ in range(%(ncycles)d):
        t0 = time.perf_counter()
        stats = sim.step()
        walls.append(time.perf_counter() - t0)
        subs += stats["force_substeps"]
    eng = sim.engine
    out[transport] = {
        "wall_per_cycle_s": float(np.mean(walls)),
        "wall_per_force_substep_us": 1e6 * float(np.sum(walls)) / subs,
        "force_substeps": subs,
        "exported_slots": int(eng.halo_exported_slots),
        "transport": eng.transport_stats(),
    }
    states[transport] = (np.asarray(eng.state.cells.pos),
                        np.asarray(eng.state.cells.u))
for a, b in zip(states["host"], states["collective"]):
    np.testing.assert_array_equal(a, b)
out["identical_physics"] = True
print("RESULT_JSON=" + json.dumps(out, default=str))
"""


def run(n_side=8, ncycles=3, nranks=4) -> list:
    script = _WORKER % {"nranks": nranks, "n_side": n_side,
                        "ncycles": ncycles,
                        "src": os.path.join(ROOT, "src")}
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"halo_transport worker failed:\n{proc.stderr[-3000:]}")
    payload = next(line for line in proc.stdout.splitlines()
                   if line.startswith("RESULT_JSON="))
    res = json.loads(payload[len("RESULT_JSON="):])

    rows = []
    for transport in ("host", "collective"):
        r = res[transport]
        extra = ""
        if transport == "collective":
            t = r["transport"]
            extra = (f";mode={t['mode']};rounds={t['rounds']};"
                     f"programs={t['programs']}")
        rows.append({
            "name": f"transport/{transport}/us_per_force_substep",
            "us_per_call": round(r["wall_per_force_substep_us"], 1),
            "derived": f"wall_per_cycle_s={r['wall_per_cycle_s']:.4f};"
                       f"force_substeps={r['force_substeps']};"
                       f"exported_slots={r['exported_slots']}"
                       f"{extra}"})
    ratio = (res["collective"]["wall_per_force_substep_us"]
             / max(res["host"]["wall_per_force_substep_us"], 1e-9))
    rows.append({
        "name": "transport/collective_over_host_ratio",
        "us_per_call": round(ratio, 3),
        "derived": f"identical_physics={res['identical_physics']};"
                   f"nranks={nranks};n_side={n_side};ncycles={ncycles}"})
    emit(rows, "halo_transport")
    return rows


if __name__ == "__main__":
    n_side = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    ncycles = int(sys.argv[2]) if len(sys.argv) > 2 else 3
    run(n_side=n_side, ncycles=ncycles)
