"""Host-scheduled vs device-scheduled fused cycles (Sedov, 4 ranks).

PR 4's fused path (``residency="device"``) made each force sub-step one
compiled program, but the *cycle* control plane stayed on host: ladder
planning, per-sub-step activity masks, pair-subset dispatch. The
device-scheduled path (``schedule="device"``) compiles whole cycles — and
with ``segment_cycles=K`` whole K-cycle segments — into one program, so
the host is consulted once per segment. This benchmark measures what that
buys on identical physics, in two regimes:

* ``small`` — n_side=4, max_depth=1: per-cycle compute is tiny, so host
  dispatch + planning dominate. This is the regime device scheduling
  exists for (the SWIFT strong-scaling limit, where control-plane
  overhead per step is the whole game) — expect multi-× speedups.
* ``deep`` — n_side=6, max_depth=4: a real ladder. The compiled scan
  runs every trip over the full-touch pair table (dead trips compute and
  discard), while the host scheduler dispatches per-level *compacted*
  programs — so on a compute-bound CPU the host path stays ahead. The
  regime is reported, not hidden: it bounds where ``schedule="device"``
  should be switched on today.

Within each regime the paths are:

* ``host_sched``  — ``residency="device"``, per-sub-step dispatch;
* ``device_K1``   — ``schedule="device"``, one compiled cycle per step;
* ``device_K4``   — ``schedule="device", segment_cycles=4``.

All paths run the same warm-up then the same measured window, and their
final states are asserted bit-for-bit identical (the window is
segment-aligned, so every path ends at a defined state). Reported per
path: wall per cycle, host↔device bytes per cycle (boundary + intra), the
intra-segment state-byte ledger (must be 0), and compile residue in the
measured window (must be 0). The headline artifact lands at the repo root
as ``BENCH_fused_cycles.json`` with ``_env`` provenance; CSV rows go to
``benchmarks/results/fused_cycles.json``.

The measurement runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the mesh exists
regardless of how the parent process configured jax.

Run:  PYTHONPATH=src python benchmarks/fused_cycles.py [ncycles]
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

try:                                    # runnable as module or script
    from .common import emit
except ImportError:                     # pragma: no cover
    from common import emit

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

REGIMES = {
    # dispatch-bound: the device scheduler's home turf
    "small": {"n_side": 4, "max_depth": 1, "dt_max": 0.005},
    # compute-bound ladder: the host scheduler's per-level compaction wins
    "deep": {"n_side": 6, "max_depth": 4, "dt_max": 0.02},
}

_WORKER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(nranks)d"
import sys, time, json
sys.path.insert(0, %(src)r)
import numpy as np
import jax
jax.config.update("jax_default_matmul_precision", "float32")
from repro.sph import SimulationSpec, SPHConfig, build_simulation

base = SimulationSpec(
    scenario="sedov",
    scenario_params={"n_side": %(n_side)d, "e0": 1.0, "seed": 0},
    physics=SPHConfig(alpha_visc=1.0, cfl=0.15),
    dt_max=%(dt_max)r, max_depth=%(max_depth)d, integrator="timebin",
    backend="distributed", ranks=%(nranks)d,
    transport="collective", residency="device")

PATHS = {
    "host_sched": base,
    "device_K1": base.with_(schedule="device", segment_cycles=1),
    "device_K4": base.with_(schedule="device", segment_cycles=4),
}

ncycles = %(ncycles)d
warm = %(max_warm)d
out = {}
states = {}
for label, spec in PATHS.items():
    sim = build_simulation(spec)
    eng = sim.engine
    for _ in range(warm):
        sim.step()
    compiles0 = eng.probe.total_compiles()
    tp0 = eng.transfers.stats()
    bytes0 = (sum(tp0["boundary_bytes"].values())
              + sum(eng.transfers.intra_bytes.values()))
    walls, subs = [], 0
    for _ in range(ncycles):
        t0 = time.perf_counter()
        stats = sim.step()
        walls.append(time.perf_counter() - t0)
        subs += stats["force_substeps"]
    tp = eng.transfers.stats()
    host_bytes = (sum(tp["boundary_bytes"].values())
                  + sum(eng.transfers.intra_bytes.values()) - bytes0)
    out[label] = {
        "wall_per_cycle_s": float(np.sum(walls)) / ncycles,
        "force_substeps": subs,
        "warmup_cycles": warm,
        "measured_cycles": ncycles,
        "compiles_during_measurement":
            eng.probe.total_compiles() - compiles0,
        "host_bytes_per_cycle": host_bytes / ncycles,
        "intra_state_bytes": tp["intra_state_bytes"],
        "segments": getattr(eng, "segments", 0),
        "segment_aborts": getattr(eng, "segment_aborts", 0),
    }
    states[label] = (np.asarray(eng.state.cells.pos),
                     np.asarray(eng.state.cells.u),
                     np.asarray(eng.state.bins))
ref = states["host_sched"]
for label in ("device_K1", "device_K4"):
    for a, b in zip(ref, states[label]):
        np.testing.assert_array_equal(a, b)
for label in PATHS:
    assert out[label]["intra_state_bytes"] == 0, (label, out[label])
    assert out[label]["compiles_during_measurement"] == 0, (label, out[label])
out["identical_physics"] = True
out["_env"] = {"python": sys.version.split()[0],
               "jax": jax.__version__,
               "backend": jax.default_backend(),
               "device_count": jax.device_count(),
               "xla_flags": os.environ.get("XLA_FLAGS", "")}
print("RESULT_JSON=" + json.dumps(out, default=str))
"""


def _measure(regime: dict, ncycles: int, nranks: int, max_warm: int) -> dict:
    # the measured window must be a multiple of every segment length so
    # all paths end segment-aligned (bitwise-comparable final states)
    script = _WORKER % {"nranks": nranks, "ncycles": ncycles,
                        "max_warm": max_warm,
                        "src": os.path.join(ROOT, "src"), **regime}
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"fused_cycles worker failed:\n{proc.stderr[-3000:]}")
    payload = next(line for line in proc.stdout.splitlines()
                   if line.startswith("RESULT_JSON="))
    return json.loads(payload[len("RESULT_JSON="):])


def run(ncycles=4, nranks=4, max_warm=4) -> list:
    rows, doc_regimes = [], {}
    env = None
    for rname, regime in REGIMES.items():
        res = _measure(regime, ncycles, nranks, max_warm)
        env = res["_env"]
        doc_regimes[rname] = {
            "config": regime,
            "paths": {k: res[k] for k in
                      ("host_sched", "device_K1", "device_K4")},
            "speedup_vs_host_sched": {
                k: res["host_sched"]["wall_per_cycle_s"]
                / max(res[k]["wall_per_cycle_s"], 1e-12)
                for k in ("device_K1", "device_K4")},
            "identical_physics": res["identical_physics"],
        }
        for label in ("host_sched", "device_K1", "device_K4"):
            r = res[label]
            rows.append({
                "name": f"fused_cycles/{rname}/{label}/us_per_cycle",
                "us_per_call": round(1e6 * r["wall_per_cycle_s"], 1),
                "derived":
                    f"host_B_per_cycle={r['host_bytes_per_cycle']:.0f};"
                    f"intra_state_bytes={r['intra_state_bytes']};"
                    f"measure_compiles="
                    f"{r['compiles_during_measurement']};"
                    f"segments={r['segments']};"
                    f"aborts={r['segment_aborts']}"})
        for label in ("device_K1", "device_K4"):
            speed = doc_regimes[rname]["speedup_vs_host_sched"][label]
            rows.append({
                "name": f"fused_cycles/{rname}/{label}"
                        f"_speedup_vs_host_sched",
                "us_per_call": round(speed, 3),
                "derived": f"identical_physics="
                           f"{res['identical_physics']};"
                           f"nranks={nranks};ncycles={ncycles};"
                           + ";".join(f"{k}={v}"
                                      for k, v in regime.items())})
    emit(rows, "fused_cycles")

    bench = {"benchmark": "fused_cycles",
             "nranks": nranks, "ncycles": ncycles,
             "regimes": doc_regimes,
             # the headline: the dispatch-bound regime device scheduling
             # was built for; the deep regime bounds its applicability
             "speedup_vs_host_sched":
                 doc_regimes["small"]["speedup_vs_host_sched"],
             "_env": env}               # provenance from the worker,
                                        # where the 4-device flag is real
    with open(os.path.join(ROOT, "BENCH_fused_cycles.json"), "w") as f:
        json.dump(bench, f, indent=1, default=str)
    return rows


if __name__ == "__main__":
    ncycles = int(sys.argv[1]) if len(sys.argv) > 1 else 4
    run(ncycles=ncycles)
