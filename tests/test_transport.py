"""Transport subsystem: bucket hysteresis, ppermute round schedules,
host/collective exchange parity and the compile-count probe.

In-process tests cover the host wire and the bucket/rounds machinery on the
single real device. Collective-wire tests need 4 addressable devices: they
run in-process when the suite is launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the second CI job)
and in an isolated subprocess otherwise.
"""

import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ppermute_rounds
from repro.distributed import (BucketPolicy, HostTransport, ShipSlots,
                               next_pow2, pack_allgather, pack_rounds)
from repro.sph import SimulationSpec, SPHConfig, build_simulation
from repro.sph.cellgrid import PairList

ROOT = os.path.join(os.path.dirname(__file__), "..")

requires4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=4)")


# ------------------------------------------------------------------- buckets
def test_next_pow2():
    assert [next_pow2(n) for n in (0, 1, 2, 3, 4, 5, 63, 64, 65)] == \
        [1, 1, 2, 4, 4, 8, 64, 64, 128]


def test_bucket_policy_grow_immediate_shrink_lazy():
    pol = BucketPolicy(min_bucket=1, shrink_patience=3)
    assert pol.fit("k", 5) == 8
    assert pol.fit("k", 9) == 16          # growth is immediate
    assert pol.fit("k", 3) == 16          # shrink needs patience
    assert pol.fit("k", 3) == 16
    assert pol.fit("k", 3) == 8           # 3rd consecutive low fit: halve
    assert pol.events == [("k", 8, 16), ("k", 16, 8)]


def test_bucket_policy_one_change_per_crossing():
    """A monotonic ramp recompiles once per power-of-two crossing; demand
    oscillating around a boundary does not thrash."""
    pol = BucketPolicy(min_bucket=1, shrink_patience=3)
    for n in range(1, 200):
        pol.fit("ramp", n)
    # 1→256 crosses 2,4,8,…,256: one grow event per crossing
    assert len(pol.events) == 8
    assert all(new == 2 * old for (_k, old, new) in pol.events)

    pol2 = BucketPolicy(min_bucket=1, shrink_patience=3)
    pol2.fit("osc", 65)                   # bucket 128
    events0 = len(pol2.events)
    for _ in range(50):
        pol2.fit("osc", 63)               # next_pow2 = 64 = bucket/2 …
        pol2.fit("osc", 65)               # … but the high fit resets it
    assert len(pol2.events) == events0    # no thrash at the boundary


def test_bucket_policy_no_immediate_reshrink_after_shrink():
    """Shrink hysteresis must be re-earned after every shrink: a stream
    sitting just under the *new* half-bucket boundary cannot halve again
    on the very next fit (that would churn one recompile per fit on a
    sustained drop instead of one per patience window)."""
    pol = BucketPolicy(min_bucket=1, shrink_patience=3)
    pol.fit("k", 100)                     # bucket 128
    for _ in range(3):
        pol.fit("k", 20)                  # need 32 ≤ 64: earns the shrink
    assert pol.current("k") == 64
    # still just under the new boundary (32 ≤ 32): patience starts over
    assert pol.fit("k", 20) == 64
    assert pol.fit("k", 20) == 64
    assert pol.fit("k", 20) == 32         # 3rd low fit: one more level
    assert [new for (_k, _old, new) in pol.events] == [64, 32]


def test_bucket_policy_floor_oscillation_counter_bounded():
    """Fits pinned at the min_bucket floor must not prime the shrink
    counter: after a long stay at the floor, demand oscillating around a
    power-of-two boundary still pays full patience per shrink — at most
    one bucket event per crossing, never one per dip."""
    pol = BucketPolicy(min_bucket=8, shrink_patience=2)
    pol.fit("k", 64)
    for _ in range(6):
        pol.fit("k", 1)                   # walks 64→32→16→8, then sits
    assert pol.current("k") == 8
    n_events = len(pol.events)
    for _ in range(50):
        pol.fit("k", 1)                   # at the floor: no events
    assert len(pol.events) == n_events
    assert pol._below["k"] <= pol.shrink_patience
    pol.fit("k", 100)                     # grow back to 128
    pol.fit("k", 63)                      # one dip under 64 …
    assert pol.current("k") == 128        # … must NOT shrink immediately
    pol.fit("k", 65)                      # back above: counter cleared
    for _ in range(30):
        pol.fit("k", 63)                  # dip primes the counter …
        pol.fit("k", 65)                  # … and the high fit resets it
    assert pol.current("k") == 128        # boundary oscillation: no churn
    assert len(pol.events) == n_events + 1   # just the grow event


def test_bucket_policy_sustained_drop_walks_down():
    pol = BucketPolicy(min_bucket=2, shrink_patience=2)
    pol.fit("k", 100)                     # 128
    for _ in range(12):
        pol.fit("k", 1)
    # walks 128→64→32→…→2, one halving per patience window, floored at min
    assert pol.current("k") == 2
    sizes = [new for (_k, _old, new) in pol.events]
    assert sizes == [64, 32, 16, 8, 4, 2]


# -------------------------------------------------------------------- rounds
@pytest.mark.parametrize("seed", range(5))
def test_ppermute_rounds_partial_permutations(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 9))
    edges = {(int(s), int(d)) for s, d in
             rng.integers(0, n, size=(3 * n, 2)) if s != d}
    rounds = ppermute_rounds(edges, n)
    covered = [e for rnd in rounds for e in rnd]
    assert sorted(covered) == sorted(edges)          # each edge exactly once
    for rnd in rounds:
        srcs = [s for s, _ in rnd]
        dsts = [d for _, d in rnd]
        assert len(set(srcs)) == len(srcs)           # partial permutation
        assert len(set(dsts)) == len(dsts)
    # greedy bound: ≤ 2Δ − 1 rounds
    deg = max([sum(1 for s, _ in edges if s == r) for r in range(n)] +
              [sum(1 for _, d in edges if d == r) for r in range(n)])
    assert len(rounds) <= max(2 * deg - 1, 1)


def test_ppermute_rounds_rejects_self_edges():
    with pytest.raises(ValueError, match="self-edge"):
        ppermute_rounds([(1, 1)])


def test_ppermute_rounds_all_pairs_is_ring_optimal():
    n = 4
    edges = [(s, d) for s in range(n) for d in range(n) if s != d]
    rounds = ppermute_rounds(edges, n)
    assert len(rounds) == n - 1                      # Δ = n−1 rounds


# ------------------------------------------------------- packing + host wire
def _random_slots(rng, nranks, nrows):
    """Random exchange honouring the engine's row invariant: source rows
    (owned, < nrows/2) and destination rows (halo, ≥ nrows/2) are disjoint
    on every rank, and each destination row is written at most once."""
    slots = ShipSlots()
    half = nrows // 2
    dst_used = {r: set() for r in range(nranks)}
    for _ in range(rng.integers(1, 3 * nranks + 1)):
        s, d = rng.choice(nranks, 2, replace=False)
        free = [x for x in range(half, nrows) if x not in dst_used[d]]
        if not free:
            continue
        drow = int(rng.choice(free))
        dst_used[d].add(drow)
        slots.add(int(s), int(d), int(rng.integers(0, half)), drow)
    return slots


def _host_reference(slots, fields):
    out = [[np.array(fr) for fr in f] for f in fields]
    for (s, d), pairs in slots.edges.items():
        for (srow, drow) in pairs:
            for f in range(len(out)):
                out[f][d][drow] = out[f][s][srow]
    return out


@pytest.mark.parametrize("seed", range(4))
def test_pack_rounds_reproduces_host_copy(seed):
    """The ppermute index tables, replayed in pure numpy exactly as the
    device program applies them, reproduce the host wire bit-for-bit."""
    rng = np.random.default_rng(seed)
    nranks, nrows = 4, 10
    slots = _random_slots(rng, nranks, nrows)
    rounds = ppermute_rounds(list(slots.edges), nranks)
    bucket = next_pow2(slots.max_edge_slots)
    pack, unpack, valid = pack_rounds(rounds, slots, nranks, bucket)

    fields = [[rng.normal(size=(nrows, 3)).astype(np.float32)
               for _ in range(nranks)] for _ in range(2)]
    ref = _host_reference(slots, fields)

    got = [[f.copy() for f in field] for field in fields]
    for t, rnd in enumerate(rounds):
        for (s, d) in rnd:
            for f in range(len(fields)):
                buf = got[f][s][pack[s, t]]          # sender packs
                for k in range(bucket):              # receiver unpacks
                    if valid[d, t, k] > 0:
                        got[f][d][unpack[d, t, k]] = buf[k]
    for f in range(len(fields)):
        for r in range(nranks):
            np.testing.assert_array_equal(got[f][r], ref[f][r])


@pytest.mark.parametrize("seed", range(4))
def test_pack_allgather_reproduces_host_copy(seed):
    rng = np.random.default_rng(seed)
    nranks, nrows = 4, 10
    slots = _random_slots(rng, nranks, nrows)
    Bo = next_pow2(slots.max_rank_exports(nranks))
    Bi = next_pow2(slots.max_rank_imports(nranks))
    pack, usrc, urows, valid = pack_allgather(slots, nranks, Bo, Bi)

    fields = [[rng.normal(size=(nrows,)).astype(np.float32)
               for _ in range(nranks)]]
    ref = _host_reference(slots, fields)
    got = [[f.copy() for f in field] for field in fields]
    gathered = np.stack([got[0][r][pack[r]] for r in range(nranks)])
    flat = gathered.reshape(-1)
    for d in range(nranks):
        for k in range(Bi):
            if valid[d, k] > 0:
                got[0][d][urows[d, k]] = flat[usrc[d, k]]
    for r in range(nranks):
        np.testing.assert_array_equal(got[0][r], ref[0][r])


def test_host_transport_touches_only_destination_rows():
    slots = ShipSlots()
    slots.add(0, 1, src_row=2, dst_row=5)
    fields = [[jnp.arange(8.0) + 10 * r for r in range(2)]]
    out = HostTransport().exchange(slots, fields)
    a0, a1 = np.asarray(out[0][0]), np.asarray(out[0][1])
    np.testing.assert_array_equal(a0, np.arange(8.0))    # source untouched
    assert a1[5] == 2.0                                  # copied row
    keep = [i for i in range(8) if i != 5]
    np.testing.assert_array_equal(a1[keep], (np.arange(8.0) + 10)[keep])


# ------------------------------------------------- mask-padding property
def _local_timebin_engine(n_side=4):
    spec = SimulationSpec(scenario="uniform",
                          scenario_params={"n_side": n_side, "seed": 0},
                          physics=SPHConfig(alpha_visc=0.8),
                          integrator="timebin", dt_max=0.004)
    return build_simulation(spec).engine


def test_padded_pairs_contribute_exact_zero():
    """Satellite acceptance: mask-padded pair entries (the bucket slack)
    change neither the density nor the force phase by a single bit —
    the property every bucketed program relies on."""
    from repro.sph.timebins import (_substep_density_phase,
                                    _substep_force_phase)
    eng = _local_timebin_engine()
    state = eng.state
    cfg = eng.cfg
    ci, cj, shift = eng._ci, eng._cj, eng._shift
    n = len(ci)

    def padded(extra):
        idxp = np.concatenate([np.arange(n), np.zeros(extra, np.int64)])
        pmask = np.concatenate([np.ones(n, np.float32),
                                np.zeros(extra, np.float32)])
        pairs = PairList(ci=jnp.asarray(ci[idxp]), cj=jnp.asarray(cj[idxp]),
                         shift=jnp.asarray(shift[idxp]))
        return pairs, jnp.asarray(pmask)

    active = state.cells.mask
    wake = jnp.zeros(state.bins.shape[0], jnp.int32)
    outs = []
    for extra in (0, 37):
        pairs, pmask = padded(extra)
        rho, om, pr, cs = _substep_density_phase(state, pairs, pmask,
                                                 active, cfg=cfg)
        new_state, _ = _substep_force_phase(
            state, pairs, pmask, active, rho, om, pr, cs, wake,
            jnp.float32(0.004), jnp.int32(0), jnp.float32(0.0), cfg=cfg)
        outs.append((rho, om, pr, cs, new_state))
    for a, b in zip(outs[0][:4], outs[1][:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    sa, sb = outs[0][4], outs[1][4]
    for name in ("pos", "vel", "u"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sa.cells, name)),
            np.asarray(getattr(sb.cells, name)), err_msg=name)
    np.testing.assert_array_equal(np.asarray(sa.accel), np.asarray(sb.accel))
    np.testing.assert_array_equal(np.asarray(sa.bins), np.asarray(sb.bins))


# ------------------------------------------------------ collective transport
def _dist_spec(transport, n_side=5, ranks=4, max_depth=3, mode="auto"):
    return SimulationSpec(
        scenario="sedov",
        scenario_params={"n_side": n_side, "e0": 1.0, "seed": 0},
        physics=SPHConfig(alpha_visc=1.0, cfl=0.15),
        integrator="timebin", backend="distributed", ranks=ranks,
        dt_max=0.02, max_depth=max_depth, transport=transport,
        transport_mode=mode)


def _assert_engine_states_equal(a, b):
    for name in ("pos", "vel", "u", "h", "mass", "mask"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.state.cells, name)),
            np.asarray(getattr(b.state.cells, name)), err_msg=name)
    for name in ("accel", "dudt", "rho", "omega", "bins", "t_start"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.state, name)),
            np.asarray(getattr(b.state, name)), err_msg=name)
    assert float(a.state.time) == float(b.state.time)


def test_spec_transport_validation():
    with pytest.raises(ValueError, match="transport"):
        SimulationSpec(transport="pigeon")
    with pytest.raises(ValueError, match="transport_mode"):
        SimulationSpec(transport_mode="carrier")
    from repro.sph.dist_timebins import DistTimeBinSimulation
    from repro.sph import uniform_ic
    ic = uniform_ic(3, seed=0)
    with pytest.raises(ValueError, match="transport"):
        DistTimeBinSimulation(ic["pos"], ic["vel"], ic["mass"], ic["u"],
                              ic["h"], box=ic["box"], transport="pigeon")


def test_collective_transport_needs_devices():
    if len(jax.devices()) >= 4:
        pytest.skip("process has 4 devices; the error path needs fewer")
    with pytest.raises(ValueError, match="host_platform_device_count"):
        build_simulation(_dist_spec("collective", ranks=4))


@pytest.mark.slow
def test_collective_one_rank_parity():
    """ranks=1: no cut, but the whole collective build path (mesh,
    transport, program cache) runs and matches the host transport."""
    host = build_simulation(_dist_spec("host", ranks=1))
    coll = build_simulation(_dist_spec("collective", ranks=1))
    for _ in range(2):
        host.step()
        coll.step()
    _assert_engine_states_equal(host.engine, coll.engine)
    assert coll.engine.transport_stats()["kind"] == "collective"


@requires4
@pytest.mark.slow
@pytest.mark.parametrize("mode", ["ppermute", "allgather"])
def test_collective_four_rank_parity(mode):
    """Acceptance: bit-for-bit parity between transport="host" and
    transport="collective" on Sedov over ≥2 full cycles on 4 devices."""
    host = build_simulation(_dist_spec("host", n_side=6, max_depth=4))
    coll = build_simulation(_dist_spec("collective", n_side=6, max_depth=4,
                                       mode=mode))
    for _ in range(2):
        sh = host.step()
        sc = coll.step()
        assert sh["depth"] == sc["depth"]
        assert sh["halo_exported_slots"] == sc["halo_exported_slots"]
    _assert_engine_states_equal(host.engine, coll.engine)
    stats = coll.engine.transport_stats()
    assert stats["mode"] == mode
    assert stats["shipped_rows"] > 0


@requires4
@pytest.mark.slow
def test_compile_probe_one_compile_per_level_bucket():
    """Acceptance: at most one recompile per (level, bucket) pair — the
    probe reads the true jit cache sizes; buckets bound them."""
    import collections
    coll = build_simulation(_dist_spec("collective", n_side=6, max_depth=4))
    for _ in range(2):
        coll.step()
    eng = coll.engine
    builds_after_two = eng._transport.programs.builds
    compiles_after_two = eng.probe.total_compiles()
    buckets = collections.defaultdict(set)
    for (prog, level, bucket) in eng.program_keys:
        buckets[prog].add(bucket)
    counts = eng.probe.counts()
    for prog in ("density", "force", "final_density", "final_force"):
        assert 1 <= counts[prog] <= len(buckets[prog if prog in buckets
                                                else "density"])
    for name, c in counts.items():
        if name.startswith("program:"):
            assert c == 1                        # exchange: compile once
    # a third cycle re-uses everything: no new programs, no new compiles
    coll.step()
    assert eng._transport.programs.builds == builds_after_two
    assert eng.probe.total_compiles() == compiles_after_two


@pytest.mark.slow
def test_collective_parity_subprocess():
    """The 4-device parity check for suites running on one real device
    (the default tier-1 lane): spawned with an emulated device mesh."""
    if len(jax.devices()) >= 4:
        pytest.skip("in-process 4-device tests cover this lane")
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, os.path.join(%r, "src"))
        import numpy as np
        import jax
        jax.config.update("jax_default_matmul_precision", "float32")
        assert len(jax.devices()) == 4
        from repro.sph import SimulationSpec, SPHConfig, build_simulation
        base = SimulationSpec(
            scenario="sedov",
            scenario_params={"n_side": 5, "e0": 1.0, "seed": 0},
            physics=SPHConfig(alpha_visc=1.0, cfl=0.15),
            integrator="timebin", backend="distributed", ranks=4,
            dt_max=0.02, max_depth=3)
        host = build_simulation(base)
        coll = build_simulation(base.with_(transport="collective"))
        for _ in range(2):
            host.step()
            coll.step()
        for name in ("pos", "vel", "u", "h"):
            np.testing.assert_array_equal(
                np.asarray(getattr(host.engine.state.cells, name)),
                np.asarray(getattr(coll.engine.state.cells, name)),
                err_msg=name)
        np.testing.assert_array_equal(np.asarray(host.engine.state.bins),
                                      np.asarray(coll.engine.state.bins))
        for name, c in coll.engine.probe.counts().items():
            if name.startswith("program:"):
                assert c == 1, (name, c)
        print("SUBPROCESS_PARITY_OK")
    """ % os.path.abspath(ROOT))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        f"subprocess failed:\nSTDOUT:{proc.stdout}\n" \
        f"STDERR:{proc.stderr[-3000:]}"
    assert "SUBPROCESS_PARITY_OK" in proc.stdout
