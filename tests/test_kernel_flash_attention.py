"""Flash attention Pallas kernel vs full-softmax oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import attention_ref, flash_attention


def make_qkv(B, S, T, H, K, hd, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32),
                    dtype)
    k = jnp.asarray(rng.standard_normal((B, T, K, hd)).astype(np.float32),
                    dtype)
    v = jnp.asarray(rng.standard_normal((B, T, K, hd)).astype(np.float32),
                    dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,K,hd", [
    (1, 64, 4, 4, 32),     # MHA
    (2, 128, 8, 2, 16),    # GQA 4:1
    (1, 256, 4, 1, 64),    # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(B, S, H, K, hd, causal):
    q, k, v = make_qkv(B, S, S, H, K, hd, seed=S + H)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_sliding_window(window):
    q, k, v = make_qkv(1, 256, 256, 4, 4, 32, seed=window)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    q, k, v = make_qkv(1, 128, 128, 4, 4, 32, seed=5, dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_uneven_blocks():
    """Block sizes that don't match S exactly must still tile."""
    q, k, v = make_qkv(2, 96, 96, 2, 2, 16, seed=7)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
