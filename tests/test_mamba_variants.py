"""Mamba-2 numerics knobs: chunk invariance and bf16 einsum tolerance."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.mamba import init_mamba2, mamba2_forward


@pytest.fixture(scope="module")
def setup():
    p = init_mamba2(jax.random.PRNGKey(0), 64, d_state=16, headdim=16,
                    dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64)) * 0.3
    y_ref, _ = mamba2_forward(p, x, d_state=16, headdim=16, chunk=16)
    return p, x, y_ref


def test_chunk_size_invariance(setup):
    p, x, y_ref = setup
    for chunk in (8, 32, 64):
        y, _ = mamba2_forward(p, x, d_state=16, headdim=16, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-5)


def test_bf16_einsum_within_tolerance(setup):
    p, x, y_ref = setup
    y, _ = mamba2_forward(p, x, d_state=16, headdim=16, chunk=16,
                          bf16_einsum=True)
    scale = max(float(jnp.abs(y_ref).max()), 1e-6)
    assert float(jnp.abs(y - y_ref).max()) / scale < 0.02
