"""SPH physics: kernels, oracle agreement, conservation laws."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.sph import (SPHConfig, Simulation, uniform_ic, clustered_ic,
                       get_kernel)
from repro.sph.smoothing import dw_dh, w_cubic, w_wendland_c2
from repro.sph.cellgrid import bin_particles, build_pair_list, choose_grid
from repro.sph.engine import compute_accelerations, init_state, step
from repro.sph.ref_nsquared import nsq_density, nsq_forces


@pytest.mark.parametrize("name", ["cubic", "wendland_c2"])
def test_kernel_normalisation(name):
    """∫ W(r,h) 4πr² dr = 1 (3-D normalisation) by quadrature."""
    w_fn, _ = get_kernel(name)
    h = 0.7
    r = np.linspace(1e-6, h, 20001)
    w = np.asarray(w_fn(jnp.asarray(r), h))
    integral = np.trapezoid(w * 4 * np.pi * r ** 2, r)
    assert abs(integral - 1.0) < 1e-3


@pytest.mark.parametrize("name", ["cubic", "wendland_c2"])
def test_kernel_gradient_matches_autodiff(name):
    w_fn, dwdr_fn = get_kernel(name)
    rs = jnp.linspace(0.05, 0.95, 19)
    h = 1.0
    auto = jax.vmap(jax.grad(lambda r: w_fn(r, h)))(rs)
    manual = dwdr_fn(rs, h)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(manual),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", ["cubic"])
def test_dwdh_matches_autodiff(name):
    w_fn, _ = get_kernel(name)
    rs = jnp.linspace(0.05, 0.95, 10)
    auto = jax.vmap(jax.grad(lambda h, r: w_fn(r, h)),
                    in_axes=(None, 0))(1.0, rs)
    manual = dw_dh(rs, 1.0, name)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(manual),
                               rtol=1e-4, atol=1e-5)


def _setup(n_side=8, seed=0, vel_scale=0.1):
    ic = uniform_ic(n_side, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ic["vel"] = (ic["vel"]
                 + vel_scale * rng.standard_normal(ic["vel"].shape)
                 ).astype(np.float32)
    return ic


def test_cell_engine_matches_nsquared_oracle():
    ic = _setup()
    pos, vel, mass, u, h, box = (ic[k] for k in
                                 ("pos", "vel", "mass", "u", "h", "box"))
    rho_ref, drho_ref, nngb_ref = nsq_density(pos, mass, h, box)
    omega_ref = 1.0 + (h / (3 * rho_ref)) * drho_ref
    dv_ref, du_ref = nsq_forces(pos, vel, mass, u, h, rho_ref, omega_ref,
                                box, alpha_visc=0.8)

    spec = choose_grid(box, float(h.max()), len(pos))
    cells, perm = bin_particles(spec, pos, vel, mass, u, h)
    pairs = build_pair_list(spec)
    dv, du, rho, nngb = compute_accelerations(
        cells, pairs, SPHConfig(alpha_visc=0.8))

    valid = perm >= 0
    idx = perm[valid]

    def flat(a):
        out = np.zeros((len(pos),) + a.shape[2:], np.float32)
        out[idx] = np.asarray(a)[valid]
        return out

    np.testing.assert_allclose(flat(rho), np.asarray(rho_ref), rtol=2e-4)
    np.testing.assert_allclose(flat(nngb), np.asarray(nngb_ref), atol=0)
    np.testing.assert_allclose(flat(dv), np.asarray(dv_ref),
                               rtol=2e-3, atol=2e-3 * float(
                                   jnp.abs(dv_ref).max()))
    np.testing.assert_allclose(flat(du), np.asarray(du_ref),
                               rtol=2e-3, atol=2e-3 * float(
                                   jnp.abs(du_ref).max()))


def test_momentum_conserved():
    ic = _setup(vel_scale=0.2)
    sim = Simulation(ic["pos"], ic["vel"], ic["mass"], ic["u"], ic["h"],
                     box=ic["box"], cfg=SPHConfig(alpha_visc=0.8),
                     rebin_every=3)
    _, p0 = sim.diagnostics()
    sim.run(8, dt=0.004)
    _, p1 = sim.diagnostics()
    assert np.abs(p1 - p0).max() < 1e-6


def test_energy_drift_small_and_converging():
    drifts = []
    for dt, nsteps in ((0.02, 5), (0.01, 10)):
        ic = _setup(vel_scale=0.2)
        sim = Simulation(ic["pos"], ic["vel"], ic["mass"], ic["u"],
                         ic["h"], box=ic["box"],
                         cfg=SPHConfig(alpha_visc=0.0), rebin_every=100)
        e0, _ = sim.diagnostics()
        sim.run(nsteps, dt=dt)
        e1, _ = sim.diagnostics()
        drifts.append(abs(e1 - e0) / abs(e0))
    assert drifts[0] < 0.01             # <1% over the run
    assert drifts[1] < drifts[0]        # converges with dt


def test_viscosity_dissipates_kinetic_into_internal():
    ic = _setup(vel_scale=0.5)
    sim = Simulation(ic["pos"], ic["vel"], ic["mass"], ic["u"], ic["h"],
                     box=ic["box"], cfg=SPHConfig(alpha_visc=1.0),
                     rebin_every=100)
    c = sim.state.cells
    m = np.asarray(c.mass * c.mask)
    ke0 = 0.5 * np.sum(m * np.sum(np.asarray(c.vel) ** 2, -1))
    ie0 = np.sum(m * np.asarray(c.u))
    sim.run(10, dt=0.005)
    c = sim.state.cells
    m = np.asarray(c.mass * c.mask)
    ke1 = 0.5 * np.sum(m * np.sum(np.asarray(c.vel) ** 2, -1))
    ie1 = np.sum(m * np.asarray(c.u))
    assert ie1 > ie0                    # heating
    assert ke1 < ke0                    # damping


def test_clustered_ic_has_dynamic_range():
    ic = clustered_ic(3000, seed=1)
    ratio = ic["h"].max() / ic["h"].min()
    assert ratio > 4.0                  # orders-of-magnitude density contrast


def test_sedov_ic_energy_and_dt_spread():
    """The blast IC injects exactly e0 and opens a ≥3-decade CFL dt spread
    — the dynamic range the time-bin hierarchy exists for."""
    from repro.sph import sedov_ic
    from repro.sph.physics import cfl_timestep_block
    import jax.numpy as jnp

    e0 = 1.0
    ic = sedov_ic(8, e0=e0, u_background=1e-6, seed=0)
    base = uniform_ic(8, temperature=1e-6, jitter=0.02, seed=0)
    injected = float(np.sum(ic["mass"] * (ic["u"] - base["u"])))
    assert injected == pytest.approx(e0, rel=1e-4)
    # per-particle CFL spread ≥ 3 decades (hot centre vs cold background)
    dt = np.asarray(cfl_timestep_block(
        jnp.asarray(ic["h"]), jnp.asarray(ic["u"]),
        jnp.asarray(ic["vel"]), jnp.ones(len(ic["u"]))))
    assert dt.max() / dt.min() > 1e3


def test_cfl_timestep_block_masks_and_scales():
    from repro.sph.physics import cfl_timestep_block, sound_speed
    import jax.numpy as jnp

    h = jnp.asarray([0.1, 0.2, 0.1])
    u = jnp.asarray([1.0, 1.0, 4.0])
    vel = jnp.zeros((3, 3))
    mask = jnp.asarray([1.0, 1.0, 0.0])
    dt = np.asarray(cfl_timestep_block(h, u, vel, mask, cfl=0.25))
    cs = np.asarray(sound_speed(jnp.ones(3), u))
    np.testing.assert_allclose(dt[0], 0.25 * 0.1 / cs[0], rtol=1e-6)
    np.testing.assert_allclose(dt[1], 2 * dt[0], rtol=1e-6)   # ∝ h
    assert np.isinf(dt[2])                                    # padded slot
