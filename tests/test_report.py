"""Report generator over the committed dry-run artifacts."""

import os

import pytest

from repro.analysis.report import (advice_list, load_cells, markdown_table,
                                   rebuild_roofline)

V0 = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "results",
                  "dryrun_v0")


@pytest.mark.skipif(not os.path.isdir(V0), reason="no archived dry-run")
def test_v0_artifacts_load_and_rebuild():
    cells = load_cells(V0)
    assert len(cells) >= 70
    ok = [c for c in cells if c.get("status") == "ok"]
    assert len(ok) >= 60
    for c in ok:
        r = rebuild_roofline(c)
        assert r is not None
        assert r.t_compute > 0 and r.t_memory > 0
        assert r.bottleneck in ("compute", "memory", "collective")
        assert 0 <= r.roofline_fraction <= 1.0 + 1e-9


@pytest.mark.skipif(not os.path.isdir(V0), reason="no archived dry-run")
def test_markdown_table_renders():
    md = markdown_table(V0, mesh="single")
    assert md.count("|") > 100
    assert "bound" in md.splitlines()[0]
    adv = advice_list(V0, mesh="single")
    assert "bound" in adv
