"""Sharding rules: divisibility safety, spec structure, placement quality."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.distributed import ShardingRules, valid_spec
from repro.distributed.pipeline import assign_stages, place_experts
from repro.models import init_params


def fake_mesh(shape=(4, 2), axes=("data", "model")):
    """Abstract mesh for spec construction (no real devices needed)."""
    from jax.sharding import AbstractMesh
    return AbstractMesh(shape, axes)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 64), min_size=1, max_size=4),
       st.integers(0, 3))
def test_valid_spec_never_invalid(dims, style):
    mesh = fake_mesh()
    prefs_by_style = {
        0: [["data"]], 1: [["model"]], 2: [[("data", "model")], ["model"]],
        3: [["model", "data"]],
    }
    prefs = [prefs_by_style[style][0] if style != 2
             else [("data", "model"), "model"] for _ in dims]
    spec = valid_spec(dims, prefs, mesh)
    # every sharded dim must divide evenly
    for dim, s in zip(dims, list(spec) + [None] * (len(dims) - len(spec))):
        if s is None:
            continue
        axes = (s,) if isinstance(s, str) else s
        size = int(np.prod([mesh.shape[a] for a in axes]))
        assert dim % size == 0


def test_valid_spec_no_axis_reuse():
    mesh = fake_mesh()
    spec = valid_spec((8, 8), [["model"], ["model"]], mesh)
    used = [s for s in spec if s is not None]
    assert len(used) <= 1               # second dim can't reuse 'model'


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("mode", ["train", "serve"])
def test_params_pspec_structure_matches(arch, mode):
    """Spec tree mirrors the param tree and every spec is divisibility-ok
    on the production mesh shape."""
    cfg = get_config(arch)
    mesh = fake_mesh((16, 16), ("data", "model"))
    rules = ShardingRules(mesh, cfg, mode)
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = rules.params_pspec(shapes)
    flat_p = jax.tree.leaves(shapes)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert isinstance(spec, P)
        for dim, s in zip(leaf.shape, tuple(spec)):
            if s is None:
                continue
            axes = (s,) if isinstance(s, str) else s
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (arch, leaf.shape, spec)


def test_train_rules_shard_big_weights_2d():
    cfg = get_config("qwen1.5-32b")
    mesh = fake_mesh((16, 16), ("data", "model"))
    rules = ShardingRules(mesh, cfg, "train")
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = rules.params_pspec(shapes)
    wq_spec = specs["segments"][0][0]["attn"]["wq"]
    flat = [a for a in jax.tree.leaves(wq_spec, is_leaf=lambda x: True)]
    # (L, d, H·hd): d → data (fsdp), out → model (tp)
    assert "model" in str(wq_spec) and "data" in str(wq_spec)


def test_stage_assignment_beats_uniform_on_heterogeneous():
    cfg = get_config("gemma3-27b")
    stages, metrics = assign_stages(cfg, 8, batch=16, seq=4096)
    assert metrics["partitioned_imbalance"] <= \
        metrics["uniform_imbalance"] + 1e-9
    assert len(stages) == cfg.n_layers


def test_expert_placement_balances_measured_load():
    rng = np.random.default_rng(0)
    load = rng.pareto(1.0, 8) + 0.1      # skewed expert popularity
    assign, metrics = place_experts(load, 4)
    assert metrics["partitioned_imbalance"] <= \
        metrics["naive_imbalance"] + 1e-9
    assert len(assign) == 8
