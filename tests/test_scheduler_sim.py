"""Discrete-event executor simulation: async vs bulk-synchronous."""

import numpy as np
import pytest

from repro.core import (AsyncExecutorSim, TaskGraph, decompose_with_comm,
                        makespan_lower_bound, wave_schedule)


def build_ring(ncells=16, cost_skew=False):
    g = TaskGraph()
    rng = np.random.default_rng(0)
    sort, ghost, kick = [], [], []
    for c in range(ncells):
        k = 5.0 if (cost_skew and c < 2) else 1.0
        sort.append(g.add_task("sort", resources=(c,), writes=(c,), cost=k))
        ghost.append(g.add_task("ghost", resources=(c,), writes=(c,),
                                cost=0.5 * k))
        kick.append(g.add_task("kick", resources=(c,), writes=(c,),
                               cost=0.5 * k))
    for c in range(ncells):
        nxt = (c + 1) % ncells
        k = 5.0 if (cost_skew and c < 2) else 2.0
        d = g.add_task("density_pair", resources=(c, nxt), writes=(c, nxt),
                       cost=k)
        f = g.add_task("force_pair", resources=(c, nxt), writes=(c, nxt),
                       cost=k)
        for r in (c, nxt):
            g.add_dependency(d, sort[r])
            g.add_dependency(ghost[r], d)
            g.add_dependency(f, ghost[r])
            g.add_dependency(kick[r], f)
    return g


def _distribute(g, ncells, ranks):
    dist, dec = decompose_with_comm(
        g, ncells, ranks, cell_bytes=[6000.0] * ncells,
        phases={"sort": "p0", "density_pair": "p1", "ghost": "p2",
                "force_pair": "p3", "kick": "p4"})
    return dist


def test_async_beats_sync_with_latency():
    g = _distribute(build_ring(16), 16, 4)
    kw = dict(ranks=4, threads=2, latency=0.5, bandwidth=1e6)
    r_async = AsyncExecutorSim(g, **kw).run()
    r_sync = AsyncExecutorSim(g, synchronous=True, **kw).run()
    assert r_async.makespan < r_sync.makespan
    assert 0 < r_async.efficiency <= 1.0
    assert 0 < r_sync.efficiency <= 1.0


def test_all_tasks_complete_and_messages_counted():
    g = _distribute(build_ring(12), 12, 3)
    r = AsyncExecutorSim(g, ranks=3, threads=1).run()
    n_send = sum(1 for t in g.tasks.values() if t.kind == "send")
    assert r.messages == n_send
    assert r.message_bytes == pytest.approx(n_send * 6000.0)


def test_makespan_at_least_lower_bound():
    g = _distribute(build_ring(16, cost_skew=True), 16, 4)
    r = AsyncExecutorSim(g, ranks=4, threads=2, latency=0.0,
                         bandwidth=1e12).run()
    # Graham bound over compute tasks only (sends ~free here)
    lb = max(t.cost for t in g.tasks.values())
    assert r.makespan >= lb


def test_more_threads_never_slower():
    g = _distribute(build_ring(16), 16, 2)
    m1 = AsyncExecutorSim(g, ranks=2, threads=1).run().makespan
    m4 = AsyncExecutorSim(g, ranks=2, threads=4).run().makespan
    assert m4 <= m1 + 1e-9
