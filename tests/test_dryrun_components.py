"""Dry-run machinery tests that don't need 512 devices."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.analysis.hlo_parse import (_shape_bytes, collective_summary,
                                      parse_collectives)
from repro.analysis.roofline import Roofline, model_flops
from repro.configs import ARCH_NAMES, SHAPES, applicable, get_config


SAMPLE_HLO = """
%all-reduce.1 = f32[8,64]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[4,2]<=[8], use_global_device_ids=true, to_apply=%add
%ag = bf16[16,128]{1,0} all-gather(%p0), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
%rs = f32[4,32]{1,0} reduce-scatter(%p1), channel_id=3, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
%cp = bf16[2,2]{1,0} collective-permute(%p2), channel_id=4, source_target_pairs={{0,1},{1,0}}
%ard = f32[8,64]{1,0} all-reduce-done(%start)
%tuple_ag = (f32[4,4]{1,0}, f32[2,2]{1,0}) all-gather(%a, %b), channel_id=5, replica_groups=[1,8]<=[8], dimensions={0}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,64]{1,0}") == 8 * 64 * 4
    assert _shape_bytes("bf16[16,128]") == 16 * 128 * 2
    assert _shape_bytes("(f32[4,4], f32[2,2])") == (16 + 4) * 4


def test_parse_collectives():
    ops = parse_collectives(SAMPLE_HLO)
    kinds = [o.op for o in ops]
    # -done is skipped; 5 real collectives
    assert kinds.count("all-reduce") == 1
    assert kinds.count("all-gather") == 2
    assert kinds.count("reduce-scatter") == 1
    assert kinds.count("collective-permute") == 1
    ar = next(o for o in ops if o.op == "all-reduce")
    assert ar.group_size == 2
    assert ar.traffic == pytest.approx(2 * 0.5 * 8 * 64 * 4)
    rs = next(o for o in ops if o.op == "reduce-scatter")
    assert rs.group_size == 4
    assert rs.traffic == pytest.approx(3 * 4 * 32 * 4)


def test_collective_summary():
    s = collective_summary(SAMPLE_HLO)
    assert s["count"] == 5
    assert s["traffic_bytes"] > 0


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="x", shape="train_4k", mesh="single",
                 flops_per_chip=197e12, bytes_per_chip=819e9,
                 collective_bytes_per_chip=25e9,
                 model_flops_per_chip=100e12)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck in ("compute", "memory")
    assert 0 < r.roofline_fraction <= 1.0


def test_model_flops_train_vs_decode():
    cfg = get_config("granite-8b")
    tr = model_flops(cfg, SHAPES["train_4k"], chips=256)
    de = model_flops(cfg, SHAPES["decode_32k"], chips=256)
    assert tr > de * 1e4                   # train step ≫ one decode token
    # train: 6·N·D — cross-check magnitude
    n = cfg.n_params()
    assert tr == pytest.approx(6 * n * 256 * 4096 / 256, rel=1e-6)


def test_input_specs_cover_every_cell():
    from repro.launch.dryrun import input_specs
    for arch in ARCH_NAMES:
        for shape_name in SHAPES:
            if not applicable(get_config(arch), shape_name)[0]:
                continue
            specs = input_specs(arch, shape_name)
            assert specs, (arch, shape_name)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_decode_cache_specs_have_no_allocation():
    """Decode cache stand-ins stay abstract even at 500k context."""
    from repro.launch.dryrun import input_specs
    specs = input_specs("falcon-mamba-7b", "long_500k")
    leaves = jax.tree.leaves(specs["caches"])
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
