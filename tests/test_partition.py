"""Multilevel partitioner properties (paper §3.2's METIS role)."""

import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Graph, evaluate, partition_geometric, partition_graph)


def random_geometric_graph(n, radius, seed, weighted_nodes=False):
    rng = np.random.default_rng(seed)
    pos = rng.random((n, 3))
    edges = {}
    for i in range(n):
        d = np.linalg.norm(pos - pos[i], axis=1)
        for j in np.nonzero((d < radius) & (np.arange(n) > i))[0]:
            edges[(i, int(j))] = 1.0 / (d[j] + 1e-3)
    w = rng.random(n) + 0.1 if weighted_nodes else None
    return Graph.from_edges(n, edges, w), pos


def test_partition_basic_quality():
    g, pos = random_geometric_graph(300, 0.15, seed=0)
    res = partition_graph(g, 4, seed=0)
    assert res.nparts == 4
    assert len(res.assignment) == g.n
    assert set(np.unique(res.assignment)) <= set(range(4))
    assert res.imbalance < 1.3


def test_partition_beats_geometric_on_clustered():
    """The paper's claim: work-partitioning beats geometric cuts on
    clustered inputs."""
    rng = np.random.default_rng(3)
    # two dense clusters + sparse background
    a = rng.normal(0.25, 0.03, (150, 3))
    b = rng.normal(0.75, 0.03, (150, 3))
    bg = rng.random((100, 3))
    pos = np.clip(np.concatenate([a, b, bg]), 0, 1)
    edges = {}
    for i in range(len(pos)):
        d = np.linalg.norm(pos - pos[i], axis=1)
        for j in np.nonzero((d < 0.1) & (np.arange(len(pos)) > i))[0]:
            edges[(i, int(j))] = 1.0
    g = Graph.from_edges(len(pos), edges)
    ours = partition_graph(g, 8, seed=0)
    geo = evaluate(g, partition_geometric(pos, 8), 8)
    assert ours.part_loads.max() <= geo.part_loads.max() * 1.05


def test_determinism():
    g, _ = random_geometric_graph(200, 0.15, seed=1)
    r1 = partition_graph(g, 4, seed=7)
    r2 = partition_graph(g, 4, seed=7)
    assert np.array_equal(r1.assignment, r2.assignment)


def test_edge_cases():
    g, _ = random_geometric_graph(20, 0.3, seed=2)
    r1 = partition_graph(g, 1)
    assert r1.edge_cut == 0 and set(np.unique(r1.assignment)) == {0}
    rn = partition_graph(g, 50)      # more parts than nodes
    assert len(rn.assignment) == 20


@settings(max_examples=20, deadline=None)
@given(st.integers(10, 120), st.integers(2, 8), st.integers(0, 5))
def test_partition_invariants(n, k, seed):
    """Properties: every vertex assigned; balance bound respected for
    connected-ish graphs; cut equals recomputed cut."""
    g, _ = random_geometric_graph(n, 0.35, seed=seed, weighted_nodes=True)
    res = partition_graph(g, k, seed=seed, max_imbalance=1.10)
    assert len(res.assignment) == n
    assert (res.assignment >= 0).all() and (res.assignment < k).all()
    again = evaluate(g, res.assignment, k)
    assert np.isclose(again.edge_cut, res.edge_cut)
    assert np.allclose(again.part_loads, res.part_loads)


def test_node_weight_balance():
    """Heavily skewed node weights must still balance work."""
    rng = np.random.default_rng(0)
    n = 200
    w = np.ones(n)
    w[:10] = 50.0                     # few very expensive cells (clustered IC)
    edges = {(i, (i + 1) % n): 1.0 for i in range(n)}
    g = Graph.from_edges(n, edges, w)
    res = partition_graph(g, 4, seed=0)
    assert res.imbalance < 1.6
