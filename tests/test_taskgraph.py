"""Task graph: dependencies, conflicts, wave schedules (paper §3.1)."""

import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (TaskGraph, TaskGraphError, balance_wave,
                        makespan_lower_bound, wave_schedule)


def build_sph_like(ncells=6):
    """sort → density(pair) → ghost → force(pair) → kick over a cell ring."""
    g = TaskGraph()
    sort = [g.add_task("sort", resources=(c,), writes=(c,), cost=1)
            for c in range(ncells)]
    ghost = [g.add_task("ghost", resources=(c,), writes=(c,), cost=0.5)
             for c in range(ncells)]
    kick = [g.add_task("kick", resources=(c,), writes=(c,), cost=0.5)
            for c in range(ncells)]
    for c in range(ncells):
        nxt = (c + 1) % ncells
        d = g.add_task("density_pair", resources=(c, nxt), writes=(c, nxt),
                       cost=2)
        f = g.add_task("force_pair", resources=(c, nxt), writes=(c, nxt),
                       cost=2)
        for r in (c, nxt):
            g.add_dependency(d, sort[r])
            g.add_dependency(ghost[r], d)
            g.add_dependency(f, ghost[r])
            g.add_dependency(kick[r], f)
    return g


def test_toposort_and_cycle_detection():
    g = TaskGraph()
    a = g.add_task("a")
    b = g.add_task("b")
    g.add_dependency(b, a)
    assert g.toposort() == [a, b]
    g.add_dependency(a, b)
    with pytest.raises(TaskGraphError):
        g.toposort()


def test_self_dependency_rejected():
    g = TaskGraph()
    a = g.add_task("a")
    with pytest.raises(TaskGraphError):
        g.add_dependency(a, a)


def test_writes_must_be_resources():
    g = TaskGraph()
    with pytest.raises(TaskGraphError):
        g.add_task("bad", resources=(1,), writes=(2,))


def test_auto_conflicts_and_wave_validity():
    g = build_sph_like(6)
    added = g.auto_conflicts()
    assert added > 0          # ring pair tasks sharing cells conflict
    waves = wave_schedule(g)
    g.validate_schedule(waves)    # raises on any violation
    # per-wave kinds homogeneous (batched-op lowering requirement)
    for w in waves:
        kinds = {g.tasks[t].kind for t in w}
        assert len(kinds) == 1


def test_wave_order_matches_sph_phases():
    g = build_sph_like(4)
    g.auto_conflicts()
    waves = wave_schedule(g)
    first = {}
    for i, w in enumerate(waves):
        k = g.tasks[w[0]].kind
        first.setdefault(k, i)
    assert first["sort"] < first["density_pair"] < first["ghost"] \
        < first["force_pair"] < first["kick"]


def test_critical_path_bounds_makespan():
    g = build_sph_like(5)
    cp, path = g.critical_path()
    assert cp > 0 and len(path) >= 5
    lb = makespan_lower_bound(g, workers=4)
    assert lb >= cp / 10      # sanity: non-degenerate


def test_cell_graph_projection():
    g = build_sph_like(4)
    nodes, edges = g.cell_graph()
    assert set(nodes) == set(range(4))
    assert all(w > 0 for w in nodes.values())
    # ring topology: 4 edges
    assert len(edges) == 4


def test_balance_wave_lpt():
    costs = [10, 1, 1, 1, 9, 2]
    bins = balance_wave(costs, 2)
    loads = [sum(costs[i] for i in b) for b in bins]
    assert max(loads) <= 14   # LPT bound ≤ 4/3 OPT (OPT=12)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 24), st.integers(0, 60), st.data())
def test_wave_schedule_random_dags(n, extra_edges, data):
    """Property: wave_schedule is valid for arbitrary DAGs + conflicts."""
    g = TaskGraph()
    ids = [g.add_task(f"k{i % 3}", resources=(i % 5,), writes=(i % 5,),
                      cost=1 + (i % 4)) for i in range(n)]
    for _ in range(extra_edges):
        a = data.draw(st.integers(0, n - 1))
        b = data.draw(st.integers(0, n - 1))
        if a < b:
            g.add_dependency(ids[b], ids[a])   # edges forward only: acyclic
    g.auto_conflicts()
    waves = wave_schedule(g)
    g.validate_schedule(waves)
    assert sum(len(w) for w in waves) == n
