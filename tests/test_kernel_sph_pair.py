"""Pallas sph_pair kernels vs pure-jnp oracle: shape/dtype sweeps."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.sph_pair.kernel import (density_pair_pallas,
                                           force_pair_pallas)
from repro.kernels.sph_pair.ref import density_pair_ref, force_pair_ref
from repro.sph import SPHConfig, uniform_ic
from repro.sph.cellgrid import (PairList, bin_particles, build_pair_list,
                                choose_grid)
from repro.sph.engine import _density_pass, _force_pass
from repro.sph.physics import ghost_update


def make_pair_inputs(P, C, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    def arr(*s):
        return jnp.asarray(rng.random(s).astype(np.float32), dtype=dtype)
    pos_i = arr(P, C, 3)
    pos_j = arr(P, C, 3) + 0.1
    h = 0.3 + 0.2 * rng.random((P, C)).astype(np.float32)
    h_i = jnp.asarray(h, dtype)
    h_j = jnp.asarray(np.roll(h, 1, 0), dtype)
    m = jnp.asarray((rng.random((P, C)) + 0.5).astype(np.float32), dtype)
    mask_i = jnp.asarray((rng.random((P, C)) > 0.2).astype(np.float32), dtype)
    mask_j = jnp.asarray((rng.random((P, C)) > 0.2).astype(np.float32), dtype)
    return pos_i, h_i, m, mask_i, pos_j, h_j, m, mask_j


@pytest.mark.parametrize("P,C", [(1, 8), (3, 16), (7, 24), (2, 64)])
@pytest.mark.parametrize("kernel", ["cubic", "wendland_c2"])
def test_density_kernel_matches_ref(P, C, kernel):
    args = make_pair_inputs(P, C, seed=P * 131 + C)
    got = density_pair_pallas(*args, kernel=kernel, interpret=True)
    want = density_pair_ref(*args, kernel=kernel)
    names = ["rho_i", "drho_i", "nngb_i", "rho_j", "drho_j", "nngb_j"]
    for n, g, w in zip(names, got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=2e-5,
            atol=2e-5 * max(float(jnp.abs(w).max()), 1.0), err_msg=n)


def _force_inputs(P, C, seed):
    rng = np.random.default_rng(seed)
    f = lambda *s: jnp.asarray(rng.random(s).astype(np.float32))
    pos_i, pos_j = f(P, C, 3), f(P, C, 3) + 0.05
    vel_i, vel_j = f(P, C, 3) - 0.5, f(P, C, 3) - 0.5
    h_i = 0.3 + 0.2 * f(P, C)
    h_j = 0.3 + 0.2 * f(P, C)
    rho_i, rho_j = 1.0 + f(P, C), 1.0 + f(P, C)
    P_i, P_j = 0.5 + f(P, C), 0.5 + f(P, C)
    om_i, om_j = 0.9 + 0.2 * f(P, C), 0.9 + 0.2 * f(P, C)
    cs_i, cs_j = 1.0 + f(P, C), 1.0 + f(P, C)
    m_i, m_j = 0.5 + f(P, C), 0.5 + f(P, C)
    mask_i = (f(P, C) > 0.2).astype(jnp.float32)
    mask_j = (f(P, C) > 0.2).astype(jnp.float32)
    return (pos_i, vel_i, h_i, P_i, rho_i, om_i, cs_i, m_i, mask_i,
            pos_j, vel_j, h_j, P_j, rho_j, om_j, cs_j, m_j, mask_j)


@pytest.mark.parametrize("P,C", [(2, 8), (4, 16), (3, 32)])
@pytest.mark.parametrize("alpha", [0.0, 0.8])
def test_force_kernel_matches_ref(P, C, alpha):
    args = _force_inputs(P, C, seed=P * 7 + C)
    got = force_pair_pallas(*args, kernel="cubic", alpha_visc=alpha,
                            interpret=True)
    want = force_pair_ref(*args, kernel="cubic", alpha_visc=alpha)
    mask_i = np.asarray(args[8]) > 0
    mask_j = np.asarray(args[17]) > 0
    names = ["dv_i", "du_i", "dv_j", "du_j"]
    masks = [mask_i, mask_i, mask_j, mask_j]
    for n, g, w, mk in zip(names, got, want, masks):
        g, w = np.asarray(g), np.asarray(w)
        if g.ndim == 3:
            mk = mk[..., None]
        scale = max(np.abs(w[np.broadcast_to(mk, w.shape)]).max(), 1.0)
        np.testing.assert_allclose(
            np.where(mk, g, 0), np.where(mk, w, 0),
            rtol=5e-5, atol=5e-5 * scale, err_msg=n)


def test_kernel_symmetric_pair_momentum():
    """Σ m_i dv_i + Σ m_j dv_j = 0 for a symmetric pair (paper: exploiting
    the pairwise symmetry keeps Newton's third law exact).

    The sums are accumulated in float64 so the assertion measures the
    *kernel outputs'* antisymmetry (whose floor is the f32 rounding of each
    dv entry), not the test reduction's own f32 summation noise.
    """
    args = _force_inputs(2, 16, seed=9)
    dv_i, du_i, dv_j, du_j = force_pair_pallas(*args, kernel="cubic",
                                               alpha_visc=0.8,
                                               interpret=True)
    m_i, mask_i = args[7], args[8]
    m_j, mask_j = args[16], args[17]
    w_i = np.asarray(m_i * mask_i, dtype=np.float64)
    w_j = np.asarray(m_j * mask_j, dtype=np.float64)
    p_i = (w_i[..., None] * np.asarray(dv_i, dtype=np.float64)).sum((0, 1))
    p_j = (w_j[..., None] * np.asarray(dv_j, dtype=np.float64)).sum((0, 1))
    np.testing.assert_allclose(p_i + p_j, 0.0, atol=1e-4)


def test_pallas_matches_vmap_on_padded_masked_pair_list():
    """The time-bin engine's level-restricted pair lists are padded to
    power-of-two lengths with ``pair_mask`` zeroing the padding; the Pallas
    wave execution must agree with the vmapped reference under that
    masking (over real particle slots — the kernel additionally zeroes
    padded receiver slots that the engine masks afterwards)."""
    ic = uniform_ic(6, seed=0)
    rng = np.random.default_rng(3)
    ic["vel"] = (0.1 * rng.standard_normal(ic["vel"].shape)).astype(
        np.float32)
    spec = choose_grid(ic["box"], float(ic["h"].max()), len(ic["pos"]))
    cells, _ = bin_particles(spec, ic["pos"], ic["vel"], ic["mass"],
                             ic["u"], ic["h"])
    pairs = build_pair_list(spec)

    # level-restricted subset: pairs touching the first half of the cells,
    # padded to the next power of two (exactly _pair_subset's layout)
    ci = np.asarray(pairs.ci)
    cj = np.asarray(pairs.cj)
    active = np.zeros(spec.ncells, bool)
    active[: spec.ncells // 2] = True
    idx = np.nonzero(active[ci] | active[cj])[0]
    npad = 1
    while npad < len(idx):
        npad *= 2
    idxp = np.concatenate([idx, np.zeros(npad - len(idx), idx.dtype)])
    pmask = np.zeros(npad, np.float32)
    pmask[: len(idx)] = 1.0
    sub = PairList(ci=jnp.asarray(ci[idxp]), cj=jnp.asarray(cj[idxp]),
                   shift=jnp.asarray(np.asarray(pairs.shift)[idxp]))
    pm = jnp.asarray(pmask)

    # consistent thermodynamics from the full pair list (what inactive
    # neighbours expose in the time-bin engine), then both force paths
    # over the masked sublist
    cfg_ref = SPHConfig(alpha_visc=0.8, use_pallas=False)
    rho_full, drho_full, _ = _density_pass(cells, pairs, cfg_ref)
    rho_full = jnp.where(cells.mask > 0, rho_full, 1.0)
    drho_full = jnp.where(cells.mask > 0, drho_full, 0.0)
    press, omega, cs = ghost_update(rho_full, drho_full, cells.u, cells.h)
    press = jnp.where(cells.mask > 0, press, 0.0)

    m = np.asarray(cells.mask)
    got = {}
    for use_pallas in (False, True):
        cfg = SPHConfig(alpha_visc=0.8, use_pallas=use_pallas)
        rho, drho, nngb = _density_pass(cells, sub, cfg, pair_mask=pm)
        dv, du = _force_pass(cells, sub, rho_full, press, omega, cs, cfg,
                             pair_mask=pm)
        got[use_pallas] = {
            "rho": np.asarray(rho) * m, "drho": np.asarray(drho) * m,
            "nngb": np.asarray(nngb) * m,
            "dv": np.asarray(dv) * m[..., None], "du": np.asarray(du) * m}
    for name in got[False]:
        a, b = got[False][name], got[True][name]
        scale = max(np.abs(a).max(), 1.0)
        np.testing.assert_allclose(a, b, atol=5e-5 * scale, rtol=5e-5,
                                   err_msg=name)
    # masking is real: padded entries contribute nothing
    sub1 = PairList(ci=sub.ci[: len(idx)], cj=sub.cj[: len(idx)],
                    shift=sub.shift[: len(idx)])
    rho_nopad, _, _ = _density_pass(cells, sub1, cfg_ref)
    np.testing.assert_allclose(got[False]["rho"], np.asarray(rho_nopad) * m,
                               atol=1e-6)
