"""Fault-tolerant loop: crash injection, restore, bit-exact resume."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.train import (AdamConfig, Checkpointer, DataConfig,
                         FaultTolerantLoop, LoopConfig, TokenStream,
                         TrainConfig, init_train_state, make_train_step)


def make_setup(tmp_path, total_steps=12, name="ckpt"):
    cfg = dataclasses.replace(get_config("granite-8b", reduced=True),
                              dtype=jnp.float32, n_layers=2, d_model=32,
                              d_ff=64, n_heads=2, n_kv=2, head_dim=16,
                              vocab=128)
    tcfg = TrainConfig(adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                       total_steps=total_steps))
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    stream = TokenStream(DataConfig(vocab=cfg.vocab, seq=32, batch=4))
    ck = Checkpointer(str(tmp_path / name), keep=5, async_save=False)
    return step_fn, params, opt, stream, ck


def run_loop(tmp_path, name, fault_hook=None, total=12):
    step_fn, params, opt, stream, ck = make_setup(tmp_path, total, name)
    loop = FaultTolerantLoop(
        train_step=step_fn, params=params, opt_state=opt, stream=stream,
        ckpt=ck, loop_cfg=LoopConfig(total_steps=total, checkpoint_every=4,
                                     log_every=1),
        fault_hook=fault_hook)
    result = loop.run()
    return loop, result


def test_clean_run_loss_decreases(tmp_path):
    loop, result = run_loop(tmp_path, "clean")
    assert result["final_step"] == 12
    losses = [m["loss"] for m in result["log"]]
    assert losses[-1] < losses[0]


def test_crash_recovery_bit_exact(tmp_path):
    """A crash at step 6 must restore from the step-4 checkpoint and end
    with exactly the same weights as an uninterrupted run (replayable data
    + deterministic step)."""
    _, clean = run_loop(tmp_path, "a")
    loop_clean, _ = run_loop(tmp_path, "a2")

    crashed = {"done": False}

    def hook(step):
        if step == 6 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    loop_faulty, result = run_loop(tmp_path, "b", fault_hook=hook)
    assert result["restores"] == 1
    assert result["final_step"] == 12
    for a, b in zip(jax.tree.leaves(loop_clean.params),
                    jax.tree.leaves(loop_faulty.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_repeated_crash_eventually_raises(tmp_path):
    def hook(step):
        raise RuntimeError("permanently broken")

    with pytest.raises(RuntimeError):
        run_loop(tmp_path, "c", fault_hook=hook)


def test_nan_guard_restores(tmp_path):
    """A NaN loss triggers restore instead of committing poisoned state."""
    step_fn, params, opt, stream, ck = make_setup(tmp_path, 8, "nan")
    calls = {"n": 0}

    def poisoned_step(params, opt_state, batch):
        calls["n"] += 1
        p2, o2, m = step_fn(params, opt_state, batch)
        if calls["n"] == 3:
            m = dict(m)
            m["loss"] = jnp.asarray(float("nan"))
        return p2, o2, m

    loop = FaultTolerantLoop(
        train_step=poisoned_step, params=params, opt_state=opt,
        stream=stream, ckpt=ck,
        loop_cfg=LoopConfig(total_steps=8, checkpoint_every=2, log_every=1))
    result = loop.run()
    assert result["final_step"] == 8
    assert result["restores"] == 1


def test_resume_from_checkpoint_after_shutdown(tmp_path):
    """Loop killed at step 8 (simulated by a fresh loop over the same ckpt
    dir) resumes at the last checkpoint, not from scratch."""
    step_fn, params, opt, stream, ck = make_setup(tmp_path, 8, "resume")
    loop1 = FaultTolerantLoop(train_step=step_fn, params=params,
                              opt_state=opt, stream=stream, ckpt=ck,
                              loop_cfg=LoopConfig(total_steps=8,
                                                  checkpoint_every=4,
                                                  log_every=1))
    loop1.run()
    # new process: same dir, higher target
    step_fn2, params2, opt2, stream2, _ = make_setup(tmp_path, 16, "unused")
    ck2 = Checkpointer(str(tmp_path / "resume"), keep=5, async_save=False)
    loop2 = FaultTolerantLoop(train_step=step_fn2, params=params2,
                              opt_state=opt2, stream=stream2, ckpt=ck2,
                              loop_cfg=LoopConfig(total_steps=16,
                                                  checkpoint_every=4,
                                                  log_every=1))
    result = loop2.run()
    assert result["final_step"] == 16
    # resumed (restored step-8 checkpoint), so first logged step is ≥ 9
    assert result["log"][0]["step"] >= 9
