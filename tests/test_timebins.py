"""Hierarchical time-bin integration: bin math, KDK ladder, activity-aware
scheduling, and conservation against the global-dt engine."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (AsyncExecutorSim, CostModel, cell_activation_frequency,
                        decompose_cells, timebin_frequency,
                        timebin_node_weights, wave_schedule)
from repro.sph import (SPHConfig, Simulation, TimeBinSimulation, active_level,
                       assign_bins, bin_timestep, sedov_ic, uniform_ic)
from repro.sph.cellgrid import bin_particles, build_pair_list, choose_grid
from repro.sph.engine import build_taskgraph, cfl_timestep_particles, \
    init_state, step
from repro.sph.timebins import cell_bin_histogram, cell_max_bins, \
    limit_neighbour_bins


# ----------------------------------------------------------------- bin math
def test_bin_assignment_roundtrips_cfl_dt():
    """dt_bin = dt_max/2**b obeys dt/2 < dt_bin ≤ dt (never overshoots the
    CFL step, never wastes more than a factor 2)."""
    rng = np.random.default_rng(0)
    dt_max = 0.8
    dt = dt_max * 10 ** (-3 * rng.random(512))       # 3 decades
    b = assign_bins(dt, dt_max, max_bin=32)
    dt_b = bin_timestep(dt_max, b)
    assert (dt_b <= dt * (1 + 1e-5)).all()
    assert (dt_b > dt / 2 * (1 - 1e-5)).all()


def test_bin_assignment_exact_powers():
    dt_max = 1.0
    dts = np.array([1.0, 0.5, 0.25, 0.125, 2.0], np.float32)
    b = assign_bins(dts, dt_max, max_bin=10)
    assert list(b) == [0, 1, 2, 3, 0]


def test_bin_assignment_clips_and_handles_inf():
    b = assign_bins(np.array([np.inf, 1e-12, 0.3]), 1.0, max_bin=4)
    assert list(b) == [0, 4, 2]


def test_active_level_ladder():
    depth = 3
    levels = [active_level(n, depth) for n in range(8)]
    # n=0 starts everything; odd sub-steps only the deepest bin
    assert levels == [0, 3, 2, 3, 1, 3, 2, 3]
    # bin b fires at multiples of 2**(depth-b): count activations per cycle
    for b in range(depth + 1):
        fires = sum(1 for n in range(1, 2 ** depth + 1)
                    if b >= active_level(n, depth))
        assert fires == 2 ** b


def test_neighbour_limiter_propagates():
    # 4 cells in a row (pairs chain), one deep cell: floor decays by delta
    # per hop
    bins = np.array([[6], [0], [0], [0]], np.int32)
    mask = np.ones((4, 1), np.float32)
    ci = np.array([0, 1, 2])
    cj = np.array([1, 2, 3])
    out = limit_neighbour_bins(bins, mask, ci, cj, delta=2, max_bin=6)
    assert list(out[:, 0]) == [6, 4, 2, 0]


# ---------------------------------------------------- cost model / partition
def test_timebin_frequency_and_node_weights():
    assert timebin_frequency(3, 3) == 1.0
    assert timebin_frequency(0, 3) == 0.125
    assert cell_activation_frequency([0, 0], 3) == 0.0
    assert cell_activation_frequency([5, 1], 3) == 0.25
    occ = np.array([[4, 0, 0, 4],      # 4 slow + 4 fastest
                    [8, 0, 0, 0]])     # all slow
    w = timebin_node_weights(occ)
    assert w[0] == pytest.approx(4 * 0.125 + 4 * 1.0)
    assert w[1] == pytest.approx(8 * 0.125)


def test_timebin_units_scale_with_activity():
    cm = CostModel(rates={})
    # all particles in the deepest bin: same as plain units
    full = cm.timebin_units("force_self", [0, 0, 8], max_bin=2)
    assert full == pytest.approx(cm.units("force_self", 8))
    # all particles in bin 0 of a depth-2 hierarchy: 4× cheaper
    idle = cm.timebin_units("force_self", [8, 0, 0], max_bin=2)
    assert idle == pytest.approx(full / 4)
    # pair tasks fire at the max of the two cells' frequencies
    pair_fast = cm.timebin_units("force_pair", [8, 0, 0], [0, 0, 8],
                                 max_bin=2)
    assert pair_fast == pytest.approx(cm.units("force_pair", 8, 8))
    # per-particle tasks: each bin pays at its own cadence
    kick = cm.timebin_units("kick", [4, 0, 4], max_bin=2)
    assert kick == pytest.approx(4 * 0.25 + 4 * 1.0)


def test_decompose_balances_time_averaged_work():
    ic = sedov_ic(8, e0=1.0, seed=0)
    spec = choose_grid(ic["box"], float(ic["h"].max()), len(ic["pos"]))
    cells, _ = bin_particles(spec, ic["pos"], ic["vel"], ic["mass"],
                             ic["u"], ic["h"])
    pairs = build_pair_list(spec)
    occ = (np.asarray(cells.mask) > 0).sum(axis=1)
    # synthetic bins: one hot cell (deepest), mild 2**2 contrast so the
    # partitioner can still balance 27 cells over 4 ranks
    bins = np.zeros(cells.mass.shape, np.int32)
    bins[0] = 2
    cb = cell_max_bins(bins, np.asarray(cells.mask))
    obb = cell_bin_histogram(bins, np.asarray(cells.mask), 3)
    g = build_taskgraph(spec, pairs, occ, CostModel(rates={}),
                        cell_bins=cb, occupancy_by_bin=obb,
                        time_average=True)
    dec = decompose_cells(g, spec.ncells, 4,
                          node_weights=timebin_node_weights(obb))
    assert dec.assignment.shape == (spec.ncells,)
    assert len(np.unique(dec.assignment)) > 1
    # the graph's time-averaged costs must weight the hot cell far above a
    # cold one with the same occupancy
    node_w, _ = g.cell_graph()
    cold = [c for c in range(1, spec.ncells) if occ[c] == occ[0]]
    if cold:
        assert node_w[0] > 2 * node_w[cold[0]]


# ------------------------------------------------- activity-aware scheduling
def _bins_graph(level):
    ic = uniform_ic(6, seed=0)
    spec = choose_grid(ic["box"], float(ic["h"].max()), len(ic["pos"]))
    cells, _ = bin_particles(spec, ic["pos"], ic["vel"], ic["mass"],
                             ic["u"], ic["h"])
    pairs = build_pair_list(spec)
    occ = (np.asarray(cells.mask) > 0).sum(axis=1)
    bins = np.zeros(cells.mass.shape, np.int32)
    bins[:2] = 4                      # two deep cells, rest at bin 0
    cb = cell_max_bins(bins, np.asarray(cells.mask))
    g = build_taskgraph(spec, pairs, occ, CostModel(rates={}),
                        cell_bins=cb, level=level)
    return g, spec


def test_wave_schedule_skips_inactive_tasks():
    g, spec = _bins_graph(level=2)
    active = g.active_tasks()
    assert 0 < len(active) < len(g.tasks)       # genuinely partial
    waves = wave_schedule(g, active_only=True)
    scheduled = {tid for w in waves for tid in w}
    assert scheduled == set(active)             # every active task, nothing else
    full = {tid for w in wave_schedule(g) for tid in w}
    assert scheduled < full
    # pair tasks touching an active cell are active even if the partner
    # cell is idle (the idle neighbour feeds the active cell's sums)
    for t in g.tasks.values():
        if t.kind == "density_pair":
            cells_active = [bool(c < 2) for c in t.resources]
            assert t.active == any(cells_active)


def test_wave_schedule_level0_activates_everything():
    g, _ = _bins_graph(level=0)
    waves = wave_schedule(g, active_only=True)
    assert {tid for w in waves for tid in w} == set(g.tasks)


def test_async_sim_skips_inactive_tasks():
    g, _ = _bins_graph(level=2)
    for t in g.tasks.values():
        object.__setattr__(t, "rank", 0)
    r_active = AsyncExecutorSim(g, ranks=1, threads=2,
                                active_only=True).run()
    r_full = AsyncExecutorSim(g, ranks=1, threads=2).run()
    assert r_active.makespan < r_full.makespan


# ------------------------------------------------------------ KDK ladder
def _ic_two_temperature(n_side=6, ratio=16.0, seed=0, hot_ball=False):
    """Hot region (u × ratio) → two CFL bins, cs ratio = sqrt(ratio).

    ``hot_ball`` localises the hot gas so that (on a fine enough cell
    grid) distant cold cells sit outside the hot region's signal-velocity
    stencil and genuinely keep long steps.
    """
    ic = uniform_ic(n_side, seed=seed, temperature=0.5)
    if hot_ball:
        d = ic["pos"] - 0.75 * ic["box"]
        d -= ic["box"] * np.round(d / ic["box"])
        hot = np.linalg.norm(d, axis=1) < 0.15 * ic["box"]
    else:
        hot = ic["pos"][:, 0] > ic["box"] / 2
    u = ic["u"].copy()
    u[hot] *= ratio
    ic["u"] = u
    rng = np.random.default_rng(seed + 1)
    ic["vel"] = (0.02 * rng.standard_normal(ic["vel"].shape)
                 ).astype(np.float32)
    return ic


def test_depth_zero_cycle_matches_global_engine():
    """With every particle in bin 0 the ladder is exactly one KDK step."""
    ic = _ic_two_temperature()
    cfg = SPHConfig(alpha_visc=0.8)
    dt = 1e-3
    tb = TimeBinSimulation(ic["pos"], ic["vel"], ic["mass"], ic["u"],
                           ic["h"], box=ic["box"], cfg=cfg, dt_max=dt,
                           depth_headroom=0, rebin_each_cycle=False)
    gl = Simulation(ic["pos"], ic["vel"], ic["mass"], ic["u"], ic["h"],
                    box=ic["box"], cfg=cfg, rebin_every=10 ** 9)
    stats = tb.run_cycle()
    assert stats["depth"] == 0 and stats["substeps"] == 1
    gl.run(1, dt=dt)
    m = np.asarray(tb.state.cells.mask) > 0
    np.testing.assert_allclose(
        np.asarray(tb.state.cells.pos)[m], np.asarray(gl.state.cells.pos)[m],
        atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(tb.state.cells.vel)[m], np.asarray(gl.state.cells.vel)[m],
        atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(tb.state.cells.u)[m], np.asarray(gl.state.cells.u)[m],
        rtol=1e-5)


def test_drift_only_prediction_is_second_order():
    """An inactive particle's drifted position differs from full KDK
    integration by the O(dt²) acceleration term only."""
    ic = _ic_two_temperature()
    cfg = SPHConfig(alpha_visc=0.0)
    spec = choose_grid(ic["box"], float(ic["h"].max()), len(ic["pos"]))
    cells, _ = bin_particles(spec, ic["pos"], ic["vel"], ic["mass"],
                             ic["u"], ic["h"])
    pairs = build_pair_list(spec)
    state = init_state(cells, pairs, cfg)
    for dt in (2e-3, 1e-3):
        full = step(state, pairs, jnp.float32(dt), ic["box"], cfg)
        drifted = np.mod(np.asarray(cells.pos)
                         + dt * np.asarray(cells.vel)
                         * np.asarray(cells.mask)[..., None], ic["box"])
        m = np.asarray(cells.mask) > 0
        err = np.abs(np.asarray(full.cells.pos)[m] - drifted[m])
        err = np.minimum(err, ic["box"] - err)       # periodic
        bound = 0.5 * dt * dt * np.abs(np.asarray(state.accel)[m])
        # 0.5·a·dt² is the *exact* gap for one KDK step (x gains ½ a dt²
        # through the half-kicked velocity); allow rounding slack
        assert err.max() <= bound.max() * 1.5 + 1e-7
        assert err.max() <= 10.0 * dt * dt           # O(dt²) scaling


@pytest.mark.slow
def test_two_bin_system_conserves_like_global():
    """A two-temperature gas lands in ≥2 occupied bins; energy drift must
    stay within 2× of the global-dt engine over the same span, and the
    momentum drift (multi-dt breaks exact pair symmetry — the global
    engine conserves to machine precision by construction) must be
    negligible against the system's momentum scale."""
    ic = _ic_two_temperature(n_side=10, ratio=16.0, hot_ball=True)
    cfg = SPHConfig(alpha_visc=0.8)
    tb = TimeBinSimulation(ic["pos"], ic["vel"], ic["mass"], ic["u"],
                           ic["h"], box=ic["box"], cfg=cfg, max_depth=4)
    e0t, p0t = tb.diagnostics()
    stats = [tb.run_cycle() for _ in range(2)]
    e1t, p1t = tb.diagnostics()
    assert all(np.count_nonzero(s["bin_hist"]) >= 2 for s in stats)
    span = float(tb.state.time)
    # fewer updates than the dt_min-equivalent lock-step ladder
    assert tb.particle_updates < 0.5 * tb.global_equiv_updates

    gl = Simulation(ic["pos"], ic["vel"], ic["mass"], ic["u"], ic["h"],
                    box=ic["box"], cfg=cfg, rebin_every=4)
    e0g, p0g = gl.diagnostics()
    while float(gl.state.time) < span:
        gl.run(1)
    e1g, p1g = gl.diagnostics()

    drift_t = abs(e1t - e0t) / abs(e0t)
    drift_g = abs(e1g - e0g) / abs(e0g)
    assert drift_t <= 2.0 * drift_g + 1e-4
    c = tb.state.cells
    p_scale = float(np.abs(np.asarray(c.mass * c.mask)[..., None]
                           * np.asarray(c.vel)).sum())
    assert np.abs(p1t - p0t).max() <= 1e-4 * max(p_scale, 1e-3)


@pytest.mark.slow
def test_multi_dt_does_less_work_on_sedov():
    """Acceptance: measurably fewer particle updates on the blast, with
    energy drift within 2× of global-dt for the same simulated span."""
    ic = sedov_ic(12, e0=1.0, seed=0)
    cfg = SPHConfig(alpha_visc=1.0, cfl=0.15)
    tb = TimeBinSimulation(ic["pos"], ic["vel"], ic["mass"], ic["u"],
                           ic["h"], box=ic["box"], cfg=cfg, dt_max=0.02,
                           max_depth=8)
    e0t, _ = tb.diagnostics()
    for _ in range(2):
        tb.run_cycle()
    e1t, _ = tb.diagnostics()
    span = float(tb.state.time)
    assert np.isfinite(e1t)
    assert tb.particle_updates < 0.5 * tb.global_equiv_updates

    gl = Simulation(ic["pos"], ic["vel"], ic["mass"], ic["u"], ic["h"],
                    box=ic["box"], cfg=cfg, rebin_every=4)
    e0g, _ = gl.diagnostics()
    steps = 0
    while float(gl.state.time) < span:
        gl.run(1)
        steps += 1
    e1g, _ = gl.diagnostics()
    # fewer updates than the global engine actually performed
    assert tb.particle_updates < steps * len(ic["pos"])
    drift_t = abs(e1t - e0t) / abs(e0t)
    drift_g = abs(e1g - e0g) / abs(e0g)
    assert drift_t <= 2.0 * drift_g + 1e-3


def test_per_particle_cfl_min_matches_global():
    ic = _ic_two_temperature()
    cfg = SPHConfig()
    spec = choose_grid(ic["box"], float(ic["h"].max()), len(ic["pos"]))
    cells, _ = bin_particles(spec, ic["pos"], ic["vel"], ic["mass"],
                             ic["u"], ic["h"])
    pairs = build_pair_list(spec)
    state = init_state(cells, pairs, cfg)
    from repro.sph.engine import cfl_timestep
    dts = np.asarray(cfl_timestep_particles(state, cfg))
    m = np.asarray(cells.mask) > 0
    assert float(dts[m].min()) == pytest.approx(
        float(cfl_timestep(state, cfg)), rel=1e-6)
    assert np.isinf(dts[~m]).all()
