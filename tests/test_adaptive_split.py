"""Recursive cell splitting (§3.1) properties."""

import numpy as np
import pytest

from repro.sph.adaptive import LeafCell, refined_cell_graph, split_cells


def clustered_positions(n=2000, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(0.3, 0.02, (n // 2, 3))
    b = rng.random((n - n // 2, 3))
    return np.clip(np.concatenate([a, b]), 0, 0.999)


def test_split_conserves_particles():
    pos = clustered_positions()
    leaves = split_cells(pos, 1.0, 4, threshold=32, max_levels=4)
    assert sum(l.occupancy for l in leaves) == len(pos)


def test_split_respects_threshold_or_level_cap():
    pos = clustered_positions()
    leaves = split_cells(pos, 1.0, 4, threshold=32, max_levels=4)
    for l in leaves:
        assert l.occupancy <= 32 or l.level == 4


def test_no_split_when_uniform():
    rng = np.random.default_rng(1)
    pos = rng.random((128, 3))
    leaves = split_cells(pos, 1.0, 4, threshold=64, max_levels=3)
    # 64 base cells, ~2 particles each: nothing splits
    assert all(l.level == 0 for l in leaves)


def test_refined_graph_weights_positive_and_bounded():
    pos = clustered_positions()
    node_w, edges, leaves = refined_cell_graph(pos, 1.0, 4, threshold=32,
                                               max_levels=4, n_ngb=16.0)
    assert (node_w > 0).all()
    occ = np.array([l.occupancy for l in leaves])
    # adaptive-h cost: no node may exceed 2·n_ngb·occ + 3·occ
    assert (node_w <= 2 * 16.0 * occ + 3 * occ + 1e-9).all()
    # edges reference valid leaves and are symmetric-by-construction keys
    for (a, b), w in edges.items():
        assert 0 <= a < b < len(leaves)
        assert w > 0


def test_adjacency_includes_mixed_levels_and_periodic():
    # two particles in opposite corners: periodic neighbours
    pos = np.array([[0.01, 0.01, 0.01], [0.99, 0.99, 0.99]])
    node_w, edges, leaves = refined_cell_graph(pos, 1.0, 4, threshold=64,
                                               max_levels=2)
    assert len(leaves) == 2
    assert (0, 1) in edges     # corner-touching across the periodic wrap


def test_splitting_reduces_max_node_weight():
    pos = clustered_positions()
    w0, _, l0 = refined_cell_graph(pos, 1.0, 4, threshold=10 ** 9,
                                   max_levels=0)
    w1, _, l1 = refined_cell_graph(pos, 1.0, 4, threshold=32, max_levels=4)
    assert w1.max() < w0.max()
    assert len(l1) > len(l0)
