"""Multi-device correctness, run in subprocesses with 8 fake CPU devices.

The main test process keeps the single real device (conftest rule); each
case here launches an isolated interpreter with
``--xla_force_host_platform_device_count=8`` and asserts inside it.
"""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_subprocess(body: str, timeout=900):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, os.path.join(%r, "src"))
        import numpy as np
        import jax, jax.numpy as jnp
        jax.config.update("jax_default_matmul_precision", "float32")
        assert len(jax.devices()) == 8

        def make_mesh(shape, axes):
            # jax >= 0.5 wants explicit Auto axis types; 0.4 has no kwarg
            try:
                return jax.make_mesh(
                    shape, axes,
                    axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
            except (AttributeError, TypeError):
                return jax.make_mesh(shape, axes)
    """ % os.path.abspath(ROOT)) + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, \
        f"subprocess failed:\nSTDOUT:{proc.stdout}\nSTDERR:{proc.stderr[-3000:]}"
    return proc.stdout


@pytest.mark.slow
def test_overlap_collectives_equivalence():
    run_subprocess("""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.distributed import allgather_matmul, matmul_reducescatter
        mesh = make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((32, 48)).astype(np.float32))
        want = x @ w
        got = jax.jit(lambda x, w: allgather_matmul(x, w, mesh))(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        got2 = jax.jit(lambda x, w: matmul_reducescatter(x, w, mesh))(x, w)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("overlap OK")
    """)


@pytest.mark.slow
def test_sp_halo_attention_equivalence():
    run_subprocess("""
        from repro.distributed import (full_window_attention_ref,
                                       sp_local_attention)
        mesh = make_mesh((8,), ("model",))
        rng = np.random.default_rng(1)
        B, S, H, hd, W = 2, 128, 4, 16, 16
        q = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
        want = full_window_attention_ref(q, k, v, window=W)
        got = jax.jit(lambda q, k, v: sp_local_attention(
            q, k, v, mesh, window=W))(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        print("halo OK")
    """)


@pytest.mark.slow
def test_distributed_sph_matches_host_engine():
    run_subprocess("""
        from repro.sph import uniform_ic
        from repro.sph.cellgrid import (bin_particles, build_pair_list,
                                        choose_grid)
        from repro.sph.engine import SPHConfig, init_state, step as hstep
        from repro.sph.distributed import DistSimulation

        ic = uniform_ic(8, seed=0)
        rng = np.random.default_rng(1)
        ic["vel"] = (ic["vel"] + 0.1 * rng.standard_normal(ic["vel"].shape)
                     ).astype(np.float32)
        spec = choose_grid(ic["box"], float(ic["h"].max()), len(ic["pos"]))
        cells, perm = bin_particles(spec, ic["pos"], ic["vel"], ic["mass"],
                                    ic["u"], ic["h"])
        pairs = build_pair_list(spec)
        cfg = SPHConfig(alpha_visc=0.8)
        st = init_state(cells, pairs, cfg)
        for _ in range(2):
            st = hstep(st, pairs, jnp.float32(0.002), ic["box"], cfg)
        for halo in ("allgather", "ring"):
            mesh = make_mesh((8,), ("data",))
            ds = DistSimulation(cells, pairs, spec, mesh, cfg=cfg, halo=halo)
            for _ in range(2):
                ds.step(0.002)
            got = ds.gather_cells()
            m = np.asarray(cells.mask) > 0
            for name in ("pos", "vel", "u"):
                a = np.asarray(getattr(st.cells, name))
                b = np.asarray(getattr(got, name))
                assert np.abs(a - b)[m].max() < 5e-4, (halo, name)
        print("sph dist OK")
    """)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """pjit'd train step on a (4,2) mesh == unsharded step."""
    run_subprocess("""
        import dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.distributed import ShardingRules
        from repro.train import (AdamConfig, TrainConfig, init_train_state,
                                 make_train_step)
        cfg = dataclasses.replace(
            get_config("granite-8b", reduced=True), dtype=jnp.float32,
            n_layers=2, d_model=32, d_ff=64, n_heads=4, n_kv=2, head_dim=8,
            vocab=128)
        tcfg = TrainConfig(adam=AdamConfig(lr=1e-3))
        params, opt = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                         cfg.vocab),
            "targets": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                          cfg.vocab)}
        ref_step = jax.jit(make_train_step(cfg, tcfg))
        p_ref, o_ref, m_ref = ref_step(params, opt, batch)

        mesh = make_mesh((4, 2), ("data", "model"))
        rules = ShardingRules(mesh, cfg, "train")
        psh = rules.params_sharding(params)
        params_s = jax.tree.map(jax.device_put, params, psh)
        step = jax.jit(make_train_step(cfg, tcfg, rules))
        with mesh:
            p_new, o_new, m_new = step(params_s, opt, batch)
        assert abs(float(m_new["loss"]) - float(m_ref["loss"])) < 1e-4
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_new)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-4)
        print("sharded train OK")
    """)
