"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs (assignment requirement)."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, SHAPES, applicable, get_config
from repro.models import forward, init_params, lm_loss

B, S = 2, 64


def _inputs(cfg, key):
    kwargs = {}
    if cfg.is_encdec:
        kwargs["enc_inputs"] = jax.random.normal(
            key, (B, 32, cfg.d_model)) * 0.1
    if cfg.vlm_patches:
        kwargs["patch_embeds"] = jax.random.normal(
            key, (B, cfg.vlm_patches, cfg.d_model)) * 0.1
    return kwargs


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_forward_and_grad(arch):
    cfg = dataclasses.replace(get_config(arch, reduced=True),
                              dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kwargs = _inputs(cfg, key)

    res = forward(params, cfg, tokens, mode="train", **kwargs)
    exp_seq = S + (cfg.vlm_patches or 0)
    assert res.logits.shape == (B, exp_seq, cfg.vocab_padded)
    assert not bool(jnp.isnan(res.logits).any())

    (loss, _), grads = jax.value_and_grad(lm_loss, has_aux=True)(
        params, cfg, tokens, tokens, **kwargs)
    assert np.isfinite(float(loss))
    assert not any(bool(jnp.isnan(g).any()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_consistency(arch):
    """Full (production) configs are structurally sound without allocation."""
    cfg = get_config(arch)
    n = cfg.n_params()
    assert n > 1e8, f"{arch}: implausibly small param count {n:.3g}"
    assert cfg.n_active_params() <= n
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(np.prod(l.shape) for l in jax.tree.leaves(shapes))
    # abstract param count within 25% of the analytic formula (the analytic
    # count folds LoRA/norm/etc. approximations)
    assert abs(total - n) / n < 0.25, (arch, total, n)


def test_applicability_matrix():
    """40 cells: every cell either runs or has a documented skip."""
    cells = [(a, s) for a in ARCH_NAMES for s in SHAPES]
    assert len(cells) == 40
    skipped = [(a, s) for a, s in cells
               if not applicable(get_config(a), s)[0]]
    # exactly the 5 pure-full-attention archs skip long_500k
    assert len(skipped) == 5
    assert all(s == "long_500k" for _, s in skipped)


def test_param_counts_match_public_scale():
    """Sanity-check full configs against their public parameter counts."""
    expect = {
        "qwen1.5-32b": 32e9, "gemma-7b": 8.5e9, "gemma3-27b": 27e9,
        "granite-8b": 8e9, "mixtral-8x7b": 47e9, "mixtral-8x22b": 141e9,
        "falcon-mamba-7b": 7e9, "seamless-m4t-large-v2": 2.3e9,
        "internvl2-2b": 2e9, "zamba2-1.2b": 1.2e9,
    }
    for arch, target in expect.items():
        n = get_config(arch).n_params()
        assert 0.5 * target < n < 1.9 * target, (arch, n, target)
