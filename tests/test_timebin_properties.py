"""Property-based time-bin invariants (hypothesis).

Randomised bin ladders and wake-up events over small random cell graphs,
asserting the two safety properties the hierarchical integrator leans on:

* the Saitoh–Makino neighbour limiter's fixpoint — after
  ``limit_neighbour_bins``, no two neighbouring cells' deepest occupied
  bins differ by more than ``delta`` (and the limiter only ever deepens);
* wake-up visibility — a particle whose cell wake floor exceeds its bin is
  *always* in the sub-step active mask, and a task graph rebuilt after a
  wake event never drops a task touching the woken cell from the active
  subgraph (the scheduler-side face of the same guarantee).

Skips cleanly when hypothesis is absent (see requirements-dev.txt).
"""

import types

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.sph.engine import build_taskgraph  # noqa: E402
from repro.sph.cellgrid import PairList  # noqa: E402
from repro.sph.timebins import (TimeBinState, limit_neighbour_bins,  # noqa: E402
                                substep_active_mask)
from repro.sph.cellgrid import ParticleCells  # noqa: E402

MAX_BIN = 6


@st.composite
def cell_graphs(draw):
    """A small random cell graph: bins, mask and an undirected pair list."""
    ncells = draw(st.integers(2, 10))
    cap = draw(st.integers(1, 4))
    bins = draw(st.lists(
        st.lists(st.integers(0, MAX_BIN), min_size=cap, max_size=cap),
        min_size=ncells, max_size=ncells))
    mask = draw(st.lists(
        st.lists(st.integers(0, 1), min_size=cap, max_size=cap),
        min_size=ncells, max_size=ncells))
    npairs = draw(st.integers(1, 3 * ncells))
    ci = draw(st.lists(st.integers(0, ncells - 1), min_size=npairs,
                       max_size=npairs))
    cj = draw(st.lists(st.integers(0, ncells - 1), min_size=npairs,
                       max_size=npairs))
    return (np.array(bins, np.int32), np.array(mask, np.float32),
            np.array(ci), np.array(cj))


@given(cell_graphs(), st.integers(1, 3))
@settings(max_examples=50, deadline=None)
def test_limiter_fixpoint_neighbours_within_delta(graph, delta):
    bins, mask, ci, cj = graph
    out = limit_neighbour_bins(bins, mask, ci, cj, delta=delta,
                               max_bin=MAX_BIN)
    # the limiter only deepens, never shallows, and only touches real slots
    assert (out >= bins).all()
    np.testing.assert_array_equal(out[mask == 0], bins[mask == 0])
    assert out.max(initial=0) <= MAX_BIN
    # fixpoint: neighbouring cells' deepest occupied bins differ ≤ delta —
    # and every particle individually respects its neighbourhood's floor
    deep = np.where(mask > 0, out, -10 ** 6).max(axis=1)
    for a, b in zip(ci, cj):
        if deep[a] < -10 ** 5 or deep[b] < -10 ** 5:
            continue                     # an empty cell constrains nothing
        assert abs(deep[a] - deep[b]) <= delta, (a, b, deep[a], deep[b])
        floor = max(deep[a], deep[b]) - delta
        for c in (a, b):
            real = out[c][mask[c] > 0]
            assert (real >= min(max(floor, 0), MAX_BIN)).all()


@given(cell_graphs(), st.integers(0, MAX_BIN), st.integers(0, MAX_BIN))
@settings(max_examples=50, deadline=None)
def test_active_mask_always_contains_woken_particles(graph, level, wake):
    bins, mask, ci, cj = graph
    ncells, cap = bins.shape
    wake_floor = np.full(ncells, wake, np.int32)
    cells = ParticleCells(pos=jnp.zeros((ncells, cap, 3)),
                          vel=jnp.zeros((ncells, cap, 3)),
                          mass=jnp.ones((ncells, cap)),
                          u=jnp.ones((ncells, cap)),
                          h=jnp.ones((ncells, cap)),
                          mask=jnp.asarray(mask))
    state = TimeBinState(cells=cells,
                         accel=jnp.zeros((ncells, cap, 3)),
                         dudt=jnp.zeros((ncells, cap)),
                         rho=jnp.ones((ncells, cap)),
                         omega=jnp.ones((ncells, cap)),
                         bins=jnp.asarray(bins),
                         t_start=jnp.zeros((ncells, cap)),
                         time=jnp.zeros(()))
    active = np.asarray(substep_active_mask(
        state, jnp.int32(level), jnp.asarray(wake_floor)))
    woken = (bins < wake_floor[:, None]) & (mask > 0)
    boundary = (bins >= level) & (mask > 0)
    # every woken or at-boundary real particle is active; padded never
    assert (active[woken] > 0).all()
    assert (active[boundary] > 0).all()
    assert (active[mask == 0] == 0).all()


@given(cell_graphs(), st.integers(1, MAX_BIN), st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_active_subgraph_never_drops_woken_cells(graph, level, delta):
    """A wake-up event (the limiter deepening a cell's bin to ≥ level)
    must surface every task touching that cell in the rebuilt active
    subgraph — no task of a woken cell may be skipped."""
    bins, mask, ci, cj = graph
    ncells = bins.shape[0]
    limited = limit_neighbour_bins(bins, mask, ci, cj, delta=delta,
                                   max_bin=MAX_BIN)
    cell_bins = np.where((mask > 0).any(axis=1),
                         np.where(mask > 0, limited, -1).max(axis=1), -1)
    spec = types.SimpleNamespace(ncells=ncells)
    pairs = PairList(ci=np.array(ci), cj=np.array(cj),
                     shift=np.zeros((len(ci), 3), np.float32))
    occ = (mask > 0).sum(axis=1).astype(np.int64)
    g = build_taskgraph(spec, pairs, occ, cell_bins=cell_bins, level=level)
    sub = g.active_subgraph()
    woken_cells = {c for c in range(ncells)
                   if cell_bins[c] >= level
                   and np.where(mask[c] > 0, bins[c], -1).max(initial=-1)
                   < level}
    for tid, task in g.tasks.items():
        touches_active = any(cell_bins[c] >= level for c in task.resources)
        if any(c in woken_cells for c in task.resources) or touches_active:
            assert task.active, (task.kind, task.resources)
            assert tid in sub.tasks, (task.kind, task.resources)
