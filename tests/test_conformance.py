"""Cross-quadrant conformance harness: every execution path pinned to one
reference.

With four {global, timebin} × {local, distributed} quadrants, two wires
(``transport="host" | "collective"``), two residencies (``residency="host" |
"device"``) and repartitioning rank counts, "the same physics" is a claim
that needs a matrix, not a pair of spot checks. The contract asserted here:

* **time-bin family — bitwise.** Every timebin execution path (local;
  distributed × {host, collective} × {host-resident, device-resident}; 1
  and 4 ranks) reproduces the single-host :class:`TimeBinSimulation`
  trajectory bit-for-bit over ≥2 full cycles, on Sedov and
  Kelvin–Helmholtz. This is the engine-family contract every transport /
  residency lowering must preserve (exchanges are pure row copies; fused
  programs re-assemble split pair work in original pair order).
* **global family — determinism + physics.** ``global × distributed``
  accumulates pair sums in per-device plan order (a *different* but fixed
  summation order from the local engine's global pair list), so bitwise
  equality with the local engine is not part of its contract; it is pinned
  by (a) run-twice bitwise determinism and (b) trajectory agreement with
  the local engine to float32 tolerances plus conservation checks.
* **transfer discipline.** The fused device-resident path moves zero bytes
  of dynamical state across the host boundary inside a cycle (measured by
  the engine's :class:`TransferProbe`, not inferred), compiles at most one
  program per shape signature, and re-runs bitwise-identically.

4-rank cases need 4 addressable devices and run in the CI job with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``; on a single real
device they skip (the 1-rank matrix plus ``tests/test_transport.py``'s
subprocess parity still run everywhere).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.sph import SimulationSpec, SPHConfig, build_simulation

requires4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=4)")

NCYCLES = 2

SCENARIOS = {
    # n_side=6 / max_depth=4 yields interior force sub-steps (a real
    # ladder), so the matrix pins the live exchange paths, not just the
    # cycle-closing boundary
    "sedov": dict(scenario="sedov",
                  scenario_params={"n_side": 6, "e0": 1.0, "seed": 0},
                  physics=SPHConfig(alpha_visc=1.0, cfl=0.15),
                  dt_max=0.02, max_depth=4),
    "kelvin_helmholtz": dict(
        scenario="kelvin_helmholtz",
        scenario_params={"n_side": 5, "v_shear": 0.5, "seed": 0},
        physics=SPHConfig(alpha_visc=1.0, cfl=0.2),
        dt_max=0.01, max_depth=3),
}

# the timebin × distributed execution paths: (transport, residency)
TIMEBIN_PATHS = [("host", "host"), ("collective", "host"),
                 ("collective", "device")]


def _timebin_spec(scenario: str, **overrides) -> SimulationSpec:
    kw = dict(SCENARIOS[scenario])
    kw.update(integrator="timebin", backend="local")
    kw.update(overrides)
    return SimulationSpec(**kw)


def _snapshot(engine) -> dict:
    out = {name: np.asarray(getattr(engine.state.cells, name))
           for name in ("pos", "vel", "u", "h", "mass", "mask")}
    for name in ("accel", "dudt", "rho", "omega", "bins", "t_start"):
        out[name] = np.asarray(getattr(engine.state, name))
    out["time"] = np.float64(engine.state.time)
    return out


def _trajectory(sim, ncycles: int = NCYCLES) -> list:
    snaps = []
    for _ in range(ncycles):
        sim.step()
        snaps.append(_snapshot(sim.engine))
    return snaps


def _assert_bitwise(got: list, want: list, label: str):
    assert len(got) == len(want)
    for cyc, (a, b) in enumerate(zip(got, want)):
        for name in b:
            np.testing.assert_array_equal(
                a[name], b[name], err_msg=f"{label}: cycle {cyc}: {name}")


_REFS: dict = {}


def _reference_run(scenario: str, ncycles: int = NCYCLES) -> tuple:
    """Single-host timebin reference (snapshots, per-cycle stats), cached.

    Longer trajectories are cached separately and reuse nothing — cheap,
    and it keeps every cached snapshot list immutable."""
    key = (scenario, ncycles)
    if key not in _REFS:
        sim = build_simulation(_timebin_spec(scenario))
        snaps, stats = [], []
        for _ in range(ncycles):
            stats.append(sim.step())
            snaps.append(_snapshot(sim.engine))
        _REFS[key] = (snaps, stats)
    return _REFS[key]


def _reference(scenario: str, ncycles: int = NCYCLES) -> list:
    return _reference_run(scenario, ncycles)[0]


# ------------------------------------------------- timebin family (bitwise)
@pytest.mark.slow
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
@pytest.mark.parametrize("transport,residency", TIMEBIN_PATHS)
def test_timebin_conformance_one_rank(scenario, transport, residency):
    spec = _timebin_spec(scenario, backend="distributed", ranks=1,
                         transport=transport, residency=residency)
    got = _trajectory(build_simulation(spec))
    _assert_bitwise(got, _reference(scenario),
                    f"{scenario}/1rank/{transport}/{residency}")


@requires4
@pytest.mark.slow
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
@pytest.mark.parametrize("transport,residency", TIMEBIN_PATHS)
def test_timebin_conformance_four_ranks(scenario, transport, residency):
    spec = _timebin_spec(scenario, backend="distributed", ranks=4,
                         transport=transport, residency=residency)
    got = _trajectory(build_simulation(spec))
    _assert_bitwise(got, _reference(scenario),
                    f"{scenario}/4rank/{transport}/{residency}")


def test_residency_requires_collective_transport():
    with pytest.raises(ValueError, match="residency"):
        SimulationSpec(residency="cloud")
    with pytest.raises(ValueError, match="collective"):
        SimulationSpec(transport="host", residency="device")
    from repro.sph.dist_timebins import DistTimeBinSimulation
    from repro.sph import uniform_ic
    ic = uniform_ic(3, seed=0)
    args = (ic["pos"], ic["vel"], ic["mass"], ic["u"], ic["h"])
    with pytest.raises(ValueError, match="collective"):
        DistTimeBinSimulation(*args, box=ic["box"], transport="host",
                              residency="device")
    with pytest.raises(ValueError, match="use_pallas"):
        DistTimeBinSimulation(*args, box=ic["box"], transport="collective",
                              residency="device",
                              cfg=SPHConfig(use_pallas=True))


# ------------------------------------------ global family (determinism + φ)
@pytest.mark.slow
@pytest.mark.parametrize("integrator,backend", [
    ("global", "local"), ("timebin", "local"),
    ("global", "distributed"), ("timebin", "distributed")])
def test_quadrant_run_twice_bitwise_deterministic(integrator, backend):
    """Same spec, two builds: bitwise-identical trajectories. The property
    the ``-p no:randomly`` CI guard protects — nothing in any engine may
    depend on interpreter state, dict order or global RNG."""
    kw = dict(SCENARIOS["sedov"])
    kw.update(integrator=integrator, backend=backend, dt=0.004)
    if backend == "distributed":
        kw.update(ranks=1)
    spec = SimulationSpec(**kw)
    a = build_simulation(spec)
    b = build_simulation(spec)
    for _ in range(2):
        a.step()
        b.step()
    ea, pa = a.diagnostics()
    eb, pb = b.diagnostics()
    assert ea == eb
    np.testing.assert_array_equal(pa, pb)
    # a.state is TimeBinState/SPHState (with .cells) or the sharded
    # ParticleCells of the global-distributed engine — compare either way
    ca = a.state.cells if hasattr(a.state, "cells") else a.state
    cb = b.state.cells if hasattr(b.state, "cells") else b.state
    for name in ("pos", "vel", "u"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ca, name)),
            np.asarray(getattr(cb, name)), err_msg=name)


@pytest.mark.slow
def test_global_distributed_tracks_local_reference():
    """global × distributed pins to the local engine within float32
    accumulation-order tolerances (its pair sums fold in per-device plan
    order — same terms, different order, so bitwise equality is out of
    contract by design; see module docstring)."""
    kw = dict(SCENARIOS["sedov"])
    # rebin_every high: the distributed engine never re-bins, so the local
    # reference must not either or the per-cell layouts drift apart
    kw.update(integrator="global", dt=0.004, rebin_every=100)
    local = build_simulation(SimulationSpec(**kw))
    dist = build_simulation(SimulationSpec(**kw, backend="distributed",
                                           ranks=1))
    for _ in range(3):
        local.step()
        dist.step()
    e_l, p_l = local.diagnostics()
    e_d, p_d = dist.diagnostics()
    assert e_d == pytest.approx(e_l, rel=1e-5)
    np.testing.assert_allclose(p_d, p_l, atol=1e-5)
    g = dist.engine.gather_cells()
    for name in ("pos", "u"):
        np.testing.assert_allclose(
            np.asarray(getattr(g, name)),
            np.asarray(getattr(local.engine.state.cells, name)),
            rtol=2e-5, atol=2e-6, err_msg=name)


# ------------------------------------------------- transfer-count regression
def _assert_resident_discipline(eng, interior_substeps: int):
    stats = eng.transfers.stats()
    # zero intra-cycle dynamical-state bytes — the tentpole's core claim
    assert stats["intra_state_bytes"] == 0, stats
    # only control plane moves mid-cycle: index tables, changed flags, and
    # bins-mirror refreshes (one event per deepening/wake-up)
    assert set(eng.transfers.intra_bytes) <= {"tables", "flags", "bins"}
    assert (eng.transfers.intra_events.get("bins", 0) == 0) \
        == (eng.bins_refreshes == 0)
    # boundary traffic exists: the scatter/gather really went through the
    # probe (guards against the ledger silently going stale)
    for f in ("pos", "vel", "u", "bins"):
        assert stats["boundary_bytes"].get(f, 0) > 0, f
    # ≤ 1 compile per fused (phase, shape-signature) program
    for name, c in eng.probe.counts().items():
        if name.startswith("program:"):
            assert c == 1, (name, c)
    assert any(k[0] == "fused_force" for k in eng.program_keys) \
        == (interior_substeps > 0)
    assert any(k[0] == "fused_final" for k in eng.program_keys)


def _run_resident(ranks: int):
    spec = _timebin_spec("sedov", backend="distributed", ranks=ranks,
                         transport="collective", residency="device")
    sim = build_simulation(spec)
    interior = 0
    for _ in range(2):
        interior += sim.step()["force_substeps"] - 1
    assert interior > 0         # the scenario must exercise a real ladder
    return sim, interior


@pytest.mark.slow
def test_fused_resident_transfer_discipline_one_rank():
    sim, interior = _run_resident(ranks=1)
    _assert_resident_discipline(sim.engine, interior)


@requires4
@pytest.mark.slow
def test_fused_resident_transfer_discipline_four_ranks():
    sim, interior = _run_resident(ranks=4)
    eng = sim.engine
    _assert_resident_discipline(eng, interior)
    assert eng.halo_exported_slots > 0          # a real cut was exchanged
    builds = eng._transport.programs.builds
    compiles = eng.probe.total_compiles()
    sim.step()                                  # stable bins: full reuse
    assert eng._transport.programs.builds == builds
    assert eng.probe.total_compiles() == compiles
    assert eng.transfers.stats()["intra_state_bytes"] == 0


def _hot_sedov_spec(ranks: int) -> SimulationSpec:
    """A Sedov configuration whose blast provably deepens bins mid-cycle
    (e0=30 with a loose CFL: the central particles' demand tightens
    inside cycle 1), so a bins-mirror refresh MUST fire. max_depth=3
    keeps the ladder — and any fully-unrolled device program — short."""
    return SimulationSpec(
        scenario="sedov", scenario_params={"n_side": 6, "e0": 30.0,
                                           "seed": 0},
        physics=SPHConfig(alpha_visc=1.0, cfl=0.3),
        dt_max=0.01, max_depth=3, integrator="timebin",
        backend="distributed", ranks=ranks,
        transport="collective", residency="device")


@requires4
@pytest.mark.slow
def test_bins_refreshes_pinned_to_per_event_minimum():
    """`bins_refreshes` counts deepening *events*, not ranks or substeps:
    the 4-rank hot Sedov trips exactly one mid-cycle deepening, so the
    counter must read 1 (a per-rank or per-substep accounting bug would
    read 4+), and the mirror pull must move one row per tripped rank —
    never a full-state readback."""
    sim = build_simulation(_hot_sedov_spec(ranks=4))
    sim.step()
    eng = sim.engine
    assert eng.bins_refreshes == 1
    # one (nrows,) int32 row per rank that owns deepened particles — the
    # central blast straddles all four ranks here, so four row pulls
    assert eng.transfers.intra_events.get("bins", 0) == 4
    assert eng.transfers.stats()["intra_state_bytes"] == 0
    # the event count is rank-independent: the single-rank run of the
    # same dynamics sees the same one event (and pulls just its own row)
    lone = build_simulation(_hot_sedov_spec(ranks=1))
    lone.step()
    assert lone.engine.bins_refreshes == 1
    assert lone.engine.transfers.intra_events.get("bins", 0) == 1


# ------------------------------------------------ device-scheduled segments
NCYC_SEG = 4


def _device_sched_spec(scenario: str, K: int, **over) -> SimulationSpec:
    return _timebin_spec(scenario, backend="distributed", ranks=4,
                         transport="collective", residency="device",
                         schedule="device", segment_cycles=K, **over)


@requires4
@pytest.mark.slow
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
@pytest.mark.parametrize("K", [1, 4])
def test_device_schedule_conformance(scenario, K):
    """Device-scheduled K-cycle segments are bitwise the host-scheduled
    ladder: state at every segment boundary, per-cycle stats everywhere.
    For K>1 the engine state is *defined* only at segment boundaries, so
    mid-segment cycles compare stats alone."""
    refs, ref_stats = _reference_run(scenario, NCYC_SEG)
    sim = build_simulation(_device_sched_spec(scenario, K))
    eng = sim.engine
    for c in range(NCYC_SEG):
        s = sim.step()
        assert s["schedule"] == "device" and s["segment_cycles"] == K
        r = ref_stats[c]
        for k in ("updates", "substeps", "depth", "force_substeps"):
            assert s.get(k) == r.get(k), (c, k, s.get(k), r.get(k))
        np.testing.assert_array_equal(s["bin_hist"], r["bin_hist"],
                                      err_msg=f"K={K} cycle {c}: bin_hist")
        assert s["t"] == float(refs[c]["time"])
        if (c + 1) % K == 0:
            snap = _snapshot(eng)
            for name in refs[c]:
                np.testing.assert_array_equal(
                    snap[name], refs[c][name],
                    err_msg=f"{scenario} K={K} cycle {c}: {name}")
    if scenario == "kelvin_helmholtz" and K == 4:
        # the shear flow crosses a cell boundary inside the segment —
        # the device plan cannot rebin, so the crossing sentinel MUST
        # abort and the host replay the cycles: still bitwise and still
        # per-cycle stats parity (both asserted above)
        assert eng.segment_aborts >= 1
    else:
        assert eng.segments == NCYC_SEG // K
        assert eng.segment_aborts == 0


@requires4
@pytest.mark.slow
def test_device_schedule_zero_intra_bytes_and_compile_discipline():
    """The tentpole contract: a segment moves NOTHING between host and
    device except the boundary table upload and the boundary stats pull —
    no per-cycle flags, no bins mirrors, no schedule tables — and each
    (signature, bucket, K) compiles its two programs exactly once, with
    full reuse on the next segment."""
    sim = build_simulation(_device_sched_spec("sedov", 4))
    for _ in range(NCYC_SEG):
        sim.step()
    eng = sim.engine
    stats = eng.transfers.stats()
    assert stats["intra_state_bytes"] == 0
    assert dict(eng.transfers.intra_bytes) == {}
    assert stats["boundary_events"]["segment_tables"] > 0
    assert stats["boundary_events"]["segment_stats"] == eng.segments == 1
    for name, c in eng.probe.counts().items():
        if name.startswith("program:"):
            assert c == 1, (name, c)
    assert any(k[0] == "cycle_scan" for k in eng.program_keys)
    assert any(k[0] == "segment_plan" for k in eng.program_keys)
    builds = eng._transport.programs.builds
    compiles = eng.probe.total_compiles()
    for _ in range(NCYC_SEG):                   # second segment: full reuse
        sim.step()
    assert eng._transport.programs.builds == builds
    assert eng.probe.total_compiles() == compiles
    assert eng.transfers.stats()["intra_state_bytes"] == 0


@requires4
@pytest.mark.slow
def test_device_schedule_mid_segment_deepening():
    """Bins that deepen in the middle of a compiled segment are handled
    entirely on device — no sentinel trip, no host fallback — and the
    boundary state stays bitwise. The host-scheduled run of the same
    configuration proves the deepening event is really there (it must
    refresh its bins mirror once)."""
    host = build_simulation(_hot_sedov_spec(ranks=4))
    host.step()
    assert host.engine.bins_refreshes == 1      # the event exists
    hot = dict(scenario="sedov",
               scenario_params={"n_side": 6, "e0": 30.0, "seed": 0},
               physics=SPHConfig(alpha_visc=1.0, cfl=0.3),
               dt_max=0.01, max_depth=3, integrator="timebin")
    ref = build_simulation(SimulationSpec(**hot, backend="local"))
    refs = _trajectory(ref, NCYC_SEG)
    sim = build_simulation(SimulationSpec(
        **hot, backend="distributed", ranks=4, transport="collective",
        residency="device", schedule="device", segment_cycles=4))
    for _ in range(NCYC_SEG):
        sim.step()
    eng = sim.engine
    snap = _snapshot(eng)
    for name in refs[-1]:
        np.testing.assert_array_equal(snap[name], refs[-1][name],
                                      err_msg=f"deepening: {name}")
    assert eng.segment_aborts == 0              # absorbed inside the scan
    assert dict(eng.transfers.intra_bytes) == {}


@requires4
@pytest.mark.slow
def test_device_schedule_nan_sentinel_trip_and_resume():
    """A NaN minted on device trips the in-program sentinel; the segment
    aborts back to the host ladder and replays bitwise — NaNs propagate
    identically to the reference, and the observer's health record shows
    the trip."""
    ref = build_simulation(_timebin_spec("sedov"))
    ref.step()
    _poison_vel(ref.engine)
    refs = []
    with np.errstate(invalid="ignore"):
        for _ in range(2):
            ref.step()
            refs.append(_snapshot(ref.engine))
    sim = build_simulation(_device_sched_spec(
        "sedov", 1, observe={"device_metrics": True}))
    sim.step()
    rec0 = sim.observer.records[-1]
    assert rec0["health"] is not None and rec0["health"]["tripped"] is False
    _poison_vel(sim.engine)
    got = []
    with np.errstate(invalid="ignore"):
        for _ in range(2):
            sim.step()
            got.append(_snapshot(sim.engine))
    eng = sim.engine
    assert eng.segment_aborts >= 1
    rec = sim.observer.records[-1]
    assert rec["health"]["tripped"] is True
    assert rec["health"]["flags"].get("flag_nan", 0) > 0
    for c, (a, b) in enumerate(zip(got, refs)):
        for name in b:
            np.testing.assert_array_equal(
                a[name], b[name], err_msg=f"nan-resume cycle {c}: {name}")


def _poison_vel(eng) -> None:
    """NaN one real particle's velocity component, in place."""
    cells = eng.state.cells
    vel = np.asarray(cells.vel).copy()
    c, p = np.argwhere(np.asarray(cells.mask) > 0)[0]
    vel[c, p, 0] = np.nan
    eng.state = eng.state._replace(
        cells=cells._replace(vel=jnp.asarray(vel)))


# --------------------------------------------------- device-metrics carry
def _quadrant_state(sim) -> dict:
    """Physics-visible state for any quadrant (plain-vs-instrumented)."""
    eng = sim.engine
    if hasattr(eng, "dcells"):                      # global × distributed
        g = eng.gather_cells()
        return {n: np.asarray(getattr(g, n))
                for n in ("pos", "vel", "u", "h", "mass", "mask")}
    if hasattr(eng.state, "bins"):                  # timebin family
        return _snapshot(eng)
    out = {n: np.asarray(getattr(eng.state.cells, n))  # global × local
           for n in ("pos", "vel", "u", "h", "mass", "mask")}
    out["rho"] = np.asarray(eng.state.rho)
    return out


@pytest.mark.slow
@pytest.mark.parametrize("integrator,backend", [
    ("global", "local"), ("timebin", "local"),
    ("global", "distributed"), ("timebin", "distributed")])
def test_device_metrics_carry_bitwise_all_quadrants(integrator, backend):
    """Enabling the telemetry carry changes no number in any quadrant, and
    every quadrant reports a populated per-rank work row."""
    kw = dict(SCENARIOS["sedov"])
    kw.update(integrator=integrator, backend=backend, dt=0.004)
    if backend == "distributed":
        kw.update(ranks=1, transport="collective")
        if integrator == "timebin":
            kw.update(residency="device")
    plain = build_simulation(SimulationSpec(**kw))
    inst = build_simulation(SimulationSpec(**kw, observe=True))
    snaps_p, snaps_i = [], []
    for _ in range(NCYCLES):
        plain.step()
        inst.step()
        snaps_p.append(_quadrant_state(plain))
        snaps_i.append(_quadrant_state(inst))
    _assert_bitwise(snaps_i, snaps_p, f"dmetrics/{integrator}/{backend}")
    eng = inst.engine
    assert eng.device_metrics_enabled
    assert plain.engine.device_metrics_last is None
    counts, values = eng.device_metrics_last
    assert counts.shape[0] == 1 and values.shape[0] == 1
    assert eng.device_metrics_pulls == NCYCLES
    rec = inst.observer.records[-1]
    work = rec["device_metrics"]["per_rank_work"]
    assert len(work) == 1 and work[0] > 0
    assert rec["health"]["tripped"] is False


@pytest.mark.slow
def test_device_metrics_carry_mints_no_extra_programs():
    """The fused program with the telemetry output IS the program: turning
    the carry off (device_metrics=False) compiles nothing different and
    produces the bitwise-same trajectory — the row is always computed, the
    flag only gates the once-per-cycle host pull."""
    base = _timebin_spec("sedov", backend="distributed", ranks=1,
                         transport="collective", residency="device")
    off = build_simulation(base.with_(
        observe={"device_metrics": False}))
    on = build_simulation(base.with_(observe=True))
    got_off = _trajectory(off)
    got_on = _trajectory(on)
    _assert_bitwise(got_on, got_off, "dmetrics-on-vs-off")
    assert on.engine.probe.total_compiles() \
        == off.engine.probe.total_compiles()
    assert on.engine.probe.counts() == off.engine.probe.counts()
    # the pull is ledgered on the instrumented engine only, once per cycle
    assert on.engine.transfers.stats()["boundary_events"]["metrics"] \
        == NCYCLES
    assert "metrics" not in off.engine.transfers.stats()["boundary_events"]
    assert off.engine.device_metrics_last is None


@requires4
@pytest.mark.slow
def test_device_metrics_four_rank_fused_rows():
    """4-rank fused run: per-rank per-phase work comes from inside the
    program, covers owned rows only (ranks sum to the global particle
    count, not 4× it), and still costs one ledgered pull per cycle."""
    spec = _timebin_spec("sedov", backend="distributed", ranks=4,
                         transport="collective", residency="device",
                         observe=True)
    sim = build_simulation(spec)
    got = _trajectory(sim)
    _assert_bitwise(got, _reference("sedov"), "dmetrics/4rank/fused")
    eng = sim.engine
    counts, values = eng.device_metrics_last
    assert counts.shape[0] == 4 and values.shape[0] == 4
    rec = sim.observer.records[-1]
    dmx = rec["device_metrics"]
    assert len(dmx["per_rank_work"]) == 4
    assert all(w > 0 for w in dmx["per_rank_work"])
    assert rec["device_imbalance"] >= 1.0
    # owned-rows-only: summed drift-active particles over ranks equals the
    # alive count exactly (halo mirrors are not double-counted)
    from repro.observability import COUNT_COLUMNS
    drift = counts[:, COUNT_COLUMNS.index("drift_active")]
    subs = counts[:, COUNT_COLUMNS.index("substeps")]
    nreal = int((np.asarray(_reference("sedov")[-1]["mask"]) > 0).sum())
    assert (subs == subs[0]).all() and subs[0] == 2 * NCYCLES \
        or (subs > 0).all()          # every rank ran every sub-step
    assert (drift > 0).all()
    assert drift.sum() == subs[0] * nreal
    assert eng.transfers.stats()["boundary_events"]["metrics"] == NCYCLES
    # the fused run feeds measured per-phase work into the cost model
    assert {"density", "force"} <= set(rec["cost_ratios"])


@requires4
@pytest.mark.slow
def test_per_cell_attribution_sums_to_phase_units_four_rank():
    """4-rank fused run: the per-cell work vectors (schema v3) are exact —
    per-rank owned-row sums equal the in-program value columns for
    density/force/exchange and the drift-active count, with halo rows
    folded onto owners (no double-counting)."""
    from repro.observability import CELL_COLUMNS
    from repro.observability import device_metrics as dm
    spec = _timebin_spec("sedov", backend="distributed", ranks=4,
                         transport="collective", residency="device",
                         observe=True)
    sim = build_simulation(spec)
    _trajectory(sim)
    eng = sim.engine
    cw = eng.device_cell_work_last
    assert cw is not None and list(cw["columns"]) == list(CELL_COLUMNS)
    cells = np.asarray(cw["cells"], np.float64)
    per_rank = np.asarray(cw["per_rank"], np.float64)
    assert per_rank.shape[0] == 4
    counts, values = (np.asarray(a) for a in eng.device_metrics_last)
    cix = {k: i for i, k in enumerate(CELL_COLUMNS)}
    # per-rank exactness, kind by kind: the scatter targets only owned
    # rows, so each rank's fold reproduces its own value column
    for kind in ("density", "force", "exchange"):
        want = values[:, dm.VALUE_INDEX[f"{kind}_units"]]
        got = per_rank[:, cix[kind]]
        np.testing.assert_allclose(got, want, rtol=1e-6, err_msg=kind)
    np.testing.assert_allclose(
        per_rank[:, cix["drift"]],
        counts[:, dm.COUNT_INDEX["drift_active"]], rtol=1e-6)
    # folding halo rows onto owner cells conserves every column globally
    np.testing.assert_allclose(cells.sum(axis=0), per_rank.sum(axis=0),
                               rtol=1e-6)
    assert (cells >= 0).all()
    # the v3 record carries the compact block and the advisor ran
    rec = sim.observer.records[-1]
    assert rec["cell_work"] is not None
    assert rec["cell_work"]["ncells"] == cells.shape[0]
    assert rec["advisor"] is not None
    assert rec["advisor"]["advised_imbalance"] \
        <= rec["advisor"]["current_imbalance"] + 1e-9
