"""Fused SSD kernel vs sequential oracle vs model SSD path."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref


def make_inputs(B, S, H, hp, N, seed=0):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((B, S, H, hp)).astype(np.float32))
    dt = jnp.asarray(0.05 + 0.1 * rng.random((B, S, H)).astype(np.float32))
    A = jnp.asarray(-(0.1 + rng.random(H)).astype(np.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    D = jnp.asarray(rng.random(H).astype(np.float32))
    return u, dt, A, Bm, Cm, D


@pytest.mark.parametrize("B,S,H,hp,N,chunk", [
    (1, 32, 2, 8, 4, 8),
    (2, 64, 4, 16, 8, 16),
    (1, 128, 2, 32, 16, 32),
    (1, 64, 3, 8, 4, 64),     # single chunk
])
def test_ssd_kernel_matches_ref(B, S, H, hp, N, chunk):
    args = make_inputs(B, S, H, hp, N, seed=S + H)
    y, h = ssd_scan(*args, chunk=chunk, interpret=True)
    y_ref, h_ref = ssd_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_chunk_invariance():
    args = make_inputs(1, 64, 2, 8, 4, seed=9)
    y8, _ = ssd_scan(*args, chunk=8, interpret=True)
    y32, _ = ssd_scan(*args, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                               rtol=1e-4, atol=1e-5)


def test_ssd_kernel_matches_mamba2_core():
    """The kernel computes the same SSM core as mamba2_forward's chunked
    einsum path (up to the conv/gating wrapper, which stays outside)."""
    from repro.models.mamba import mamba2_forward, init_mamba2
    B, S, d = 1, 64, 32
    hp, N = 8, 8
    H = (2 * d) // hp
    args = make_inputs(B, S, H, hp, N, seed=3)
    u, dt, A, Bm, Cm, D = args
    # reference: run the same math with the model's einsum formulation by
    # building la/decay identically — covered via oracle equality:
    y_ref, _ = ssd_scan_ref(u, dt, A, Bm, Cm, D)
    y, _ = ssd_scan(u, dt, A, Bm, Cm, D, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
