"""Gradient compression with error feedback."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.distributed.compression import (compress_grads, compressed_bytes,
                                           decompress_grads,
                                           init_compress_state)


def grads_like(seed):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32)),
            "b": jnp.asarray(rng.standard_normal(64).astype(np.float32))}


@pytest.mark.parametrize("scheme", ["int8", "topk"])
def test_error_feedback_tracks_running_sum(scheme):
    """Σ decompressed ≈ Σ true gradients (residual carries the error)."""
    state = init_compress_state(grads_like(0))
    total_true = jax.tree.map(jnp.zeros_like, grads_like(0))
    total_sent = jax.tree.map(jnp.zeros_like, grads_like(0))
    for step in range(20):
        g = grads_like(step)
        payload, state = compress_grads(g, state, scheme=scheme,
                                        topk_frac=0.2)
        d = decompress_grads(payload, scheme=scheme)
        total_true = jax.tree.map(lambda t, x: t + x, total_true, g)
        total_sent = jax.tree.map(lambda t, x: t + x, total_sent, d)
    for t, s, r in zip(jax.tree.leaves(total_true),
                       jax.tree.leaves(total_sent),
                       jax.tree.leaves(state.residual)):
        # accumulated error equals the residual still held back
        np.testing.assert_allclose(np.asarray(t - s), np.asarray(r),
                                   rtol=1e-4, atol=1e-4)
        # and the residual is bounded (no divergence)
        assert float(jnp.abs(r).max()) < 10.0


def test_int8_payload_size():
    g = grads_like(1)
    payload, _ = compress_grads(g, init_compress_state(g), scheme="int8")
    n_elems = sum(x.size for x in jax.tree.leaves(g))
    n_tensors = len(jax.tree.leaves(g))
    # 1 byte/elem + one f32 scale per tensor ⇒ ~4× traffic saving
    assert compressed_bytes(payload, scheme="int8") == n_elems + 4 * n_tensors


def test_int8_quantisation_error_bounded():
    g = grads_like(2)
    payload, _ = compress_grads(g, init_compress_state(g), scheme="int8")
    d = decompress_grads(payload, scheme="int8")
    for x, y in zip(jax.tree.leaves(g), jax.tree.leaves(d)):
        scale = float(jnp.abs(x).max()) / 127.0
        assert float(jnp.abs(x - y).max()) <= scale * 0.5 + 1e-6


def test_topk_keeps_largest():
    g = {"a": jnp.asarray([1.0, -5.0, 0.1, 3.0, -0.2, 0.05, 2.0, -1.5])}
    payload, _ = compress_grads(g, init_compress_state(g), scheme="topk",
                                topk_frac=0.25)
    d = decompress_grads(payload, scheme="topk")["a"]
    nz = np.nonzero(np.asarray(d))[0]
    assert set(nz) == {1, 3}           # the two largest magnitudes
