"""Checkpointing: atomicity, corruption tolerance, elastic restore."""

import os
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train.checkpoint import Checkpointer


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.random((8, 16), np.float32)),
                   "b": jnp.asarray(rng.random(16, np.float32))},
        "opt": {"mu": [jnp.asarray(rng.random(4, np.float32)),
                       jnp.asarray(rng.random((2, 2), np.float32))]},
    }


def assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip_sync(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = tree(1)
    ck.save(7, t, extra={"data_step": 7})
    step, got, extra = ck.restore_latest(t)
    assert step == 7 and extra["data_step"] == 7
    assert_tree_equal(t, got)


def test_roundtrip_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        ck.save(s, tree(s))
    ck.wait()
    steps = ck.list_steps()
    assert steps == [3, 4]
    step, got, _ = ck.restore_latest(tree(0))
    assert step == 4
    assert_tree_equal(tree(4), got)


def test_uncommitted_checkpoint_skipped(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(5, tree(5))
    # simulate a crash mid-save at step 9: directory without DONE marker
    broken = tmp_path / "step_000000009"
    broken.mkdir()
    (broken / "meta.json").write_text("{}")
    step, got, _ = ck.restore_latest(tree(0))
    assert step == 5
    assert_tree_equal(tree(5), got)


def test_restore_empty_dir(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    assert ck.restore_latest(tree(0)) is None


def test_elastic_restore_with_shardings(tmp_path):
    """Restore re-places leaves with current-topology shardings (here: the
    1-device mesh — the mechanism is identical at 256 devices)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = tree(3)
    ck.save(1, t)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    step, got, _ = ck.restore_latest(t, shardings=sh)
    assert_tree_equal(t, got)
    for leaf in jax.tree.leaves(got):
        assert leaf.sharding == NamedSharding(mesh, P())


def test_overwrite_same_step(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(2, tree(1))
    ck.save(2, tree(9))
    _, got, _ = ck.restore_latest(tree(0))
    assert_tree_equal(tree(9), got)
