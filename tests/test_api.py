"""SimulationSpec front-end: registry, quadrants, distributed time-bin
parity and activity-aware halo volumes."""

import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (CostModel, bin_occupancy_imbalance, insert_comm_tasks,
                        rank_bin_occupancy, TaskGraph)
from repro.sph import (SCENARIOS, SimulationProtocol, SimulationSpec, SPHConfig,
                       build_simulation, kelvin_helmholtz_ic, make_ic,
                       register_scenario, sedov_ic)
from repro.sph.dist_timebins import build_rank_plan, halo_export_schedule


# ------------------------------------------------------------------ the spec
def test_spec_validation():
    with pytest.raises(ValueError, match="integrator"):
        SimulationSpec(integrator="leapfrog")
    with pytest.raises(ValueError, match="backend"):
        SimulationSpec(backend="mpi")
    with pytest.raises(ValueError, match="scenario"):
        SimulationSpec(scenario="warp-core-breach")
    with pytest.raises(ValueError, match="halo"):
        SimulationSpec(halo="pigeon")


def test_spec_frozen_and_with():
    spec = SimulationSpec(scenario="sedov", integrator="timebin")
    with pytest.raises(Exception):
        spec.integrator = "global"
    spec2 = spec.with_(backend="distributed", ranks=4)
    assert spec2.scenario == "sedov" and spec2.ranks == 4
    assert spec.backend == "local"          # original untouched


def test_scenario_registry():
    assert {"uniform", "clustered", "sedov",
            "kelvin_helmholtz"} <= set(SCENARIOS)
    ic = make_ic("uniform", n_side=4)
    assert set(ic) >= {"pos", "vel", "mass", "u", "h", "box"}
    with pytest.raises(KeyError, match="unknown scenario"):
        make_ic("nope")

    @register_scenario("test_two_particles")
    def _two(**kw):
        return {"pos": np.zeros((2, 3), np.float32),
                "vel": np.zeros((2, 3), np.float32),
                "mass": np.ones(2, np.float32),
                "u": np.ones(2, np.float32),
                "h": np.full(2, 0.3, np.float32), "box": 1.0}

    try:
        assert "test_two_particles" in SCENARIOS
        assert len(make_ic("test_two_particles")["pos"]) == 2
    finally:
        del SCENARIOS["test_two_particles"]


def test_kelvin_helmholtz_ic_structure():
    ic = kelvin_helmholtz_ic(8, v_shear=0.5, perturb=0.05, seed=0)
    z = ic["pos"][:, 2] / ic["box"]
    vx = ic["vel"][:, 0]
    inner = (np.abs(z - 0.5) < 0.15)
    outer = (np.abs(z - 0.5) > 0.35)
    assert vx[inner].mean() > 0.4            # central slab streams +x
    assert vx[outer].mean() < -0.4           # outer gas streams -x
    assert np.abs(ic["vel"][:, 2]).max() > 0  # seeded perturbation
    assert np.abs(ic["vel"][:, 2]).max() < 0.5 * 0.5  # but subdominant
    # uniform density: one equal-mass lattice
    assert np.allclose(ic["mass"], ic["mass"][0])


# ------------------------------------------------------------- the quadrants
def test_all_four_quadrants_run():
    """Every {integrator} × {backend} combination builds and advances
    through the one front-end (the acceptance criterion)."""
    base = SimulationSpec(scenario="uniform",
                          scenario_params={"n_side": 5, "seed": 0},
                          physics=SPHConfig(alpha_visc=0.8),
                          dt=0.004, dt_max=0.004, ranks=1)
    for integrator in ("global", "timebin"):
        for backend in ("local", "distributed"):
            spec = base.with_(integrator=integrator, backend=backend)
            sim = build_simulation(spec)
            assert isinstance(sim, SimulationProtocol)
            log = sim.run(0.008)
            assert sim.time == pytest.approx(0.008, rel=1e-5)
            assert len(log["t"]) >= 1
            e, p = sim.diagnostics()
            assert np.isfinite(e) and np.isfinite(p).all()


def test_legacy_constructors_warn_but_work():
    from repro.sph import Simulation, TimeBinSimulation, uniform_ic
    ic = uniform_ic(4, seed=0)
    args = (ic["pos"], ic["vel"], ic["mass"], ic["u"], ic["h"])
    with pytest.warns(DeprecationWarning):
        Simulation(*args, box=ic["box"])
    with pytest.warns(DeprecationWarning):
        TimeBinSimulation(*args, box=ic["box"])
    # the API path must not warn
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        build_simulation(SimulationSpec(
            scenario="uniform", scenario_params={"n_side": 4}))


def test_legacy_constructors_match_spec_built_states():
    """Deprecation-shim regression: the legacy constructors keep warning
    AND still produce states bitwise-equal to the spec-built engines."""
    from repro.sph import Simulation, TimeBinSimulation, uniform_ic
    ic = uniform_ic(4, seed=0)
    args = (ic["pos"], ic["vel"], ic["mass"], ic["u"], ic["h"])
    params = {"n_side": 4, "seed": 0}

    with pytest.warns(DeprecationWarning):
        legacy = Simulation(*args, box=ic["box"])
    built = build_simulation(SimulationSpec(
        scenario="uniform", scenario_params=params)).engine
    for name in ("pos", "vel", "mass", "u", "h", "mask"):
        np.testing.assert_array_equal(
            np.asarray(getattr(legacy.state.cells, name)),
            np.asarray(getattr(built.state.cells, name)), err_msg=name)
    for name in ("accel", "dudt", "rho"):
        np.testing.assert_array_equal(
            np.asarray(getattr(legacy.state, name)),
            np.asarray(getattr(built.state, name)), err_msg=name)

    with pytest.warns(DeprecationWarning):
        legacy_tb = TimeBinSimulation(*args, box=ic["box"], dt_max=0.004)
    built_tb = build_simulation(SimulationSpec(
        scenario="uniform", scenario_params=params,
        integrator="timebin", dt_max=0.004)).engine
    legacy_tb.run_cycle()
    built_tb.run_cycle()
    for name in ("pos", "vel", "u"):
        np.testing.assert_array_equal(
            np.asarray(getattr(legacy_tb.state.cells, name)),
            np.asarray(getattr(built_tb.state.cells, name)), err_msg=name)
    for name in ("accel", "dudt", "rho", "omega", "bins", "t_start"):
        np.testing.assert_array_equal(
            np.asarray(getattr(legacy_tb.state, name)),
            np.asarray(getattr(built_tb.state, name)), err_msg=name)
    assert float(legacy_tb.state.time) == float(built_tb.state.time)


# ------------------------------------------- distributed time-bin: host plan
def _toy_plan(nranks=2):
    # 4 cells in a chain, alternate ownership: every cell is a cut cell
    # except with nranks=1
    assignment = np.arange(4) % nranks
    ci = np.array([0, 1, 2, 0, 1, 2, 3])
    cj = np.array([1, 2, 3, 0, 1, 2, 3])
    return build_rank_plan(assignment, ci, cj, nranks=nranks)


def test_rank_plan_structure():
    plan = _toy_plan(2)
    assert plan.nranks == 2
    assert sorted(np.concatenate(plan.owned).tolist()) == [0, 1, 2, 3]
    # chain 0-1-2-3 with alternating ranks: cells 0..3 all sit on the cut
    assert set(plan.cut) == {0, 1, 2, 3}
    for c, (owner, orow, imps) in plan.cut.items():
        assert owner == plan.assignment[c]
        assert all(r != owner for r, _ in imps)
        assert all(row >= plan.K for _, row in imps)    # halo rows
    # single rank: no cut, trivially empty halo
    p1 = _toy_plan(1)
    assert p1.cut == {} and p1.H == 0


def test_halo_export_schedule_activity_beats_full():
    """The static accounting: with bins concentrated in few cells, the
    activity-aware export volume over a cycle is far below full-boundary."""
    plan = _toy_plan(2)
    depth = 4
    cell_bins = np.array([depth, 0, 0, 0])       # one deep cell
    sched = halo_export_schedule(cell_bins, plan, depth)
    active, full = sched["active"].sum(), sched["full"].sum()
    assert 0 < active < full
    # uniform deep bins: no advantage (every sub-step ships everything)
    sched_u = halo_export_schedule(np.full(4, depth), plan, depth)
    assert sched_u["active"].sum() == sched_u["full"].sum()


def test_rank_bin_occupancy_and_imbalance():
    assignment = np.array([0, 0, 1, 1])
    obb = np.array([[4, 0], [4, 0],          # rank 0: all slow (bin 0)
                    [0, 4], [0, 4]])         # rank 1: all fast (bin 1)
    per_rank = rank_bin_occupancy(assignment, obb)
    assert per_rank.tolist() == [[8, 0], [0, 8]]
    # rank 1 does 2x the mean time-averaged work -> imbalance 4/3
    imb = bin_occupancy_imbalance(assignment, obb)
    assert imb == pytest.approx((8.0) / ((8 * 0.5 + 8) / 2))
    balanced = bin_occupancy_imbalance(np.array([0, 1, 0, 1]), obb)
    assert balanced == pytest.approx(1.0)


def test_comm_tasks_weighted_by_activation_frequency():
    """send/recv costs and bytes scale with the resource's activation
    frequency (the activity-aware halo at the task-graph layer)."""
    def graph():
        g = TaskGraph()
        s = g.add_task("produce", resources=(0,), writes=(0,), cost=1, rank=0)
        c = g.add_task("consume", resources=(0,), cost=1, rank=1)
        g.add_dependency(c, s)
        return g

    g_full = graph()
    full = insert_comm_tasks(g_full, {0: 0}, {0: 1000.0},
                             phases={"produce": "p0", "consume": "p1"})
    g_rare = graph()
    rare = insert_comm_tasks(g_rare, {0: 0}, {0: 1000.0},
                             phases={"produce": "p0", "consume": "p1"},
                             resource_freq={0: 0.125})
    assert rare.total_bytes == pytest.approx(full.total_bytes / 8)
    send_cost = {t.kind: t.cost for t in g_rare.tasks.values()}["send"]
    send_cost_full = {t.kind: t.cost for t in g_full.tasks.values()}["send"]
    assert send_cost == pytest.approx(send_cost_full / 8)


def test_timebin_units_send_recv_activation_frequency():
    cm = CostModel(rates={})
    # cell active every sub-step: full message cost
    assert cm.timebin_units("send", [0, 0, 8], max_bin=2) == \
        pytest.approx(cm.units("send", 8))
    # cell active 1/4 of sub-steps: the whole buffer ships 1/4 as often
    assert cm.timebin_units("send", [8, 0, 0], max_bin=2) == \
        pytest.approx(cm.units("send", 8) / 4)
    # empty cell never ships
    assert cm.timebin_units("recv", [0, 0, 0], max_bin=2) == 0.0


# ------------------------------------- distributed time-bin: engine parity
def _parity_engines(nranks, n_side=5, max_depth=3):
    from repro.sph import TimeBinSimulation
    ic = sedov_ic(n_side, e0=1.0, seed=0)
    cfg = SPHConfig(alpha_visc=1.0, cfl=0.15)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        single = TimeBinSimulation(
            ic["pos"], ic["vel"], ic["mass"], ic["u"], ic["h"],
            box=ic["box"], cfg=cfg, dt_max=0.02, max_depth=max_depth)
    spec = SimulationSpec(
        scenario="sedov",
        scenario_params={"n_side": n_side, "e0": 1.0, "seed": 0},
        physics=cfg, integrator="timebin", backend="distributed",
        ranks=nranks, dt_max=0.02, max_depth=max_depth)
    dist = build_simulation(spec)
    return single, dist


def _assert_states_equal(single, dist):
    for name in ("pos", "vel", "u", "h"):
        a = np.asarray(getattr(single.state.cells, name))
        b = np.asarray(getattr(dist.engine.state.cells, name))
        np.testing.assert_array_equal(a, b, err_msg=name)
    np.testing.assert_array_equal(np.asarray(single.state.bins),
                                  np.asarray(dist.engine.state.bins))
    assert float(single.state.time) == float(dist.engine.state.time)


@pytest.mark.slow
def test_distributed_timebin_one_rank_bitwise_parity():
    """Satellite acceptance: SimulationSpec(integrator="timebin",
    backend="distributed") on one rank matches the single-host
    TimeBinSimulation trajectory bit-for-bit over ≥2 full cycles."""
    single, dist = _parity_engines(nranks=1)
    for _ in range(2):
        s1 = single.run_cycle()
        s2 = dist.step()
        assert s1["depth"] == s2["depth"]
        assert s1["substeps"] == s2["substeps"]
    _assert_states_equal(single, dist)
    assert dist.engine.halo_full_slots == 0      # one rank: no cut


@pytest.mark.slow
def test_distributed_timebin_multirank_matches_and_saves_volume():
    """Three ranks: identical physics (owned sums are complete through the
    halos) and, on a blast with real bin contrast, activity-aware halos
    ship measurably less than the full boundary."""
    single, dist = _parity_engines(nranks=3, n_side=6, max_depth=4)
    for _ in range(2):
        single.run_cycle()
        dist.step()
    _assert_states_equal(single, dist)

    # fine-grained Sedov: background cells idle through deep sub-steps
    spec = SimulationSpec(
        scenario="sedov",
        scenario_params={"n_side": 8, "e0": 1.0, "seed": 0,
                         "n_target": 16.0, "r_inject": 0.06},
        physics=SPHConfig(alpha_visc=1.0, cfl=0.15, n_target=16.0),
        integrator="timebin", backend="distributed", ranks=4, max_depth=6)
    sim = build_simulation(spec)
    stats = sim.step()
    assert stats["halo_full_slots"] > 0
    assert stats["halo_exported_slots"] < 0.7 * stats["halo_full_slots"]
