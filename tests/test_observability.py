"""Observability contract: tracing is free, faithful, and non-invasive.

Three claims pinned here, mirroring the conformance harness's discipline:

* **non-interference** — ``observe=True`` changes no number: traced runs
  are bitwise-identical to untraced runs (the fences only *wait*, they
  never reorder or recompute), and mint zero extra compiled programs.
* **fidelity** — the exported Chrome trace passes the schema validator,
  carries one row per rank with per-phase slices, and the per-cycle JSONL
  counters agree *exactly* (not approximately) with the engines' live
  ``TransferProbe``/``CompileProbe`` ledgers.
* **cost** — an enabled span costs < 5 µs median on CPU, and the
  ``CompileProbe`` fallback counts signatures instead of reporting ``-1``.
"""

import json
import time

import numpy as np
import pytest

from repro.core.cost_model import CostModel
from repro.observability import (METRICS_SCHEMA_VERSION, NULL_TRACER,
                                 ObserveSpec, Tracer, UMBRELLA_SPANS,
                                 chrome_trace, jsonify, read_metrics_jsonl,
                                 validate_chrome_trace, write_metrics_jsonl)
from repro.sph import SimulationSpec, SPHConfig, build_simulation

from test_conformance import (SCENARIOS, _assert_bitwise, _reference,
                              _timebin_spec, _trajectory)


# ----------------------------------------------------------- tracer basics
def test_span_records_attrs_and_ctx():
    tr = Tracer()
    tr.ctx["cycle"] = 3
    with tr.span("density", rank=1, units=64):
        pass
    tr.ctx.pop("cycle")
    with tr.span("force", rank=0):
        pass
    spans = tr.spans
    assert [s.name for s in spans] == ["density", "force"]
    assert spans[0].rank == 1 and spans[0].attrs["units"] == 64
    assert spans[0].attrs["cycle"] == 3          # ambient ctx merged in
    assert (spans[1].attrs or {}).get("cycle") is None   # only while set
    assert all(s.t1 >= s.t0 for s in spans)
    assert tr.ranks() == [0, 1]


def test_record_all_duplicates_collective_interval():
    tr = Tracer()
    t0 = tr.now()
    tr.record_all(range(3), "exchange1", t0, units=10, collective=1)
    spans = tr.spans
    assert [s.rank for s in spans] == [0, 1, 2]
    assert len({(s.t0, s.t1) for s in spans}) == 1   # same interval per rank
    assert all(s.attrs["collective"] == 1 for s in spans)


def test_null_tracer_is_inert_but_timed_measures():
    assert not NULL_TRACER.enabled
    with NULL_TRACER.span("x", rank=0):
        pass
    NULL_TRACER.record_all(range(4), "y", 0.0)
    assert NULL_TRACER.fence("payload") == "payload"
    with NULL_TRACER.timed("wall") as sp:
        time.sleep(0.001)
    assert sp.elapsed >= 0.001                    # "wall" stats still work
    assert NULL_TRACER.spans == []


def test_enabled_span_overhead_under_5us():
    tr = Tracer()
    n = 2000
    samples = []
    for _ in range(15):
        t0 = time.perf_counter()
        for _ in range(n):
            with tr.span("bench", rank=0):
                pass
        samples.append((time.perf_counter() - t0) / n)
        tr.clear()
    samples.sort()
    assert samples[len(samples) // 2] < 5e-6, samples


# ------------------------------------------------------- chrome trace sink
def _toy_tracer() -> Tracer:
    tr = Tracer()
    for r in (0, 1):
        with tr.span("density", rank=r, units=8):
            pass
        with tr.span("force", rank=r):
            pass
    tr.record_all(range(2), "exchange1", tr.now(), collective=1)
    return tr


def test_chrome_trace_schema_valid_and_ordered():
    doc = chrome_trace(_toy_tracer().spans, process_name="toy")
    assert validate_chrome_trace(doc) == []
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert [e["ts"] for e in xs] == sorted(e["ts"] for e in xs)
    assert {e["tid"] for e in xs} == {0, 1}
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert any(m["name"] == "thread_name" for m in metas)


def test_chrome_trace_validator_catches_tampering():
    doc = chrome_trace(_toy_tracer().spans)
    bad = json.loads(json.dumps(doc))
    bad["traceEvents"][-1]["dur"] = -1.0
    assert validate_chrome_trace(bad)
    bad = json.loads(json.dumps(doc))
    xs = [e for e in bad["traceEvents"] if e["ph"] == "X"]
    xs[0]["ts"], xs[-1]["ts"] = xs[-1]["ts"], xs[0]["ts"]
    assert validate_chrome_trace(bad)
    bad = json.loads(json.dumps(doc))
    bad["traceEvents"] = [e for e in bad["traceEvents"]
                          if e.get("name") != "thread_name"]
    assert validate_chrome_trace(bad)             # rank mapping lost


# ------------------------------------------------- spec coercion / wiring
def test_observe_spec_coercion():
    assert SimulationSpec().observe == ObserveSpec(enabled=False)
    assert SimulationSpec(observe=True).observe.enabled
    ospec = SimulationSpec(observe={"trace": False}).observe
    assert ospec.enabled and not ospec.trace and ospec.metrics
    with pytest.raises(ValueError, match="observe"):
        SimulationSpec(observe=3.14)


@pytest.mark.parametrize("integrator,backend", [
    ("global", "local"), ("timebin", "local"),
    ("global", "distributed"), ("timebin", "distributed")])
def test_every_quadrant_reports_wall_and_observes(integrator, backend):
    kw = dict(SCENARIOS["sedov"])
    kw.update(integrator=integrator, backend=backend, dt=0.004,
              observe=True)
    if backend == "distributed":
        kw.update(ranks=1)
    sim = build_simulation(SimulationSpec(**kw))
    stats = sim.step()
    assert stats["wall"] > 0.0
    assert sim.observer is not None
    rec = sim.observer.records[-1]
    assert rec["cycle"] == 0 and rec["wall"] == stats["wall"]
    assert sim.observer.tracer.spans          # something was traced


# ------------------------------------------------ bitwise non-interference
@pytest.mark.slow
@pytest.mark.parametrize("transport,residency",
                         [("host", "host"), ("collective", "device")])
def test_tracing_is_bitwise_invisible(transport, residency):
    """observe=True vs observe=False: identical trajectories, the fences
    only wait on values the untraced run computes anyway."""
    spec = _timebin_spec("sedov", backend="distributed", ranks=1,
                         transport=transport, residency=residency,
                         observe=True)
    got = _trajectory(build_simulation(spec))
    _assert_bitwise(got, _reference("sedov"),
                    f"traced/{transport}/{residency}")


@pytest.mark.slow
def test_tracing_is_bitwise_invisible_local_timebin():
    spec = _timebin_spec("sedov", observe=True)
    got = _trajectory(build_simulation(spec))
    _assert_bitwise(got, _reference("sedov"), "traced/local-timebin")


@pytest.mark.slow
def test_tracing_mints_no_extra_programs():
    base = _timebin_spec("sedov", backend="distributed", ranks=1,
                         transport="collective", residency="device")
    plain = build_simulation(base)
    traced = build_simulation(_timebin_spec(
        "sedov", backend="distributed", ranks=1, transport="collective",
        residency="device", observe=True))
    for _ in range(2):
        plain.step()
        traced.step()
    assert traced.engine.probe.total_compiles() \
        == plain.engine.probe.total_compiles()
    assert traced.engine.probe.counts() == plain.engine.probe.counts()


# -------------------------------------------- ledger fidelity + sinks e2e
@pytest.mark.slow
def test_metrics_record_agrees_exactly_with_probes(tmp_path):
    spec = _timebin_spec("sedov", backend="distributed", ranks=1,
                         transport="collective", residency="device",
                         observe=True)
    sim = build_simulation(spec)
    for _ in range(2):
        sim.step()
    obs, eng = sim.observer, sim.engine
    rec = obs.records[-1]
    assert rec["compiles"] == jsonify(eng.probe.counts())
    assert rec["total_compiles"] == eng.probe.total_compiles()
    assert rec["transfers"] == jsonify(eng.transfers.stats())
    assert rec["schema"] == METRICS_SCHEMA_VERSION

    trace_path = tmp_path / "trace.json"
    metrics_path = tmp_path / "metrics.jsonl"
    doc = obs.export_chrome_trace(str(trace_path))
    obs.write_metrics_jsonl(str(metrics_path))
    assert validate_chrome_trace(doc) == []
    assert validate_chrome_trace(json.loads(trace_path.read_text())) == []
    back = read_metrics_jsonl(str(metrics_path))
    assert len(back) == 2
    assert back[-1]["transfers"] == rec["transfers"]
    assert back[-1]["total_compiles"] == rec["total_compiles"]
    # every force sub-step shows up as a fused-program slice on the row
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    nsub = sum(r["force_substeps"] for r in obs.records)
    fused = [e for e in xs
             if e["name"] in ("fused_substep", "fused_final")]
    assert len(fused) >= nsub
    # cost feedback reached the engine's model
    assert obs.records[-1]["cost_ratios"]
    assert any(v > 0 for v in obs.records[-1]["observed_units"].values())


# --------------------------------------------------- compile-probe fallback
def test_compile_probe_counts_signatures_not_minus_one():
    from repro.distributed.transport import CompileProbe
    probe = CompileProbe()
    with pytest.warns(RuntimeWarning, match="no jit cache"):
        fn = probe.register("plain", lambda x: x + 1)
    assert probe.counts() == {"plain": 0}
    fn(np.zeros(3, np.float32))
    fn(np.zeros(3, np.float32))                   # same signature: no growth
    assert probe.counts() == {"plain": 1}
    fn(np.zeros(4, np.float32))                   # new shape: new "compile"
    fn(np.zeros(3, np.float64))                   # new dtype: new "compile"
    assert probe.counts() == {"plain": 3}
    assert probe.total_compiles() == 3
    assert all(c >= 0 for c in probe.counts().values())


def test_compile_probe_keeps_jit_cache_when_present():
    import jax
    from repro.distributed.transport import CompileProbe
    probe = CompileProbe()
    fn = probe.register("jitted", jax.jit(lambda x: x * 2))
    fn(np.zeros(3, np.float32))
    assert probe.counts()["jitted"] == 1


# ------------------------------------------------------ cost-model feedback
def test_cost_model_observe_and_ratio():
    cm = CostModel(rates={"density": 2e-9})
    assert cm.observed_units("density") == 0.0
    assert cm.observed_rate("density") is None
    cm.observe("density", units=1000.0, seconds=4e-6)      # 4e-9 s/unit
    cm.observe("density", units=1000.0, seconds=4e-6)
    assert cm.observed_units("density") == 2000.0
    assert cm.observed_seconds("density") == pytest.approx(8e-6)
    assert cm.observed_rate("density") == pytest.approx(4e-9)
    ratios = cm.measured_vs_modelled()
    # measured twice the modelled baseline rate, baseline frozen pre-EMA
    assert ratios["density"] == pytest.approx(2.0)
    assert cm.modelled_baseline["density"] == pytest.approx(2e-9)
    assert cm.rates["density"] > 2e-9              # EMA pulled toward measured


# ------------------------------------------------------------- report CLI
def test_trace_report_renders_timeline_and_tables(tmp_path):
    from repro.analysis.report import (metrics_summary, render_timeline,
                                       trace_report)
    doc = chrome_trace(_toy_tracer().spans)
    text = render_timeline(doc, width=40)
    # row labels come from the trace's thread_name metadata ("rank N"
    # for rank traces, request ids for fleet traces)
    assert "rank 0 |" in text and "rank 1 |" in text
    assert "legend:" in text and "D=density" in text
    assert all(n not in UMBRELLA_SPANS
               for n in ("density", "force", "exchange1"))

    records = [{"cycle": 0, "wall": 0.5, "imbalance": 1.25,
                "dead_frac": 0.1, "updates": 216, "total_compiles": 3},
               {"cycle": 1, "wall": 0.4, "imbalance": None,
                "dead_frac": None, "updates": 216,
                "cost_ratios": {"density": 1.5},
                "observed_units": {"density": 4000.0}}]
    table = metrics_summary(records)
    assert "1.250" in table and "measured vs modelled" in table

    trace_path = tmp_path / "trace.json"
    trace_path.write_text(json.dumps(doc))
    metrics_path = tmp_path / "metrics.jsonl"
    write_metrics_jsonl(str(metrics_path), records)
    out = trace_report(str(trace_path), str(metrics_path), width=40)
    assert "task timeline" in out and "per-cycle summary" in out


# ------------------------------------- device metrics / flight recorder
def test_upgrade_record_chains_v1_to_v3():
    from repro.observability import upgrade_record
    v1 = {"schema": 1, "cycle": 3, "wall": 0.5, "imbalance": 1.2}
    up = upgrade_record(dict(v1))
    assert up["schema"] == METRICS_SCHEMA_VERSION == 3
    assert up["schema_original"] == 1
    # the v1→v2 step's columns…
    for key in ("device_metrics", "device_phase_units",
                "device_imbalance", "health"):
        assert key in up and up[key] is None
    # …then the v2→v3 step's columns, applied in the same pass
    for key in ("cell_work", "cost_calibration", "advisor"):
        assert key in up and up[key] is None
    assert up["cost_ratios"] == {} and up["observed_units"] == {}
    assert up["cycle"] == 3 and up["imbalance"] == 1.2


def test_upgrade_record_v2_to_v3_round_trip():
    from repro.observability import upgrade_record
    v2 = {"schema": 2, "cycle": 7, "device_imbalance": 1.1,
          "health": {"tripped": False},
          "cost_ratios": {"density": 1.5}}
    up = upgrade_record(dict(v2))
    assert up["schema"] == 3 and up["schema_original"] == 2
    # v2 payload survives untouched; only the missing v3 columns appear
    assert up["device_imbalance"] == 1.1
    assert up["health"] == {"tripped": False}
    assert up["cost_ratios"] == {"density": 1.5}
    assert up["cell_work"] is None and up["advisor"] is None
    # upgrading an already-current record is the identity
    assert upgrade_record(dict(up)) == up


def test_upgrade_record_rejects_newer_schema():
    from repro.observability import upgrade_record
    with pytest.raises(ValueError, match="newer"):
        upgrade_record({"schema": METRICS_SCHEMA_VERSION + 1, "cycle": 0})
    # tampered/nonsense versions that claim the future are refused too
    with pytest.raises(ValueError):
        upgrade_record({"schema": 99})


def test_report_renders_dash_for_pre_v3_records():
    from repro.analysis.report import advisor_trend, attribution_table
    old = [{"schema": 1, "cycle": 0, "wall": 0.5},
           {"schema": 2, "cycle": 1, "wall": 0.4,
            "device_imbalance": 1.1}]
    table = attribution_table(old)
    assert "-" in table and "predates schema v3" in table
    trend = advisor_trend(old)
    lines = [ln for ln in trend.splitlines() if ln.strip()]
    assert any(ln.split()[1] == "-" for ln in lines
               if ln.split() and ln.split()[0].isdigit())
    assert "no advisor records" in trend


def test_cost_model_calibrate_recovers_rates():
    rng = np.random.default_rng(0)
    true = {"density": 4e-6, "force": 9e-6, "exchange": 1e-6}
    samples = []
    for _ in range(12):
        units = {k: float(rng.uniform(1e3, 1e5)) for k in true}
        secs = sum(true[k] * u for k, u in units.items())
        samples.append((units, secs))
    cm = CostModel(rates={"density": 1e-9})
    cal = cm.calibrate(samples)
    for kind, rate in true.items():
        assert cal[kind]["rate"] == pytest.approx(rate, rel=1e-6)
        assert cal[kind]["confidence"] == pytest.approx(1.0, abs=1e-6)
    # fitted rates folded into the model's EMA stream
    assert cm.rates["density"] > 1e-9


def test_task_cost_ledger_warmup_residual_and_weights():
    from repro.observability import TaskCostLedger
    cm = CostModel(rates={"density": 1e-9})
    led = TaskCostLedger(cm, skip_first=1)
    # cycle 0: compile-dominated wall — observed, but not in the window
    led.record({"density": 100.0, "force": 100.0}, 50.0)
    assert led.snapshot()["nsamples"] == 0
    rng = np.random.default_rng(1)
    for _ in range(6):
        # unit mixes must vary cycle to cycle or the kinds are collinear
        # and only their joint rate is identifiable
        u = {"density": float(rng.uniform(50, 500)),
             "force": float(rng.uniform(50, 500))}
        led.record(u, 4e-6 * u["density"] + 8e-6 * u["force"])
    snap = led.snapshot()
    assert snap["nsamples"] == 6
    assert snap["residual"] is not None and snap["residual"] < 0.05
    assert led.rate("density") == pytest.approx(4e-6, rel=1e-3)
    assert led.rate("force") == pytest.approx(8e-6, rel=1e-3)
    cell_work = {"columns": ["drift", "density", "force", "exchange"],
                 "cells": np.array([[0.0, 10.0, 0.0, 0.0],
                                    [0.0, 0.0, 10.0, 0.0]])}
    w = led.cell_weights(cell_work)
    assert w[1] / w[0] == pytest.approx(2.0, rel=1e-3)


@pytest.mark.slow
def test_calibration_band_on_traced_sedov():
    """Acceptance: after warmup, the joint fit predicts the fused wall
    of a traced Sedov run within a pinned band (the warmup cycle and
    mid-run compile spikes are excluded from the window, like any
    benchmark's warmup)."""
    spec = _timebin_spec("sedov", backend="distributed", ranks=1,
                         transport="collective", residency="device",
                         observe=True)
    sim = build_simulation(spec)
    for _ in range(5):
        sim.step()
    cal = sim.observer.records[-1]["cost_calibration"]
    assert cal is not None and cal["kinds"]
    assert cal["nsamples"] >= 2
    assert cal["residual"] is not None and cal["residual"] < 0.5
    assert all(v["rate"] >= 0 for v in cal["kinds"].values())


def test_weighted_imbalance_counts_empty_ranks():
    from repro.observability import weighted_imbalance
    # all weight on rank 0 of 4 → max/mean = 4
    assert weighted_imbalance([0, 0], [1.0, 1.0], 4) \
        == pytest.approx(4.0)
    assert weighted_imbalance([0, 1], [1.0, 1.0], 2) \
        == pytest.approx(1.0)


@pytest.mark.slow
def test_advisor_improves_clustered_imbalance():
    """Acceptance: on a clustered scenario the advisor's replay of the
    partitioner with *measured* weights never reports worse than the
    current partition, and actually improves it."""
    spec = SimulationSpec(
        scenario="clustered", scenario_params={"n": 96, "seed": 0},
        physics=SPHConfig(alpha_visc=1.0, cfl=0.15),
        dt_max=0.02, max_depth=3, integrator="timebin",
        backend="distributed", ranks=4, transport="host",
        observe=True)
    sim = build_simulation(spec)
    advs = []
    for _ in range(2):
        sim.step()
        rec = sim.observer.records[-1]
        assert rec["cell_work"] is not None
        adv = rec["advisor"]
        assert adv is not None
        advs.append(adv)
        assert adv["advised_imbalance"] \
            <= adv["current_imbalance"] + 1e-9
    # clustered ICs leave the occupancy-seeded partition measurably
    # imbalanced; the measured-weight replay must find a better one
    assert any(a["accepted"] for a in advs)
    assert advs[-1]["advised_imbalance"] < advs[-1]["current_imbalance"]
    assert advs[-1]["per_cell_ratio"]["mean"] > 0


@pytest.mark.slow
def test_per_cell_units_match_value_columns_host_dist():
    """Host-transport distributed ladder: per-rank sums of the per-cell
    drift/density/force vectors equal the device-metrics value columns
    exactly (exchange is receiver-side truth, checked >= 0)."""
    from repro.observability import CELL_COLUMNS
    from repro.observability import device_metrics as dm
    spec = SimulationSpec(
        scenario="clustered", scenario_params={"n": 96, "seed": 0},
        physics=SPHConfig(alpha_visc=1.0, cfl=0.15),
        dt_max=0.02, max_depth=3, integrator="timebin",
        backend="distributed", ranks=4, transport="host",
        observe=True)
    sim = build_simulation(spec)
    sim.step()
    eng = sim.engine
    cw = eng.device_cell_work_last
    assert cw is not None and list(cw["columns"]) == list(CELL_COLUMNS)
    cells = np.asarray(cw["cells"])
    per_rank = np.asarray(cw["per_rank"])
    # folding halo rows onto owners conserves every column
    np.testing.assert_allclose(cells.sum(axis=0), per_rank.sum(axis=0),
                               rtol=1e-6)
    counts, values = eng.device_metrics_last
    counts, values = np.asarray(counts), np.asarray(values)
    ci = {k: i for i, k in enumerate(CELL_COLUMNS)}
    for kind in ("density", "force"):
        want = values[:, dm.VALUE_INDEX[f"{kind}_units"]].sum()
        got = per_rank[:, ci[kind]].sum()
        assert got == pytest.approx(want, rel=1e-6), kind
    assert per_rank[:, ci["drift"]].sum() == pytest.approx(
        counts[:, dm.COUNT_INDEX["drift_active"]].sum(), rel=1e-6)
    assert (cells >= 0).all()


def test_local_quadrant_density_cells_sum_to_pairs():
    kw = dict(SCENARIOS["sedov"])
    kw.update(integrator="global", backend="local", dt=0.004,
              observe=True)
    sim = build_simulation(SimulationSpec(**kw))
    sim.step()
    cw = sim.engine.device_cell_work_last
    assert cw is not None
    cells = np.asarray(cw["cells"])
    cols = list(cw["columns"])
    npairs = int(np.asarray(sim.engine.pairs.ci).shape[0])
    assert cells[:, cols.index("density")].sum() == pytest.approx(npairs)
    assert cells[:, cols.index("force")].sum() == pytest.approx(npairs)


def test_end_cycle_always_emits_v3_keys():
    """``cost_ratios`` (and friends) are always present — empty/None
    fallbacks, never missing keys — so downstream readers need no
    per-key existence checks."""
    kw = dict(SCENARIOS["sedov"])
    kw.update(integrator="global", backend="local", dt=0.004,
              observe=True)
    sim = build_simulation(SimulationSpec(**kw))
    sim.step()
    rec = sim.observer.records[-1]
    assert rec["schema"] == METRICS_SCHEMA_VERSION
    assert "cost_ratios" in rec and isinstance(rec["cost_ratios"], dict)
    assert "observed_units" in rec \
        and isinstance(rec["observed_units"], dict)
    for key in ("cell_work", "cost_calibration", "advisor"):
        assert key in rec
    # jsonl round-trip preserves the always-present contract
    buf = json.loads(json.dumps(jsonify(rec)))
    assert "cost_ratios" in buf


def test_flight_recorder_ring_dump_and_validation(tmp_path):
    from repro.observability import (COUNT_COLUMNS, VALUE_COLUMNS,
                                     FlightRecorder, read_bundle,
                                     validate_bundle)
    from repro.observability import device_metrics as dm
    fr = FlightRecorder(k=3)
    for cyc in range(5):
        counts, values = dm.zero_rows(2)
        counts[:, 0] = cyc + 1
        fr.record(cyc, counts, values)
    assert [r["cycle"] for r in fr.rows()] == [2, 3, 4]  # keeps last 3
    path = fr.dump(str(tmp_path), reason="unit test!", cycle=4,
                   extra={"note": "x"})
    manifest = validate_bundle(path)
    assert manifest["reason"] == "unit test!"
    assert manifest["cycle"] == 4 and manifest["records"] == 3
    assert manifest["ring_cycles"] == [2, 3, 4]
    assert manifest["note"] == "x"
    bundle = read_bundle(path)
    assert bundle["records"][0]["count_columns"] == list(COUNT_COLUMNS)
    assert bundle["records"][-1]["counts"][0][0] == 5
    assert len(bundle["records"][0]["values"][0]) == len(VALUE_COLUMNS)
    # tampering is caught
    mpath = tmp_path / path.split("/")[-1] / "manifest.json"
    doc = json.loads(mpath.read_text())
    doc["records"] = 99
    mpath.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="record count"):
        validate_bundle(path)


@pytest.mark.slow
def test_nan_sentinel_trips_and_dumps_flight_bundle(tmp_path):
    """Poisoning one velocity component trips the in-program NaN sentinel
    on the very next cycle and drops a validated post-mortem bundle whose
    manifest names that cycle."""
    from repro.observability.flight import validate_bundle
    import jax.numpy as jnp
    spec = _timebin_spec("sedov", backend="distributed", ranks=1,
                         transport="collective", residency="device",
                         observe={"flight_dir": str(tmp_path)})
    sim = build_simulation(spec)
    sim.step()
    obs, eng = sim.observer, sim.engine
    assert obs.records[-1]["health"]["tripped"] is False
    assert not obs.flight.dumps

    cells = eng.state.cells
    vel = np.asarray(cells.vel).copy()
    c, p = np.argwhere(np.asarray(cells.mask) > 0)[0]
    vel[c, p, 0] = np.nan
    eng.state = eng.state._replace(cells=cells._replace(vel=jnp.asarray(vel)))
    with np.errstate(invalid="ignore"):
        sim.step()

    rec = obs.records[-1]
    assert rec["health"]["tripped"] is True
    assert rec["health"]["flags"]["flag_nan"] > 0
    assert rec["flight_dump"] == obs.flight.dumps[-1]
    manifest = validate_bundle(rec["flight_dump"])
    assert manifest["reason"] == "nan"
    assert manifest["cycle"] == 1             # tripped on the second cycle
    assert obs.registry.snapshot()["counters"]["sentinel_trips"] == 1
    assert obs.registry.snapshot()["counters"]["flight_dumps"] == 1
