import os
import sys

# Tests run on the single real CPU device — NEVER set a fake device count
# here (the dry-run sets 512 in its own process only).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_default_matmul_precision", "float32")
