"""Benchmark-harness helpers: the repo-root BENCH artifacts stay valid.

The benchmark modules each leave a headline ``BENCH_*.json`` at the repo
root; ``benchmarks/common.py`` (standalone-runnable, factored out of the
full ``benchmarks.run`` sweep) validates them into the trajectory block
of ``summary.json``. These tests pin that contract without running any
benchmark: the three checked-in artifacts must parse, name their
benchmark, and never claim a metrics schema newer than this tree.
"""

import json
import os
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, ROOT)

from benchmarks.common import bench_trajectory, env_provenance

EXPECTED_ARTIFACTS = ("BENCH_fleet.json", "BENCH_fused_cycles.json",
                      "BENCH_observability.json")


def test_bench_trajectory_nonempty_and_valid():
    traj = bench_trajectory()
    assert traj, "no BENCH_*.json artifacts found at the repo root"
    by_file = {e["file"]: e for e in traj}
    for fname in EXPECTED_ARTIFACTS:
        assert fname in by_file, f"missing artifact {fname}"
        entry = by_file[fname]
        assert entry["valid"], f"{fname}: {entry['problems']}"
        assert entry["benchmark"]
        assert entry["problems"] == []


def test_bench_trajectory_flags_malformed_artifact(tmp_path):
    (tmp_path / "BENCH_broken.json").write_text("{not json")
    (tmp_path / "BENCH_list.json").write_text("[1, 2]")
    (tmp_path / "BENCH_anon.json").write_text("{}")
    (tmp_path / "BENCH_future.json").write_text(
        json.dumps({"benchmark": "x", "metrics_schema_version": 999}))
    traj = {e["file"]: e for e in bench_trajectory(str(tmp_path))}
    assert len(traj) == 4
    assert not traj["BENCH_broken.json"]["valid"]
    assert not traj["BENCH_list.json"]["valid"]
    assert not traj["BENCH_anon.json"]["valid"]
    assert not traj["BENCH_future.json"]["valid"]
    assert any("newer" in p or "schema" in p
               for p in traj["BENCH_future.json"]["problems"])


def test_env_provenance_reports_toolchain():
    env = env_provenance()
    assert env["python"] and env["platform"]
    assert "jax" in env
    assert env.get("metrics_schema_version", 0) >= 3


def test_common_is_standalone_runnable():
    import subprocess
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "common.py")],
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr[-2000:]
    doc = json.loads(proc.stdout)
    assert doc["_bench_trajectory"]
    assert all(e["valid"] for e in doc["_bench_trajectory"])
