"""Prefill + decode must reproduce the train-forward logits exactly.

Covers every cache mechanism: full KV, rolling sliding-window KV (gemma3
local / mixtral SWA), SSM+conv states (mamba1/2), shared-attention caches
(zamba2), and enc-dec cross caches (seamless). A subset of archs keeps the
suite fast; all 10 are covered across this file and the smoke tests.
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import forward, init_params
from repro.serve.serve_step import decode_step, prefill

B, S, S0 = 2, 64, 32

ARCHS = ["gemma3-27b",          # local rolling + global full caches
         "mixtral-8x7b",        # MoE + SWA
         "falcon-mamba-7b",     # pure SSM states
         "zamba2-1.2b",         # hybrid + shared attn cache
         "seamless-m4t-large-v2"]  # enc-dec cross cache


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_train_forward(arch):
    cfg = dataclasses.replace(get_config(arch, reduced=True),
                              dtype=jnp.float32)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kwargs = {}
    extra = 0
    if cfg.is_encdec:
        kwargs["enc_inputs"] = jax.random.normal(
            key, (B, 16, cfg.d_model)) * 0.1
    if cfg.vlm_patches:
        kwargs["patch_embeds"] = jax.random.normal(
            key, (B, cfg.vlm_patches, cfg.d_model)) * 0.1
        extra = cfg.vlm_patches

    ref = forward(params, cfg, tokens, mode="train", **kwargs).logits
    if extra:
        ref = ref[:, extra:]

    logits0, caches, rolling = prefill(params, cfg, tokens[:, :S0],
                                       cache_len=S + extra, **kwargs)
    scale = max(float(jnp.abs(ref).max()), 1.0)
    assert float(jnp.abs(logits0 - ref[:, S0 - 1]).max()) < 2e-3 * scale

    pos = jnp.asarray(S0 + extra, jnp.int32)
    worst = 0.0
    for t in range(S0, S):
        lg, caches = decode_step(params, cfg, tokens[:, t:t + 1], caches,
                                 pos, rolling=rolling)
        worst = max(worst, float(jnp.abs(lg - ref[:, t]).max()))
        pos = pos + 1
    assert worst < 2e-3 * scale, f"{arch}: {worst}"


def test_rolling_cache_matches_full_cache():
    """Sliding-window decode with a rolling (wrap-around) cache must equal
    decode with a big non-rolling cache."""
    cfg = dataclasses.replace(get_config("mixtral-8x7b", reduced=True),
                              dtype=jnp.float32, window=16)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    ref = forward(params, cfg, tokens, mode="train").logits

    # rolling path: cache_len = S (> window 16 → rolling buffers)
    _, caches, rolling = prefill(params, cfg, tokens[:, :S0], cache_len=S)
    assert rolling.get("moe", False), "expected rolling caches"
    pos = jnp.asarray(S0, jnp.int32)
    worst = 0.0
    scale = max(float(jnp.abs(ref).max()), 1.0)
    for t in range(S0, S):
        lg, caches = decode_step(params, cfg, tokens[:, t:t + 1], caches,
                                 pos, rolling=rolling)
        worst = max(worst, float(jnp.abs(lg - ref[:, t]).max()))
        pos = pos + 1
    assert worst < 2e-3 * scale, worst


def test_greedy_generate_runs():
    cfg = dataclasses.replace(get_config("granite-8b", reduced=True),
                              dtype=jnp.float32)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    prompt = jax.random.randint(key, (1, 8), 0, cfg.vocab)
    from repro.serve.serve_step import greedy_generate
    out = greedy_generate(params, cfg, prompt, n_new=6)
    assert out.shape == (1, 6)
    assert (np.asarray(out) >= 0).all()
