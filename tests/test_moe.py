"""MoE layer: routing correctness, load stats, differentiability."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.moe import init_moe, moe


def dense_reference(p, x, top_k):
    """Every expert on every token, combined by top-k gates — equals the
    dispatch path when capacity is unbounded."""
    B, S, d = x.shape
    E = p["router"].shape[1]
    xt = x.reshape(-1, d)
    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    outs = []
    for e in range(E):
        h = jax.nn.silu(xt @ p["wg"][e]) * (xt @ p["wi"][e])
        outs.append(h @ p["wo"][e])
    outs = jnp.stack(outs, 1)                      # (N, E, d)
    sel = jnp.take_along_axis(outs, gi[..., None], axis=1)
    return (sel * gv[..., None]).sum(1).reshape(B, S, d)


def test_moe_matches_dense_reference_when_no_drops():
    key = jax.random.PRNGKey(0)
    p = init_moe(key, 16, 32, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16)) * 0.5
    out, stats = moe(p, x, top_k=2, capacity_factor=8.0)
    want = dense_reference(p, x, 2)
    assert float(stats.dropped_fraction) == 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    key = jax.random.PRNGKey(2)
    p = init_moe(key, 16, 32, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, 16))
    _, stats = moe(p, x, top_k=2, capacity_factor=0.3)
    assert float(stats.dropped_fraction) > 0.0


def test_moe_stats_counts():
    key = jax.random.PRNGKey(4)
    p = init_moe(key, 8, 16, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 64, 8))
    _, stats = moe(p, x, top_k=2, capacity_factor=8.0)
    # every token routes to exactly top_k experts
    assert float(stats.tokens_per_expert.sum()) == 64 * 2
    assert float(stats.aux_loss) > 0.0


def test_moe_gradients_flow_through_gates():
    key = jax.random.PRNGKey(6)
    p = init_moe(key, 8, 16, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 16, 8))

    def loss(p):
        out, stats = moe(p, x, top_k=2, capacity_factor=8.0)
        return jnp.sum(out ** 2) + 0.01 * stats.aux_loss

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0.0
    assert float(jnp.abs(g["wi"]).max()) > 0.0
    assert not any(bool(jnp.isnan(l).any()) for l in jax.tree.leaves(g))
