"""Fleet serving contracts: signatures, batching, parity, compile counts.

The subsystem's three load-bearing claims, asserted:

* **Grouping is sound.** A frozen spec hashes/compares by *content*
  (scenario_params insertion order is canonicalised away), program
  signatures separate shape from value (different blast energies batch
  together; different lattice sides do not).
* **Batching is invisible.** A batched fleet of N heterogeneous requests
  produces, per request, *bitwise* the particles of N sequential
  single-simulation runs (the vmap path; scenario fixtures reused from
  ``test_conformance``).
* **Compiles are bounded.** Wobbling arrival sizes (3, 7, 5, 8) cost one
  XLA compile per (signature, batch-bucket) — counted by ``CompileProbe``
  from the jit caches, not inferred.
"""

import numpy as np
import pytest
import jax

from test_conformance import SCENARIOS, requires4

from repro.fleet import (AdmissionError, FleetRunner, RequestState,
                         SignatureBatcher, TransferBufferPool,
                         sequential_reference, split_scenario_params)
from repro.sph import SimulationSpec, SPHConfig


def _spec(scenario, **overrides):
    """A global×local spec from the conformance fixtures (which pin the
    timebin fields; the fleet's batched quadrant ignores those)."""
    kw = dict(SCENARIOS[scenario])
    kw.pop("dt_max", None)
    kw.pop("max_depth", None)
    params = dict(kw.pop("scenario_params"))
    params.update(overrides.pop("scenario_params", {}))
    kw.update(overrides)
    return SimulationSpec(scenario_params=params, **kw)


# ------------------------------------------------------------- signatures
class TestSpecHashing:
    def test_insertion_order_canonicalised(self):
        a = SimulationSpec(scenario="sedov",
                           scenario_params={"n_side": 5, "e0": 1.0,
                                            "seed": 3})
        b = SimulationSpec(scenario="sedov",
                           scenario_params={"seed": 3, "n_side": 5,
                                            "e0": 1.0})
        assert a == b
        assert hash(a) == hash(b)
        assert a.program_signature() == b.program_signature()
        assert a.signature_key() == b.signature_key()

    def test_spec_usable_as_dict_key(self):
        a = SimulationSpec(scenario_params={"x": 1, "y": 2})
        b = SimulationSpec(scenario_params={"y": 2, "x": 1})
        assert len({a: 0, b: 1}) == 1

    def test_params_mapping_still_reads_like_a_dict(self):
        a = SimulationSpec(scenario_params={"n_side": 5, "seed": 3})
        assert a.scenario_params["n_side"] == 5
        assert dict(a.scenario_params) == {"n_side": 5, "seed": 3}

    def test_value_params_share_signature(self):
        a = _spec("sedov", scenario_params={"e0": 1.0, "seed": 0})
        b = _spec("sedov", scenario_params={"e0": 2.5, "seed": 9})
        assert a.signature_key() == b.signature_key()

    def test_shape_params_split_signature(self):
        a = _spec("sedov")
        b = _spec("sedov", scenario_params={"n_side": 4})
        assert a.signature_key() != b.signature_key()

    def test_engine_fields_split_signature(self):
        a = _spec("sedov")
        assert a.signature_key() != \
            _spec("sedov", integrator="timebin").signature_key()
        assert a.signature_key() != \
            _spec("sedov",
                  physics=SPHConfig(alpha_visc=0.5)).signature_key()

    def test_split_scenario_params(self):
        shape, value = split_scenario_params(
            "sedov", {"n_side": 5, "e0": 2.0, "seed": 7})
        assert dict(shape) == {"n_side": 5}
        assert dict(value) == {"e0": 2.0, "seed": 7}


# ------------------------------------------------------------------ queue
class TestQueue:
    def test_admission_bounded(self):
        runner = FleetRunner(max_inflight=2, fleet_devices=1)
        runner.submit(_spec("sedov"))
        runner.submit(_spec("sedov"))
        with pytest.raises(AdmissionError):
            runner.submit(_spec("sedov"))

    def test_deadline_expiry_fires_callback(self):
        runner = FleetRunner(fleet_devices=1)
        seen = []
        req = runner.submit(_spec("sedov"), deadline=0.0,
                            callback=seen.append)
        import time
        time.sleep(0.01)
        dead = runner.queue.expire()
        assert dead == [req]
        assert req.state is RequestState.EXPIRED
        assert isinstance(req.error, TimeoutError)
        assert seen == [req]

    def test_duplicate_request_id_rejected(self):
        runner = FleetRunner(fleet_devices=1)
        runner.submit(_spec("sedov"), request_id="r1")
        with pytest.raises(ValueError):
            runner.submit(_spec("sedov"), request_id="r1")

    def test_expiry_fires_on_poll_without_claim(self):
        """The sweep-only-on-claim bug: a request whose deadline passes
        must reach EXPIRED (callback fired) from a pure status check —
        no take_ready/drain claim anywhere in the sequence."""
        runner = FleetRunner(fleet_devices=1, observe=True)
        seen = []
        req = runner.submit(_spec("sedov"), deadline=0.0,
                            callback=seen.append)
        import time
        time.sleep(0.01)
        stats = runner.poll()
        assert req.state is RequestState.EXPIRED
        assert isinstance(req.error, TimeoutError)
        assert seen == [req]
        assert stats["queue"]["expired"] == 1
        assert runner.terminal_status == {"expired": 1}
        # and the expiry left a visible span on the request's row
        assert [s for s in runner.tracer.spans if s.name == "expired"]

    def test_expiry_fires_on_next_submit(self):
        """A later submission is also a front-door entry: it sweeps the
        stale request out (freeing its admission slot) before admitting."""
        runner = FleetRunner(max_inflight=1, fleet_devices=1)
        seen = []
        stale = runner.submit(_spec("sedov"), deadline=0.0,
                              callback=seen.append)
        import time
        time.sleep(0.01)
        # at max_inflight=1 this would raise AdmissionError if the
        # overdue request still held its slot
        fresh = runner.submit(_spec("sedov"))
        assert stale.state is RequestState.EXPIRED
        assert seen == [stale]
        assert fresh.state is RequestState.QUEUED
        assert runner.terminal_status == {"expired": 1}


# ---------------------------------------------------------------- batcher
class TestBatcher:
    def _reqs(self, n, **overrides):
        from repro.fleet import RequestQueue
        q = RequestQueue()
        return [q.submit(_spec("sedov", **overrides)) for _ in range(n)]

    def test_groups_by_signature(self):
        from repro.fleet import RequestQueue
        q = RequestQueue()
        reqs = [q.submit(_spec("sedov")), q.submit(_spec("kelvin_helmholtz")),
                q.submit(_spec("sedov", scenario_params={"e0": 3.0}))]
        batches = SignatureBatcher().form(reqs)
        assert len(batches) == 2
        assert [b.size for b in batches] == [2, 1]

    def test_buckets_never_shrink(self):
        b = SignatureBatcher()
        sizes = [bb.bucket for bb in (b.form(self._reqs(7))
                                      + b.form(self._reqs(3))
                                      + b.form(self._reqs(5)))]
        assert sizes == [8, 8, 8]       # grew to 8, never back down

    def test_bucket_divisible_by_mesh(self):
        b = SignatureBatcher(min_bucket=4)
        (batch,) = b.form(self._reqs(3))
        assert batch.bucket == 4 and batch.pad == 1

    def test_max_batch_chunks(self):
        b = SignatureBatcher(max_batch=4)
        batches = b.form(self._reqs(10))
        assert [bb.size for bb in batches] == [4, 4, 2]


# ------------------------------------------------------- batched execution
def _served_ok(reqs):
    assert all(r.state is RequestState.DONE for r in reqs), \
        [(r.request_id, r.error) for r in reqs]


class TestBatchedParity:
    """Batched fleet == N sequential runs, bitwise, on the vmap path."""

    def test_heterogeneous_fleet_bitwise(self):
        specs = [
            _spec("sedov", scenario_params={"e0": 1.0, "seed": 0}),
            _spec("sedov", scenario_params={"e0": 1.7, "seed": 1}),
            _spec("sedov", scenario_params={"e0": 0.6, "seed": 2}),
            _spec("kelvin_helmholtz",
                  scenario_params={"v_shear": 0.5, "seed": 0}),
            _spec("kelvin_helmholtz",
                  scenario_params={"v_shear": 0.8, "seed": 3}),
        ]
        runner = FleetRunner(fleet_devices=1)
        reqs = [runner.submit(s, n_steps=3) for s in specs]
        runner.drain()
        _served_ok(reqs)
        assert all(r.result.batched for r in reqs)
        for r in reqs:
            ref = sequential_reference(r.spec, r.n_steps)
            assert r.result.particles.keys() == ref.particles.keys()
            for k in r.result.particles:
                np.testing.assert_array_equal(
                    np.asarray(r.result.particles[k]),
                    np.asarray(ref.particles[k]),
                    err_msg=f"{r.request_id}: field {k} not bitwise")
            assert r.result.t == ref.t

    def test_heterogeneous_step_counts(self):
        """Members with different n_steps finish at their own horizon."""
        runner = FleetRunner(fleet_devices=1)
        reqs = [runner.submit(_spec("sedov",
                                    scenario_params={"e0": 1.0 + i,
                                                     "seed": i}),
                              n_steps=n)
                for i, n in enumerate([2, 4, 3])]
        runner.drain()
        _served_ok(reqs)
        for r, n in zip(reqs, [2, 4, 3]):
            assert r.result.steps == n
            ref = sequential_reference(r.spec, n)
            for k in r.result.particles:
                np.testing.assert_array_equal(
                    np.asarray(r.result.particles[k]),
                    np.asarray(ref.particles[k]))

    def test_timebin_quadrant_served_sequentially(self):
        kw = dict(SCENARIOS["sedov"])
        spec = SimulationSpec(integrator="timebin", **kw)
        runner = FleetRunner(fleet_devices=1)
        req = runner.submit(spec, n_steps=1)
        runner.drain()
        _served_ok([req])
        assert not req.result.batched
        assert req.result.energy == pytest.approx(
            sequential_reference_timebin(spec).energy, rel=1e-5)


def sequential_reference_timebin(spec):
    """One time-bin cycle on the plain path, diagnostics only."""
    from repro.sph import build_simulation
    from repro.fleet.queue import FleetResult
    sim = build_simulation(spec)
    sim.step()
    e, p = sim.diagnostics()
    return FleetResult(particles={}, energy=e, momentum=p, t=sim.time,
                       steps=1, wall=0.0, batched=False)


# --------------------------------------------------------- compile counts
class TestCompileDiscipline:
    def test_wobbling_arrivals_one_compile_per_bucket(self):
        """Arrival waves of 3, 7, 5, 8 same-signature requests: buckets 4
        and 8 exist, so exactly two (step, cfl) entry-point pairs compile,
        each exactly once — wave sizes never reach the XLA compiler."""
        runner = FleetRunner(fleet_devices=1)
        i = 0
        for wave in (3, 7, 5, 8):
            for _ in range(wave):
                runner.submit(_spec("sedov",
                                    scenario_params={"seed": i,
                                                     "e0": 1.0 + 0.01 * i}),
                              n_steps=1)
                i += 1
            runner.drain()
        stats = runner.queue.stats()
        assert stats["done"] == 23
        counts = runner.compile_counts()
        step_programs = [k for k in counts if "fleet_step" in k]
        assert len(step_programs) == 2, counts      # buckets 4 and 8 only
        assert all(c == 1 for c in counts.values()), counts
        runner.assert_compile_discipline()
        assert set(runner.batcher.policy._bucket.values()) == {8}

    def test_second_same_signature_fleet_compiles_nothing(self):
        runner = FleetRunner(fleet_devices=1)
        for wave in (2, 2):
            for i in range(wave):
                runner.submit(_spec("kelvin_helmholtz",
                                    scenario_params={"seed": i}), n_steps=1)
            runner.drain()
        assert runner.programs.builds == 2          # one step + one cfl
        runner.assert_compile_discipline()


# ------------------------------------------------------------ result pool
class TestTransferPool:
    def test_buffers_reused_after_give(self):
        pool = TransferBufferPool()
        a = pool.take(np.arange(6, dtype=np.float32))
        assert pool.stats() == {"hits": 0, "misses": 1, "resident": 0}
        pool.give(a)
        b = pool.take(np.ones(6, dtype=np.float32))
        assert b is a                               # same buffer, new bytes
        assert b[0] == 1.0
        assert pool.stats()["hits"] == 1

    def test_shape_buckets_are_distinct(self):
        pool = TransferBufferPool()
        a = pool.take(np.zeros(4))
        pool.give(a)
        b = pool.take(np.zeros(5))
        assert b is not a
        assert pool.stats()["misses"] == 2


# ------------------------------------------------------------------ trace
class TestFleetTrace:
    def test_rows_named_by_request_id(self):
        from repro.observability.sinks import validate_chrome_trace
        runner = FleetRunner(fleet_devices=1, observe=True)
        reqs = [runner.submit(_spec("sedov",
                                    scenario_params={"seed": i}), n_steps=2)
                for i in range(2)]
        runner.drain()
        _served_ok(reqs)
        doc = runner.export_trace("/dev/null")
        assert validate_chrome_trace(doc) == []
        names = {e["tid"]: e["args"]["name"] for e in doc["traceEvents"]
                 if e.get("name") == "thread_name"}
        assert set(names.values()) == {r.request_id for r in reqs}
        slices = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
        assert slices and all(
            e["args"].get("request_id") in names.values() for e in slices)


# -------------------------------------------------------------- 4 devices
@requires4
class TestShardedFleet:
    def test_fleet_axis_sharded_over_mesh(self):
        """8 requests over 4 devices: the fleet axis shards 2 lanes per
        device; per-device SPMD partitioning reassociates pair-sum
        reductions, so the sharded contract is ulp-level, not bitwise."""
        runner = FleetRunner(fleet_devices=4)
        reqs = [runner.submit(_spec("sedov",
                                    scenario_params={"seed": i,
                                                     "e0": 1.0 + 0.1 * i}),
                              n_steps=2)
                for i in range(8)]
        runner.drain()
        _served_ok(reqs)
        assert all(r.result.batched and r.result.bucket == 8 for r in reqs)
        runner.assert_compile_discipline()
        for r in reqs:
            ref = sequential_reference(r.spec, r.n_steps)
            for k in r.result.particles:
                np.testing.assert_allclose(
                    np.asarray(r.result.particles[k]),
                    np.asarray(ref.particles[k]), rtol=1e-4, atol=1e-5,
                    err_msg=f"{r.request_id}: field {k}")


# ---------------------------------------------------- terminal visibility
class TestTerminalVisibility:
    def test_expired_sweep_counts_traces_and_dumps(self, tmp_path):
        from repro.observability.flight import validate_bundle
        runner = FleetRunner(fleet_devices=1, observe=True,
                             flight_dir=str(tmp_path))
        ok = runner.submit(_spec("sedov"), n_steps=1)
        import time
        dead = runner.submit(_spec("sedov"), n_steps=1, deadline=0.0)
        time.sleep(0.01)
        runner.drain()
        assert ok.state is RequestState.DONE
        assert dead.state is RequestState.EXPIRED
        # every lane's terminal state is counted, including the swept one
        assert runner.terminal_status == {"done": 1, "expired": 1}
        assert runner.stats()["terminal_status"] == runner.terminal_status
        # the sweep is a first-class span on the lane's timeline row
        spans = [s for s in runner.tracer.spans if s.name == "expired"]
        assert len(spans) == 1
        assert spans[0].attrs["request_id"] == dead.request_id
        assert "deadline" in spans[0].attrs["error"]
        # ...and produced one validated post-mortem bundle
        assert len(runner.flight_dumps) == 1
        manifest = validate_bundle(runner.flight_dumps[0])
        assert manifest["reason"].startswith("expired")
        assert manifest["expired"] == [dead.request_id]

    def test_no_flight_dump_without_flight_dir(self):
        runner = FleetRunner(fleet_devices=1)
        runner.submit(_spec("sedov"), n_steps=1, deadline=0.0)
        import time
        time.sleep(0.01)
        runner.drain()
        assert runner.terminal_status == {"expired": 1}
        assert runner.flight_dumps == []
