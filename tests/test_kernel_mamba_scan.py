"""Selective-scan Pallas kernel vs sequential oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan import selective_scan, selective_scan_ref


def make_inputs(B, S, dI, N, seed=0):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal((B, S, dI)).astype(np.float32))
    dt = jnp.asarray(0.05 + 0.1 * rng.random((B, S, dI)).astype(np.float32))
    A = jnp.asarray(-rng.random((dI, N)).astype(np.float32) - 0.1)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    D = jnp.asarray(rng.random(dI).astype(np.float32))
    return u, dt, A, Bm, Cm, D


@pytest.mark.parametrize("B,S,dI,N,bd", [
    (1, 32, 16, 8, 16),
    (2, 64, 32, 16, 16),
    (1, 128, 64, 16, 32),
    (3, 48, 24, 4, 8),
])
def test_scan_kernel_matches_ref(B, S, dI, N, bd):
    u, dt, A, Bm, Cm, D = make_inputs(B, S, dI, N, seed=S + dI)
    y, h = selective_scan(u, dt, A, Bm, Cm, D, block_d=bd, interpret=True)
    y_ref, h_ref = selective_scan_ref(u, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-5, atol=2e-5)


def test_scan_kernel_matches_model_chunked_path():
    """The kernel, the sequential oracle and the model's chunked scan
    (mamba1_forward internals) must agree."""
    from repro.models.mamba import init_mamba1, mamba1_forward
    B, S, d = 2, 64, 32
    key = jax.random.PRNGKey(0)
    p = init_mamba1(key, d, d_state=8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.1
    y_model, _ = mamba1_forward(p, x, d_state=8, chunk=16)
    y_model2, _ = mamba1_forward(p, x, d_state=8, chunk=64)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_model2),
                               rtol=1e-4, atol=1e-5)


def test_long_sequence_stability():
    """No overflow/NaN across a long scan with small decay."""
    u, dt, A, Bm, Cm, D = make_inputs(1, 512, 16, 8, seed=3)
    y, h = selective_scan(u, dt, A, Bm, Cm, D, block_d=16, interpret=True)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(h)).all()
