"""Communication planning: send/recv generation and message statistics."""

import numpy as np
import pytest

from repro.core import (CommStats, TaskGraph, decompose_with_comm,
                        insert_comm_tasks, pairwise_stats_from_partition,
                        plan_halo_1d, wave_schedule)


def two_rank_graph():
    """4 cells in a ring, ranks {0,0,1,1}: pairs (1,2) and (3,0) are cut."""
    g = TaskGraph()
    sort = [g.add_task("sort", resources=(c,), writes=(c,), cost=1, rank=c // 2)
            for c in range(4)]
    dens = []
    for c in range(4):
        nxt = (c + 1) % 4
        # duplicated on both ranks when cut — here assign to owner of c
        d = g.add_task("density_pair", resources=(c, nxt), writes=(c,),
                       cost=2, rank=c // 2)
        g.add_dependency(d, sort[c])
        g.add_dependency(d, sort[nxt])
        dens.append(d)
    return g


def test_insert_comm_tasks_generates_send_recv_pairs():
    g = two_rank_graph()
    stats = insert_comm_tasks(
        g, resource_rank={c: c // 2 for c in range(4)},
        resource_bytes={c: 6000.0 for c in range(4)},
        phases={"sort": "p0", "density_pair": "p1"})
    kinds = [t.kind for t in g.tasks.values()]
    assert kinds.count("send") == stats.messages
    assert kinds.count("recv") == stats.messages
    assert stats.messages > 0
    assert stats.mean_message_bytes == 6000.0
    # consumers depend on recv; recv on send; graph still acyclic + schedulable
    waves = wave_schedule(g)
    g.validate_schedule(waves)


def test_comm_deduplicated_per_phase():
    """Two consumers of the same remote cell in the same phase share one
    message; a later phase re-sends (paper: positions then densities)."""
    g = TaskGraph()
    s = g.add_task("produce", resources=(0,), writes=(0,), cost=1, rank=0)
    c1 = g.add_task("phase_a", resources=(0,), cost=1, rank=1)
    c2 = g.add_task("phase_a", resources=(0,), cost=1, rank=1)
    c3 = g.add_task("phase_b", resources=(0,), cost=1, rank=1)
    g.add_dependency(c1, s)
    g.add_dependency(c2, s)
    g.add_dependency(c3, s)
    stats = insert_comm_tasks(g, {0: 0}, {0: 100.0},
                              phases={"produce": "p0", "phase_a": "p1",
                                      "phase_b": "p2"})
    assert stats.messages == 2          # one per consuming phase


def test_pairwise_stats_two_phases_per_step():
    edges = {(0, 1): 1.0, (1, 2): 1.0}
    assignment = np.array([0, 0, 1])
    stats = pairwise_stats_from_partition(edges, assignment,
                                          cell_bytes=[10.0, 10.0, 10.0])
    # cut edge (1,2): cell 1 → rank 1 and cell 2 → rank 0, 2 phases each
    assert stats.messages == 4
    assert stats.total_bytes == 40.0


def test_halo_plan_perms():
    plan = plan_halo_1d(axis="data", radius=2)
    perms = plan.perms(4)
    assert len(perms) == 4              # +1, -1, +2, -2
    for p in perms:
        srcs = [a for a, _ in p]
        dsts = [b for _, b in p]
        assert sorted(srcs) == [0, 1, 2, 3]
        assert sorted(dsts) == [0, 1, 2, 3]


def test_decompose_with_comm_end_to_end():
    g = two_rank_graph()
    dist, dec = decompose_with_comm(
        g, 4, 2, cell_bytes=[6000.0] * 4,
        phases={"sort": "p0", "density_pair": "p1"})
    assert dec.comm is not None
    assert dec.comm.messages >= 2
    waves = wave_schedule(dist)
    dist.validate_schedule(waves)
