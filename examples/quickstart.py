"""Quickstart: the paper's pipeline end-to-end at laptop scale.

1. Build a clustered SPH initial condition (EAGLE-like density contrast).
2. Decompose into cells; build the SWIFT task graph (sort → density →
   ghost → force → kick) with dependencies and conflicts.
3. Compile the graph into a wave schedule, partition the cell graph over 4
   simulated ranks, insert send/recv tasks (§3.3), and compare the async
   executor against the bulk-synchronous baseline.
4. Run the real SPH engine for a few steps and verify conservation.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (AsyncExecutorSim, decompose_with_comm,
                        wave_schedule)
from repro.sph import SPHConfig, clustered_ic
from repro.sph.cellgrid import bin_particles, build_pair_list, choose_grid
from repro.sph.engine import build_taskgraph


def main():
    print("=== 1. clustered initial conditions")
    ic = clustered_ic(3000, seed=0)
    print(f"    {len(ic['pos'])} particles, h ∈ "
          f"[{ic['h'].min():.4f}, {ic['h'].max():.4f}] "
          f"({ic['h'].max()/ic['h'].min():.0f}× dynamic range)")

    print("=== 2. cell decomposition + task graph")
    from repro.core import CostModel
    spec = choose_grid(ic["box"], float(np.percentile(ic["h"], 95)), 3000)
    cells, _ = bin_particles(spec, ic["pos"], ic["vel"], ic["mass"],
                             ic["u"], ic["h"])
    pairs = build_pair_list(spec)
    occupancy = np.asarray(cells.mask.sum(axis=1))
    cm = CostModel(rates={})
    g = build_taskgraph(spec, pairs, occupancy, cm)
    # calibrate task costs to seconds (≈2 ns per pair interaction, the
    # measured-cost refinement of §3.2)
    for t in g.tasks.values():
        object.__setattr__(t, "cost", max(t.cost * 2e-9, 1e-8))
    print(f"    {spec.ncells} cells, {len(pairs.ci)} pair tasks, "
          f"{len(g)} tasks total")

    waves = wave_schedule(g)
    cp, _ = g.critical_path()
    print(f"    wave schedule: {len(waves)} waves, critical path "
          f"{cp*1e3:.3g} ms")

    print("=== 3. graph partition + async communication (4 ranks)")
    cell_bytes = [float(max(o, 1)) * 64.0 for o in occupancy]
    dist, dec = decompose_with_comm(
        g, spec.ncells, 4, cell_bytes=cell_bytes,
        phases={"sort": "p0", "density_self": "p1", "density_pair": "p1",
                "ghost": "p2", "force_self": "p3", "force_pair": "p3",
                "kick": "p4"})
    print(f"    partition: {dec.partition.summary()}")
    print(f"    messages: {dec.comm.messages} "
          f"(mean {dec.comm.mean_message_bytes/1024:.2f} kB)")
    kw = dict(ranks=4, threads=2, latency=1.5e-5, bandwidth=5e9)
    a = AsyncExecutorSim(dist, **kw).run()
    s = AsyncExecutorSim(dist, synchronous=True, **kw).run()
    print(f"    async makespan {a.makespan*1e3:.3f} ms "
          f"(eff {a.efficiency:.2f})  vs  sync {s.makespan*1e3:.3f} ms "
          f"(eff {s.efficiency:.2f})  → {s.makespan/a.makespan:.2f}× faster")

    print("=== 4. real SPH integration (conservation check)")
    from repro.sph import SimulationSpec, build_simulation, uniform_ic
    rng = np.random.default_rng(1)
    ic2 = uniform_ic(8, seed=2)                  # 512 particles: fast on CPU
    ic2["vel"] = (ic2["vel"]
                  + 0.2 * rng.standard_normal(ic2["vel"].shape)
                  ).astype(np.float32)
    spec = SimulationSpec(scenario="uniform",
                          physics=SPHConfig(alpha_visc=0.8),
                          integrator="global", backend="local",
                          dt=0.004, rebin_every=5)
    sim = build_simulation(spec, ic=ic2)
    e0, p0 = sim.diagnostics()
    sim.run(10 * 0.004)
    e1, p1 = sim.diagnostics()
    print(f"    10 steps: |ΔE|/E = {abs(e1-e0)/abs(e0):.2e}, "
          f"|Δp| = {np.abs(p1-p0).max():.2e}")
    print("done.")


if __name__ == "__main__":
    main()
