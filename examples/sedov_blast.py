"""Sedov–Taylor point explosion with hierarchical time bins.

The scenario the time-bin subsystem exists for: a blast wave in a cold
uniform gas produces a CFL time-step contrast of >3 decades between the
hot centre and the quiescent background. The multi-dt engine integrates
each particle at its own power-of-two step — only the blast region burns
compute — while the global-dt engine would grind everything at the
minimum.

Prints per cycle: the time-bin histogram, the fraction of particle
updates actually performed vs the global-dt equivalent, energy drift,
and the shock radius against the analytic Sedov solution
r_s(t) = ξ (E t² / ρ)^{1/5}, ξ ≈ 1.15 for γ = 5/3.

Run:  PYTHONPATH=src python examples/sedov_blast.py [n_side] [ncycles]
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    n_side = int(sys.argv[1]) if len(sys.argv) > 1 else 12
    ncycles = int(sys.argv[2]) if len(sys.argv) > 2 else 4

    from repro.sph import (SPHConfig, SimulationSpec, assign_bins,
                           build_simulation, sedov_ic)
    from repro.sph.physics import cfl_timestep_block

    ic = sedov_ic(n_side, e0=1.0, seed=0)
    n = len(ic["pos"])
    cfg = SPHConfig(alpha_visc=1.0, cfl=0.15)
    spec = SimulationSpec(
        scenario="sedov", scenario_params={"n_side": n_side, "e0": 1.0,
                                           "seed": 0},
        physics=cfg, integrator="timebin", backend="local",
        dt_max=0.02, max_depth=10)
    sim = build_simulation(spec, ic=ic).engine

    # raw CFL spread of the IC — the dynamic range the bins quantise
    cells = sim.state.cells
    dts = np.asarray(cfl_timestep_block(cells.h, cells.u, cells.vel,
                                        cells.mask, gamma=cfg.gamma,
                                        cfl=cfg.cfl))
    live = dts[np.asarray(cells.mask) > 0]
    spread = float(live.max() / live.min())
    raw_bins = assign_bins(live, float(live.max()), 32)
    print(f"N = {n}, CFL dt spread = {spread:.1e} "
          f"({np.log10(spread):.1f} decades, "
          f"{int(raw_bins.max()) + 1} power-of-two bins)")

    e_start, _ = sim.diagnostics()
    print("\ncycle       t  depth  upd_frac  dE_rel   r_shock  r_sedov")
    for c in range(ncycles):
        stats = sim.run_cycle()
        e_now, _ = sim.diagnostics()
        frac = stats["updates"] / stats["global_equiv_updates"]
        # shock radius: mass-weighted radius of the fastest decile
        st = sim.state.cells
        m = np.asarray(st.mask) > 0
        pos = np.asarray(st.pos)[m]
        v = np.linalg.norm(np.asarray(st.vel)[m], axis=-1)
        d = pos - ic["box"] / 2.0
        d -= ic["box"] * np.round(d / ic["box"])
        r = np.linalg.norm(d, axis=-1)
        fast = v > max(np.percentile(v, 90), 1e-6)
        r_shock = float(np.median(r[fast])) if fast.any() else 0.0
        t = stats["t"]
        r_sedov = 1.15 * (1.0 * t * t) ** 0.2
        print(f"{c:5d}  {t:6.3f}  {stats['depth']:5d}  {frac:8.3f}  "
              f"{(e_now - e_start) / abs(e_start):+.2e}  {r_shock:7.3f}  "
              f"{r_sedov:7.3f}")
        print(f"       bins: {[int(x) for x in stats['bin_hist']]}")

    print(f"\ntotal particle updates: {sim.particle_updates} "
          f"(global-dt equivalent: {sim.global_equiv_updates}, "
          f"saved {1 - sim.particle_updates / sim.global_equiv_updates:.1%})")


if __name__ == "__main__":
    main()
