"""Strong-scaling experiment (paper Figs. 5/6/8 at laptop scale).

Sweeps rank counts over the clustered task graph and prints the speed-up
and parallel-efficiency columns for async (SWIFT) vs bulk-synchronous
execution — CSV ready for plotting.

Run:  PYTHONPATH=src python examples/sph_strong_scaling.py [n_particles]
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 8000
    from benchmarks.strong_scaling import run
    rows = run(n_particles=n,
               ranks_list=(1, 2, 4, 8, 16, 32, 64, 128))
    print("\nranks,mode,makespan_us,efficiency")
    for r in rows:
        parts = r["name"].split("/")
        eff = r["derived"].split("=")[1]
        print(f"{parts[2][5:]},{parts[1]},{r['us_per_call']},{eff}")


if __name__ == "__main__":
    main()
