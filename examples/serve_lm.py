"""Serve a small model with batched requests: prefill + decode loop with a
continuous-batching-style slot manager (finished sequences are replaced by
queued requests between decode steps).

**Legacy (LM-zoo era).** The repo's serving path is now the simulation
fleet — ``PYTHONPATH=src python -m repro.fleet --scenario sedov
--requests 64`` — which applies the same continuous-batching idea to whole
simulation requests (see ``examples/fleet_serve.py``). This example stays
as a model-zoo exercise.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import time

import numpy as np


def main():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.serve_step import decode_step, prefill

    cfg = dataclasses.replace(get_config("granite-8b", reduced=True),
                              dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)

    B, S0, MAXLEN = 4, 16, 64
    n_requests = 12
    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab, S0).astype(np.int32)
             for _ in range(n_requests)]

    # fill the first batch
    active = [queue.pop(0) for _ in range(B)]
    prompts = jnp.asarray(np.stack(active))
    logits, caches, rolling = prefill(params, cfg, prompts,
                                      cache_len=MAXLEN)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    lengths = [S0] * B
    done = 0
    t0 = time.perf_counter()
    decoded = 0
    pos = jnp.asarray(S0, jnp.int32)
    # simple continuous batching: sequences "finish" at a random target
    targets = [int(rng.integers(S0 + 8, MAXLEN - 1)) for _ in range(B)]
    while done < n_requests and int(pos) < MAXLEN - 1:
        logits, caches = decode_step(params, cfg, tok, caches, pos,
                                     rolling=rolling)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        decoded += B
        pos = pos + 1
        for b in range(B):
            lengths[b] += 1
            if lengths[b] >= targets[b]:
                done += 1
                if queue:
                    # slot reuse: in a full serving stack the slot would be
                    # re-prefilled; here we just restart its counter
                    queue.pop(0)
                    lengths[b] = S0
                    targets[b] = int(rng.integers(S0 + 8, MAXLEN - 1))
    dt = time.perf_counter() - t0
    print(f"served {done}/{n_requests} requests, {decoded} tokens in "
          f"{dt*1e3:.0f} ms ({decoded/max(dt,1e-9):.0f} tok/s)")


if __name__ == "__main__":
    main()
