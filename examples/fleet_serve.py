"""Serve a fleet of SPH simulation requests as batched mesh programs.

A request-driven tour of :mod:`repro.fleet`: heterogeneous Sedov and
Kelvin–Helmholtz requests (different blast energies, shear speeds, seeds —
but the same *shapes*) arrive in wobbling bursts, are grouped by
compiled-program signature, and each group runs as ONE vmapped program.
Completion callbacks fire per request; the exported Chrome trace shows
every request on its own timeline row (open at https://ui.perfetto.dev).

Run:  PYTHONPATH=src python examples/fleet_serve.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    from repro.fleet import FleetRunner
    from repro.sph import SimulationSpec

    runner = FleetRunner(observe=True)

    def on_done(req):
        r = req.result
        print(f"  done {req.request_id}  [{req.spec.scenario:>16}] "
              f"E={r.energy:.6f}  batch={r.batch_size}/{r.bucket} "
              f"latency={req.latency * 1e3:.1f} ms")

    # wobbling bursts of value-heterogeneous requests: two signatures
    # (sedov, kelvin_helmholtz shapes), many parameter values
    bursts = [3, 5, 4]
    i = 0
    for burst in bursts:
        for _ in range(burst):
            if i % 2 == 0:
                spec = SimulationSpec(
                    scenario="sedov",
                    scenario_params={"n_side": 4, "seed": i,
                                     "e0": 1.0 + 0.05 * i})
            else:
                spec = SimulationSpec(
                    scenario="kelvin_helmholtz",
                    scenario_params={"n_side": 4, "seed": i,
                                     "v_shear": 0.3 + 0.02 * i})
            runner.submit(spec, n_steps=4, callback=on_done)
            i += 1
        print(f"burst of {burst} submitted; draining…")
        runner.drain()

    stats = runner.stats()
    print(f"\nfleet: {stats['queue']['done']} requests in "
          f"{stats['batches']} batches, {stats['compiles']} compiles "
          f"({stats['programs']} entry points), "
          f"{stats['particle_steps']} particle-steps")
    runner.assert_compile_discipline()
    doc = runner.export_trace("fleet_trace_example.json")
    print(f"trace: fleet_trace_example.json "
          f"({len(doc['traceEvents'])} events; rows are request_ids)")


if __name__ == "__main__":
    main()
