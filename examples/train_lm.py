"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the production substrate end to end — config system, sharding rules
(if >1 device), AdamW, deterministic data pipeline, async checkpointing and
the fault-tolerant loop.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
(A 3-step smoke variant runs in under a minute: --steps 3.)
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.models.config import ModelConfig
    from repro.train import (AdamConfig, Checkpointer, DataConfig,
                             FaultTolerantLoop, LoopConfig, TokenStream,
                             TrainConfig, init_train_state, make_train_step)

    # ~100M params: granite-style dense decoder
    cfg = ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv=4, head_dim=64, d_ff=2048, vocab=32000,
        tie_embeddings=True, dtype=jnp.float32, scan_group=4)
    tcfg = TrainConfig(adam=AdamConfig(lr=6e-4, warmup_steps=20,
                                       total_steps=args.steps))
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    n = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params | {args.steps} steps of "
          f"{args.batch}×{args.seq} tokens")

    step_fn = jax.jit(make_train_step(cfg, tcfg))
    stream = TokenStream(DataConfig(vocab=cfg.vocab, seq=args.seq,
                                    batch=args.batch, seed=7))
    ck = Checkpointer(args.ckpt, keep=2, async_save=True)
    loop = FaultTolerantLoop(
        train_step=step_fn, params=params, opt_state=opt, stream=stream,
        ckpt=ck, loop_cfg=LoopConfig(
            total_steps=args.steps,
            checkpoint_every=max(args.steps // 4, 1),
            log_every=max(args.steps // 20, 1)))
    result = loop.run()
    losses = [m["loss"] for m in result["log"]]
    for m in result["log"]:
        print(f"  step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"{m['wall']*1e3:7.0f} ms")
    if len(losses) >= 2:
        print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f} "
              f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})")


if __name__ == "__main__":
    main()
