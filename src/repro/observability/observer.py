"""Run-level observer: merges span streams + engine ledgers per cycle.

One :class:`RunObserver` is attached per run (``SimulationSpec(observe=
True)`` → ``build_simulation`` wires its tracer into the engine and its
transport). After every cycle the API layer calls :meth:`RunObserver.
end_cycle`, which

* folds the cycle's spans into per-phase wall/count/units aggregates and
  per-rank busy time (SWIFT's task plot, reduced: imbalance = max/mean of
  per-rank *distinguishable* work, dead time = cycle wall not covered by
  any task);
* copies the engine's ledgers **verbatim** — ``TransferProbe.stats()``,
  ``CompileProbe.counts()``, transport stats, halo export counters — so
  the JSONL record's byte/compile numbers agree exactly with the probes
  (asserted by ``python -m repro.observability`` and the tests);
* feeds measured (units, seconds) pairs into the
  :class:`~repro.core.cost_model.CostModel` (``observe``), closing the
  loop the ROADMAP's online task-cost-feedback repartitioning item needs:
  the report prints measured-vs-modelled rate ratios per task kind.

The record layout (one JSONL line per cycle) is versioned by
:data:`~repro.observability.metrics.METRICS_SCHEMA_VERSION`.
"""

from __future__ import annotations

import os
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.cost_model import CostModel
from . import device_metrics as dm
from .flight import DEFAULT_RING, FlightRecorder
from .metrics import METRICS_SCHEMA_VERSION, MetricsRegistry
from .sinks import jsonify, write_chrome_trace, write_metrics_jsonl
from .tracer import NULL_TRACER, Tracer

# umbrella spans cover a whole cycle/step — they time the container, not a
# task, and must not count toward any rank's busy time
UMBRELLA_SPANS = frozenset({"cycle", "step", "engine_step"})

# stats keys copied into the per-cycle record when the engine provides them
_STAT_KEYS = ("t", "dt_max", "dt", "depth", "substeps", "force_substeps",
              "updates", "global_equiv_updates", "pair_tasks",
              "halo_exported_slots", "halo_full_slots", "nranks",
              "residency")


@dataclass(frozen=True)
class ObserveSpec:
    """What to observe. ``SimulationSpec(observe=True)`` coerces to the
    all-on default; ``observe=ObserveSpec(enabled=True, trace=False)``
    keeps the metrics log without span recording/fencing.

    ``device_metrics`` pulls the engines' in-program telemetry row once
    per cycle (the row is *computed* unconditionally inside the compiled
    programs either way — see ``device_metrics.py`` — so toggling this
    only gates the one host↔device pull and the record fields, never the
    compiled program). ``flight_cycles``/``flight_dir`` size and place
    the flight recorder's post-mortem bundles (dumped on any health
    sentinel trip)."""
    enabled: bool = False
    trace: bool = True
    metrics: bool = True
    device_metrics: bool = True
    flight_cycles: int = DEFAULT_RING
    flight_dir: Optional[str] = None

    # relative per-cycle change of the total-energy fingerprint above
    # which the energy-drift sentinel trips (blowup detector, not a
    # conservation test — SPH with viscosity drifts legitimately)
    energy_drift_tol: float = 0.5


class RunObserver:
    """Collects one run's trace + per-cycle metrics records."""

    def __init__(self, spec: ObserveSpec = ObserveSpec(enabled=True),
                 cost_model: Optional[CostModel] = None):
        self.spec = spec
        self.tracer: Tracer = Tracer() if spec.trace else NULL_TRACER
        self.registry = MetricsRegistry()
        self.records: List[Dict[str, Any]] = []
        self.cycle = 0
        self._span_mark = 0
        # fallback cost model when the engine doesn't carry one (local
        # quadrants) — the measured-vs-modelled report works everywhere
        self._own_cost_model = cost_model or CostModel(rates={})
        # flight recorder: ring of the last K cycles' device-metric rows,
        # plus the span mark at each ring cycle's start so a dump can
        # slice exactly the ring window out of the trace
        self.flight = FlightRecorder(spec.flight_cycles)
        self._cycle_marks = deque(maxlen=max(int(spec.flight_cycles), 1))
        self._last_energy: Optional[float] = None
        # cost-attribution pipeline (schema v3): ledger of measured
        # (units-by-kind, seconds) samples driving CostModel.calibrate,
        # plus the repartition advisor replaying decompose_cells against
        # measured cell weights — both built lazily on first use
        self._ledger = None
        self._advisor = None
        self._advisor_failed = False

    # ---------------------------------------------------------- per cycle
    def end_cycle(self, sim, stats: Dict[str, Any]) -> Dict[str, Any]:
        eng = getattr(sim, "engine", sim)
        self._cycle_marks.append((self.cycle, self._span_mark))
        spans = self.tracer.spans[self._span_mark:]
        self._span_mark = len(self.tracer.spans)

        phase_wall: Dict[str, float] = {}
        phase_count: Dict[str, int] = {}
        phase_units: Dict[str, float] = {}
        # phase wall with collective duplicates folded once — the honest
        # seconds for apportioning fused-program cost across phases
        dedup_wall: Dict[str, float] = {}
        busy: Dict[int, float] = {}
        work: Dict[int, float] = {}
        cm = getattr(eng, "_cost_model", None) or self._own_cost_model
        seen_collective = set()
        seen_wall = set()
        for s in spans:
            if s.name in UMBRELLA_SPANS:
                continue
            a = s.attrs or {}
            dur = s.dur
            phase_wall[s.name] = phase_wall.get(s.name, 0.0) + dur
            phase_count[s.name] = phase_count.get(s.name, 0) + 1
            busy[s.rank] = busy.get(s.rank, 0.0) + dur
            collective = bool(a.get("collective"))
            wkey = (s.name, s.t0, s.t1)
            if not collective or wkey not in seen_wall:
                dedup_wall[s.name] = dedup_wall.get(s.name, 0.0) + dur
                seen_wall.add(wkey)
            if not collective:
                work[s.rank] = work.get(s.rank, 0.0) + dur
            units = a.get("units", a.get("pairs"))
            if units:
                # a collective span is one task duplicated onto every
                # participating rank's row — fold its cost/units once
                key = (s.name, s.t0, s.t1)
                if collective:
                    if key in seen_collective:
                        continue
                    seen_collective.add(key)
                phase_units[s.name] = phase_units.get(s.name, 0.0) \
                    + float(units)
                if hasattr(cm, "observe"):
                    cm.observe(s.name, float(units), dur)

        rec: Dict[str, Any] = {
            "schema": METRICS_SCHEMA_VERSION,
            "cycle": self.cycle,
            "wall": float(stats.get("wall", 0.0)),
        }
        for k in _STAT_KEYS:
            if k in stats:
                rec[k] = stats[k]
        if "bin_hist" in stats:
            rec["bin_hist"] = [int(x) for x in np.asarray(stats["bin_hist"])]
        if spans:
            rec["phase_wall"] = phase_wall
            rec["phase_count"] = phase_count
            rec["phase_units"] = phase_units
            rec["rank_busy"] = {int(r): v for r, v in sorted(busy.items())}
            base = work if work else busy
            vals = list(base.values())
            mean = sum(vals) / len(vals) if vals else 0.0
            rec["imbalance"] = (max(vals) / mean) if mean > 0 else 1.0
            wall = rec["wall"]
            if wall > 0 and busy:
                mean_busy = sum(busy.values()) / len(busy)
                rec["dead_frac"] = max(0.0, 1.0 - mean_busy / wall)

        # ---- engine ledgers, copied verbatim (exact-agreement contract)
        transfers = getattr(eng, "transfers", None)
        if transfers is not None:
            rec["transfers"] = transfers.stats()
        probe = getattr(eng, "probe", None)
        if probe is not None:
            rec["compiles"] = probe.counts()
            rec["total_compiles"] = probe.total_compiles()
        transport = getattr(eng, "_transport", None)
        if transport is not None:
            rec["transport"] = transport.stats()
        nbucket = 0
        fused = getattr(eng, "_fused_buckets", None)
        if fused is not None:
            nbucket += len(fused.events)
        if transport is not None and hasattr(transport, "buckets"):
            nbucket += len(transport.buckets.events)
        if fused is not None or transport is not None:
            rec["bucket_events"] = nbucket
        for k in ("bins_refreshes", "repartitions"):
            if hasattr(eng, k):
                rec[k] = getattr(eng, k)

        # per-rank time-averaged work imbalance of the decomposition (the
        # repartition trigger's own metric, logged every cycle)
        if hasattr(eng, "_assignment") and "depth" in stats:
            try:
                from ..core.decompose import bin_occupancy_imbalance
                from ..sph.timebins import cell_bin_histogram
                bins_h = np.asarray(eng.state.bins)
                mask_h = np.asarray(eng.state.cells.mask)
                obb = cell_bin_histogram(bins_h, mask_h,
                                         int(stats["depth"]) + 1)
                rec["bin_occupancy_imbalance"] = float(
                    bin_occupancy_imbalance(eng._assignment, obb,
                                            eng.nranks))
            except Exception:       # diagnostics must never kill the run
                pass

        # ---- device metrics: the in-program telemetry row the engine
        # accumulated on device and pulled once this cycle (schema v2),
        # plus the per-cell work vectors riding the same pull (schema v3)
        dmx = getattr(eng, "device_metrics_last", None)
        cell_work = getattr(eng, "device_cell_work_last", None) \
            if self.spec.device_metrics else None
        rec["cell_work"] = dm.cell_work_record(cell_work)
        rec["cost_calibration"] = None
        rec["advisor"] = None
        if self.spec.device_metrics and dmx is not None:
            counts, values = dmx
            summary = dm.summarize(counts, values)
            rec["device_metrics"] = summary
            rec["device_imbalance"] = summary["imbalance"]
            du = dm.phase_units(summary)
            rec["device_phase_units"] = du
            # health: in-program sentinel flags + the host-side
            # energy-drift check on the fingerprint
            energy = [fp["energy_total"]
                      for fp in dm.fingerprint(np.asarray(values))]
            e_tot = (sum(e for e in energy if e is not None)
                     if any(e is not None for e in energy) else None)
            drift = False
            if e_tot is not None and self._last_energy is not None:
                ref = max(abs(self._last_energy), 1e-12)
                drift = abs(e_tot - self._last_energy) / ref \
                    > self.spec.energy_drift_tol
            self._last_energy = e_tot
            tripped = bool(summary["tripped"]) or drift
            rec["health"] = {"flags": summary["flags"],
                             "energy_drift": drift, "tripped": tripped}
            # fully fused runs have no per-phase spans — feed the cost
            # ledger one aggregate (units-by-kind, fused wall) sample:
            # it keeps CostModel.observe flowing (unit-share
            # apportioning, so measured_vs_modelled refines from cycle
            # one) and re-runs the joint CostModel.calibrate() fit over
            # its sample window each cycle
            if "density" not in phase_wall and hasattr(cm, "observe"):
                fused_wall = sum(dedup_wall.get(n, 0.0)
                                 for n in ("fused_substep", "fused_final"))
                if fused_wall > 0:
                    if cell_work is not None:
                        totals = np.asarray(
                            cell_work["per_rank"], np.float64).sum(axis=0)
                        units = {k: float(v) for k, v in
                                 zip(cell_work["columns"], totals)}
                    else:
                        units = {k: float(du.get(k, 0.0))
                                 for k in ("density", "force", "exchange")}
                    rec["cost_calibration"] = self._get_ledger(cm).record(
                        units, fused_wall)
            self.flight.record(self.cycle, counts, values)
            if tripped:
                reason = drift and "energy-drift" or next(
                    (k.replace("flag_", "") for k, v in
                     summary["flags"].items() if v), "sentinel")
                rec["flight_dump"] = self.dump_flight(reason=reason)

        # ---- repartition advisor: replay the graph partitioner against
        # the measured per-cell weights (advisory only — nothing moves;
        # PR-11's device-side migration consumes this series)
        if cell_work is not None and hasattr(eng, "_assignment") \
                and int(getattr(eng, "nranks", 1)) > 1:
            advisor = self._get_advisor(eng)
            if advisor is not None:
                try:
                    ledger = self._get_ledger(cm)
                    weights = ledger.cell_weights(cell_work)
                    adv = advisor.advise(eng._assignment, weights)
                    rec["advisor"] = {
                        "current_imbalance":
                            float(adv["current_imbalance"]),
                        "candidate_imbalance":
                            float(adv["candidate_imbalance"]),
                        "advised_imbalance":
                            float(adv["advised_imbalance"]),
                        "accepted": bool(adv["accepted"]),
                        "per_cell_ratio": ledger.per_cell_ratio(
                            cell_work, advisor.modelled_weights),
                    }
                except Exception:   # diagnostics must never kill the run
                    pass

        # ---- cost-model feedback summary (always present: the schema-v3
        # record carries these keys even before any observation lands)
        rec["cost_ratios"] = cm.measured_vs_modelled() \
            if hasattr(cm, "measured_vs_modelled") else {}
        rec["observed_units"] = (
            {k: cm.observed_units(k) for k in cm.observed}
            if hasattr(cm, "observed_units") else {})

        self._update_registry(rec)
        if self.spec.metrics:
            rec["metrics"] = self.registry.snapshot()
            self.records.append(jsonify(rec))
        self.cycle += 1
        return rec

    # ------------------------------------------------- cost attribution
    def _get_ledger(self, cm):
        """The run's TaskCostLedger, bound to the resolved cost model on
        first use (the model is stable per run)."""
        if self._ledger is None:
            from .costs import TaskCostLedger
            self._ledger = TaskCostLedger(cm)
        return self._ledger

    def _get_advisor(self, eng):
        """Build the repartition advisor once from the engine's grid and
        pair structure (structure changes rarely; weights every cycle).
        Engines without the required surface (spec/pairs/_assignment)
        simply get no advisor block."""
        if self._advisor is not None or self._advisor_failed:
            return self._advisor
        try:
            spec = getattr(eng, "spec", None)
            pairs = getattr(eng, "pairs", None)
            nranks = int(getattr(eng, "nranks", 1))
            if spec is None or pairs is None or nranks <= 1:
                self._advisor_failed = True
                return None
            from ..sph.engine import build_taskgraph
            from .costs import RepartitionAdvisor
            occ = np.asarray(eng.state.cells.mask).sum(axis=1) \
                .astype(np.int64)
            g = build_taskgraph(spec, pairs, occ,
                                getattr(eng, "_cost_model", None))
            self._advisor = RepartitionAdvisor(
                g, spec.ncells, nranks,
                seed=int(getattr(eng, "_seed", 0)))
        except Exception:       # diagnostics must never kill the run
            self._advisor_failed = True
        return self._advisor

    # ------------------------------------------------------ flight recorder
    def dump_flight(self, *, reason: str,
                    out_dir: Optional[str] = None) -> str:
        """Write a post-mortem bundle of the flight ring + trace slice.

        Called automatically on a sentinel trip; callers (the fleet
        runner on a lane EXPIRED / deadline miss, the ``dump`` CLI) may
        invoke it directly. Returns the bundle directory."""
        base = out_dir or self.spec.flight_dir \
            or os.environ.get("REPRO_FLIGHT_DIR", "flight-dumps")
        mark = self._cycle_marks[0][1] if self._cycle_marks else 0
        return self.flight.dump(base, reason=reason, cycle=self.cycle,
                                spans=self.tracer.spans[mark:])

    def _update_registry(self, rec: Dict[str, Any]) -> None:
        reg = self.registry
        tr = rec.get("transfers")
        if tr:
            reg.count("transfer_boundary_bytes",
                      sum(tr["boundary_bytes"].values()))
            reg.count("transfer_intra_bytes", sum(tr["intra_bytes"].values()))
            reg.count("transfer_total_bytes", tr["total_bytes"])
        if "total_compiles" in rec:
            reg.count("compiles_total", rec["total_compiles"])
        tp = rec.get("transport")
        if tp:
            reg.count("transport_host_bytes", tp.get("host_bytes", 0))
            reg.count("transport_exchanges", tp.get("exchanges", 0))
        if "halo_exported_slots" in rec:
            reg.inc("halo_exported_slots", rec["halo_exported_slots"])
            reg.inc("halo_full_slots", rec.get("halo_full_slots", 0))
        if "bucket_events" in rec:
            reg.count("bucket_events", rec["bucket_events"])
        for k in ("bins_refreshes", "repartitions"):
            if k in rec:
                reg.count(k, rec[k])
        du = rec.get("device_phase_units")
        if du:
            for kind, units in du.items():
                reg.inc(f"device_units_{kind}", units)
        adv = rec.get("advisor")
        if adv:
            reg.gauge("advisor_current_imbalance", adv["current_imbalance"])
            reg.gauge("advisor_advised_imbalance", adv["advised_imbalance"])
        cal = rec.get("cost_calibration")
        if cal and cal.get("residual") is not None:
            reg.gauge("calibration_residual", cal["residual"])
        health = rec.get("health")
        if health:
            reg.inc("sentinel_trips", 1 if health["tripped"] else 0)
            for name, n in health["flags"].items():
                reg.inc(f"sentinel_{name}", n)
        if "flight_dump" in rec:
            reg.inc("flight_dumps", 1)
        reg.inc("cycles", 1)
        reg.inc("updates", rec.get("updates", 0))
        reg.inc("pair_tasks", rec.get("pair_tasks", 0))
        for k in ("imbalance", "dead_frac", "bin_occupancy_imbalance",
                  "device_imbalance"):
            if rec.get(k) is not None:
                reg.gauge(k, rec[k])
        if "depth" in rec:
            reg.gauge("depth", rec["depth"])

    # -------------------------------------------------------------- export
    def export_chrome_trace(self, path: str,
                            process_name: str = "repro") -> Dict[str, Any]:
        return write_chrome_trace(path, self.tracer.spans,
                                  self.tracer.t_origin, process_name)

    def write_metrics_jsonl(self, path: str) -> None:
        write_metrics_jsonl(path, self.records)
