"""Device-side telemetry rows: in-program counters for fused programs.

PR-5's tracer times phase *programs* from the host, fencing at every
phase boundary. That goes blind exactly where the engines are headed:
once a whole force sub-step is one fused shard_map program (and a fleet
batch one ``jit(vmap(step))``), the host sees a single opaque span.
Following SWIFT's rule that every task reports its own cost from inside
the runtime (arXiv:1606.02738 §4) — and the in-kernel per-bin counter
idiom of task-based runtimes — this module defines a ``DeviceMetrics``
carry: two fixed-shape buffers **computed inside the compiled program**,

* ``counts`` — int32 ``(N_COUNTS,)`` per rank: sub-step executions,
  per-phase active-particle counts, live interior/cut pair counts,
  exchange slots and bytes, deepening/wake events, and health sentinel
  trips (NaN / Inf / non-positive density);
* ``values`` — float32 ``(N_VALUES,)`` per rank: per-phase accumulated
  work units (the asymptotic units the cost model runs on) plus a
  compact state fingerprint (total energy, |momentum|, max speed,
  min density) for the flight recorder.

The carry is **always present**: instrumented and uninstrumented runs
execute the *same* compiled program (the metrics row is an unconditional
extra output whose reductions only read values the physics already
computes), so enabling device metrics adds **zero compiles** per shape
signature and is bitwise invisible to the state — both pinned in
``tests/test_observability.py`` / ``tests/test_conformance.py``.
Accumulation across sub-steps happens on device (eager adds on the tiny
rows); the accumulated row is pulled **once per cycle** and ledgered
through the engine's :class:`~repro.distributed.transport.TransferProbe`.

Nothing here imports jax at module scope (package rule: the CLI must be
able to set ``XLA_FLAGS`` before jax loads); in-program helpers import
``jax.numpy`` lazily.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

DEVICE_METRICS_VERSION = 2

COUNT_COLUMNS: Tuple[str, ...] = (
    "substeps",         # sub-step program executions folded into this row
    "drift_active",     # particles drifted (alive mask count)
    "density_active",   # particles active in the density phase
    "force_active",     # particles kicked in the force phase
    "pair_int",         # live interior pair blocks
    "pair_cut",         # live cut (halo-crossing) pair blocks
    "exch_slots",       # halo slots shipped across both exchanges
    "exch_bytes",       # bytes moved through the exchanges
    "deepen_events",    # owned rows whose time bin deepened mid-cycle
    "wake_events",      # cells woken above the current ladder level
    "flag_nan",         # sub-steps on which any state value went NaN
    "flag_inf",         # ... or infinite
    "flag_neg_rho",     # ... or produced a non-positive density
)
VALUE_COLUMNS: Tuple[str, ...] = (
    "density_units",    # live pair blocks worked in the density phase
    "force_units",      # live pair blocks worked in the force phase
    "exchange_units",   # shipped halo slots (send/recv work units)
    "kick_units",       # particles integrated by the kick
    "energy_total",     # fingerprint: sum m·(u + v²/2) over alive rows
    "momentum_abs",     # fingerprint: |Σ m·v|
    "max_speed",        # fingerprint: max |v| over alive rows
    "min_rho",          # fingerprint: min density over alive rows
)
N_COUNTS = len(COUNT_COLUMNS)
N_VALUES = len(VALUE_COLUMNS)

# Per-cell work vectors (device-metrics version 2). One float32 row per
# *extended* cell row (owned rows first, halo replicas after), integer
# valued — work units stay far below 2**24 per cycle so float32 adds are
# exact. Drift/density/force land on owned rows; exchange is counted
# receiver-side on the halo rows it unpacks into (folded back onto the
# owner cell on the host, so no slot is ever double-counted).
CELL_COLUMNS: Tuple[str, ...] = (
    "drift",      # alive particles drifted in this cell's rows
    "density",    # live pair blocks attributed to this cell (density)
    "force",      # live pair blocks attributed to this cell (force)
    "exchange",   # halo slots unpacked for this cell (recv-side units)
)
N_CELL_COLS = len(CELL_COLUMNS)
CELL_INDEX = {name: i for i, name in enumerate(CELL_COLUMNS)}

# how each value column folds across sub-steps within one cycle
_V_ACCUM: Tuple[str, ...] = ("sum", "sum", "sum", "sum",
                             "last", "last", "max", "min")
_FLAG_COLUMNS = ("flag_nan", "flag_inf", "flag_neg_rho")
COUNT_INDEX = {name: i for i, name in enumerate(COUNT_COLUMNS)}
VALUE_INDEX = {name: i for i, name in enumerate(VALUE_COLUMNS)}
_CI = COUNT_INDEX
_VI = VALUE_INDEX


def zero_rows(nranks: int = 1):
    """Host-side zero accumulator: ``(counts, values)`` numpy buffers of
    shape ``(nranks, N_COUNTS)`` / ``(nranks, N_VALUES)``."""
    counts = np.zeros((nranks, N_COUNTS), np.int64)
    values = np.zeros((nranks, N_VALUES), np.float64)
    values[..., _VI["min_rho"]] = np.inf
    return counts, values


# --------------------------------------------------------------- in-program
def measure_substep(*, mask, active, vel, u, mass, rho,
                    live_pairs, pair_int, pair_cut,
                    exch_slots, exch_bytes, deepened, woken, kicked):
    """Build one per-rank metrics row *inside* a compiled program.

    Every argument is a jax value already flowing through the fused
    sub-step body (masks, post-kick state fields, live pair/slot counts)
    — the reductions here add consumers to the existing dataflow but
    never feed back into it, which is what keeps the carry bitwise
    invisible to the physics. Returns ``(counts int32[N_COUNTS],
    values float32[N_VALUES])``.
    """
    import jax.numpy as jnp

    alive = mask > 0
    f32 = jnp.float32
    nan_hit = (jnp.any(jnp.isnan(vel) & alive[..., None])
               | jnp.any(jnp.isnan(u) & alive)
               | jnp.any(jnp.isnan(rho) & alive))
    inf_hit = (jnp.any(jnp.isinf(vel) & alive[..., None])
               | jnp.any(jnp.isinf(u) & alive)
               | jnp.any(jnp.isinf(rho) & alive))
    neg_rho = jnp.any((rho <= 0) & alive & (active > 0))

    counts = jnp.stack([
        jnp.ones((), jnp.int32),
        jnp.sum(alive).astype(jnp.int32),
        jnp.sum((active > 0) & alive).astype(jnp.int32),
        jnp.asarray(kicked, jnp.int32).reshape(()),
        jnp.asarray(pair_int, jnp.int32).reshape(()),
        jnp.asarray(pair_cut, jnp.int32).reshape(()),
        jnp.asarray(exch_slots, jnp.int32).reshape(()),
        jnp.asarray(exch_bytes, jnp.int32).reshape(()),
        jnp.asarray(deepened, jnp.int32).reshape(()),
        jnp.asarray(woken, jnp.int32).reshape(()),
        nan_hit.astype(jnp.int32),
        inf_hit.astype(jnp.int32),
        neg_rho.astype(jnp.int32),
    ])

    m = jnp.where(alive, mass, 0.0)
    speed = jnp.sqrt(jnp.sum(vel * vel, axis=-1))
    energy = jnp.sum(m * (u + 0.5 * speed * speed))
    mom = jnp.sqrt(jnp.sum(jnp.sum(m[..., None] * vel,
                                   axis=tuple(range(vel.ndim - 1))) ** 2))
    values = jnp.stack([
        jnp.asarray(live_pairs, f32).reshape(()),
        jnp.asarray(pair_int + pair_cut, f32).reshape(()),
        jnp.asarray(exch_slots, f32).reshape(()),
        jnp.asarray(kicked, f32).reshape(()),
        energy.astype(f32),
        mom.astype(f32),
        jnp.max(jnp.where(alive, speed, 0.0)).astype(f32),
        jnp.min(jnp.where(alive, rho, jnp.inf)).astype(f32),
    ])
    return counts, values


def measure_cells(*, nrows: int, K: int, mask, pmask, ci, cj,
                  exch_rows=None, exch_valid=None, nexch=1):
    """Per-cell work vector of one sub-step, *inside* a compiled program.

    Returns a float32 ``(nrows, N_CELL_COLS)`` buffer over this rank's
    extended rows. Attribution rules (the identities the tests pin):

    * drift — alive-particle count per owned row (rows ``[0, K)``); the
      owned-row sum equals the ``drift_active`` count column.
    * density/force — each live pair block is charged to its *owned*
      endpoint (``ci`` when ``ci < K``, else ``cj``; the pair tables
      guarantee at least one endpoint is owned). The owned-row sums
      equal the ``density_units``/``force_units`` value columns.
    * exchange — ``nexch`` units per valid slot, charged receiver-side
      at the row the slot unpacks into. The all-row sum equals the
      ``exchange_units`` value column; the host fold maps halo rows
      back onto owner cells.

    Like :func:`measure_substep`, every input already flows through the
    fused body, so the scatters only add consumers — never producers —
    to the physics dataflow (bitwise invisible, zero extra compiles).
    Row ``nrows`` is a scratch row: invalid entries scatter there and
    are sliced away.
    """
    import jax.numpy as jnp

    f32 = jnp.float32
    cw = jnp.zeros((nrows + 1, N_CELL_COLS), f32)

    alive = jnp.sum((mask > 0).astype(f32), axis=-1)
    cw = cw.at[:K, CELL_INDEX["drift"]].set(alive[:K])

    pm = jnp.asarray(pmask, f32).reshape(-1)
    ci = jnp.asarray(ci).reshape(-1)
    cj = jnp.asarray(cj).reshape(-1)
    owner = jnp.where(ci < K, ci, cj)
    tgt = jnp.where(pm > 0, owner, nrows)
    cw = cw.at[tgt, CELL_INDEX["density"]].add(pm)
    cw = cw.at[tgt, CELL_INDEX["force"]].add(pm)

    if exch_rows is not None:
        ev = jnp.asarray(exch_valid, f32).reshape(-1)
        rows = jnp.asarray(exch_rows).reshape(-1)
        et = jnp.where(ev > 0, rows, nrows)
        cw = cw.at[et, CELL_INDEX["exchange"]].add(
            ev * jnp.asarray(nexch, f32))
    return cw[:nrows]


def zero_cell_work(ncells: int, nranks: int = 1):
    """Host-side zero accumulator for per-cell attribution: a global
    ``(ncells, N_CELL_COLS)`` float64 buffer plus a per-rank
    ``(nranks, N_CELL_COLS)`` totals buffer."""
    return (np.zeros((ncells, N_CELL_COLS), np.float64),
            np.zeros((nranks, N_CELL_COLS), np.float64))


def fold_cell_rows(cell_rows, owned: Sequence[np.ndarray],
                   halo: Sequence[np.ndarray], ncells: int,
                   K: int) -> Dict[str, object]:
    """Fold pulled per-rank extended-row buffers onto global cells.

    ``cell_rows`` is the stacked ``(nranks, nrows, N_CELL_COLS)`` device
    output; ``owned[r]``/``halo[r]`` map rank ``r``'s rows to global cell
    ids (owned rows from 0, halo rows from the shared owned-slot count
    ``K``). Halo rows only ever carry exchange units, which fold onto
    the *owner* cell's global entry — each shipped slot is counted
    exactly once. Returns the engine's ``device_cell_work_last``
    contract dict.
    """
    rows = np.asarray(cell_rows, np.float64)
    nranks = rows.shape[0]
    cells = np.zeros((ncells, N_CELL_COLS), np.float64)
    per_rank = np.zeros((nranks, N_CELL_COLS), np.float64)
    for r in range(nranks):
        own = np.asarray(owned[r], np.int64)
        hal = np.asarray(halo[r], np.int64) if r < len(halo) else \
            np.zeros(0, np.int64)
        np.add.at(cells, own, rows[r, :len(own)])
        if len(hal):
            np.add.at(cells, hal, rows[r, K:K + len(hal)])
        per_rank[r] = rows[r].sum(axis=0)
    return {"columns": list(CELL_COLUMNS), "cells": cells,
            "per_rank": per_rank}


def cell_work_record(cell_work: Optional[Dict[str, object]]) \
        -> Optional[Dict[str, object]]:
    """Compact per-record shape for metrics schema v3: columns, per-rank
    totals and global totals (the full per-cell vector stays on the
    engine — JSONL records would balloon at ncells scale)."""
    if not cell_work:
        return None
    per_rank = np.asarray(cell_work["per_rank"], np.float64)
    cells = np.asarray(cell_work["cells"], np.float64)
    return {
        "columns": list(cell_work["columns"]),
        "per_rank": [[float(x) for x in row] for row in per_rank.tolist()],
        "totals": [float(x) for x in cells.sum(axis=0).tolist()],
        "ncells": int(cells.shape[0]),
    }


def combine(acc, row, xp=np):
    """Fold one sub-step row into a cycle accumulator.

    Counts add; work-unit values add; fingerprint values take the
    latest/extremum per ``_V_ACCUM``. Works on numpy (host paths) and,
    with ``xp=jax.numpy``, on device arrays (eager adds on the tiny
    rows — no host sync, no registered program).
    """
    counts, values = acc
    rc, rv = row
    counts = counts + xp.asarray(rc, counts.dtype)
    rv = xp.asarray(rv, values.dtype)
    sel_sum = xp.asarray([a == "sum" for a in _V_ACCUM])
    sel_last = xp.asarray([a == "last" for a in _V_ACCUM])
    sel_max = xp.asarray([a == "max" for a in _V_ACCUM])
    out = xp.where(sel_sum, values + rv,
                   xp.where(sel_last, rv,
                            xp.where(sel_max, xp.maximum(values, rv),
                                     xp.minimum(values, rv))))
    return counts, out


def host_row(**named) -> Tuple[np.ndarray, np.ndarray]:
    """Build one 1-D ``(counts, values)`` row from host-side python
    scalars (the host-transport and local-ladder paths, which already
    hold these numbers). Unnamed columns default to zero (``min_rho``
    to +inf)."""
    counts = np.zeros(N_COUNTS, np.int64)
    values = np.zeros(N_VALUES, np.float64)
    values[_VI["min_rho"]] = np.inf
    for k, v in named.items():
        if k in _CI:
            counts[_CI[k]] = int(v)
        elif k in _VI:
            values[_VI[k]] = float(v)
        else:
            raise KeyError(f"unknown device-metrics column {k!r}")
    return counts, values


def state_health(mask, vel, u, rho, mass, counts, values, rank: int = 0,
                 active=None) -> None:
    """Fill one rank's sentinel flags + fingerprint columns in place from
    host-visible (numpy) state arrays — the host-residency paths'
    equivalent of the in-program reductions in :func:`measure_substep`.
    ``mask``/``vel``/``u``/``rho``/``mass`` are that rank's rows."""
    alive = np.asarray(mask) > 0
    vel = np.asarray(vel)
    u = np.asarray(u)
    rho = np.asarray(rho)
    mass = np.asarray(mass)
    counts[rank, _CI["flag_nan"]] += int(
        np.isnan(vel[alive]).any() or np.isnan(u[alive]).any()
        or np.isnan(rho[alive]).any())
    counts[rank, _CI["flag_inf"]] += int(
        np.isinf(vel[alive]).any() or np.isinf(u[alive]).any()
        or np.isinf(rho[alive]).any())
    neg = alive & (rho <= 0)
    if active is not None:
        neg &= np.asarray(active) > 0
    counts[rank, _CI["flag_neg_rho"]] += int(neg.any())
    m = np.where(alive, mass, 0.0)
    speed = np.sqrt((vel * vel).sum(axis=-1))
    values[rank, _VI["energy_total"]] = float(
        (m * (u + 0.5 * speed * speed)).sum())
    values[rank, _VI["momentum_abs"]] = float(np.sqrt(
        ((m[..., None] * vel).sum(axis=tuple(range(vel.ndim - 1)))
         ** 2).sum()))
    values[rank, _VI["max_speed"]] = float(speed[alive].max()) \
        if alive.any() else 0.0
    values[rank, _VI["min_rho"]] = float(rho[alive].min()) \
        if alive.any() else np.inf


# ------------------------------------------------------------- host summary
def _clean(x: float) -> Optional[float]:
    return None if (x is None or not math.isfinite(x)) else float(x)


def summarize(counts, values) -> Dict[str, object]:
    """Host-side digest of a pulled ``(nranks, N)`` metrics row pair.

    The per-record shape exported under ``device_metrics`` in schema-v2
    metrics records: raw per-rank columns plus the derived per-rank work
    (density+force units), the work imbalance (max/mean — SWIFT's
    figure of merit), and the sentinel flags.
    """
    c = np.atleast_2d(np.asarray(counts))
    v = np.atleast_2d(np.asarray(values))
    per_rank_work = (v[:, _VI["density_units"]]
                     + v[:, _VI["force_units"]]).astype(float)
    mean = float(per_rank_work.mean()) if per_rank_work.size else 0.0
    imb = float(per_rank_work.max() / mean) if mean > 0 else None
    flags = {name: int(c[:, _CI[name]].sum()) for name in _FLAG_COLUMNS}
    return {
        "version": DEVICE_METRICS_VERSION,
        "count_columns": list(COUNT_COLUMNS),
        "value_columns": list(VALUE_COLUMNS),
        "counts": c.astype(int).tolist(),
        "values": [[_clean(x) for x in row] for row in v.tolist()],
        "per_rank_work": per_rank_work.tolist(),
        "imbalance": imb,
        "flags": flags,
        "tripped": any(flags.values()),
    }


def fingerprint(values) -> List[Dict[str, Optional[float]]]:
    """Per-rank compact state fingerprint from a pulled values row."""
    v = np.atleast_2d(np.asarray(values))
    keys = ("energy_total", "momentum_abs", "max_speed", "min_rho")
    return [{k: _clean(row[_VI[k]]) for k in keys} for row in v.tolist()]


def phase_units(summary: Dict[str, object]) -> Dict[str, float]:
    """Total per-phase work units from a ``summarize()`` dict — what the
    observer feeds into ``CostModel.observe`` for fully fused runs."""
    vals = np.asarray(summary["values"], dtype=object)
    cols = list(summary["value_columns"])

    def col(name: str) -> float:
        i = cols.index(name)
        return float(sum(0.0 if x is None else float(x)
                         for x in vals[:, i]))

    return {"density": col("density_units"), "force": col("force_units"),
            "exchange": col("exchange_units"), "kick": col("kick_units")}
