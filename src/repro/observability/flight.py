"""Flight recorder: last-K-cycles metric ring + post-mortem dump bundles.

A crashed 100k-core SWIFT run is diagnosed from what the runtime logged
*before* it died; the analogue here is a ring buffer of the last ``K``
cycles' device-metric rows plus a compact state fingerprint per cycle.
The ring holds **references to the device arrays** the engines already
accumulated — entries stay device-resident (no extra host↔device
traffic) until a dump actually pulls them.

On any health-sentinel trip (NaN / Inf / non-positive density / energy
drift), a deadline miss, or a fleet lane sweeping to EXPIRED, the
recorder writes a post-mortem bundle::

    <out>/flight-cycle00012-nan/
        manifest.json       # reason, cycle, schema, ring span
        metrics.jsonl       # one record per ring entry (named columns)
        fingerprints.json   # per-cycle per-rank state fingerprints
        trace.json          # Chrome-trace slice covering the ring window

``validate_bundle`` checks a bundle's shape (CI and the sentinel-trip
test run it); the ``python -m repro.observability dump`` subcommand
produces and validates one end-to-end. No jax at module scope (package
rule) — rows arrive as arrays and are only ``np.asarray``-ed at dump
time.
"""

from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from . import device_metrics as dm
from .sinks import chrome_trace, jsonify, validate_chrome_trace

FLIGHT_SCHEMA = 2
_BUNDLE_FILES = ("manifest.json", "metrics.jsonl", "fingerprints.json",
                 "trace.json")
DEFAULT_RING = 8


class FlightRecorder:
    """Ring of the last ``k`` cycles' metric rows, dump-on-trip."""

    def __init__(self, k: int = DEFAULT_RING):
        self.k = max(int(k), 1)
        self._ring = deque(maxlen=self.k)   # (cycle, counts, values)
        self.dumps: List[str] = []          # bundle dirs written so far

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def cycles(self) -> List[int]:
        return [c for c, _, _ in self._ring]

    def record(self, cycle: int, counts, values) -> None:
        """Append one cycle's accumulated row (device refs kept as-is)."""
        self._ring.append((int(cycle), counts, values))

    def rows(self) -> List[Dict[str, object]]:
        """Pull the ring to host: one summarised record per entry."""
        out = []
        for cycle, counts, values in self._ring:
            rec = dm.summarize(np.asarray(counts), np.asarray(values))
            rec["cycle"] = cycle
            out.append(rec)
        return out

    # -------------------------------------------------------------- dumping
    def dump(self, out_dir: str, *, reason: str, cycle: int,
             spans: Sequence = (), row_names: Optional[Dict] = None,
             extra: Optional[Dict[str, object]] = None) -> str:
        """Write one post-mortem bundle; returns the bundle directory."""
        tag = "".join(ch if ch.isalnum() else "-" for ch in reason) or "trip"
        path = os.path.join(out_dir, f"flight-cycle{int(cycle):05d}-{tag}")
        os.makedirs(path, exist_ok=True)

        rows = self.rows()
        with open(os.path.join(path, "metrics.jsonl"), "w") as f:
            for rec in rows:
                f.write(json.dumps(jsonify(rec)) + "\n")

        prints = [{"cycle": c, "ranks": dm.fingerprint(np.asarray(v))}
                  for c, _, v in self._ring]
        with open(os.path.join(path, "fingerprints.json"), "w") as f:
            json.dump(jsonify(prints), f, indent=1)

        trace = chrome_trace(list(spans), row_names=row_names)
        with open(os.path.join(path, "trace.json"), "w") as f:
            json.dump(trace, f)

        manifest = {
            "schema": FLIGHT_SCHEMA,
            "reason": str(reason),
            "cycle": int(cycle),
            "ring_cycles": self.cycles,
            "ring_size": self.k,
            "created_unix": time.time(),
            "records": len(rows),
            "spans": len(trace.get("traceEvents", [])),
        }
        if extra:
            manifest.update(jsonify(dict(extra)))
        with open(os.path.join(path, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        self.dumps.append(path)
        return path


def read_bundle(path: str) -> Dict[str, object]:
    """Load a bundle back (manifest + records + fingerprints + trace)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(path, "metrics.jsonl")) as f:
        records = [json.loads(line) for line in f if line.strip()]
    with open(os.path.join(path, "fingerprints.json")) as f:
        prints = json.load(f)
    with open(os.path.join(path, "trace.json")) as f:
        trace = json.load(f)
    return {"manifest": manifest, "records": records,
            "fingerprints": prints, "trace": trace}


def validate_bundle(path: str) -> Dict[str, object]:
    """Assert a dump bundle is well-formed; returns its manifest.

    Checks: all four files present and parseable, manifest carries the
    schema/reason/cycle keys, every metrics record has the named-column
    layout, the trip cycle is inside the recorded ring span, and the
    trace slice passes the Chrome-trace schema validator.
    """
    for fname in _BUNDLE_FILES:
        if not os.path.isfile(os.path.join(path, fname)):
            raise ValueError(f"flight bundle {path!r} missing {fname}")
    bundle = read_bundle(path)
    manifest = bundle["manifest"]
    for key in ("schema", "reason", "cycle", "ring_cycles", "records"):
        if key not in manifest:
            raise ValueError(f"flight manifest missing {key!r}")
    if manifest["schema"] != FLIGHT_SCHEMA:
        raise ValueError(f"flight schema {manifest['schema']} != "
                         f"{FLIGHT_SCHEMA}")
    records = bundle["records"]
    if len(records) != manifest["records"]:
        raise ValueError("manifest record count disagrees with metrics.jsonl")
    for rec in records:
        for key in ("cycle", "count_columns", "value_columns", "counts",
                    "values", "flags", "per_rank_work"):
            if key not in rec:
                raise ValueError(f"flight record missing {key!r}")
        if rec["count_columns"] != list(dm.COUNT_COLUMNS):
            raise ValueError("flight record count-column layout mismatch")
    ring = manifest["ring_cycles"]
    if ring and not (min(ring) <= manifest["cycle"] <= max(ring) + 1):
        raise ValueError(f"trip cycle {manifest['cycle']} outside ring "
                         f"span {ring}")
    errors = validate_chrome_trace(bundle["trace"])
    if errors:
        raise ValueError(f"flight trace slice invalid: {errors[:3]}")
    return manifest
