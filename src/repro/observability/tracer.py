"""Low-overhead host-side span tracer: SWIFT's per-task tic/toc for XLA.

SWIFT instruments every task with per-core tic/toc timestamps and reads the
resulting task-timeline plots to find load imbalance and dead time
(arXiv:1606.02738 §4). On an XLA substrate the "task" is a phase program
dispatch, and the complication is asynchrony: a jitted call returns before
the device work finishes, so a naive ``perf_counter`` pair times the
*dispatch*, not the work. The :class:`Tracer` therefore pairs spans with
explicit :meth:`Tracer.fence` calls (``jax.block_until_ready`` — only when
tracing is enabled) so device work is attributed to the phase that launched
it. The observer effect is the fence itself: tracing serialises dispatch
against completion, which is exactly what a task plot needs and exactly
what a production run doesn't — hence the hard requirement, asserted in
``tests/test_observability.py``, that tracing changes *no computed value*
(fences don't alter results) and triggers *no extra compiles*.

Design constraints:

* **Disabled must be free.** Engines are instrumented unconditionally and
  hold :data:`NULL_TRACER` by default; its ``span()`` returns one shared
  no-op context manager (no allocation, no clock read) and ``fence()`` is
  a pass. The enabled path is a clock read + a NamedTuple append per span
  (< 5 µs median, asserted).
* **Spans carry task attrs**, SWIFT-style: rank, cycle, sub-step, time-bin
  level, pair bucket, live pair count, active-particle fraction — whatever
  the call site knows. ``units`` is the conventional attr for the task's
  asymptotic work (live pairs, shipped slots), consumed by the
  measured-cost feedback into :class:`~repro.core.cost_model.CostModel`.
* **Collective phases appear on every participating rank's row**
  (:meth:`Tracer.record_all`) — one shard_map program is one task on each
  rank's timeline, like SWIFT's send/recv tasks on each core's row.

This module imports jax only inside ``fence`` so the observability layer
stays importable (and its CLI can set ``XLA_FLAGS``) before jax loads.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, NamedTuple, Optional, Sequence


class Span(NamedTuple):
    """One closed tic/toc interval on one rank's timeline."""
    name: str
    rank: int
    t0: float                       # perf_counter seconds
    t1: float
    attrs: Optional[Dict[str, Any]]

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class _ActiveSpan:
    """Context manager of one in-flight span.

    Also the ``timed()`` result: ``elapsed`` is always measured (the
    engines' ``stats["wall"]`` comes from it), recording into the tracer
    happens only when one is attached.
    """

    __slots__ = ("_tracer", "name", "rank", "attrs", "t0", "elapsed")

    def __init__(self, tracer: Optional["Tracer"], name: str, rank: int,
                 attrs: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.rank = rank
        self.attrs = attrs
        self.t0 = 0.0
        self.elapsed = 0.0

    def __enter__(self) -> "_ActiveSpan":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self.elapsed = t1 - self.t0
        tr = self._tracer
        if tr is not None:
            tr._spans.append(Span(self.name, self.rank, self.t0, t1,
                                  self.attrs))
        return False


class _NoopSpan:
    """The disabled-path context manager: shared, stateless, free."""

    __slots__ = ()
    elapsed = 0.0

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP_SPAN = _NoopSpan()


class Tracer:
    """Collects :class:`Span` records for one run (all ranks, one stream).

    ``t_origin`` anchors the run's timeline; exported traces report µs
    since this origin so per-rank rows line up in one Perfetto view.
    """

    enabled = True

    def __init__(self, t_origin: Optional[float] = None):
        self._spans: List[Span] = []
        self.t_origin = (time.perf_counter() if t_origin is None
                         else float(t_origin))
        # ambient attrs merged into every span — engines park loop state
        # here (cycle, sub-step) so leaf call sites (e.g. a transport's
        # exchange) inherit it without plumbing arguments through layers
        self.ctx: Dict[str, Any] = {}

    def _merge(self, attrs: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        if self.ctx:
            merged = dict(self.ctx)
            merged.update(attrs)
            return merged
        return attrs or None

    # ------------------------------------------------------------ recording
    def span(self, name: str, rank: int = 0, **attrs) -> _ActiveSpan:
        """``with tracer.span("density", rank=r, units=npairs): ...``"""
        return _ActiveSpan(self, name, rank, self._merge(attrs))

    def timed(self, name: str, rank: int = 0, **attrs) -> _ActiveSpan:
        """A span whose ``elapsed`` the caller consumes (wall-clock stats).

        On :data:`NULL_TRACER` this still measures — it is the one shared
        timing helper behind every quadrant's ``stats["wall"]``.
        """
        return _ActiveSpan(self, name, rank, self._merge(attrs))

    def now(self) -> float:
        """Clock read for manual record()/record_all() intervals."""
        return time.perf_counter()

    def record(self, name: str, rank: int, t0: float,
               t1: Optional[float] = None, **attrs) -> None:
        """Append a closed span (manual tic/toc)."""
        if t1 is None:
            t1 = time.perf_counter()
        self._spans.append(Span(name, rank, t0, t1, self._merge(attrs)))

    def record_all(self, ranks: Sequence[int], name: str, t0: float,
                   t1: Optional[float] = None, **attrs) -> None:
        """Append the same interval to every participating rank's row —
        how one collective program (an exchange, a fused sub-step) shows
        up as a task on each rank's timeline."""
        if t1 is None:
            t1 = time.perf_counter()
        a = self._merge(attrs)
        for r in ranks:
            self._spans.append(Span(name, int(r), t0, t1, a))

    # -------------------------------------------------------------- fencing
    def fence(self, value: Any) -> Any:
        """``jax.block_until_ready`` — attribute in-flight device work to
        the enclosing span. No-op on :data:`NULL_TRACER`, so tracing-off
        keeps the engines' fully-asynchronous dispatch."""
        import jax
        return jax.block_until_ready(value)

    # -------------------------------------------------------------- reading
    @property
    def spans(self) -> List[Span]:
        return self._spans

    def clear(self) -> None:
        self._spans.clear()

    def ranks(self) -> List[int]:
        return sorted({s.rank for s in self._spans})


class NullTracer(Tracer):
    """The default, disabled tracer: recording is free, fencing is off."""

    enabled = False

    def __init__(self):
        super().__init__(t_origin=0.0)

    def span(self, name: str, rank: int = 0, **attrs) -> _NoopSpan:
        return _NOOP_SPAN

    def timed(self, name: str, rank: int = 0, **attrs) -> _ActiveSpan:
        return _ActiveSpan(None, name, rank, None)

    def record(self, name, rank, t0, t1=None, **attrs) -> None:
        pass

    def record_all(self, ranks, name, t0, t1=None, **attrs) -> None:
        pass

    def fence(self, value: Any) -> Any:
        return value


NULL_TRACER = NullTracer()
