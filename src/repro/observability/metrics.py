"""Unified metrics registry: one API over the engines' scattered ledgers.

The engines already measure a lot — :class:`~repro.distributed.transport.
TransferProbe` (host↔device bytes by field), :class:`~repro.distributed.
transport.CompileProbe` (true XLA compile counts), :class:`~repro.
distributed.transport.BucketPolicy` (grow/shrink events), the halo export
counters in ``sph/dist_timebins.py`` — but each behind its own ad-hoc
accessor. The :class:`MetricsRegistry` absorbs them behind two primitives:

* **counters** — monotonically non-decreasing totals (bytes moved, compiles
  performed, slots shipped, bucket events). ``count(name, total)`` adopts a
  ledger's cumulative value; ``inc(name, delta)`` accumulates directly.
* **gauges** — point-in-time values (per-cycle load imbalance, dead-time
  fraction, bin-occupancy imbalance).

``snapshot()`` returns a plain-JSON view; the per-cycle JSONL sink writes
one snapshot-bearing record per cycle (see ``observer.py``). The schema
version below is stamped into every record and into the benchmark
provenance (``benchmarks/run.py``), so downstream consumers can detect
field renames across PRs.
"""

from __future__ import annotations

from typing import Dict

# bump when metric record field names / meanings change
# v1 (PR 5): phase aggregates + verbatim engine ledgers
# v2 (PR 7): adds the device-metrics block pulled from inside the
#     compiled programs — ``device_metrics`` (named per-rank
#     counts/values columns), ``device_phase_units``,
#     ``device_imbalance``, ``health`` (sentinel flags + energy drift),
#     and ``flight_dump`` on a sentinel trip. v1 readers that ignore
#     unknown fields keep working; ``analysis/report.py`` upgrades v1
#     records on read (``upgrade_record``).
# v3 (PR 10): per-cell cost attribution — ``cell_work`` (per-rank /
#     total work units by task kind, computed per owned cell inside the
#     compiled programs and folded on the host), ``cost_calibration``
#     (the TaskCostLedger's jointly-fitted per-kind rates + confidence
#     + window residual), ``advisor`` (repartition advisor's
#     current/candidate/advised imbalance + accepted flag), and
#     ``cost_ratios``/``observed_units`` now always present (empty dict
#     before any observation). ``upgrade_record`` chains v1→v2→v3.
METRICS_SCHEMA_VERSION = 3


class MetricsRegistry:
    """Counters + gauges with a JSON-safe snapshot."""

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}

    # ------------------------------------------------------------- counters
    def inc(self, name: str, delta: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + delta

    def count(self, name: str, total: float) -> None:
        """Adopt a ledger's cumulative total. Counters never go backwards —
        a regressing source (a probe reset mid-run) keeps the high-water
        mark rather than corrupting the monotonicity contract."""
        self.counters[name] = max(self.counters.get(name, 0), total)

    # --------------------------------------------------------------- gauges
    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    # ------------------------------------------------------------- snapshot
    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {"counters": dict(self.counters), "gauges": dict(self.gauges)}
