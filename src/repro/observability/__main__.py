"""One traced Sedov run → validated ``trace.json`` + ``metrics.jsonl``.

The acceptance harness and the CI artifact step:

    PYTHONPATH=src python -m repro.observability --ranks 4 --cycles 1 \
        --out-dir observability-artifacts

runs the time-bin × distributed engine (collective transport,
device-resident by default) with tracing on, exports the Chrome trace and
the per-cycle metrics log, validates the trace against the minimal schema,
and asserts the record's byte/compile counters agree exactly with the
engine's ``TransferProbe``/``CompileProbe``. With device metrics enabled
(the default) it additionally checks the in-program telemetry row: per-rank
per-phase work present, exactly one ledgered ``metrics`` pull per cycle.
Exit status 0 means every check passed.

The ``dump`` subcommand exercises the flight recorder end-to-end:

    python -m repro.observability dump --inject-nan --out-dir flight-dumps

runs the same scenario, optionally corrupts one velocity component with a
NaN mid-run (tripping the NaN sentinel), and validates the post-mortem
bundle that results. ``dump --validate PATH`` just validates an existing
bundle.

The ``advise`` subcommand is the offline what-if repartition analysis:

    python -m repro.observability advise --metrics metrics.jsonl
    python -m repro.observability advise --ranks 4 --cycles 2

renders the per-rank cost-attribution table and the repartition advisor's
current-vs-advised imbalance trend, either from an existing metrics log
or from a fresh short clustered run (host transport — emulated ranks).

Must run before jax is imported elsewhere: it sets ``XLA_FLAGS`` to emulate
the requested rank count when the environment hasn't already.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _ensure_devices(ranks: int) -> None:
    if "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{ranks}").strip()


def _spec(args):
    from repro.sph import SimulationSpec, SPHConfig
    return SimulationSpec(
        scenario="sedov",
        scenario_params={"n_side": args.n_side, "e0": 1.0, "seed": 0},
        physics=SPHConfig(alpha_visc=1.0, cfl=0.15),
        integrator="timebin", backend="distributed", ranks=args.ranks,
        dt_max=0.02, max_depth=4,
        transport=args.transport, residency=args.residency,
        observe={"flight_dir": args.out_dir})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.observability",
        description="traced Sedov run + trace/metrics export & validation")
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--cycles", type=int, default=1)
    ap.add_argument("--out-dir", default=".")
    ap.add_argument("--residency", default="device",
                    choices=("host", "device"))
    ap.add_argument("--transport", default="collective",
                    choices=("host", "collective"))
    ap.add_argument("--n-side", type=int, default=6)
    ap.add_argument("--no-device-metrics", action="store_true",
                    help="disable the per-cycle telemetry pull (the row is "
                         "still computed in-program)")
    args = ap.parse_args(argv)

    if args.transport == "collective":
        _ensure_devices(args.ranks)

    from repro.sph import build_simulation
    from repro.observability import jsonify, validate_chrome_trace

    spec = _spec(args)
    if args.no_device_metrics:
        spec = spec.with_(observe={"device_metrics": False,
                                   "flight_dir": args.out_dir})
    sim = build_simulation(spec)
    for _ in range(args.cycles):
        sim.step()
    obs = sim.observer
    eng = sim.engine

    os.makedirs(args.out_dir, exist_ok=True)
    trace_path = os.path.join(args.out_dir, "trace.json")
    metrics_path = os.path.join(args.out_dir, "metrics.jsonl")
    doc = obs.export_chrome_trace(trace_path, process_name="sedov traced run")
    obs.write_metrics_jsonl(metrics_path)
    # cost-attribution table + repartition-advisor trend, uploaded with
    # the trace artifacts by the CI acceptance step
    from repro.analysis.report import advisor_trend, attribution_table
    trend_path = os.path.join(args.out_dir, "advisor_trend.txt")
    with open(trend_path, "w") as f:
        f.write(attribution_table(obs.records) + "\n\n"
                + advisor_trend(obs.records) + "\n")

    failures = []
    errors = validate_chrome_trace(doc)
    if errors:
        failures.append(f"trace schema: {errors[:5]}")

    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    rows = {e["tid"] for e in xs}
    if rows != set(range(args.ranks)):
        failures.append(f"expected one row per rank 0..{args.ranks - 1}, "
                        f"got {sorted(rows)}")
    # one phase-program slice per force sub-step on every rank
    per_sub = ("fused_substep", "fused_final") \
        if args.residency == "device" else ("density", "force")
    nsub = sum(r["force_substeps"] for r in obs.records)
    for r in sorted(rows):
        got = sum(1 for e in xs if e["tid"] == r and e["name"] in per_sub)
        if got < nsub:
            failures.append(f"rank {r}: {got} phase slices < "
                            f"{nsub} force sub-steps")

    # JSONL counters agree exactly with the live probes
    rec = obs.records[-1]
    if rec["compiles"] != jsonify(eng.probe.counts()):
        failures.append(f"compile counters diverged: {rec['compiles']} != "
                        f"{eng.probe.counts()}")
    if rec["total_compiles"] != eng.probe.total_compiles():
        failures.append("total_compiles diverged")
    if rec["transfers"] != jsonify(eng.transfers.stats()):
        failures.append(f"transfer ledger diverged: {rec['transfers']} != "
                        f"{eng.transfers.stats()}")

    # device metrics: in-program per-rank rows, one ledgered pull per cycle
    if not args.no_device_metrics:
        dmx = rec.get("device_metrics")
        if not dmx:
            failures.append("no device_metrics in the cycle record")
        else:
            # per-cell attribution sums exactly to the device phase-unit
            # totals (owned rows only — halo replicas fold onto their
            # owner cell, so nothing is double-counted)
            cw = getattr(eng, "device_cell_work_last", None)
            if cw is None:
                failures.append("no device_cell_work_last on the engine")
            else:
                import numpy as np
                cells = np.asarray(cw["cells"])
                per_rank = np.asarray(cw["per_rank"])
                cols = list(cw["columns"])
                du = rec.get("device_phase_units") or {}
                # exchange exactness is a device-path identity (the host
                # ladder's value column splits shipped slots evenly per
                # rank; its per-cell column is the receiver-side truth)
                exact = ("density", "force") + (
                    ("exchange",) if args.residency == "device" else ())
                for kind in exact:
                    tot = float(cells[:, cols.index(kind)].sum())
                    want = float(du.get(kind, 0.0))
                    if abs(tot - want) > 1e-6 * max(want, 1.0):
                        failures.append(
                            f"per-cell {kind} units {tot} != device "
                            f"phase total {want}")
                if not np.allclose(cells.sum(axis=0), per_rank.sum(axis=0)):
                    failures.append(
                        "per-cell column sums disagree with per-rank "
                        f"attribution: {cells.sum(axis=0)} vs "
                        f"{per_rank.sum(axis=0)}")
            if len(dmx["per_rank_work"]) != args.ranks:
                failures.append(
                    f"device per_rank_work has "
                    f"{len(dmx['per_rank_work'])} rows != {args.ranks}")
            if not all(w > 0 for w in dmx["per_rank_work"]):
                failures.append(f"device per-rank work not all positive: "
                                f"{dmx['per_rank_work']}")
            if rec.get("device_imbalance") is None \
                    and sum(dmx["per_rank_work"]) > 0:
                failures.append("device_imbalance missing")
            if "health" not in rec:
                failures.append("health block missing")
        pulls = eng.transfers.stats()["boundary_events"].get("metrics", 0)
        if pulls != args.cycles:
            failures.append(f"{pulls} ledgered metrics pulls != "
                            f"{args.cycles} cycles (pull-once contract)")

    summary = {
        "ranks": args.ranks, "cycles": args.cycles,
        "residency": args.residency, "spans": len(xs),
        "force_substeps": nsub,
        "imbalance": rec.get("imbalance"),
        "device_imbalance": rec.get("device_imbalance"),
        "device_phase_units": rec.get("device_phase_units"),
        "health": rec.get("health"),
        "dead_frac": rec.get("dead_frac"),
        "bin_occupancy_imbalance": rec.get("bin_occupancy_imbalance"),
        "total_compiles": rec.get("total_compiles"),
        "cell_work": rec.get("cell_work"),
        "cost_calibration": rec.get("cost_calibration"),
        "advisor": rec.get("advisor"),
        "trace": trace_path, "metrics": metrics_path,
        "advisor_trend": trend_path,
        "ok": not failures,
    }
    print(json.dumps(jsonify(summary), indent=1))
    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    return 0


def dump_main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.observability dump",
        description="produce (and validate) a flight-recorder post-mortem "
                    "bundle; --inject-nan trips the NaN sentinel on purpose")
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--cycles", type=int, default=2)
    ap.add_argument("--out-dir", default="flight-dumps")
    ap.add_argument("--residency", default="device",
                    choices=("host", "device"))
    ap.add_argument("--transport", default="collective",
                    choices=("host", "collective"))
    ap.add_argument("--n-side", type=int, default=6)
    ap.add_argument("--inject-nan", action="store_true",
                    help="corrupt one velocity component before the last "
                         "cycle so the NaN sentinel trips")
    ap.add_argument("--validate", metavar="PATH",
                    help="only validate an existing bundle directory")
    args = ap.parse_args(argv)

    from repro.observability.flight import validate_bundle

    if args.validate:
        manifest = validate_bundle(args.validate)
        print(json.dumps({"bundle": args.validate,
                          "manifest": manifest, "ok": True}, indent=1))
        return 0

    if args.transport == "collective":
        _ensure_devices(args.ranks)

    import numpy as np
    from repro.sph import build_simulation
    from repro.observability import jsonify

    sim = build_simulation(_spec(args))
    eng = sim.engine
    for n in range(args.cycles):
        if args.inject_nan and n == args.cycles - 1:
            # poison one alive particle's velocity on the global mirror —
            # the next cycle's scatter carries it onto the mesh and the
            # in-program sentinel must catch it
            cells = eng.state.cells
            vel = np.asarray(cells.vel).copy()
            alive = np.argwhere(np.asarray(cells.mask) > 0)
            c, p = alive[0]
            vel[c, p, 0] = np.nan
            import jax.numpy as jnp
            eng.state = eng.state._replace(
                cells=cells._replace(vel=jnp.asarray(vel)))
        sim.step()
    obs = sim.observer

    dumps = list(obs.flight.dumps)
    if not dumps:
        # no sentinel tripped (healthy run without --inject-nan): dump the
        # ring explicitly so the bundle path is exercised either way
        dumps = [obs.dump_flight(reason="manual")]

    out = []
    for path in dumps:
        manifest = validate_bundle(path)
        out.append({"bundle": path, "reason": manifest["reason"],
                    "cycle": manifest["cycle"],
                    "records": manifest["records"]})
    tripped = bool(obs.records and obs.records[-1]
                   .get("health", {}).get("tripped"))
    print(json.dumps(jsonify({"dumps": out, "tripped": tripped,
                              "ok": True}), indent=1))
    if args.inject_nan and not tripped:
        print("FAIL: NaN injected but no sentinel tripped", file=sys.stderr)
        return 1
    return 0


def advise_main(argv=None) -> int:
    """Offline what-if repartition analysis (schema v3).

    With ``--metrics`` renders the cost-attribution table and advisor
    trend from an existing per-cycle JSONL (any supported schema —
    pre-v3 logs render '-' markers). Without it, runs a short clustered
    scenario on an emulated rank partition (host transport — no real
    devices needed) and advises on its *measured* cell weights.
    """
    ap = argparse.ArgumentParser(
        prog="python -m repro.observability advise",
        description="offline what-if repartition analysis: attribution "
                    "table + advisor trend from a metrics.jsonl, or from "
                    "a fresh short clustered run")
    ap.add_argument("--metrics", metavar="PATH",
                    help="existing metrics.jsonl to analyse")
    ap.add_argument("--scenario", default="clustered")
    ap.add_argument("--n", type=int, default=96,
                    help="particle count for the fresh-run mode")
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--cycles", type=int, default=2)
    ap.add_argument("--out", metavar="PATH",
                    help="also write the rendered report here")
    args = ap.parse_args(argv)

    from repro.analysis.report import advisor_trend, attribution_table

    if args.metrics:
        from repro.observability import read_metrics_jsonl
        records = read_metrics_jsonl(args.metrics)
    else:
        from repro.sph import SimulationSpec, build_simulation
        spec = SimulationSpec(
            scenario=args.scenario,
            scenario_params={"n": args.n, "seed": 0},
            integrator="timebin", backend="distributed", ranks=args.ranks,
            transport="host", observe=True)
        sim = build_simulation(spec)
        for _ in range(args.cycles):
            sim.step()
        records = sim.observer.records
    report = attribution_table(records) + "\n\n" + advisor_trend(records)
    print(report)
    if args.out:
        with open(args.out, "w") as f:
            f.write(report + "\n")
    return 0


if __name__ == "__main__":
    _argv = sys.argv[1:]
    if _argv and _argv[0] == "dump":
        raise SystemExit(dump_main(_argv[1:]))
    if _argv and _argv[0] == "advise":
        raise SystemExit(advise_main(_argv[1:]))
    raise SystemExit(main(_argv))
