"""One traced Sedov run → validated ``trace.json`` + ``metrics.jsonl``.

The acceptance harness and the CI artifact step:

    PYTHONPATH=src python -m repro.observability --ranks 4 --cycles 1 \
        --out-dir observability-artifacts

runs the time-bin × distributed engine (collective transport,
device-resident by default) with tracing on, exports the Chrome trace and
the per-cycle metrics log, validates the trace against the minimal schema,
and asserts the record's byte/compile counters agree exactly with the
engine's ``TransferProbe``/``CompileProbe``. Exit status 0 means every
check passed.

Must run before jax is imported elsewhere: it sets ``XLA_FLAGS`` to emulate
the requested rank count when the environment hasn't already.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.observability",
        description="traced Sedov run + trace/metrics export & validation")
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--cycles", type=int, default=1)
    ap.add_argument("--out-dir", default=".")
    ap.add_argument("--residency", default="device",
                    choices=("host", "device"))
    ap.add_argument("--transport", default="collective",
                    choices=("host", "collective"))
    ap.add_argument("--n-side", type=int, default=6)
    args = ap.parse_args(argv)

    if args.transport == "collective" and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.ranks}").strip()

    from repro.sph import SimulationSpec, SPHConfig, build_simulation
    from repro.observability import jsonify, validate_chrome_trace

    spec = SimulationSpec(
        scenario="sedov",
        scenario_params={"n_side": args.n_side, "e0": 1.0, "seed": 0},
        physics=SPHConfig(alpha_visc=1.0, cfl=0.15),
        integrator="timebin", backend="distributed", ranks=args.ranks,
        dt_max=0.02, max_depth=4,
        transport=args.transport, residency=args.residency,
        observe=True)
    sim = build_simulation(spec)
    for _ in range(args.cycles):
        sim.step()
    obs = sim.observer
    eng = sim.engine

    os.makedirs(args.out_dir, exist_ok=True)
    trace_path = os.path.join(args.out_dir, "trace.json")
    metrics_path = os.path.join(args.out_dir, "metrics.jsonl")
    doc = obs.export_chrome_trace(trace_path, process_name="sedov traced run")
    obs.write_metrics_jsonl(metrics_path)

    failures = []
    errors = validate_chrome_trace(doc)
    if errors:
        failures.append(f"trace schema: {errors[:5]}")

    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    rows = {e["tid"] for e in xs}
    if rows != set(range(args.ranks)):
        failures.append(f"expected one row per rank 0..{args.ranks - 1}, "
                        f"got {sorted(rows)}")
    # one phase-program slice per force sub-step on every rank
    per_sub = ("fused_substep", "fused_final") \
        if args.residency == "device" else ("density", "force")
    nsub = sum(r["force_substeps"] for r in obs.records)
    for r in sorted(rows):
        got = sum(1 for e in xs if e["tid"] == r and e["name"] in per_sub)
        if got < nsub:
            failures.append(f"rank {r}: {got} phase slices < "
                            f"{nsub} force sub-steps")

    # JSONL counters agree exactly with the live probes
    rec = obs.records[-1]
    if rec["compiles"] != jsonify(eng.probe.counts()):
        failures.append(f"compile counters diverged: {rec['compiles']} != "
                        f"{eng.probe.counts()}")
    if rec["total_compiles"] != eng.probe.total_compiles():
        failures.append("total_compiles diverged")
    if rec["transfers"] != jsonify(eng.transfers.stats()):
        failures.append(f"transfer ledger diverged: {rec['transfers']} != "
                        f"{eng.transfers.stats()}")

    summary = {
        "ranks": args.ranks, "cycles": args.cycles,
        "residency": args.residency, "spans": len(xs),
        "force_substeps": nsub,
        "imbalance": rec.get("imbalance"),
        "dead_frac": rec.get("dead_frac"),
        "bin_occupancy_imbalance": rec.get("bin_occupancy_imbalance"),
        "total_compiles": rec.get("total_compiles"),
        "trace": trace_path, "metrics": metrics_path,
        "ok": not failures,
    }
    print(json.dumps(jsonify(summary), indent=1))
    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
