"""Observability: task-timeline tracing + unified metrics (SWIFT §4).

SWIFT's engineering loop is *instrument every task, read the task plot*:
per-core tic/toc timestamps rendered as one row per core, one slice per
task, from which load imbalance, dead time and communication stalls are
read off directly (arXiv:1606.02738 §4; first-class tooling in modern
SWIFT, arXiv:2305.13380). This package is that loop for the XLA substrate:

* :mod:`~repro.observability.tracer` — the low-overhead span tracer with
  ``block_until_ready`` fencing (device work attributed to the phase that
  launched it); free when disabled.
* :mod:`~repro.observability.metrics` — counters/gauges registry absorbing
  the engines' ledgers (transfer bytes, compile counts, bucket events,
  halo volume, bin-occupancy imbalance) behind one API.
* :mod:`~repro.observability.sinks` — Chrome-trace/Perfetto JSON export
  (the task plot) + per-cycle JSONL metrics log, with the minimal schema
  validator CI runs on every traced cycle.
* :mod:`~repro.observability.observer` — the per-run merge point wired in
  by ``SimulationSpec(observe=True)``; feeds measured task costs back into
  :class:`~repro.core.cost_model.CostModel`.
* :mod:`~repro.observability.device_metrics` — the in-program telemetry
  carry (fixed-shape per-rank counter/value rows computed *inside* the
  fused programs, accumulated on device, pulled once per cycle).
* :mod:`~repro.observability.flight` — last-K-cycles flight recorder +
  post-mortem dump bundles, written on any health-sentinel trip.

``python -m repro.observability`` runs one traced Sedov cycle on an
emulated rank mesh and exports + validates ``trace.json`` /
``metrics.jsonl`` (the CI artifact job); ``python -m repro.observability
dump`` produces and validates a flight-recorder bundle (optionally
tripping the NaN sentinel on purpose).

This package must stay importable before jax is configured (its CLI sets
``XLA_FLAGS``), so nothing here imports jax at module scope.
"""

from .costs import RepartitionAdvisor, TaskCostLedger, weighted_imbalance
from .device_metrics import (CELL_COLUMNS, COUNT_COLUMNS, VALUE_COLUMNS,
                             DEVICE_METRICS_VERSION)
from .flight import FlightRecorder, read_bundle, validate_bundle
from .metrics import METRICS_SCHEMA_VERSION, MetricsRegistry
from .observer import ObserveSpec, RunObserver, UMBRELLA_SPANS
from .sinks import (chrome_trace, jsonify, read_metrics_jsonl,
                    upgrade_record, validate_chrome_trace,
                    write_chrome_trace, write_metrics_jsonl)
from .tracer import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "METRICS_SCHEMA_VERSION", "MetricsRegistry",
    "CELL_COLUMNS", "COUNT_COLUMNS", "VALUE_COLUMNS",
    "DEVICE_METRICS_VERSION",
    "RepartitionAdvisor", "TaskCostLedger", "weighted_imbalance",
    "FlightRecorder", "read_bundle", "validate_bundle",
    "ObserveSpec", "RunObserver", "UMBRELLA_SPANS",
    "chrome_trace", "jsonify", "read_metrics_jsonl", "upgrade_record",
    "validate_chrome_trace", "write_chrome_trace", "write_metrics_jsonl",
    "NULL_TRACER", "NullTracer", "Span", "Tracer",
]
