"""Trace/metrics sinks: Chrome-trace JSON (the task plot) + per-cycle JSONL.

The Chrome trace event format is the Perfetto-openable analogue of SWIFT's
task plots (arXiv:1606.02738 Figs. 9-11): one row per rank (``tid``), one
complete ("X") slice per phase program, with the task attrs (cycle,
sub-step, time-bin level, bucket, pair count, …) in ``args``. Open the
exported file at https://ui.perfetto.dev or ``chrome://tracing``.

:func:`validate_chrome_trace` is the minimal schema contract CI enforces on
every traced run: a ``traceEvents`` list whose "X" events have numeric
``ts``/non-negative ``dur`` in sorted order, whose "B"/"E" events match up
per (pid, tid), and whose every rank row is named by a ``thread_name``
metadata event.

The JSONL sink writes one self-describing record per cycle (see
``observer.py`` for the record layout) — ``jq``-able, append-only, schema
version stamped in every line.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Sequence

from .tracer import Span

TRACE_PID = 0


def jsonify(obj: Any) -> Any:
    """Best-effort conversion to plain JSON types (numpy scalars/arrays,
    tuples, sets, dict keys)."""
    if isinstance(obj, dict):
        return {str(k): jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set)):
        return [jsonify(v) for v in obj]
    if isinstance(obj, (str, bool)) or obj is None:
        return obj
    if isinstance(obj, (int, float)):
        return obj
    if hasattr(obj, "item"):            # numpy / jax scalar
        try:
            return jsonify(obj.item())
        except Exception:
            pass
    if hasattr(obj, "tolist"):          # numpy / jax array
        try:
            return jsonify(obj.tolist())
        except Exception:
            pass
    return str(obj)


# ------------------------------------------------------------- chrome trace
def chrome_trace(spans: Sequence[Span], t_origin: float = 0.0,
                 process_name: str = "repro",
                 row_names: Dict[int, str] = None) -> Dict[str, Any]:
    """Spans → a Chrome-trace document: per-rank rows, per-phase slices.

    ``ts``/``dur`` are microseconds since ``t_origin`` (the tracer's run
    anchor), so one run's ranks share a timeline in the Perfetto view.
    ``row_names`` overrides the default ``rank {r}`` row labels — the
    fleet serving trace names each row by its ``request_id`` so a
    multi-request timeline reads per user, not per rank.
    """
    ranks = sorted({s.rank for s in spans})
    events: List[Dict[str, Any]] = [{
        "ph": "M", "name": "process_name", "pid": TRACE_PID, "tid": 0,
        "args": {"name": process_name}}]
    row_names = row_names or {}
    for r in ranks:
        events.append({"ph": "M", "name": "thread_name", "pid": TRACE_PID,
                       "tid": r,
                       "args": {"name": row_names.get(r, f"rank {r}")}})
        # ranks sort by index, not lexically, in the viewer
        events.append({"ph": "M", "name": "thread_sort_index",
                       "pid": TRACE_PID, "tid": r,
                       "args": {"sort_index": r}})
    slices = [{
        "ph": "X", "name": s.name, "cat": "task", "pid": TRACE_PID,
        "tid": s.rank,
        "ts": (s.t0 - t_origin) * 1e6,
        "dur": max(s.dur, 0.0) * 1e6,
        "args": jsonify(s.attrs or {}),
    } for s in spans]
    slices.sort(key=lambda e: (e["ts"], e["tid"]))
    return {"traceEvents": events + slices, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: Sequence[Span],
                       t_origin: float = 0.0,
                       process_name: str = "repro",
                       row_names: Dict[int, str] = None) -> Dict[str, Any]:
    doc = chrome_trace(spans, t_origin, process_name, row_names=row_names)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def validate_chrome_trace(doc: Dict[str, Any]) -> List[str]:
    """Minimal schema check; returns a list of violations (empty = valid).

    * ``traceEvents`` is a list of dicts with a ``ph`` field;
    * "X" events carry numeric ``ts`` and ``dur`` ≥ 0, appear in
      non-decreasing ``ts`` order, and their ``(pid, tid)`` row is mapped
      by a ``thread_name`` metadata event;
    * "B"/"E" events nest properly per ``(pid, tid)`` (every E closes a B,
      nothing left open).
    """
    errors: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    named_rows = set()
    for e in events:
        if isinstance(e, dict) and e.get("ph") == "M" \
                and e.get("name") == "thread_name":
            named_rows.add((e.get("pid"), e.get("tid")))
    last_ts = None
    stacks: Dict[tuple, List[str]] = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict) or "ph" not in e:
            errors.append(f"event {i}: not a dict with 'ph'")
            continue
        ph = e["ph"]
        row = (e.get("pid"), e.get("tid"))
        if ph == "X":
            ts, dur = e.get("ts"), e.get("dur")
            if not isinstance(ts, (int, float)):
                errors.append(f"event {i}: X without numeric ts")
                continue
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i} ({e.get('name')}): bad dur {dur}")
            if last_ts is not None and ts < last_ts:
                errors.append(f"event {i} ({e.get('name')}): ts {ts} < "
                              f"previous {last_ts} (unsorted)")
            last_ts = ts
            if row not in named_rows:
                errors.append(f"event {i} ({e.get('name')}): row {row} has "
                              f"no thread_name metadata (rank mapping)")
        elif ph == "B":
            stacks.setdefault(row, []).append(e.get("name", ""))
        elif ph == "E":
            if not stacks.get(row):
                errors.append(f"event {i}: E without matching B on {row}")
            else:
                stacks[row].pop()
    for row, stack in stacks.items():
        if stack:
            errors.append(f"row {row}: unclosed B events {stack}")
    return errors


# -------------------------------------------------------------------- jsonl
def write_metrics_jsonl(path: str, records: Iterable[Dict[str, Any]]) -> None:
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(jsonify(rec)) + "\n")


def _v1_to_v2(up: Dict[str, Any]) -> None:
    """v2 (PR 7) added the device-metrics block; absent on v1 records."""
    up.setdefault("device_metrics", None)
    up.setdefault("device_phase_units", None)
    up.setdefault("device_imbalance", None)
    up.setdefault("health", None)


def _v2_to_v3(up: Dict[str, Any]) -> None:
    """v3 (PR 10) added per-cell cost attribution / calibration /
    advisor blocks and made the cost-feedback dicts always present."""
    up.setdefault("cell_work", None)
    up.setdefault("cost_calibration", None)
    up.setdefault("advisor", None)
    up.setdefault("cost_ratios", {})
    up.setdefault("observed_units", {})


_UPGRADES = {1: _v1_to_v2, 2: _v2_to_v3}


def upgrade_record(rec: Dict[str, Any]) -> Dict[str, Any]:
    """Normalise a metrics record to the current schema shape, chaining
    one version step at a time (v1→v2→v3).

    Older records predate newer blocks; readers that branch on them
    (``analysis/report.py``, the flight-bundle tools) call this so any
    supported log renders through the same code path — the added fields
    are explicit "not measured" markers, and the original schema number
    is preserved under ``schema_original``. A record claiming a schema
    *newer* than this build understands is rejected loudly rather than
    mis-rendered.
    """
    from .metrics import METRICS_SCHEMA_VERSION
    ver = int(rec.get("schema", 1))
    if ver > METRICS_SCHEMA_VERSION:
        raise ValueError(
            f"metrics record has schema {ver}, newer than this build's "
            f"{METRICS_SCHEMA_VERSION} — upgrade the reader, not the "
            f"record")
    if ver >= METRICS_SCHEMA_VERSION:
        return rec
    up = dict(rec)
    up["schema_original"] = ver
    while ver < METRICS_SCHEMA_VERSION:
        _UPGRADES[ver](up)
        ver += 1
    up["schema"] = ver
    return up


def read_metrics_jsonl(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
