"""Per-cell task-cost attribution → calibration → repartition advice.

SWIFT refines its domain decomposition with *measured* task costs (§3.2:
"after a task has been executed, its effective computational cost is
computed and used"). Fully fused runs never execute tasks one at a time,
so there is nothing to time individually — but the compiled programs do
attribute their work to cells (``device_metrics.measure_cells``), and the
once-per-cycle metrics pull delivers a per-cell units vector per task
kind. This module closes the loop on the host:

* :class:`TaskCostLedger` — accumulates per-cycle (units-by-kind, fused
  wall seconds) samples, keeps the direct per-kind ``CostModel.observe``
  stream flowing (so ``measured_vs_modelled`` reports from cycle one),
  and periodically runs the joint :meth:`CostModel.calibrate` fit that
  replaces the crude units-share apportioning. Its fitted rates convert
  per-cell unit vectors into measured per-cell *weights* — the currency
  the decomposition balances.
* :class:`RepartitionAdvisor` — replays ``decompose_cells`` against the
  measured cell weights each cycle and reports what the imbalance *would
  be* under the advised partition vs the current one. Purely advisory:
  it never moves a cell (PR-11's device-side migration consumes this
  contract), it just emits the ``advised_imbalance`` ≤
  ``current_imbalance`` time-series into the metrics record.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Dict, Optional, Sequence

import numpy as np

from .device_metrics import CELL_COLUMNS

__all__ = ["TaskCostLedger", "RepartitionAdvisor", "weighted_imbalance"]


def weighted_imbalance(assignment, weights, nranks: int) -> float:
    """max/mean of per-rank load for per-cell ``weights`` under
    ``assignment`` (1.0 = perfectly balanced). Pass ``nranks`` explicitly
    so ranks owning zero cells still count."""
    assignment = np.asarray(assignment, np.int64)
    w = np.asarray(weights, np.float64)
    rank_w = np.zeros(int(nranks))
    np.add.at(rank_w, assignment, w)
    mean = rank_w.mean()
    return float(rank_w.max() / mean) if mean > 0 else 1.0


class TaskCostLedger:
    """Sliding window of measured (units-by-kind, seconds) cycle samples
    feeding :meth:`CostModel.calibrate`.

    ``record`` is called once per cycle on fused paths with the aggregate
    work units (from the per-cell vectors' totals) and the deduped fused
    program wall. It apportions the wall across kinds by unit share and
    feeds ``CostModel.observe`` — the same information the pre-calibration
    heuristic provided, so ``cost_ratios``/``observed_units`` behave
    identically — then refits the joint per-kind rates over the window.
    """

    def __init__(self, cost_model, *, window: int = 64,
                 refit_every: int = 1, skip_first: int = 1,
                 outlier_factor: float = 8.0):
        self.cm = cost_model
        self.samples: deque = deque(maxlen=int(window))
        self.refit_every = max(int(refit_every), 1)
        # the first cycle's fused wall is dominated by XLA compiles —
        # feed it to observe() (pre-existing behaviour) but keep it out
        # of the calibration window, like any benchmark warmup
        self.skip_first = max(int(skip_first), 0)
        # compiles can also land mid-run (rebucketing mints a new
        # program): samples whose wall exceeds ``outlier_factor`` × the
        # window's fastest wall are compile spikes, not work, and are
        # excluded from the fit the same way the warmup cycle is
        self.outlier_factor = float(outlier_factor)
        self.last_calibration: Dict[str, Dict[str, float]] = {}
        self.last_residual: Optional[float] = None
        self.last_nfit = 0
        self._since_fit = 0
        self._seen = 0

    # ------------------------------------------------------------ feeding
    def record(self, units: Dict[str, float], seconds: float
               ) -> Dict[str, Any]:
        """Fold one cycle's aggregate sample in; returns the current
        calibration block (see :meth:`snapshot`)."""
        units = {k: float(v) for k, v in units.items() if float(v) > 0}
        if seconds > 0 and units:
            tot = sum(units.values())
            if hasattr(self.cm, "observe") and tot > 0:
                for k, u in units.items():
                    self.cm.observe(k, u, seconds * u / tot)
            self._seen += 1
            if self._seen > self.skip_first:
                self.samples.append((units, float(seconds)))
                self._since_fit += 1
                if self._since_fit >= self.refit_every:
                    self.calibrate()
        return self.snapshot()

    def _fit_window(self) -> list:
        """The window minus compile spikes (walls ≫ the fastest wall)."""
        if not self.samples:
            return []
        floor = min(s for _, s in self.samples)
        cut = self.outlier_factor * floor
        return [(u, s) for u, s in self.samples if s <= cut]

    def calibrate(self) -> Dict[str, Dict[str, float]]:
        """Joint per-kind rate fit over the outlier-filtered sample
        window (needs ≥ 2 surviving samples; keeps the last fit
        otherwise)."""
        self._since_fit = 0
        fit = self._fit_window()
        if len(fit) >= 2 and hasattr(self.cm, "calibrate"):
            cal = self.cm.calibrate(fit)
            if cal:
                self.last_calibration = cal
                self.last_nfit = len(fit)
                self.last_residual = self._residual(cal, fit)
        return self.last_calibration

    def _residual(self, cal: Dict[str, Dict[str, float]],
                  fit: list) -> Optional[float]:
        """Mean relative |predicted − measured| wall over the fit set."""
        rates = {k: v["rate"] for k, v in cal.items()}
        num = den = 0.0
        for u, s in fit:
            pred = sum(rates.get(k, 0.0) * v for k, v in u.items())
            num += abs(pred - s)
            den += abs(s)
        return (num / den) if den > 0 else None

    def snapshot(self) -> Dict[str, Any]:
        """The ``cost_calibration`` block of the metrics record."""
        return {"kinds": {k: dict(v)
                          for k, v in self.last_calibration.items()},
                "residual": self.last_residual,
                "nsamples": self.last_nfit}

    # ------------------------------------------------------------ weights
    def rate(self, kind: str) -> float:
        """Fitted seconds-per-unit for ``kind``; falls back to the cost
        model's EMA rate, then its default."""
        cal = self.last_calibration.get(kind)
        if cal and cal.get("rate", 0.0) > 0:
            return float(cal["rate"])
        return float(self.cm.rates.get(kind, self.cm.default_rate))

    def cell_weights(self, cell_work: Dict[str, Any]) -> np.ndarray:
        """Measured per-cell weight: Σ over kinds of rate·units.

        ``cell_work`` is the engines' ``device_cell_work_last`` dict
        (columns / cells / per_rank). This is the node-weight vector the
        advisor feeds back into ``decompose_cells``."""
        cells = np.asarray(cell_work["cells"], np.float64)
        cols = list(cell_work.get("columns", CELL_COLUMNS))
        w = np.zeros(cells.shape[0], np.float64)
        for i, k in enumerate(cols):
            w += self.rate(k) * cells[:, i]
        return w

    def per_cell_ratio(self, cell_work: Dict[str, Any],
                       modelled: Sequence[float]) -> Dict[str, float]:
        """Distribution of measured/modelled per-cell weight (both
        normalised to unit mass): how far the analytic model's *shape*
        is from the measured one, cell by cell."""
        meas = self.cell_weights(cell_work)
        mod = np.maximum(np.asarray(modelled, np.float64), 1e-300)
        ms, ds = meas.sum(), mod.sum()
        if ms <= 0 or ds <= 0:
            return {"mean": 1.0, "max": 1.0}
        ratio = (meas / ms) / (mod / ds)
        live = ratio[meas > 0]
        if live.size == 0:
            return {"mean": 1.0, "max": 1.0}
        return {"mean": float(live.mean()), "max": float(live.max())}


class RepartitionAdvisor:
    """What-if replay of the graph partitioner against measured weights.

    Holds the task graph built from the *current* grid/pair structure
    (structure changes rarely; weights every cycle). Each ``advise``
    call partitions with the measured per-cell weights as node weights
    and compares per-rank load imbalance under the candidate vs the
    engine's current assignment. ``advised_imbalance`` is
    ``min(candidate, current)`` — the advisor may always *keep* the
    current partition, so its advice is never worse than doing nothing.
    """

    def __init__(self, graph, ncells: int, nranks: int, *, seed: int = 0):
        self.graph = graph
        self.ncells = int(ncells)
        self.nranks = int(nranks)
        self.seed = int(seed)
        node_w, _ = graph.cell_graph()
        mod = np.zeros(self.ncells, np.float64)
        for r, w in node_w.items():
            if r < self.ncells:
                mod[r] = w
        self.modelled_weights = np.maximum(mod, 1e-12)

    def advise(self, assignment, cell_weights) -> Dict[str, Any]:
        """One advisory step. Returns the ``advisor`` block of the
        metrics record plus the candidate ``assignment`` (stripped
        before serialisation)."""
        w = np.maximum(np.asarray(cell_weights, np.float64), 1e-12)
        cur = weighted_imbalance(assignment, w, self.nranks)
        if self.nranks <= 1:
            return {"current_imbalance": cur, "candidate_imbalance": cur,
                    "advised_imbalance": cur, "accepted": False,
                    "assignment": np.asarray(assignment, np.int64)}
        from ..core.decompose import decompose_cells
        dec = decompose_cells(self.graph, self.ncells, self.nranks,
                              seed=self.seed, node_weights=w)
        cand_assign = np.asarray(dec.assignment, np.int64)
        cand = weighted_imbalance(cand_assign, w, self.nranks)
        accepted = cand < cur - 1e-9
        return {"current_imbalance": cur,
                "candidate_imbalance": cand,
                "advised_imbalance": min(cand, cur),
                "accepted": bool(accepted),
                "assignment": cand_assign if accepted
                else np.asarray(assignment, np.int64)}
