"""internvl2-2b — InternViT (stub patch embeddings) + InternLM2 backbone.

The vision frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, P, d_model). [arXiv:2404.16821; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv=8, head_dim=128,
    d_ff=8192, vocab=92553,
    vlm_patches=256,
)

REDUCED = ModelConfig(
    name="internvl2-2b-reduced", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512, vlm_patches=16,
)
