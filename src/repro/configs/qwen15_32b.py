"""qwen1.5-32b — dense, QKV bias, MHA (kv=40). [hf:Qwen/Qwen1.5-0.5B; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv=40, head_dim=128,
    d_ff=27392, vocab=152064,
    qkv_bias=True, rope_base=1.0e6, act="silu",
)

REDUCED = ModelConfig(
    name="qwen1.5-32b-reduced", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512, qkv_bias=True, rope_base=1.0e6,
)
