"""zamba2-1.2b — Mamba-2 backbone + shared attention block (+LoRA).
[arXiv:2411.15242; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, head_dim=64,
    d_ff=8192, vocab=32000,
    ssm="mamba2", d_state=64, d_conv=4, expand=2, ssm_headdim=64,
    shared_attn_every=6, shared_lora_rank=32,
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512,
    ssm="mamba2", d_state=16, d_conv=4, expand=2, ssm_headdim=16,
    shared_attn_every=4, shared_lora_rank=8, ssm_chunk=16,
)
