"""mixtral-8x7b — MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=32000,
    n_experts=8, top_k=2, window=4096,
)

REDUCED = ModelConfig(
    name="mixtral-8x7b-reduced", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512, n_experts=4, top_k=2, window=64,
    capacity_factor=8.0,
)
