"""falcon-mamba-7b — attention-free Mamba-1 (with B/C/dt RMS norm).
[arXiv:2410.05355; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv=1, head_dim=64,
    d_ff=0, vocab=65024,
    ssm="mamba1", d_state=16, d_conv=4, expand=2,
)

REDUCED = ModelConfig(
    name="falcon-mamba-7b-reduced", family="ssm",
    n_layers=4, d_model=64, n_heads=1, n_kv=1, head_dim=16,
    d_ff=0, vocab=512,
    ssm="mamba1", d_state=8, d_conv=4, expand=2, ssm_chunk=16,
)
