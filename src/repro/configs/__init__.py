"""Architecture registry: ``--arch <id>`` resolves here."""

from . import (falcon_mamba_7b, gemma3_27b, gemma_7b, granite_8b,
               internvl2_2b, mixtral_8x22b, mixtral_8x7b, qwen15_32b,
               seamless_m4t_large_v2, zamba2_1_2b)
from .shapes import SHAPES, Shape, applicable

_MODULES = {
    "qwen1.5-32b": qwen15_32b,
    "gemma-7b": gemma_7b,
    "gemma3-27b": gemma3_27b,
    "granite-8b": granite_8b,
    "mixtral-8x7b": mixtral_8x7b,
    "mixtral-8x22b": mixtral_8x22b,
    "falcon-mamba-7b": falcon_mamba_7b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "internvl2-2b": internvl2_2b,
    "zamba2-1.2b": zamba2_1_2b,
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str, *, reduced: bool = False):
    mod = _MODULES[name.removesuffix("-reduced")]
    return mod.REDUCED if (reduced or name.endswith("-reduced")) else mod.CONFIG


__all__ = ["ARCH_NAMES", "SHAPES", "Shape", "applicable", "get_config"]
