"""gemma-7b — dense, GeGLU, head_dim=256, tied embeddings, (1+w) RMSNorm.
[arXiv:2403.08295; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv=16, head_dim=256,
    d_ff=24576, vocab=256000,
    act="gelu", rms_plus_one=True, embed_scale=True, tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma-7b-reduced", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv=4, head_dim=32,
    d_ff=128, vocab=512,
    act="gelu", rms_plus_one=True, embed_scale=True, tie_embeddings=True,
)
