"""Assigned input shapes (identical across all 10 LM architectures)."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str            # train | prefill | decode
    seq: int             # sequence length (KV length for decode)
    batch: int           # global batch


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}

# archs whose every attention layer is full/global (KV grows with context and
# attention is quadratic in prefill) — long_500k is skipped for these per the
# assignment; see DESIGN.md §5.
_FULL_ATTENTION = {"qwen1.5-32b", "gemma-7b", "granite-8b",
                   "seamless-m4t-large-v2", "internvl2-2b"}


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape_name == "long_500k" and cfg.name in _FULL_ATTENTION:
        return False, ("pure full-attention arch: 500k dense KV/quadratic "
                       "attention — skipped per assignment (DESIGN.md §5)")
    return True, ""
