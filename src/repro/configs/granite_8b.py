"""granite-8b — llama-arch dense (code model). [arXiv:2405.04324; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=49152,
    act="silu", tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="granite-8b-reduced", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512, tie_embeddings=True,
)
