"""gemma3-27b — dense, 5:1 local:global, QK-norm, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv=16, head_dim=128,
    d_ff=21504, vocab=262144,
    act="gelu", rms_plus_one=True, embed_scale=True, tie_embeddings=True,
    local_global=(5, 1), local_window=1024, global_rope_base=1.0e6,
    qk_norm=True,
)

REDUCED = ModelConfig(
    name="gemma3-27b-reduced", family="dense",
    n_layers=8, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512,
    act="gelu", rms_plus_one=True, embed_scale=True, tie_embeddings=True,
    local_global=(5, 1), local_window=32, global_rope_base=1.0e6,
    qk_norm=True,
)
