"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone.

The modality frontend (speech feature extractor) is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings
(B, S_enc, d_model) as the encoder input. [arXiv:2308.11596; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, head_dim=64,
    d_ff=8192, vocab=256206,
    n_enc_layers=24,
)

REDUCED = ModelConfig(
    name="seamless-m4t-large-v2-reduced", family="encdec",
    n_layers=3, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512, n_enc_layers=3,
)
