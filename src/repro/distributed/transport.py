"""Transport subsystem: bucketed exchange buffers + compiled-program reuse.

SWIFT's communication is "just another task": data ships the moment it is
ready and consumers defer until it arrives. On an XLA device mesh the
equivalent discipline is that the *exchange program* must be compiled once
and reused for every sub-step, no matter how many cut-cell rows happen to be
active — recompiling per message size would serialise the whole ladder on
the compiler. This module provides the generic machinery for that:

* :func:`next_pow2` / :class:`BucketPolicy` — power-of-two bucket sizing
  with grow/shrink **hysteresis**: growth is immediate (correctness), but a
  bucket only shrinks after the demand has sat at half a bucket or less for
  ``shrink_patience`` consecutive fits. Demand oscillating around a
  power-of-two boundary therefore costs at most one recompile per crossing,
  not one per sub-step.
* :class:`CompileProbe` / :class:`ProgramCache` — the compile-count probe:
  every jitted program is registered by name, and ``total_compiles()``
  reports the true number of XLA compilations (via the jit cache), so tests
  can assert "at most one compile per (program, bucket)".
* :class:`ShipSlots` + :func:`pack_rounds` / :func:`pack_allgather` — the
  host-side image of one exchange: which (source row → destination row)
  copies each rank-to-rank edge carries, packed into bucket-padded index
  tables for the device program.
* :class:`HostTransport` — the host-mediated wire (numpy row copies between
  the ranks' jitted phase programs); the reference semantics every
  device-collective lowering must reproduce bit-for-bit.
* :class:`TransferProbe` / :class:`ResidentBuffers` — the residency layer:
  per-field accounting of every byte the engine moves across the
  host↔device boundary (split into cycle-*boundary* traffic — scatter and
  gather — and *intra-cycle* traffic), and the named stacked device buffers
  the fused device-resident engine keeps on the mesh between exchanges.
  The transfer probe is the ``CompileProbe`` of the wire: tests assert the
  fused path's intra-cycle traffic carries **zero** dynamical-state bytes.
* :func:`make_transport` — factory over ``"host" | "collective"`` (the
  collective implementation lives in ``repro.sph.collectives``; imported
  lazily so this layer stays free of SPH specifics).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from ..observability.tracer import NULL_TRACER

TRANSPORTS = ("host", "collective")
RESIDENCIES = ("host", "device")

# the dynamical per-particle state of the time-bin engine: the arrays whose
# intra-cycle host↔device movement the fused device-resident path eliminates.
# ``bins`` is deliberately *not* here — it is the schedule (1 int32/particle)
# and its host mirror is refreshed only on deepening/wake-up events, which
# the TransferProbe counts separately.
DYNAMIC_STATE_FIELDS = ("pos", "vel", "mass", "u", "h", "mask", "accel",
                        "dudt", "rho", "omega", "t_start", "time")


def next_pow2(n: int) -> int:
    """Smallest power of two ≥ max(n, 1)."""
    p = 1
    while p < max(int(n), 1):
        p *= 2
    return p


class BucketPolicy:
    """Per-stream power-of-two bucket sizing with grow/shrink hysteresis.

    ``fit(key, n)`` returns the bucket to pad stream ``key``'s current
    demand ``n`` to. Growth (n > bucket) snaps immediately to
    ``next_pow2(n)``. Shrinking is damped: only after ``shrink_patience``
    consecutive fits with ``next_pow2(n) ≤ bucket / 2`` does the bucket
    halve (one level per event, so a demand collapse walks down one
    power of two at a time). The result: each power-of-two crossing of the
    demand costs at most one bucket change — and therefore at most one
    compile of any program keyed by the bucket.
    """

    def __init__(self, *, min_bucket: int = 1, shrink_patience: int = 4):
        self.min_bucket = next_pow2(min_bucket)
        self.shrink_patience = int(shrink_patience)
        self._bucket: Dict[object, int] = {}
        self._below: Dict[object, int] = {}
        self.events: List[Tuple[object, int, int]] = []   # (key, old, new)

    def current(self, key) -> Optional[int]:
        return self._bucket.get(key)

    def fit(self, key, n: int) -> int:
        need = max(next_pow2(n), self.min_bucket)
        cur = self._bucket.get(key)
        if cur is None:
            self._bucket[key] = need
            self._below[key] = 0
            return need
        if need > cur:                                   # grow: immediate
            self.events.append((key, cur, need))
            self._bucket[key] = need
            self._below[key] = 0
            return need
        if need <= cur // 2:
            # need ≥ min_bucket, so the halved bucket is always legal
            # here — no separate floor guard, and at the floor itself
            # (cur == min_bucket) this branch can never be entered.
            self._below[key] = self._below[key] + 1
            if self._below[key] >= self.shrink_patience:
                new = cur // 2
                self.events.append((key, cur, new))
                self._bucket[key] = new
                # re-earn the patience at the new size: without this
                # reset, a stream sitting just under the *new* half-
                # bucket boundary would halve again on the very next
                # fit, churning one recompile per fit on a collapse.
                self._below[key] = 0
                return new
        else:
            self._below[key] = 0
        return self._bucket[key]


class _SignatureCountingProgram:
    """Fallback compile counter for callables without a jit cache.

    Wraps a program that exposes no ``_cache_size`` (not produced by
    ``jax.jit``, or an older/newer jax without that private hook) and
    counts the distinct flattened call signatures — pytree structure plus
    per-leaf (shape, dtype) — which is exactly the key a jit cache would
    compile per. The count is an upper bound on true compiles but, unlike
    the old silent ``-1``, it is monotone, non-negative, and agrees with
    the jit cache for shape-keyed programs.
    """

    __slots__ = ("_fn", "_signatures", "__wrapped__")

    def __init__(self, fn):
        self._fn = fn
        self.__wrapped__ = fn
        self._signatures = set()

    def __call__(self, *args, **kwargs):
        try:
            import jax
            leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
            sig = (treedef, tuple(
                (getattr(x, "shape", None),
                 str(getattr(x, "dtype", type(x).__name__)))
                for x in leaves))
            self._signatures.add(sig)
        except Exception:
            self._signatures.add(("<unflattenable>",))
        return self._fn(*args, **kwargs)

    def _cache_size(self) -> int:
        return len(self._signatures)


class CompileProbe:
    """Registry of jitted programs with true compile counts.

    ``register(name, fn)`` tracks a ``jax.jit``-wrapped callable;
    ``counts()`` reads each program's jit cache size — the number of
    distinct XLA compilations actually performed — so tests can assert the
    bucketing bounds recompiles without guessing from shapes. A callable
    without a jit cache is detected *at registration* and wrapped in a
    :class:`_SignatureCountingProgram` (with a :class:`RuntimeWarning`),
    so ``counts()`` never reports the old silent ``-1``.
    """

    def __init__(self):
        self._fns: Dict[str, object] = {}

    def register(self, name: str, fn):
        if not callable(getattr(fn, "_cache_size", None)):
            warnings.warn(
                f"compile probe: program {name!r} exposes no jit cache "
                "(_cache_size); counting distinct call signatures instead — "
                "compile counts for this program are an upper bound",
                RuntimeWarning, stacklevel=2)
            fn = _SignatureCountingProgram(fn)
        self._fns[name] = fn
        return fn

    def counts(self) -> Dict[str, int]:
        return {name: int(fn._cache_size()) for name, fn in self._fns.items()}

    def total_compiles(self) -> int:
        return sum(max(c, 0) for c in self.counts().values())


class ProgramCache:
    """Build-once cache of compiled exchange programs, keyed by the static
    exchange signature (bucket, rounds, field shapes). Each build is
    registered with the probe so its XLA compiles are counted."""

    def __init__(self, probe: Optional[CompileProbe] = None):
        self.probe = probe or CompileProbe()
        self._programs: Dict[object, Callable] = {}
        self.builds = 0

    def get(self, key, builder: Callable[[], Callable]) -> Callable:
        if key not in self._programs:
            prog = builder()
            self.probe.register(f"program:{key}", prog)
            self._programs[key] = prog
            self.builds += 1
        return self._programs[key]

    @property
    def keys(self):
        return set(self._programs)


class TransferProbe:
    """Host↔device transfer accounting, CompileProbe-style.

    Every byte the engine moves across the host boundary is ``record``-ed
    under a field name, tagged as cycle-``boundary`` traffic (the scatter at
    cycle start / gather at cycle end) or intra-cycle traffic. Tests assert
    the residency discipline on the *measured* ledger instead of trusting
    the control flow: the fused device-resident path must show zero
    intra-cycle bytes for every :data:`DYNAMIC_STATE_FIELDS` entry, with
    only control-plane traffic (index ``tables``, ``flags``, and ``bins``
    mirror refreshes on wake events) in between.
    """

    def __init__(self):
        self.boundary_bytes: Dict[str, int] = {}
        self.intra_bytes: Dict[str, int] = {}
        self.intra_events: Dict[str, int] = {}
        self.boundary_events: Dict[str, int] = {}

    def record(self, fname: str, nbytes: int, *, boundary: bool) -> None:
        book = self.boundary_bytes if boundary else self.intra_bytes
        book[fname] = book.get(fname, 0) + int(nbytes)
        events = self.boundary_events if boundary else self.intra_events
        events[fname] = events.get(fname, 0) + 1

    def intra_state_bytes(
            self, fields: Sequence[str] = DYNAMIC_STATE_FIELDS) -> int:
        """Intra-cycle bytes of dynamical state — 0 on the resident path."""
        return sum(self.intra_bytes.get(f, 0) for f in fields)

    def total_bytes(self) -> int:
        return (sum(self.boundary_bytes.values())
                + sum(self.intra_bytes.values()))

    def stats(self) -> Dict[str, object]:
        return {"boundary_bytes": dict(self.boundary_bytes),
                "boundary_events": dict(self.boundary_events),
                "intra_bytes": dict(self.intra_bytes),
                "intra_state_bytes": self.intra_state_bytes(),
                "total_bytes": self.total_bytes()}


class ResidentBuffers:
    """Named stacked device buffers of the fused device-resident engine.

    Holds one ``(nranks, …)`` mesh-sharded array per state field for the
    duration of a cycle. The only mutation path is :meth:`update` with the
    outputs of a compiled program (a device→device handoff, no transfer);
    host access goes through :meth:`put` / :meth:`pull`, which record their
    bytes with the :class:`TransferProbe` — so the ledger is complete by
    construction as long as the engine never touches ``arrays`` directly.
    """

    def __init__(self, probe: TransferProbe):
        self.probe = probe
        self.arrays: Dict[str, object] = {}

    def put(self, name: str, host_array: np.ndarray, place: Callable,
            *, boundary: bool = True) -> None:
        """Upload a host array through ``place`` (e.g. a device_put with a
        mesh sharding) and record the bytes."""
        self.probe.record(name, host_array.nbytes, boundary=boundary)
        self.arrays[name] = place(host_array)

    def pull(self, name: str, *, boundary: bool = True,
             index: Optional[object] = None) -> np.ndarray:
        """Materialise a buffer (or an indexed slice of it) on host —
        pull only what the caller consumes; the ledger records the
        actually-transferred bytes."""
        arr = self.arrays[name]
        out = np.asarray(arr if index is None else arr[index])
        self.probe.record(name, out.nbytes, boundary=boundary)
        return out

    def update(self, mapping: Dict[str, object]) -> None:
        """Adopt compiled-program outputs (stays on device: no transfer)."""
        self.arrays.update(mapping)

    def __getitem__(self, name: str):
        return self.arrays[name]


# ---------------------------------------------------------------- ship slots
@dataclass
class ShipSlots:
    """One exchange's copies, grouped by rank-to-rank edge.

    ``edges[(src, dst)]`` lists (src_row, dst_row) pairs: the source rank's
    extended-state row to read and the destination rank's row to overwrite.
    Rows are unique per destination (each replica row has one owner), so
    copy order is irrelevant.
    """
    edges: Dict[Tuple[int, int], List[Tuple[int, int]]] = \
        field(default_factory=dict)

    def add(self, src: int, dst: int, src_row: int, dst_row: int) -> None:
        self.edges.setdefault((src, dst), []).append((src_row, dst_row))

    @property
    def total(self) -> int:
        return sum(len(v) for v in self.edges.values())

    @property
    def max_edge_slots(self) -> int:
        return max((len(v) for v in self.edges.values()), default=0)

    def max_rank_exports(self, nranks: int) -> int:
        out = [0] * nranks
        for (s, _d), v in self.edges.items():
            out[s] += len(v)
        return max(out, default=0)

    def max_rank_imports(self, nranks: int) -> int:
        out = [0] * nranks
        for (_s, d), v in self.edges.items():
            out[d] += len(v)
        return max(out, default=0)


def pack_rounds(rounds: Sequence[Sequence[Tuple[int, int]]],
                slots: ShipSlots, nranks: int, bucket: int
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Bucket-padded index tables for a ppermute-rounds exchange.

    Returns ``(pack_rows, unpack_rows, unpack_valid)``, each
    ``(nranks, R, bucket)``: in round ``t`` rank ``r`` sends the rows
    ``pack_rows[r, t]`` (0-padded) and, if it is the round's destination,
    writes the received slots ``k`` with ``unpack_valid[r, t, k] > 0`` into
    rows ``unpack_rows[r, t, k]``. Each round is a partial permutation
    (``core.comm_planner.ppermute_rounds``), so sender and receiver agree on
    slot order by construction.
    """
    scheduled = {e for rnd in rounds for e in rnd}
    missing = set(slots.edges) - scheduled
    if missing:
        raise ValueError(
            f"ship slots on edges {sorted(missing)} absent from the round "
            f"schedule — transport.prepare() did not run for this plan")
    R = max(len(rounds), 1)
    pack = np.zeros((nranks, R, bucket), dtype=np.int32)
    unpack = np.zeros((nranks, R, bucket), dtype=np.int32)
    valid = np.zeros((nranks, R, bucket), dtype=np.float32)
    for t, rnd in enumerate(rounds):
        for (s, d) in rnd:
            pairs = slots.edges.get((s, d), ())
            if len(pairs) > bucket:
                raise ValueError(
                    f"edge ({s}->{d}) ships {len(pairs)} rows > bucket "
                    f"{bucket}")
            for k, (srow, drow) in enumerate(pairs):
                pack[s, t, k] = srow
                unpack[d, t, k] = drow
                valid[d, t, k] = 1.0
    return pack, unpack, valid


def pack_allgather(slots: ShipSlots, nranks: int, bucket_out: int,
                   bucket_in: int
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Bucket-padded index tables for the all-gather fallback.

    Every rank contributes one export buffer of ``bucket_out`` rows
    (``pack_rows``); after the gather each rank reads slot
    ``unpack_src[r, k]`` of the flattened ``(nranks * bucket_out)`` buffer
    into row ``unpack_rows[r, k]`` where ``unpack_valid[r, k] > 0``.
    """
    pack = np.zeros((nranks, bucket_out), dtype=np.int32)
    unpack_src = np.zeros((nranks, bucket_in), dtype=np.int32)
    unpack_rows = np.zeros((nranks, bucket_in), dtype=np.int32)
    valid = np.zeros((nranks, bucket_in), dtype=np.float32)
    out_n = [0] * nranks
    in_n = [0] * nranks
    for (s, d) in sorted(slots.edges):
        for (srow, drow) in slots.edges[(s, d)]:
            k = out_n[s]
            if k >= bucket_out:
                raise ValueError(
                    f"rank {s} exports {k + 1} rows > bucket {bucket_out}")
            pack[s, k] = srow
            out_n[s] += 1
            m = in_n[d]
            if m >= bucket_in:
                raise ValueError(
                    f"rank {d} imports {m + 1} rows > bucket {bucket_in}")
            unpack_src[d, m] = s * bucket_out + k
            unpack_rows[d, m] = drow
            valid[d, m] = 1.0
            in_n[d] += 1
    return pack, unpack_src, unpack_rows, valid


# ---------------------------------------------------------------- transports
class Transport:
    """One exchange step: owner rows → replica rows across ranks.

    ``fields`` is a list of per-rank array lists (``fields[f][r]`` has the
    extended row layout on rank ``r``); the returned structure is the same
    with the destination rows of every slot overwritten by the source rank's
    values, bit-for-bit. Implementations must be pure copies — all transport
    lowerings produce identical states by construction.
    """

    kind = "abstract"
    # observability hook: rebound to the run's tracer by the engine when
    # SimulationSpec(observe=True); an exchange is SWIFT's send/recv task
    # and shows up on every participating rank's timeline row
    tracer = NULL_TRACER

    def prepare(self, edges: Sequence[Tuple[int, int]]) -> None:
        """New decomposition: the rank-to-rank export edge list changed."""

    def exchange(self, slots: ShipSlots, fields: List[List],
                 stream: str = "substep",
                 label: Optional[str] = None) -> List[List]:
        """``stream`` names the demand stream for bucket sizing: exchanges
        with systematically different volumes (activity-restricted
        sub-steps vs the full-cut cycle sync) must not share a bucket, or
        the hysteresis would churn once per cycle. ``label`` names the
        traced span (e.g. ``"exchange1"``/``"exchange2"``) — engine
        position of this exchange in the sub-step, not its bucket
        stream."""
        raise NotImplementedError

    def stats(self) -> Dict[str, object]:
        return {"kind": self.kind}


class HostTransport(Transport):
    """Host-mediated wire: numpy row copies between jitted phase programs.

    ``host_bytes`` counts what this wire costs beyond the copies
    themselves: every exchanged field makes a device→host→device round
    trip of its *full* per-rank arrays (not just the shipped rows) — the
    overhead the device-resident fused path exists to eliminate.
    """

    kind = "host"

    def __init__(self):
        self.host_bytes = 0
        self.exchanges = 0

    def exchange(self, slots: ShipSlots, fields: List[List],
                 stream: str = "substep",
                 label: Optional[str] = None) -> List[List]:
        tr = self.tracer
        t0 = tr.now() if tr.enabled else 0.0
        nranks = max(len(f) for f in fields)
        arrays = [[np.array(fr) for fr in f] for f in fields]
        self.host_bytes += 2 * sum(a.nbytes for f in arrays for a in f)
        self.exchanges += 1
        for (s, d), pairs in slots.edges.items():
            for (srow, drow) in pairs:
                for f in range(len(arrays)):
                    arrays[f][d][drow] = arrays[f][s][srow]
        out = [[jnp.asarray(arrays[f][r]) for r in range(nranks)]
               for f in range(len(arrays))]
        if tr.enabled:
            tr.record_all(range(nranks), label or "exchange", t0,
                          stream=stream, units=slots.total,
                          kind="host", collective=1)
        return out

    def stats(self) -> Dict[str, object]:
        return {"kind": self.kind, "exchanges": self.exchanges,
                "host_bytes": self.host_bytes}


def make_transport(kind: str, *, nranks: int,
                   probe: Optional[CompileProbe] = None,
                   mode: str = "auto") -> Transport:
    """Build a transport: ``"host"`` (numpy copies) or ``"collective"``
    (shard_map + ppermute/all_gather over bucketed buffers; needs
    ``nranks`` addressable devices)."""
    if kind == "host":
        return HostTransport()
    if kind == "collective":
        from ..sph.collectives import CollectiveTransport
        return CollectiveTransport(nranks=nranks, probe=probe, mode=mode)
    raise ValueError(f"transport must be one of {TRANSPORTS}, got {kind!r}")
