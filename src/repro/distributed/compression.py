"""Gradient compression for data-parallel all-reduce (distributed-opt trick).

Two schemes with **error feedback** (residual carried to the next step so
compression error doesn't bias the optimizer — Karimireddy et al. 2019):

* int8 quantisation — per-tensor symmetric scale; 4× traffic reduction.
* top-k sparsification — keep the k largest-|g| entries; (1-k/n)× reduction.

``compress_grads``/``decompress_grads`` wrap a grad pytree; the train loop
applies them around the DP all-reduce when ``TrainConfig.compression`` is
set. Numerical contract (tested): with error feedback the *running sum* of
decompressed gradients tracks the running sum of true gradients.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class CompressState(NamedTuple):
    residual: Any           # pytree like grads (f32)


def init_compress_state(grads) -> CompressState:
    return CompressState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def _quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _topk_mask(x, frac: float):
    n = x.size
    k = max(int(n * frac), 1)
    flat = jnp.abs(x).reshape(-1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(jnp.float32)


def compress_grads(grads, state: CompressState, *, scheme: str = "int8",
                   topk_frac: float = 0.1):
    """Returns (compressed payload pytree, new residual state).

    The payload is what would cross the network; ``decompress_grads``
    reconstructs the dense gradient.
    """
    def one(g, r):
        x = g.astype(jnp.float32) + r
        if scheme == "int8":
            q, scale = _quantize_int8(x)
            approx = _dequantize_int8(q, scale)
            return (q, scale), x - approx
        if scheme == "topk":
            mask = _topk_mask(x, topk_frac)
            kept = x * mask
            return (kept, jnp.zeros((), jnp.float32)), x - kept
        raise ValueError(scheme)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(state.residual)
    payloads, residuals = [], []
    for g, r in zip(flat_g, flat_r):
        p, res = one(g, r)
        payloads.append(p)
        residuals.append(res)
    return (tdef.unflatten(payloads),
            CompressState(tdef.unflatten(residuals)))


def decompress_grads(payload, *, scheme: str = "int8"):
    def one(p):
        if scheme == "int8":
            q, scale = p
            return _dequantize_int8(q, scale)
        kept, _ = p
        return kept

    return jax.tree.map(one, payload,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and not isinstance(x[0], tuple))


def compressed_bytes(payload, *, scheme: str = "int8") -> int:
    total = 0
    for leaf in jax.tree.leaves(payload):
        if scheme == "int8" and leaf.dtype == jnp.int8:
            total += leaf.size
        elif scheme == "topk":
            total += int(leaf.size * 4)      # value+index stream estimate
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
