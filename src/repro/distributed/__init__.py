"""Distribution layer: sharding rules, overlapped collectives, placement."""

from .mesh_utils import axis_size, batch_pref, data_axes, \
    mesh_with_auto_axes, named, ranks_mesh, ring_perm, valid_spec
from .sharding_rules import ShardingRules
from .transport import (BucketPolicy, CompileProbe, HostTransport,
                        ProgramCache, ResidentBuffers, ShipSlots,
                        TransferProbe, Transport, make_transport,
                        next_pow2, pack_allgather, pack_rounds)
from .overlap import (allgather_matmul, allgather_matmul_local,
                      matmul_reducescatter, matmul_reducescatter_local)
from .halo import full_window_attention_ref, sp_local_attention, \
    swa_halo_exchange
from .pipeline import assign_stages, layer_costs, place_experts
from .compression import (CompressState, compress_grads, compressed_bytes,
                          decompress_grads, init_compress_state)

__all__ = [
    "axis_size", "batch_pref", "data_axes", "mesh_with_auto_axes",
    "named", "ranks_mesh", "ring_perm", "valid_spec", "ShardingRules",
    "BucketPolicy", "CompileProbe", "HostTransport", "ProgramCache",
    "ResidentBuffers", "ShipSlots", "TransferProbe", "Transport",
    "make_transport", "next_pow2", "pack_allgather", "pack_rounds",
    "allgather_matmul", "allgather_matmul_local", "matmul_reducescatter",
    "matmul_reducescatter_local", "full_window_attention_ref",
    "sp_local_attention", "swa_halo_exchange", "assign_stages",
    "layer_costs", "place_experts", "CompressState", "compress_grads",
    "compressed_bytes", "decompress_grads", "init_compress_state",
]
