"""Sequence-parallel halo exchange for windowed attention (C3, LM side).

When activations are sharded along the sequence axis, a sliding-window
attention layer only needs ``window`` trailing keys from the previous shard
— a 1-hop halo, not an all-gather. ``swa_halo_exchange`` ships exactly that
window via one ``ppermute`` (SWIFT: send the boundary cells only), and
``sp_local_attention`` runs the windowed attention entirely shard-locally.

Used by the gemma3 §Perf hillclimb (local layers with sequence-parallel
activations) and tested against full attention in
``tests/test_halo_attention.py``.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .mesh_utils import axis_size, ring_perm


def swa_halo_exchange(kv_local, *, axis: str, window: int):
    """kv_local (B, S_shard, …): returns the previous shard's trailing
    ``window`` positions (zeros for shard 0)."""
    n = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    tail = kv_local[:, -window:]
    halo = jax.lax.ppermute(tail, axis, ring_perm(n))   # from shard idx-1
    halo = jnp.where(idx == 0, jnp.zeros_like(halo), halo)
    return halo


def _window_attn_local(q, k, v, halo_k, halo_v, *, axis: str, window: int,
                       scale: float):
    """Shard-local causal sliding-window attention.

    q/k/v (B, S_shard, H, hd); halo_* (B, window, H, hd) from the previous
    shard. Positions are globalised with the shard offset so the band mask
    is exact across the seam.
    """
    B, Ss, H, hd = q.shape
    idx = jax.lax.axis_index(axis)
    off = idx * Ss
    k_ext = jnp.concatenate([halo_k, k], axis=1)
    v_ext = jnp.concatenate([halo_v, v], axis=1)
    qpos = off + jnp.arange(Ss)
    kpos = off - window + jnp.arange(Ss + window)
    ok = (kpos[None, :] <= qpos[:, None]) \
        & (kpos[None, :] > qpos[:, None] - window) \
        & (kpos[None, :] >= 0)
    mask = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k_ext).astype(jnp.float32)
    scores = scores * scale + mask[None, None]
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v_ext)


def sp_local_attention(q, k, v, mesh: Mesh, *, axis: str = "model",
                       window: int):
    """Sequence-parallel sliding-window attention.

    q/k/v (B, S, H, hd) sharded (None, axis, None, None). One ppermute of
    ``window`` keys replaces the S-length all-gather a naive lowering emits:
    halo bytes / allgather bytes = window / S.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])

    def body(q_l, k_l, v_l):
        hk = swa_halo_exchange(k_l, axis=axis, window=window)
        hv = swa_halo_exchange(v_l, axis=axis, window=window)
        return _window_attn_local(q_l, k_l, v_l, hk, hv, axis=axis,
                                  window=window, scale=scale)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, axis, None, None),) * 3,
                   out_specs=P(None, axis, None, None))
    return fn(q, k, v)


def full_window_attention_ref(q, k, v, *, window: int):
    """Oracle: unsharded causal banded attention."""
    B, S, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    pos = jnp.arange(S)
    ok = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - window)
    mask = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores * scale + mask[None, None]
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
