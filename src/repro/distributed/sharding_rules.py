"""Per-architecture sharding rules for the production mesh.

Train (``mode="train"``): 2-D weight sharding — tensor parallelism over
``model`` on the output-feature dim and FSDP/ZeRO-3-style sharding over
``pod``+``data`` on the input-feature dim; optimizer moments inherit the
parameter specs (ZeRO-1 falls out for free). Activations are constrained to
batch-over-data at block boundaries.

Serve (``mode="serve"``): TP over ``model`` only (weights replicated across
the batch axes) except MoE expert FFNs, which shard their hidden dim over
(data×model) so mixtral-8x22b's 282 GB of bf16 experts fit the pod. KV
caches shard batch→data and sequence→model (split-KV decoding: softmax over
a sharded KV length lowers to partial reductions + an all-reduce, which is
exactly flash-decoding's math); ``long_500k`` (batch=1) shards the 500k KV
over all axes.

Every spec is built with :func:`valid_spec`, so indivisible dims degrade to
replication instead of failing to lower — e.g. qwen's 40 KV heads on a
16-way model axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from .mesh_utils import batch_pref, data_axes, valid_spec

FSDP = ("data",)        # input-feature sharding axes (train)
TP = ("model",)         # output-feature sharding axes


def _leaf_spec(path: str, leaf, cfg: ModelConfig, mesh: Mesh,
               mode: str, moe_ep: bool = False) -> P:
    """Sharding rule for one parameter leaf, dispatched on its path/name."""
    shape = leaf.shape
    nd = len(shape)
    train = mode == "train"
    fsdp = ["data"] if train else []
    fsdp_pod = [("pod", "data"), "data"] if train else []

    def spec(prefs):
        return valid_spec(shape, prefs, mesh)

    def stacked(prefs):
        """Leading repeat/stack dims replicated, trailing dims per prefs."""
        return spec([[]] * (nd - len(prefs)) + prefs)

    name = path.split("/")[-1]

    if name in ("embed",):
        return spec([["model"], fsdp_pod])
    if name in ("head",):
        return spec([fsdp_pod, ["model"]])
    if name.startswith("ln") or name in ("q_norm", "k_norm", "norm",
                                         "enc_ln_f", "dt_bias", "A_log",
                                         "D", "conv_b", "b_norm", "c_norm",
                                         "dt_norm"):
        if cfg.ssm == "mamba1" and name in ("conv_b", "dt_bias", "A_log",
                                            "D") and "mix" in path:
            # mamba1 d_inner-TP: these carry a d_inner dim
            if name == "A_log":
                return stacked([["model"], []])
            return stacked([["model"]])
        return P()
    if name in ("wq", "wk", "wv"):
        return stacked([fsdp_pod, ["model"]])
    if name in ("bq", "bk", "bv"):
        return stacked([["model"]])
    if name == "wo" and "attn" in path or name == "wo" and "xattn" in path:
        return stacked([["model"], fsdp_pod])
    if name in ("wi", "wg"):
        if "ffn" in path and cfg.n_experts and "segments" in path:
            # MoE experts (…, E, d, ff)
            if moe_ep:
                # expert parallelism: experts over model, FFN local
                return stacked([["model"], fsdp_pod, []])
            ff_pref = [("data", "model"), "model"] if not train \
                else ["model"]
            return stacked([[], fsdp_pod, ff_pref])
        return stacked([fsdp_pod, ["model"]])
    if name == "wo":
        if "ffn" in path and cfg.n_experts and "segments" in path:
            if moe_ep:
                return stacked([["model"], [], fsdp_pod])
            ff_pref = [("data", "model"), "model"] if not train \
                else ["model"]
            return stacked([[], ff_pref, fsdp_pod])
        return stacked([["model"], fsdp_pod])
    if name == "router":
        return stacked([fsdp_pod, []])
    if name == "out":                       # zamba2 shared out (2d, d)
        return stacked([fsdp_pod, []])
    if name == "lora_a":
        return stacked([fsdp_pod, []])
    if name == "lora_b":
        return stacked([[], fsdp_pod])
    if name == "in_proj":
        if cfg.ssm == "mamba1":
            return stacked([fsdp_pod, ["model"]])
        return stacked([fsdp_pod, []])      # mamba2: mixed outputs
    if name == "out_proj":
        if cfg.ssm == "mamba1":
            return stacked([["model"], fsdp_pod])
        return stacked([[], fsdp_pod])
    if name == "conv_w":
        if cfg.ssm == "mamba1":
            return stacked([[], ["model"]])
        return P()
    if name == "x_proj":
        return stacked([["model"], []])
    if name == "dt_proj":
        return stacked([[], ["model"]])
    return P()


def _tree_with_paths(tree, fn, prefix=""):
    if isinstance(tree, dict):
        return {k: _tree_with_paths(v, fn, f"{prefix}/{k}") for k, v in
                tree.items()}
    if isinstance(tree, (list, tuple)):
        out = [_tree_with_paths(v, fn, f"{prefix}/{i}")
               for i, v in enumerate(tree)]
        return type(tree)(out) if not hasattr(tree, "_fields") \
            else type(tree)(*out)
    return fn(prefix, tree)


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    cfg: ModelConfig
    mode: str = "train"            # train | serve
    moe_ep: bool = False           # experts → model axis (EP) instead of TP

    # ------------------------------------------------------------- params
    def params_pspec(self, params):
        return _tree_with_paths(
            params, lambda p, l: _leaf_spec(p, l, self.cfg, self.mesh,
                                            self.mode, self.moe_ep))

    def params_sharding(self, params):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.params_pspec(params),
                            is_leaf=lambda x: isinstance(x, P))

    # -------------------------------------------------------------- data
    def tokens_pspec(self, batch: int):
        bp = batch_pref(self.mesh)
        return valid_spec((batch, 1), [bp, []], self.mesh)

    def act_pspec(self, batch: int):
        bp = batch_pref(self.mesh)
        return valid_spec((batch, 1, 1), [bp, [], []], self.mesh)

    def constrain(self, x, kind=None):
        """Activation constraint at block boundaries."""
        if x.ndim >= 2:
            spec = self.act_pspec(x.shape[0])
            spec = P(*(list(spec) + [None] * (x.ndim - len(spec))))
            return jax.lax.with_sharding_constraint(x, spec)
        return x

    # ------------------------------------------------------------- caches
    def cache_leaf_spec(self, path: str, leaf):
        """KV: batch→data, length→model (split-KV); batch=1 (long_500k)
        spreads the KV length over every axis. Mamba states: channel/head
        dim→model. Works for both stacked (R, …) and per-layer layouts."""
        shape = leaf.shape
        nd = len(shape)
        bp = batch_pref(self.mesh)
        if nd == 5:          # stacked KV (R,B,S,K,hd) / mamba2 ssm stacked
            seq_pref = ["model"] if shape[1] > 1 else \
                [("data", "model"), "model", "data"]
            return valid_spec(shape, [[], bp, seq_pref, [], []], self.mesh)
        if nd == 4:          # per-layer KV (B,S,K,hd) / mamba2 ssm (B,H,p,N)
            seq_pref = ["model"] if shape[0] > 1 else \
                [("data", "model"), "model", "data"]
            return valid_spec(shape, [bp, seq_pref, ["model"], []],
                              self.mesh)
        if nd == 3:          # mamba1 ssm (B,dI,N) / conv (B,K-1,dI)
            return valid_spec(shape, [bp, ["model"], ["model"]], self.mesh)
        return P()

    def caches_pspec(self, caches):
        return _tree_with_paths(
            caches, lambda p, l: self.cache_leaf_spec(p, l)
            if hasattr(l, "shape") and l.ndim > 0 else P())

    # ---------------------------------------------------------- optimizer
    def opt_pspec(self, params):
        from ..train.optimizer import AdamState
        pp = self.params_pspec(params)
        return AdamState(step=P(), mu=pp, nu=pp)
