"""Chunked, overlapped collectives — SWIFT's C3 mapped to TP matmuls.

SWIFT sends many small messages and acts on data as it arrives instead of
one bulk exchange. The TPU-native incarnation: decompose a TP collective
into P−1 ``ppermute`` rounds where every round's chunk feeds its slice of
the matmul immediately:

* ``allgather_matmul``  — computes ``allgather(x, axis) @ w_local`` as a
  ring: each round multiplies the chunk currently held while the next chunk
  is in flight. XLA's latency-hiding scheduler overlaps the ppermute with
  the per-round matmul because they are independent ops in the round body.
* ``matmul_reducescatter`` — computes ``reduce_scatter(x @ w, axis)`` the
  dual way: partial products are accumulated into a chunk that rides the
  ring.

These are the beyond-paper §Perf variants; the baseline path relies on
XLA's own all-gather/reduce-scatter insertion. Equivalence against the
plain collective is tested in ``tests/test_overlap.py``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .mesh_utils import axis_size, ring_perm


def allgather_matmul_local(x_local, w_local, *, axis: str):
    """Local body: x_local (m, k_shard) — gathered dim is k? No: x is sharded
    on its leading (row) dim; result = concat of all rows @ w_local.

    x_local (m_shard, k), w_local (k, n) → out (m_shard * P, n) is what a
    plain allgather-then-matmul gives; here each round contributes the rows
    owned by a different shard, written into its slice of the output.
    """
    n_dev = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m = x_local.shape[0]
    out = jnp.zeros((m * n_dev, w_local.shape[1]), x_local.dtype)
    chunk = x_local
    perm = ring_perm(n_dev)
    for r in range(n_dev):
        # after r forward hops of the i→i+1 ring, we hold idx−r's rows
        src = (idx - r) % n_dev
        part = chunk @ w_local            # (m, n) — overlaps next ppermute
        out = jax.lax.dynamic_update_slice(out, part, (src * m, 0))
        if r != n_dev - 1:
            chunk = jax.lax.ppermute(chunk, axis, perm)
    return out


def matmul_reducescatter_local(x_local, w_local, *, axis: str):
    """Local body: full-row x_local (m, k), w_local (k, n); the result rows
    are reduce-scattered over ``axis``: out (m // P, n).

    Round r computes the partial destined for the neighbour r hops away and
    adds it to the accumulator riding the ring — the classic reduce-scatter
    matmul fusion.
    """
    n_dev = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m = x_local.shape[0]
    assert m % n_dev == 0, "row dim must divide the axis"
    ms = m // n_dev
    perm = ring_perm(n_dev)
    acc = None
    for r in range(n_dev - 1, -1, -1):
        dst = (idx + r) % n_dev
        part = jax.lax.dynamic_slice(x_local, (dst * ms, 0),
                                     (ms, x_local.shape[1])) @ w_local
        acc = part if acc is None else acc + part
        if r != 0:
            acc = jax.lax.ppermute(acc, axis, perm)
    return acc


def allgather_matmul(x, w, mesh: Mesh, *, axis: str = "model"):
    """x sharded (axis, None); w sharded (None, axis) replicated rows.
    Returns full (M, n_shard-concat) product — jit-able from outside."""
    fn = shard_map(
        functools.partial(allgather_matmul_local, axis=axis),
        mesh=mesh,
        in_specs=(P(axis, None), P(None, axis)),
        out_specs=P(None, axis))
    return fn(x, w)


def matmul_reducescatter(x, w, mesh: Mesh, *, axis: str = "model"):
    """x replicated rows, sharded cols (None, axis); w sharded (axis, None).
    Returns (M/P-sharded rows, n) = reduce_scatter(x @ w)."""
    fn = shard_map(
        functools.partial(matmul_reducescatter_local, axis=axis),
        mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(axis, None))
    return fn(x, w)
