"""Mesh helpers and divisibility-safe PartitionSpec construction."""

from __future__ import annotations

import math
from typing import Iterable, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisPref = Sequence[Union[str, Tuple[str, ...]]]


def valid_spec(shape: Sequence[int], prefs: Sequence[AxisPref],
               mesh: Mesh) -> P:
    """Build a PartitionSpec, taking each dim's first *valid* axis choice.

    A choice is valid if the dim size is divisible by the (product) axis size
    and no axis is reused. Composite choices like ``("data", "model")`` shard
    one dim over both axes. Invalid choices degrade to replication — the
    rules never produce an unlowerable sharding.
    """
    out: List[Optional[Union[str, Tuple[str, ...]]]] = []
    used: set = set()
    for dim, pref in zip(shape, prefs):
        chosen = None
        for cand in pref:
            axes = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(a in used for a in axes):
                continue
            if any(a not in mesh.shape for a in axes):
                continue
            size = math.prod(mesh.shape[a] for a in axes)
            if dim > 0 and dim % size == 0 and size > 1:
                chosen = axes[0] if len(axes) == 1 else tuple(axes)
                used.update(axes)
                break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch: ("pod", "data") when a pod axis exists."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_pref(mesh: Mesh) -> AxisPref:
    """Preference list for batch dims: pod+data together, then data alone."""
    da = data_axes(mesh)
    prefs: List[Union[str, Tuple[str, ...]]] = []
    if len(da) > 1:
        prefs.append(tuple(da))
    prefs.extend(da[::-1] if len(da) > 1 else da)
    return prefs
