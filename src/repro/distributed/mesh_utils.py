"""Mesh helpers and divisibility-safe PartitionSpec construction."""

from __future__ import annotations

import math
from typing import Iterable, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisPref = Sequence[Union[str, Tuple[str, ...]]]


def ranks_mesh(nranks: int, *, axis: str = "ranks") -> Mesh:
    """1-D mesh over the first ``nranks`` devices (the transport mesh).

    Raises with the emulation hint when the process has too few devices —
    on CPU the collective transports are exercised with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    devices = jax.devices()
    if nranks > len(devices):
        raise ValueError(
            f"collective transport needs {nranks} addressable devices, "
            f"have {len(devices)}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={nranks} before "
            f"importing jax, or use transport='host'")
    return Mesh(np.array(devices[:nranks]), (axis,))


def ring_perm(n: int, offset: int = 1) -> List[Tuple[int, int]]:
    """The ring permutation (i → i + offset mod n) for ``lax.ppermute``."""
    return [(i, (i + offset) % n) for i in range(n)]


def axis_size(axis: str):
    """Named-axis size from inside shard_map/pmap, across jax versions:
    ``jax.lax.axis_size`` arrived after 0.4; older jax constant-folds a
    ``psum`` of a literal to the axis size."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    return jax.lax.psum(1, axis_name=axis)


def mesh_with_auto_axes(devices, axes: Sequence[str]) -> Mesh:
    """``Mesh(devices, axes)`` with explicit Auto axis types where the jax
    version has them (``jax.sharding.AxisType`` arrived after 0.4; older
    jax has no ``axis_types`` keyword and defaults to the same
    behaviour)."""
    try:
        from jax.sharding import AxisType
    except ImportError:                 # pragma: no cover
        return Mesh(devices, tuple(axes))
    return Mesh(devices, tuple(axes),
                axis_types=(AxisType.Auto,) * len(tuple(axes)))


def valid_spec(shape: Sequence[int], prefs: Sequence[AxisPref],
               mesh: Mesh) -> P:
    """Build a PartitionSpec, taking each dim's first *valid* axis choice.

    A choice is valid if the dim size is divisible by the (product) axis size
    and no axis is reused. Composite choices like ``("data", "model")`` shard
    one dim over both axes. Invalid choices degrade to replication — the
    rules never produce an unlowerable sharding.
    """
    out: List[Optional[Union[str, Tuple[str, ...]]]] = []
    used: set = set()
    for dim, pref in zip(shape, prefs):
        chosen = None
        for cand in pref:
            axes = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(a in used for a in axes):
                continue
            if any(a not in mesh.shape for a in axes):
                continue
            size = math.prod(mesh.shape[a] for a in axes)
            if dim > 0 and dim % size == 0 and size > 1:
                chosen = axes[0] if len(axes) == 1 else tuple(axes)
                used.update(axes)
                break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch: ("pod", "data") when a pod axis exists."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_pref(mesh: Mesh) -> AxisPref:
    """Preference list for batch dims: pod+data together, then data alone."""
    da = data_axes(mesh)
    prefs: List[Union[str, Tuple[str, ...]]] = []
    if len(da) > 1:
        prefs.append(tuple(da))
    prefs.extend(da[::-1] if len(da) > 1 else da)
    return prefs
