"""Graph-partitioned placement for the LM stack (SWIFT C2, beyond-paper).

Two placements use the multilevel partitioner with *measured* costs, exactly
the paper's cost-refinement loop:

* ``assign_stages`` — layer chain → pipeline stages. For the heterogeneous
  archs (gemma3 local/global, zamba2 mamba/shared-attn) uniform chunking is
  provably imbalanced; the DP/partitioner assignment equalises measured
  per-layer cost. Stage boundaries feed ``dryrun``'s per-stage meshes.
* ``place_experts`` — MoE experts → expert shards balancing the router's
  measured token counts (``MoEStats.tokens_per_expert``), the LM analogue
  of SWIFT's clustered particles.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import (CostModel, Graph, attention_cost, decompose_layers,
                    mamba_cost, mlp_cost, moe_cost, partition_graph)
from ..models.config import ModelConfig
from ..models.model import plan_segments


def layer_costs(cfg: ModelConfig, *, batch: int, seq: int,
                measured: Optional[Sequence[float]] = None) -> np.ndarray:
    """Analytic FLOPs per layer in model order (refined by measurements)."""
    out: List[float] = []
    for pattern, repeats in plan_segments(cfg):
        for _ in range(repeats):
            for kind in pattern:
                c = 0.0
                if kind in ("attn", "local", "global", "moe", "enc", "dec"):
                    window = None
                    if kind == "local":
                        window = cfg.local_window
                    elif cfg.window and kind in ("attn", "moe"):
                        window = cfg.window
                    c += attention_cost(
                        batch=batch, q_len=seq, kv_len=seq,
                        d_model=cfg.d_model, n_heads=cfg.n_heads,
                        n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                        window=window).flops
                    if kind == "dec":       # cross-attention
                        c += attention_cost(
                            batch=batch, q_len=seq, kv_len=seq,
                            d_model=cfg.d_model, n_heads=cfg.n_heads,
                            n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                            causal=False).flops
                    if kind == "moe":
                        c += moe_cost(batch=batch, seq=seq,
                                      d_model=cfg.d_model, d_ff=cfg.d_ff,
                                      num_experts=cfg.n_experts,
                                      top_k=cfg.top_k).flops
                    else:
                        c += mlp_cost(batch=batch, seq=seq,
                                      d_model=cfg.d_model,
                                      d_ff=cfg.d_ff).flops
                if kind in ("mamba1", "mamba2", "mamba2s"):
                    c += mamba_cost(batch=batch, seq=seq,
                                    d_model=cfg.d_model,
                                    d_state=cfg.d_state,
                                    expand=cfg.expand).flops
                if kind == "mamba2s":       # plus the shared attn block
                    c += attention_cost(
                        batch=batch, q_len=seq, kv_len=seq,
                        d_model=2 * cfg.d_model, n_heads=cfg.n_heads,
                        n_kv=cfg.n_kv, head_dim=cfg.head_dim).flops
                    c += mlp_cost(batch=batch, seq=seq,
                                  d_model=2 * cfg.d_model,
                                  d_ff=cfg.d_ff).flops
                out.append(c)
    costs = np.asarray(out, dtype=np.float64)
    if measured is not None:
        m = np.asarray(measured, dtype=np.float64)
        if len(m) == len(costs) and m.sum() > 0:
            costs = m                      # measured replaces asymptotic
    return costs


def assign_stages(cfg: ModelConfig, n_stages: int, *, batch: int, seq: int,
                  measured: Optional[Sequence[float]] = None
                  ) -> Tuple[np.ndarray, Dict[str, float]]:
    """Layer → stage with minimised max-stage cost. Returns (assignment,
    {imbalance metrics for uniform vs partitioned})."""
    costs = layer_costs(cfg, batch=batch, seq=seq, measured=measured)
    L = len(costs)
    stages = decompose_layers(costs, n_stages)
    uniform = np.repeat(np.arange(n_stages), int(np.ceil(L / n_stages)))[:L]

    def max_stage(a):
        return max(costs[a == s].sum() for s in range(n_stages))

    mean = costs.sum() / n_stages
    return stages, {
        "uniform_imbalance": max_stage(uniform) / mean,
        "partitioned_imbalance": max_stage(stages) / mean,
    }


def place_experts(tokens_per_expert: np.ndarray, n_shards: int,
                  *, affinity: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, Dict[str, float]]:
    """Experts → shards balancing measured token load (SWIFT's measured-cost
    partition). ``affinity[e,f]`` (co-activation counts of expert pairs from
    top-2 routing) becomes the edge weight: co-activated experts placed
    together avoid double all-to-all hops.
    """
    E = len(tokens_per_expert)
    load = np.maximum(np.asarray(tokens_per_expert, np.float64), 1e-9)
    if affinity is None:
        affinity = np.ones((E, E)) * load.mean() * 0.01
    edges = {(i, j): float(affinity[i, j])
             for i in range(E) for j in range(i + 1, E)
             if affinity[i, j] > 0}
    g = Graph.from_edges(E, edges, load)
    res = partition_graph(g, n_shards, seed=0, max_imbalance=1.10)
    naive = np.arange(E) % n_shards

    def max_load(a):
        return max(load[a == s].sum() for s in range(n_shards))

    mean = load.sum() / n_shards
    return res.assignment, {
        "naive_imbalance": max_load(naive) / mean,
        "partitioned_imbalance": max_load(res.assignment) / mean,
    }
