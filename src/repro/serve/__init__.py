"""Serving substrate."""

from .serve_step import decode_step, greedy_generate, pad_caches, prefill

__all__ = ["decode_step", "greedy_generate", "pad_caches", "prefill"]
