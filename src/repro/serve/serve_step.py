"""Serving: prefill and decode steps over the zoo's cache structures.

.. deprecated:: **Legacy (LM-zoo era).** Kept importable for the language-
   model examples, but this is no longer the repo's serving path. The
   simulation-serving subsystem lives in :mod:`repro.fleet`
   (``python -m repro.fleet --scenario sedov --requests 64``), which batches
   *simulation requests* by compiled-program signature the way this module
   batched decode slots.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.layers import KVCache
from ..models.model import forward, make_caches, plan_segments


def _pad_kv(kv: KVCache, target_len: int, rolling: bool) -> KVCache:
    """Grow a prefill-built KV cache (stacked leading repeat dim) to
    ``target_len`` slots; rolling caches keep the last ``target_len`` keys in
    wrap-aligned slots."""
    R, B, S0, K, hd = kv.k.shape
    if rolling:
        W = target_len
        # slot s ← key position p: the largest p < S0 with p ≡ s (mod W)
        s = jnp.arange(W)
        p = s + ((S0 - 1 - s) // W) * W
        valid = (p >= 0) & (p < S0)
        idx = jnp.clip(p, 0, S0 - 1)
        k = jnp.where(valid[None, None, :, None, None],
                      kv.k[:, :, idx], 0)
        v = jnp.where(valid[None, None, :, None, None],
                      kv.v[:, :, idx], 0)
        return KVCache(k, v, kv.pos)
    pad = target_len - S0
    if pad <= 0:
        return kv
    padw = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
    return KVCache(jnp.pad(kv.k, padw), jnp.pad(kv.v, padw), kv.pos)


def pad_caches(cfg: ModelConfig, caches: list, cache_len: int,
               rolling: Dict[str, bool]) -> list:
    """Grow prefill caches to decode capacity, kind-aware, and convert the
    stacked (scan) layout into the per-layer list (unrolled decode)
    layout."""
    from ..models.model import attn_spec
    out = []
    for si, (pattern, repeats) in enumerate(plan_segments(cfg)):
        pos_out = []
        for pi, kind in enumerate(pattern):
            c = caches[si][pi]
            if kind in ("attn", "local", "global", "moe", "enc"):
                spec = attn_spec(cfg, kind)
                roll = rolling.get(kind, False)
                tgt = spec.window if roll else cache_len
                padded = _pad_kv(c, tgt, roll)
            elif kind == "dec":
                self_c, cross_c = c
                padded = (_pad_kv(self_c, cache_len, False), cross_c)
            elif kind == "mamba2s":
                kv, ssm = c
                padded = (_pad_kv(kv, cache_len, False), ssm)
            else:                        # mamba states pass through
                padded = c
            # unstack: (R, …) leaves → list of R per-layer caches
            pos_out.append([jax.tree.map(lambda a: a[r], padded)
                            for r in range(repeats)])
        out.append(pos_out)
    return out


def prefill(params, cfg: ModelConfig, tokens, *, cache_len: int,
            enc_inputs=None, patch_embeds=None,
            constrain: Callable = lambda x, kind=None: x):
    """Run the prompt, return (last-token logits, decode-ready caches)."""
    _, rolling = make_caches(cfg, tokens.shape[0], cache_len,
                             enc_len=enc_inputs.shape[1]
                             if enc_inputs is not None else 0)
    res = forward(params, cfg, tokens, mode="prefill", rolling=rolling,
                  enc_inputs=enc_inputs, patch_embeds=patch_embeds,
                  constrain=constrain)
    caches = pad_caches(cfg, res.caches, cache_len, rolling)
    return res.logits[:, -1], caches, rolling


def decode_step(params, cfg: ModelConfig, token, caches, pos, *,
                rolling: Dict[str, bool],
                constrain: Callable = lambda x, kind=None: x):
    """One decode step. token (B, 1) int32; pos scalar int32 (tokens so far).

    Returns (logits (B, vocab), new caches).
    """
    positions = pos + jnp.arange(token.shape[1])
    res = forward(params, cfg, token, mode="decode", caches=caches,
                  rolling=rolling, positions=positions, constrain=constrain)
    return res.logits[:, -1], res.caches


def greedy_generate(params, cfg: ModelConfig, prompt, n_new: int, *,
                    cache_len: Optional[int] = None, enc_inputs=None,
                    patch_embeds=None):
    """Simple greedy generation driver (small-scale examples/tests)."""
    B, S0 = prompt.shape
    cache_len = cache_len or (S0 + n_new)
    logits, caches, rolling = prefill(params, cfg, prompt,
                                      cache_len=cache_len,
                                      enc_inputs=enc_inputs,
                                      patch_embeds=patch_embeds)
    extra = patch_embeds.shape[1] if patch_embeds is not None else 0
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = [tok]
    pos = jnp.asarray(S0 + extra, jnp.int32)
    step = jax.jit(functools.partial(decode_step, cfg=cfg, rolling=rolling),
                   static_argnames=())
    for _ in range(n_new - 1):
        logits, caches = decode_step(params, cfg, tok, caches, pos,
                                     rolling=rolling)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(tok)
        pos = pos + 1
    return jnp.concatenate(outs, axis=1)
