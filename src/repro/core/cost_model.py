"""Task cost model: analytic first, measured after (SWIFT §3.2).

    "The cost of each task is initially approximated via the asymptotic cost
    of the task type and the number of particles involved. After a task has
    been executed, its effective computational cost is computed and used."

Two clients:

* the SPH engine — per-task-type asymptotic costs in "interactions" units,
  refined by an exponential moving average of measured per-type rates;
* the LM stack — per-layer analytic FLOPs/bytes, refined by
  ``compiled.cost_analysis()`` from the dry-run (see ``analysis/roofline.py``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple


# Asymptotic per-type cost exponents for SPH tasks: a self task over a cell of
# N particles does ~N^2/2 pair checks; a pair task over (N, M) does ~N*M.
_SPH_ASYMPTOTIC: Dict[str, Callable[..., float]] = {
    "sort": lambda n, m=0: n * max(math.log2(max(n, 2)), 1.0),
    "density_self": lambda n, m=0: 0.5 * n * n,
    "density_pair": lambda n, m: n * m,
    "ghost": lambda n, m=0: n,
    "force_self": lambda n, m=0: 0.5 * n * n,
    "force_pair": lambda n, m: n * m,
    "kick": lambda n, m=0: n,
    "send": lambda n, m=0: n,
    "recv": lambda n, m=0: n,
}


def timebin_frequency(bin_idx: int, max_bin: int) -> float:
    """Fraction of the finest sub-steps on which bin ``bin_idx`` is active.

    Bin b steps with dt = dt_max / 2**b, so over one dt_max cycle of
    2**max_bin sub-steps it is integrated 2**b times: frequency 2**(b−d).
    """
    return 2.0 ** (min(int(bin_idx), int(max_bin)) - int(max_bin))


def cell_activation_frequency(occ_by_bin, max_bin: int) -> float:
    """Fraction of sub-steps on which a cell has *anything* due.

    A cell wakes whenever its deepest-bin (smallest-dt) particle does, so
    the frequency is that of the highest occupied bin; an empty cell never
    wakes.
    """
    occupied = [b for b, o in enumerate(occ_by_bin) if o > 0]
    if not occupied:
        return 0.0
    return timebin_frequency(max(occupied), max_bin)


@dataclass
class CostModel:
    """Per-task-type cost = rate[type] * asymptotic(type, sizes).

    ``update`` folds in a measured execution time with an EMA — the paper's
    measured-cost refinement. Rates are in seconds per asymptotic unit.
    ``timebin_units`` is the time-averaged variant used when particles sit
    in a hierarchy of time bins (see ``sph/timebins.py``).
    """

    rates: Dict[str, float] = field(default_factory=dict)
    ema: float = 0.3
    default_rate: float = 1e-9
    asymptotic: Dict[str, Callable[..., float]] = field(
        default_factory=lambda: dict(_SPH_ASYMPTOTIC))
    # measured-cost ledger fed by the observability layer: per task kind,
    # [seconds, units, calls] accumulated over the run, plus the rate each
    # kind carried *before* its first measurement (the modelled baseline
    # the measured-vs-modelled report compares against)
    observed: Dict[str, list] = field(default_factory=dict)
    modelled_baseline: Dict[str, float] = field(default_factory=dict)

    def units(self, kind: str, n: int, m: int = 0) -> float:
        fn = self.asymptotic.get(kind)
        if fn is None:
            return float(max(n, 1))
        return float(fn(n, m))

    def cost(self, kind: str, n: int, m: int = 0) -> float:
        return self.rates.get(kind, self.default_rate) * self.units(kind, n, m)

    # --------------------------------------------------- time-bin weighting
    def timebin_units(self, kind: str, occ_by_bin, occ_by_bin_j=None, *,
                      max_bin: Optional[int] = None) -> float:
        """Time-averaged cost units of a task under the bin hierarchy.

        ``occ_by_bin`` is the per-bin occupancy histogram of the task's cell
        (bin b holds particles stepped with dt_max/2**b, so bin b is active
        a fraction 2**(b - max_bin) of the finest sub-steps). Per-particle
        tasks (ghost/kick/sort) cost the *sum over bins of occupancy scaled
        by each bin's activity fraction* — every particle pays at its own
        cadence. Interaction tasks (density/force, self and pair) evaluate
        the full block whenever the cell — for pairs: either cell — has
        anything due, so they pay the full asymptotic cost at the *cell's*
        activation frequency. This is the per-task weight that makes the
        domain decomposition balance what actually runs, extending the
        paper's "work, not data" principle along the time axis.
        """
        occ = [float(x) for x in occ_by_bin]
        d = int(max_bin) if max_bin is not None else max(len(occ) - 1, 0)
        n_tot = int(sum(occ))
        if kind in ("send", "recv"):
            # activity-aware halos: the whole cell buffer ships whenever the
            # cell has *anything* due (and only then), so communication
            # tasks pay the full message cost at the cell's activation
            # frequency — not per-particle cadence (the buffer is shipped
            # as one message either way).
            return (cell_activation_frequency(occ, d)
                    * self.units(kind, n_tot))
        if kind in ("sort", "ghost", "kick"):
            # linear-ish per-particle work: each bin pays at its cadence
            n_eff = sum(o * timebin_frequency(b, d) for b, o in enumerate(occ))
            return self.units(kind, n_tot) * n_eff / max(n_tot, 1)
        freq = cell_activation_frequency(occ, d)
        if occ_by_bin_j is not None:
            occ_j = [float(x) for x in occ_by_bin_j]
            freq = max(freq, cell_activation_frequency(occ_j, d))
            return freq * self.units(kind, n_tot, int(sum(occ_j)))
        return freq * self.units(kind, n_tot)

    def update(self, kind: str, n: int, m: int, measured_seconds: float) -> None:
        u = self.units(kind, n, m)
        if u <= 0 or measured_seconds <= 0:
            return
        rate = measured_seconds / u
        old = self.rates.get(kind)
        self.rates[kind] = rate if old is None else (
            (1 - self.ema) * old + self.ema * rate)

    # ----------------------------------------------- measured-cost feedback
    def observe(self, kind: str, units: float, seconds: float) -> None:
        """Fold one measured task execution into the model (paper §3.2:
        "after a task has been executed, its effective computational cost
        is computed and used").

        Unlike :meth:`update`, the caller supplies the work units directly
        (live pair count, shipped slots — whatever the span measured), so
        task kinds the asymptotic table doesn't know about still refine.
        The rate each kind carried before its first observation is
        snapshotted as the modelled baseline for
        :meth:`measured_vs_modelled`.
        """
        if units <= 0 or seconds <= 0:
            return
        if kind not in self.modelled_baseline:
            self.modelled_baseline[kind] = self.rates.get(kind,
                                                          self.default_rate)
        acc = self.observed.setdefault(kind, [0.0, 0.0, 0])
        acc[0] += float(seconds)
        acc[1] += float(units)
        acc[2] += 1
        rate = seconds / units
        old = self.rates.get(kind)
        self.rates[kind] = rate if old is None else (
            (1 - self.ema) * old + self.ema * rate)

    def observed_units(self, kind: str) -> float:
        """Total measured work units folded in for ``kind`` (0 if never
        observed)."""
        acc = self.observed.get(kind)
        return acc[1] if acc else 0.0

    def observed_seconds(self, kind: str) -> float:
        acc = self.observed.get(kind)
        return acc[0] if acc else 0.0

    def observed_rate(self, kind: str) -> Optional[float]:
        """Mean measured seconds-per-unit over the whole run (not the
        EMA-refined ``rates`` entry)."""
        acc = self.observed.get(kind)
        if not acc or acc[1] <= 0:
            return None
        return acc[0] / acc[1]

    def measured_vs_modelled(self) -> Dict[str, float]:
        """Per-kind ratio of the mean measured rate to the rate the model
        assumed before any measurement. 1.0 = the analytic model was
        right; ≫1 = the task is more expensive per unit than modelled
        (the decomposition under-weights it)."""
        out = {}
        for kind, acc in self.observed.items():
            if acc[1] <= 0:
                continue
            base = self.modelled_baseline.get(kind, self.default_rate)
            out[kind] = (acc[0] / acc[1]) / base if base > 0 else float("inf")
        return out

    def calibrate(self, samples) -> Dict[str, Dict[str, float]]:
        """Fit one seconds-per-unit coefficient per task kind from joint
        (units-by-kind, seconds) samples — the online refinement of the
        paper's measured-cost feedback when the run is fully fused and
        only aggregate walls exist.

        ``samples`` is a sequence of ``(units: Dict[str, float],
        seconds: float)`` pairs, one per cycle. A non-negative
        least-squares fit (lstsq with clamping) recovers each kind's
        rate; the fit's R² is reported as a shared confidence and each
        positively-fitted rate is EMA-folded into :attr:`rates`. Kinds
        whose unit columns are collinear across samples (e.g. density
        and force when every live pair runs both) split the joint rate
        between them — the *sum* of their costs is still right, which is
        what the decomposition weights need. Returns ``{kind: {"rate",
        "confidence"}}`` (empty if under-determined)."""
        import numpy as _np
        samples = [(dict(u), float(s)) for u, s in samples
                   if s > 0 and any(v > 0 for v in u.values())]
        kinds = sorted({k for u, _ in samples for k in u if u[k] > 0})
        if not kinds or len(samples) < 1:
            return {}
        A = _np.array([[float(u.get(k, 0.0)) for k in kinds]
                       for u, _ in samples], dtype=_np.float64)
        b = _np.array([s for _, s in samples], dtype=_np.float64)
        coef, *_ = _np.linalg.lstsq(A, b, rcond=None)
        coef = _np.clip(coef, 0.0, None)
        pred = A @ coef
        ss_res = float(((b - pred) ** 2).sum())
        ss_tot = float(((b - b.mean()) ** 2).sum())
        r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else (
            1.0 if ss_res < 1e-18 else 0.0)
        confidence = float(max(0.0, min(1.0, r2)))
        out: Dict[str, Dict[str, float]] = {}
        for k, c in zip(kinds, coef):
            c = float(c)
            out[k] = {"rate": c, "confidence": confidence}
            if c > 0:
                if k not in self.modelled_baseline:
                    self.modelled_baseline[k] = self.rates.get(
                        k, self.default_rate)
                old = self.rates.get(k)
                self.rates[k] = c if old is None else (
                    (1 - self.ema) * old + self.ema * c)
        return out


# --------------------------------------------------------------- LM analytic
@dataclass(frozen=True)
class LayerCost:
    flops: float
    param_bytes: float
    act_bytes: float

    @property
    def total_bytes(self) -> float:
        return self.param_bytes + self.act_bytes


def attention_cost(*, batch: int, q_len: int, kv_len: int, d_model: int,
                   n_heads: int, n_kv: int, head_dim: int,
                   dtype_bytes: int = 2, causal: bool = True,
                   window: Optional[int] = None) -> LayerCost:
    """Analytic attention FLOPs/bytes (projections + scores + output)."""
    d_q = n_heads * head_dim
    d_kv = n_kv * head_dim
    proj = 2 * batch * q_len * d_model * (d_q + 2 * d_kv)      # qkv
    proj += 2 * batch * q_len * d_q * d_model                  # out proj
    kv_eff = kv_len
    if window is not None:
        kv_eff = min(kv_len, window)
    score_frac = 0.5 if (causal and q_len == kv_len and window is None) else 1.0
    scores = 2 * batch * n_heads * q_len * kv_eff * head_dim * 2 * score_frac
    params = (d_model * (d_q + 2 * d_kv) + d_q * d_model) * dtype_bytes
    acts = batch * q_len * (d_model + d_q + 2 * d_kv) * dtype_bytes
    acts += batch * n_heads * q_len * min(kv_eff, 4096) * dtype_bytes  # tile-resident scores
    return LayerCost(proj + scores, float(params), float(acts))


def mlp_cost(*, batch: int, seq: int, d_model: int, d_ff: int,
             gated: bool = True, dtype_bytes: int = 2) -> LayerCost:
    mats = 3 if gated else 2
    flops = 2 * batch * seq * d_model * d_ff * mats
    params = mats * d_model * d_ff * dtype_bytes
    acts = batch * seq * (d_model + d_ff * (2 if gated else 1)) * dtype_bytes
    return LayerCost(float(flops), float(params), float(acts))


def moe_cost(*, batch: int, seq: int, d_model: int, d_ff: int,
             num_experts: int, top_k: int, dtype_bytes: int = 2) -> LayerCost:
    dense = mlp_cost(batch=batch, seq=seq, d_model=d_model, d_ff=d_ff,
                     gated=True, dtype_bytes=dtype_bytes)
    router = 2 * batch * seq * d_model * num_experts
    return LayerCost(dense.flops * top_k + router,
                     dense.param_bytes * num_experts,
                     dense.act_bytes * top_k)


def mamba_cost(*, batch: int, seq: int, d_model: int, d_state: int,
               expand: int = 2, d_conv: int = 4,
               dtype_bytes: int = 2) -> LayerCost:
    d_inner = expand * d_model
    flops = 2 * batch * seq * d_model * d_inner * 2          # in_proj (x, z)
    flops += 2 * batch * seq * d_inner * d_conv              # conv1d
    flops += 6 * batch * seq * d_inner * d_state             # selective scan
    flops += 2 * batch * seq * d_inner * d_model             # out_proj
    params = (d_model * d_inner * 3 + d_inner * d_state * 2) * dtype_bytes
    acts = batch * seq * (d_model + 3 * d_inner) * dtype_bytes
    return LayerCost(float(flops), float(params), float(acts))


def model_flops_6nd(n_params: float, n_tokens: float) -> float:
    """MODEL_FLOPS = 6·N·D for a training step (fwd+bwd)."""
    return 6.0 * n_params * n_tokens


def model_flops_2nd(n_params: float, n_tokens: float) -> float:
    """Inference (fwd only): 2·N·D."""
    return 2.0 * n_params * n_tokens
