"""Domain-decomposition driver: cells → ranks via the task graph (SWIFT §3.2).

Pipeline (exactly the paper's):

1. build the SPH task graph for the current cell grid (``sph/engine.py``),
2. project it onto the cell graph (``TaskGraph.cell_graph``) with
   cost-weighted edges,
3. partition with the multilevel partitioner (``core/partition.py``),
4. insert send/recv tasks for the cut (``core/comm_planner.py``),
5. re-decompose every ``repartition_every`` steps with *measured* costs.

The same driver serves the LM stack: ``decompose_layers`` partitions a layer
task graph into pipeline stages.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .comm_planner import CommStats, insert_comm_tasks, pairwise_stats_from_partition
from .cost_model import CostModel
from .partition import Graph, PartitionResult, evaluate, partition_graph
from .taskgraph import TaskGraph


@dataclass
class Decomposition:
    assignment: np.ndarray            # cell -> rank
    partition: PartitionResult
    comm: Optional[CommStats] = None

    @property
    def nranks(self) -> int:
        return self.partition.nparts


def timebin_node_weights(occupancy_by_bin: np.ndarray) -> np.ndarray:
    """Per-cell time-averaged work: Σ_b occ[c, b] · 2**(b − max_bin).

    ``occupancy_by_bin`` is (ncells, nbins) with bin b holding particles
    stepped at dt_max/2**b. A bin-b particle is integrated on a fraction
    2**(b − d) of the finest sub-steps, so this weight measures updates
    actually performed per sub-step — the quantity the partitioner must
    balance under hierarchical time-stepping (the paper's "work, not data"
    extended along the time axis).
    """
    occ = np.asarray(occupancy_by_bin, dtype=np.float64)
    if occ.ndim != 2:
        raise ValueError("occupancy_by_bin must be (ncells, nbins)")
    d = occ.shape[1] - 1
    freq = 2.0 ** (np.arange(occ.shape[1]) - d)
    return occ @ freq


def rank_bin_occupancy(assignment: np.ndarray,
                       occupancy_by_bin: np.ndarray,
                       nranks: Optional[int] = None) -> np.ndarray:
    """(nranks, nbins) per-rank time-bin occupancy under a partition.

    Pass ``nranks`` explicitly when ranks may own zero cells — inferring
    it from ``assignment.max()`` makes empty ranks invisible.
    """
    assignment = np.asarray(assignment, dtype=np.int64)
    occ = np.asarray(occupancy_by_bin, dtype=np.int64)
    if nranks is None:
        nranks = int(assignment.max()) + 1 if assignment.size else 1
    out = np.zeros((nranks, occ.shape[1]), dtype=np.int64)
    np.add.at(out, assignment, occ)
    return out


def bin_occupancy_imbalance(assignment: np.ndarray,
                            occupancy_by_bin: np.ndarray,
                            nranks: Optional[int] = None) -> float:
    """max/mean ratio of per-rank *time-averaged active work*.

    The repartition trigger for the distributed time-bin engine: a rank's
    load is Σ over its cells of ``timebin_node_weights`` — updates actually
    performed per finest sub-step — so a rank that inherited the deep
    (short-step) bins shows up here long before raw particle counts drift.
    Returns 1.0 for a perfectly balanced partition. Pass ``nranks``
    explicitly when ranks may own zero cells — a starved rank inferred
    away from ``assignment.max()`` would masquerade as perfect balance,
    the one condition the trigger must fire on.
    """
    assignment = np.asarray(assignment, dtype=np.int64)
    if nranks is None:
        nranks = int(assignment.max()) + 1 if assignment.size else 1
    w = timebin_node_weights(occupancy_by_bin)
    rank_w = np.zeros(nranks)
    np.add.at(rank_w, assignment, w)
    mean = rank_w.mean()
    if mean <= 0:
        return 1.0
    return float(rank_w.max() / mean)


def decompose_cells(graph: TaskGraph, num_cells: int, nranks: int, *,
                    seed: int = 0, max_imbalance: float = 1.05,
                    cell_bytes: Optional[Sequence[float]] = None,
                    node_weights: Optional[Sequence[float]] = None,
                    occupancy_by_bin: Optional[np.ndarray] = None
                    ) -> Decomposition:
    """Partition the computation (not just the data): SWIFT §3.2.

    ``node_weights`` overrides the cell weights projected from the task
    graph — used with :func:`timebin_node_weights` to balance the
    *time-averaged* active work when particles carry per-particle
    time-steps (a graph built with ``time_average=True`` already carries
    these weights in its task costs, in which case no override is needed).

    ``occupancy_by_bin`` (ncells, nbins) is the convenience form of the
    same: per-cell time-bin occupancy histograms, converted internally via
    :func:`timebin_node_weights`. This is the input the distributed
    time-bin engine's repartition trigger feeds (see
    :func:`bin_occupancy_imbalance`); explicit ``node_weights`` wins if
    both are given.
    """
    node_w, edge_w = graph.cell_graph()
    vw = np.zeros(num_cells)
    for r, w in node_w.items():
        if r < num_cells:
            vw[r] = w
    if node_weights is None and occupancy_by_bin is not None:
        node_weights = timebin_node_weights(occupancy_by_bin)
    if node_weights is not None:
        vw = np.asarray(node_weights, dtype=np.float64).copy()
        if len(vw) != num_cells:
            raise ValueError(
                f"node_weights has {len(vw)} entries for {num_cells} cells")
    vw = np.maximum(vw, 1e-12)      # empty cells still need a home
    edges = {(u, v): w for (u, v), w in edge_w.items()
             if u < num_cells and v < num_cells}
    g = Graph.from_edges(num_cells, edges, vw)
    part = partition_graph(g, nranks, seed=seed, max_imbalance=max_imbalance)
    comm = None
    if cell_bytes is not None:
        comm = pairwise_stats_from_partition(edges, part.assignment, cell_bytes)
    return Decomposition(part.assignment, part, comm)


def assign_tasks(graph: TaskGraph, assignment: np.ndarray) -> TaskGraph:
    """Return a new graph with each task pinned to a rank.

    Single-cell tasks go to the owner rank. Pair tasks spanning two ranks are
    *duplicated* on both ranks (the paper's Fig. 2: green tasks along the cut
    are executed on both partitions) — here realised as one task per side,
    each reading the remote cell via a recv dependency.
    """
    out = TaskGraph()
    id_map: Dict[int, List[int]] = {}
    for t in graph.tasks.values():
        ranks = sorted({int(assignment[r]) for r in t.resources}) or [0]
        new_ids = []
        for rk in ranks:
            nid = out.add_task(t.kind, resources=t.resources, writes=t.writes,
                               cost=t.cost, rank=rk, payload=t.payload)
            new_ids.append(nid)
        id_map[t.tid] = new_ids
    for t in graph.tasks.values():
        for dep in graph.dependencies(t.tid):
            for a in id_map[t.tid]:
                for b in id_map[dep]:
                    out.add_dependency(a, b)
    for t in graph.tasks.values():
        for c in graph.conflicts(t.tid):
            for a in id_map[t.tid]:
                for b in id_map.get(c, ()):  # conflicts only matter same-rank
                    if a != b and out.tasks[a].rank == out.tasks[b].rank:
                        out.add_conflict(a, b)
    return out


def decompose_with_comm(graph: TaskGraph, num_cells: int, nranks: int, *,
                        cell_bytes: Sequence[float],
                        phases: Optional[Dict[str, str]] = None,
                        seed: int = 0) -> Tuple[TaskGraph, Decomposition]:
    """Full §3.2+§3.3 pipeline → (distributed task graph, decomposition)."""
    dec = decompose_cells(graph, num_cells, nranks, seed=seed,
                          cell_bytes=cell_bytes)
    dist = assign_tasks(graph, dec.assignment)
    resource_rank = {c: int(dec.assignment[c]) for c in range(num_cells)}
    resource_bytes = {c: float(cell_bytes[c]) for c in range(num_cells)}
    comm = insert_comm_tasks(dist, resource_rank, resource_bytes,
                             phases={k: v for k, v in (phases or {}).items()})
    dec.comm = comm
    return dist, dec


# ----------------------------------------------------------- LM: layer→stage
def decompose_layers(layer_costs: Sequence[float], num_stages: int, *,
                     act_bytes: float = 1.0,
                     contiguous: bool = True) -> np.ndarray:
    """Partition a layer chain into pipeline stages.

    For a chain graph the optimal contiguous partition is found by DP
    (minimise max stage cost); the graph partitioner is overkill there but
    non-contiguous assignment is allowed with ``contiguous=False`` where it
    uses the multilevel partitioner on the chain + skip edges.
    Returns layer -> stage.
    """
    n = len(layer_costs)
    costs = np.asarray(layer_costs, dtype=np.float64)
    if num_stages >= n:
        return np.arange(n) % max(num_stages, 1)
    if contiguous:
        # DP over prefix sums: minimise the maximum stage sum.
        prefix = np.concatenate([[0.0], np.cumsum(costs)])
        INF = float("inf")
        dp = np.full((num_stages + 1, n + 1), INF)
        cut = np.zeros((num_stages + 1, n + 1), dtype=np.int64)
        dp[0, 0] = 0.0
        for s in range(1, num_stages + 1):
            for i in range(1, n + 1):
                for j in range(s - 1, i):
                    cand = max(dp[s - 1, j], prefix[i] - prefix[j])
                    if cand < dp[s, i]:
                        dp[s, i] = cand
                        cut[s, i] = j
        stages = np.zeros(n, dtype=np.int64)
        i = n
        for s in range(num_stages, 0, -1):
            j = cut[s, i]
            stages[j:i] = s - 1
            i = j
        return stages
    edges = {(i, i + 1): act_bytes for i in range(n - 1)}
    g = Graph.from_edges(n, edges, costs)
    res = partition_graph(g, num_stages, seed=0)
    return res.assignment
