"""Communication planning: send/recv task generation and halo-exchange plans.

SWIFT §3.3: for every task that uses data residing on a different rank,
``send``/``recv`` tasks are generated automatically on the source and
destination ranks, and the consumer is made dependent on the ``recv``. This
module does exactly that, given a partitioned task graph, and additionally
compiles the resulting point-to-point pattern into a **halo exchange plan** —
the static, TPU-lowerable form (a sequence of ``lax.ppermute`` rounds over
mesh axes) used by ``sph/distributed.py`` and ``distributed/halo.py``.

Message statistics (count, bytes) reproduce the paper's §5 numbers
(~58 000 point-to-point messages of ~6 kB per node per step on 32 nodes of
SuperMUC) in ``benchmarks/comm_stats.py``.
"""

from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .taskgraph import TaskGraph


@dataclass
class CommStats:
    messages: int
    total_bytes: float
    per_pair: Dict[Tuple[int, int], int]
    per_pair_bytes: Dict[Tuple[int, int], float]

    @property
    def mean_message_bytes(self) -> float:
        return self.total_bytes / self.messages if self.messages else 0.0


def insert_comm_tasks(graph: TaskGraph, resource_rank: Dict[int, int],
                      resource_bytes: Dict[int, float],
                      phases: Optional[Dict[int, str]] = None,
                      resource_freq: Optional[Dict[int, float]] = None
                      ) -> CommStats:
    """Insert send/recv tasks for every cross-rank (consumer, resource) pair.

    Parameters
    ----------
    graph: task graph whose tasks already carry ``rank`` assignments.
    resource_rank: owner rank of each resource (cell).
    resource_bytes: payload size of each resource.
    phases: optional task-kind -> phase label; data is re-sent once per
        phase that needs it (the paper sends twice per step: positions for
        the density phase, densities for the force phase).
    resource_freq: optional activation frequency of each resource under a
        time-bin hierarchy (``core.cost_model.cell_activation_frequency``).
        Send/recv task costs and message statistics are scaled by it: a
        boundary cell that wakes on 1/8 of the sub-steps ships (and costs)
        1/8 of what an always-active cell does — the activity-aware halo
        accounting of ``sph/dist_timebins.py`` at the task-graph layer.

    The function deduplicates: one send/recv pair per
    (resource, destination rank, phase). Consumers are made dependent on the
    recv; the recv depends on the send; the send depends on all *producer*
    tasks of that resource on the owner rank in an earlier phase (tasks that
    write the resource).

    Returns message statistics.
    """
    tasks = list(graph.tasks.values())
    # producers[resource][phase] = [tid...] writing that resource
    def phase_of(kind: str) -> str:
        return phases.get(kind, kind) if phases is not None else ""

    producers: Dict[Tuple[int, str], List[int]] = collections.defaultdict(list)
    for t in tasks:
        for w in t.writes:
            producers[(w, phase_of(t.kind))].append(t.tid)

    # ordered phases via topological order of first appearance
    phase_order: List[str] = []
    for tid in graph.toposort():
        ph = phase_of(graph.tasks[tid].kind)
        if ph not in phase_order:
            phase_order.append(ph)
    phase_idx = {ph: i for i, ph in enumerate(phase_order)}

    pair_tasks: Dict[Tuple[int, int, str], Tuple[int, int]] = {}
    per_pair: Dict[Tuple[int, int], int] = collections.defaultdict(int)
    per_pair_bytes: Dict[Tuple[int, int], float] = collections.defaultdict(float)
    messages = 0
    total_bytes = 0.0

    for t in tasks:
        if t.kind in ("send", "recv"):
            continue
        for r in t.resources:
            owner = resource_rank.get(r, t.rank)
            if owner == t.rank:
                continue
            key = (r, t.rank, phase_of(t.kind))
            if key not in pair_tasks:
                freq = 1.0
                if resource_freq is not None:
                    freq = float(resource_freq.get(r, 1.0))
                nbytes = resource_bytes.get(r, 0.0) * freq
                send = graph.add_task("send", resources=(r,),
                                      cost=1e-6 * freq, rank=owner,
                                      payload=(t.rank, nbytes))
                recv = graph.add_task("recv", resources=(r,),
                                      cost=1e-6 * freq, rank=t.rank,
                                      payload=(owner, nbytes))
                graph.add_dependency(recv, send)
                # send waits for the freshest producers in strictly earlier
                # phases (data must be ready before it is shipped)
                my_phase = phase_idx[phase_of(t.kind)]
                best_phase = -1
                best: List[int] = []
                for (rr, ph), tids in producers.items():
                    if rr != r or phase_idx.get(ph, -1) >= my_phase:
                        continue
                    if phase_idx[ph] > best_phase:
                        best_phase, best = phase_idx[ph], tids
                for ptid in best:
                    graph.add_dependency(send, ptid)
                pair_tasks[key] = (send, recv)
                messages += 1
                total_bytes += nbytes
                per_pair[(owner, t.rank)] += 1
                per_pair_bytes[(owner, t.rank)] += nbytes
            graph.add_dependency(t.tid, pair_tasks[key][1])

    return CommStats(messages, total_bytes, dict(per_pair),
                     dict(per_pair_bytes))


# ------------------------------------------------------------------ halo plan
@dataclass(frozen=True)
class HaloPlan:
    """Static halo-exchange plan over a 1-D device ring.

    ``offsets`` lists the ring offsets whose neighbour data is needed (e.g.
    (+1, -1) for nearest-neighbour halos). Lowered with ``lax.ppermute`` —
    one round per offset; rounds are independent so XLA may overlap them
    with interior compute (the dependency structure guarantees interior
    work never waits on the halo: SWIFT's "strictly local tasks first").
    """

    axis: str
    offsets: Tuple[int, ...]

    def perms(self, axis_size: int) -> List[List[Tuple[int, int]]]:
        out = []
        for off in self.offsets:
            out.append([(i, (i + off) % axis_size) for i in range(axis_size)])
        return out


def plan_halo_1d(*, axis: str, radius: int = 1) -> HaloPlan:
    offs: List[int] = []
    for r in range(1, radius + 1):
        offs.extend([+r, -r])
    return HaloPlan(axis=axis, offsets=tuple(offs))


def ppermute_rounds(edges, nranks: Optional[int] = None
                    ) -> List[List[Tuple[int, int]]]:
    """Decompose directed rank edges into ``lax.ppermute`` rounds.

    SWIFT's send/recv tasks are point-to-point; the TPU-lowerable image is a
    sequence of *partial permutations* — in each round every rank sends to at
    most one rank and receives from at most one (``ppermute``'s contract).
    Greedy edge colouring over the export edge list: each round grabs a
    maximal set of edges with distinct sources and distinct destinations, so
    all edges are covered in at most 2·Δ − 1 rounds (Δ = max in/out degree).
    For the graph-partitioned cut the degree is the number of neighbouring
    ranks, independent of the total rank count — the neighbour-to-neighbour
    schedule the paper's asynchronous exchange relies on.

    ``edges``: iterable of (src, dst) rank pairs, src ≠ dst. Deduplicated and
    sorted for determinism. Returns a list of rounds, each a list of
    (src, dst) forming a partial permutation.
    """
    remaining = sorted({(int(s), int(d)) for s, d in edges})
    for s, d in remaining:
        if s == d:
            raise ValueError(f"self-edge ({s}, {d}) in export edge list")
        if nranks is not None and not (0 <= s < nranks and 0 <= d < nranks):
            raise ValueError(f"edge ({s}, {d}) outside rank range {nranks}")
    rounds: List[List[Tuple[int, int]]] = []
    while remaining:
        used_src: Set[int] = set()
        used_dst: Set[int] = set()
        rnd: List[Tuple[int, int]] = []
        rest: List[Tuple[int, int]] = []
        for (s, d) in remaining:
            if s in used_src or d in used_dst:
                rest.append((s, d))
            else:
                rnd.append((s, d))
                used_src.add(s)
                used_dst.add(d)
        rounds.append(rnd)
        remaining = rest
    return rounds


def pairwise_stats_from_partition(
        cell_edges: Dict[Tuple[int, int], float],
        assignment: np.ndarray,
        cell_bytes: Sequence[float],
        cell_freq: Optional[Sequence[float]] = None) -> CommStats:
    """Message statistics implied by a cell partition: one message per
    (cut cell, neighbouring rank, phase) with two phases per step (density +
    force), matching the paper's accounting.

    With ``cell_freq`` (per-cell activation frequency under a time-bin
    hierarchy) the counts and bytes become *expected values per finest
    sub-step*: a cut cell ships only on the sub-steps it is active, so its
    messages and bytes are scaled by its frequency — the planning-side
    image of the activity-aware halo exchange.
    """
    per_pair: Dict[Tuple[int, int], float] = collections.defaultdict(float)
    per_pair_bytes: Dict[Tuple[int, int], float] = collections.defaultdict(float)
    seen: Set[Tuple[int, int]] = set()
    for (u, v), _w in cell_edges.items():
        ru, rv = int(assignment[u]), int(assignment[v])
        if ru == rv:
            continue
        for (cell, src, dst) in ((u, ru, rv), (v, rv, ru)):
            if (cell, dst) in seen:
                continue
            seen.add((cell, dst))
            f = 1.0 if cell_freq is None else float(cell_freq[cell])
            per_pair[(src, dst)] += 2 * f                  # density + force
            per_pair_bytes[(src, dst)] += 2 * f * float(cell_bytes[cell])
    messages = sum(per_pair.values())
    total = sum(per_pair_bytes.values())
    return CommStats(messages, total, dict(per_pair), dict(per_pair_bytes))
