"""Multilevel k-way graph partitioner (SWIFT §3.2's METIS role).

METIS is not available in this environment, so the same algorithm family
[Karypis & Kumar, SIAM J. Sci. Comput. 20(1), 1998] is implemented from
scratch:

1. **Coarsening** — heavy-edge matching (HEM): repeatedly collapse the
   heaviest incident edge of each unmatched vertex until the graph is small.
2. **Initial partitioning** — greedy graph growing on the coarsest graph
   (k-way; BFS region growth from pseudo-peripheral seeds, balanced by node
   weight), with an LPT fallback for disconnected graphs.
3. **Uncoarsening + refinement** — project the partition back up, at every
   level running boundary Fiduccia–Mattheyses (FM) refinement: greedy
   max-gain moves with a balance constraint and hill-climbing rollback.

The objective follows the paper: minimise the **maximum per-partition work**
(node weight plus edge weight of cut edges, which are "computed twice" —
Fig. 2), with edge-cut reported alongside. Deterministic given the input.

Graphs are plain ``numpy`` CSR arrays; no external dependencies.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Graph:
    """Undirected weighted graph in CSR form.

    ``xadj[i]:xadj[i+1]`` indexes ``adjncy``/``adjwgt`` for vertex ``i``.
    Every edge appears twice (both directions) with equal weight.
    """

    xadj: np.ndarray      # (n+1,) int64
    adjncy: np.ndarray    # (m,)   int64
    adjwgt: np.ndarray    # (m,)   float64
    vwgt: np.ndarray      # (n,)   float64

    @property
    def n(self) -> int:
        return len(self.vwgt)

    def degree(self, v: int) -> int:
        return int(self.xadj[v + 1] - self.xadj[v])

    def neighbors(self, v: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.xadj[v], self.xadj[v + 1]
        return self.adjncy[s:e], self.adjwgt[s:e]

    @staticmethod
    def from_edges(num_nodes: int,
                   edges: Dict[Tuple[int, int], float],
                   node_weights: Optional[Sequence[float]] = None) -> "Graph":
        """Build from an ``{(u,v): w}`` dict (u != v; duplicates summed)."""
        acc: Dict[Tuple[int, int], float] = {}
        for (u, v), w in edges.items():
            if u == v:
                continue
            key = (min(u, v), max(u, v))
            acc[key] = acc.get(key, 0.0) + float(w)
        deg = np.zeros(num_nodes, dtype=np.int64)
        for (u, v) in acc:
            deg[u] += 1
            deg[v] += 1
        xadj = np.zeros(num_nodes + 1, dtype=np.int64)
        np.cumsum(deg, out=xadj[1:])
        adjncy = np.zeros(xadj[-1], dtype=np.int64)
        adjwgt = np.zeros(xadj[-1], dtype=np.float64)
        fill = xadj[:-1].copy()
        for (u, v), w in acc.items():
            adjncy[fill[u]] = v
            adjwgt[fill[u]] = w
            fill[u] += 1
            adjncy[fill[v]] = u
            adjwgt[fill[v]] = w
            fill[v] += 1
        vwgt = (np.ones(num_nodes) if node_weights is None
                else np.asarray(node_weights, dtype=np.float64))
        if len(vwgt) != num_nodes:
            raise ValueError("node_weights length mismatch")
        return Graph(xadj, adjncy, adjwgt, vwgt)


@dataclass
class PartitionResult:
    assignment: np.ndarray         # (n,) int: vertex -> part
    nparts: int
    edge_cut: float                # total weight of cut edges
    part_loads: np.ndarray         # node weight + cut-edge weight per part
    imbalance: float               # max load / mean load

    def summary(self) -> str:
        return (f"parts={self.nparts} cut={self.edge_cut:.3g} "
                f"imbalance={self.imbalance:.3f} "
                f"max_load={self.part_loads.max():.3g}")


# ----------------------------------------------------------------- metrics
def evaluate(g: Graph, part: np.ndarray, nparts: int) -> PartitionResult:
    """Edge cut and per-partition *work* loads (paper's Fig. 2 cost model:
    cut tasks are executed on both sides)."""
    loads = np.zeros(nparts, dtype=np.float64)
    np.add.at(loads, part, g.vwgt)
    cut = 0.0
    for u in range(g.n):
        s, e = g.xadj[u], g.xadj[u + 1]
        nbr = g.adjncy[s:e]
        w = g.adjwgt[s:e]
        mask = part[nbr] != part[u]
        if mask.any():
            wcut = w[mask]
            cut += wcut.sum()            # counted once per direction; halved below
            loads[part[u]] += wcut.sum() # duplicated work lands on this side too
    cut *= 0.5
    mean = loads.mean() if nparts else 0.0
    imbalance = float(loads.max() / mean) if mean > 0 else 1.0
    return PartitionResult(part.copy(), nparts, float(cut), loads, imbalance)


# --------------------------------------------------------------- coarsening
def _heavy_edge_matching(g: Graph, rng: np.random.Generator) -> np.ndarray:
    """Return match[v] = partner (or v itself). Visit order randomised by
    ``rng`` but resulting coarse graph is deterministic for a fixed seed."""
    match = np.full(g.n, -1, dtype=np.int64)
    order = rng.permutation(g.n)
    for v in order:
        if match[v] != -1:
            continue
        nbr, w = g.neighbors(v)
        best, best_w = -1, -1.0
        for u, wu in zip(nbr, w):
            if match[u] == -1 and u != v and wu > best_w:
                best, best_w = int(u), float(wu)
        if best >= 0:
            match[v] = best
            match[best] = v
        else:
            match[v] = v
    return match


def _coarsen(g: Graph, rng: np.random.Generator) -> Tuple[Graph, np.ndarray]:
    """One coarsening level. Returns (coarse graph, fine->coarse map)."""
    match = _heavy_edge_matching(g, rng)
    cmap = np.full(g.n, -1, dtype=np.int64)
    nc = 0
    for v in range(g.n):
        if cmap[v] != -1:
            continue
        u = match[v]
        cmap[v] = nc
        if u != v:
            cmap[u] = nc
        nc += 1
    cvwgt = np.zeros(nc, dtype=np.float64)
    np.add.at(cvwgt, cmap, g.vwgt)
    edges: Dict[Tuple[int, int], float] = {}
    for v in range(g.n):
        cv = cmap[v]
        s, e = g.xadj[v], g.xadj[v + 1]
        for u, w in zip(g.adjncy[s:e], g.adjwgt[s:e]):
            cu = cmap[u]
            if cu == cv:
                continue
            key = (min(cv, cu), max(cv, cu))
            edges[key] = edges.get(key, 0.0) + float(w)
    # each undirected edge visited twice above -> halve
    for k in edges:
        edges[k] *= 0.5
    coarse = Graph.from_edges(nc, edges, cvwgt)
    return coarse, cmap


# ------------------------------------------------------ initial partitioning
def _greedy_growth(g: Graph, nparts: int,
                   rng: np.random.Generator) -> np.ndarray:
    """K-way greedy graph growing, balanced by node weight."""
    target = g.vwgt.sum() / nparts
    part = np.full(g.n, -1, dtype=np.int64)
    loads = np.zeros(nparts)
    unassigned = set(range(g.n))
    order = sorted(unassigned, key=lambda v: -g.vwgt[v])
    for p in range(nparts):
        if not unassigned:
            break
        # seed: heaviest unassigned vertex
        seed = next(v for v in order if part[v] == -1)
        frontier = [seed]
        while frontier and loads[p] < target:
            # pick the frontier vertex with max connectivity into part p
            v = frontier.pop(0)
            if part[v] != -1:
                continue
            part[v] = p
            loads[p] += g.vwgt[v]
            unassigned.discard(v)
            nbr, w = g.neighbors(v)
            cand = [int(u) for u in nbr[np.argsort(-w)] if part[u] == -1]
            frontier.extend(cand)
    # leftovers: LPT into lightest part
    for v in sorted(unassigned, key=lambda v: -g.vwgt[v]):
        p = int(np.argmin(loads))
        part[v] = p
        loads[p] += g.vwgt[v]
    return part


# ---------------------------------------------------------------- refinement
def _fm_refine(g: Graph, part: np.ndarray, nparts: int, *,
               max_imbalance: float, passes: int = 8) -> np.ndarray:
    """Boundary FM: greedy max-gain single-vertex moves with rollback.

    Gain of moving v from a to b = (edge weight to b) − (edge weight to a),
    i.e. the edge-cut reduction. Moves violating the balance bound are
    skipped. Each pass moves each vertex at most once, tracking the best
    prefix (classic FM hill-climbing), then rolls back past it. The boundary
    set is maintained incrementally so a pass costs O(boundary × degree), not
    O(n²).
    """
    part = part.copy()
    total = g.vwgt.sum()
    max_load = max_imbalance * total / nparts
    loads = np.zeros(nparts)
    np.add.at(loads, part, g.vwgt)

    def best_move_for(v: int):
        """(gain, target_part) of the best feasible move for v, or None."""
        nbr, w = g.neighbors(v)
        if len(nbr) == 0:
            return None
        pv = part[v]
        ext: Dict[int, float] = {}
        internal = 0.0
        for u, wu in zip(nbr, w):
            pu = part[u]
            if pu == pv:
                internal += wu
            else:
                ext[pu] = ext.get(pu, 0.0) + wu
        if not ext:
            return None
        best = None
        for pb, wb in ext.items():
            if loads[pb] + g.vwgt[v] > max_load:
                continue
            gain = wb - internal
            if best is None or gain > best[0]:
                best = (gain, pb)
        return best

    for _ in range(passes):
        # initial boundary: vertices with ≥1 cross-part edge
        boundary = set()
        for v in range(g.n):
            nbr, _w = g.neighbors(v)
            if len(nbr) and (part[nbr] != part[v]).any():
                boundary.add(v)
        moved = np.zeros(g.n, dtype=bool)
        history: List[Tuple[int, int, int, float]] = []  # v, from, to, gain
        cum = 0.0
        best_cum, best_len = 0.0, 0
        improved = False
        max_moves = max(64, g.n // 2)
        for _step in range(max_moves):
            best_move = None
            best_gain = -np.inf
            for v in boundary:
                if moved[v]:
                    continue
                cand = best_move_for(v)
                if cand is None:
                    continue
                gain, pb = cand
                if gain > best_gain:
                    best_gain = gain
                    best_move = (v, int(part[v]), pb)
            if best_move is None:
                break
            v, pa, pb = best_move
            part[v] = pb
            loads[pa] -= g.vwgt[v]
            loads[pb] += g.vwgt[v]
            moved[v] = True
            cum += best_gain
            history.append((v, pa, pb, best_gain))
            if cum > best_cum + 1e-12:
                best_cum, best_len = cum, len(history)
                improved = True
            # moved vertex and its neighbours may enter/leave the boundary
            boundary.add(v)
            nbr, _w = g.neighbors(v)
            boundary.update(int(u) for u in nbr)
            if best_gain <= 0 and len(history) - best_len > 16:
                break  # plateau: stop exploring
        # rollback past the best prefix
        for (v, pa, pb, _) in reversed(history[best_len:]):
            part[v] = pa
            loads[pb] -= g.vwgt[v]
            loads[pa] += g.vwgt[v]
        if not improved:
            break
    return part


# ------------------------------------------------------------ balance repair
def _work_loads(g: Graph, part: np.ndarray, nparts: int) -> np.ndarray:
    """Per-part *work* = node weight + cut-edge weight (the paper's Fig. 2
    objective: cut tasks execute on both sides)."""
    loads = np.zeros(nparts)
    np.add.at(loads, part, g.vwgt)
    for u in range(g.n):
        s, e = g.xadj[u], g.xadj[u + 1]
        nbr = g.adjncy[s:e]
        w = g.adjwgt[s:e]
        cutw = w[part[nbr] != part[u]].sum()
        loads[part[u]] += cutw
    return loads


def _balance_repair(g: Graph, part: np.ndarray, nparts: int, *,
                    max_imbalance: float, max_moves: int = 400
                    ) -> np.ndarray:
    """Greedy repair on the *work* metric: repeatedly move the best boundary
    vertex off the max-work part, accepting only moves that reduce the
    maximum work (the paper's slowest-rank objective)."""
    part = part.copy()
    loads = _work_loads(g, part, nparts)
    for _ in range(max_moves):
        over = int(np.argmax(loads))
        mean = loads.sum() / nparts
        if loads[over] <= max(max_imbalance * mean, loads.mean() + 1e-12):
            break
        cands = np.nonzero(part == over)[0]
        best = None
        cur_max = loads[over]
        for v in cands:
            nbr, w = g.neighbors(v)
            ext: Dict[int, float] = {}
            internal = 0.0
            for u, wu in zip(nbr, w):
                if part[u] == over:
                    internal += wu
                else:
                    ext[int(part[u])] = ext.get(int(part[u]), 0.0) + wu
            targets = set(ext) | ({int(np.argmin(loads))} if not ext
                                  else set())
            for pb in targets:
                if pb == over:
                    continue
                # work deltas: vertex weight moves; its cut edges flip roles
                d_over = -(g.vwgt[v] + ext.get(pb, 0.0))     # loses v + cut→pb
                d_over += 0.0
                d_pb = g.vwgt[v] + internal                  # gains v + new cut
                new_over = loads[over] + d_over + internal - internal
                new_pb = loads[pb] + d_pb - ext.get(pb, 0.0)
                new_max_pair = max(new_over, new_pb)
                if new_max_pair >= cur_max - 1e-12:
                    continue
                key = -new_max_pair
                if best is None or key > best[0]:
                    best = (key, v, pb)
        if best is None:
            break
        _, v, pb = best
        part[v] = pb
        loads = _work_loads(g, part, nparts)     # exact recompute (safe)
    return part


# ------------------------------------------------------------------- driver
def partition_graph(g: Graph, nparts: int, *, seed: int = 0,
                    max_imbalance: float = 1.05,
                    coarsen_to: int = 64,
                    refine_passes: int = 8) -> PartitionResult:
    """Multilevel k-way partition. Deterministic for fixed ``seed``."""
    if nparts <= 0:
        raise ValueError("nparts must be positive")
    if nparts == 1 or g.n <= 1:
        return evaluate(g, np.zeros(g.n, dtype=np.int64), max(nparts, 1))
    if nparts >= g.n:
        # one vertex per part (extra parts stay empty)
        return evaluate(g, np.arange(g.n, dtype=np.int64) % nparts, nparts)

    rng = np.random.default_rng(seed)
    levels: List[Tuple[Graph, np.ndarray]] = []   # (fine graph, fine->coarse)
    cur = g
    while cur.n > max(coarsen_to, 4 * nparts):
        coarse, cmap = _coarsen(cur, rng)
        if coarse.n >= cur.n * 0.95:   # matching stalled (e.g. star graphs)
            break
        levels.append((cur, cmap))
        cur = coarse

    part = _greedy_growth(cur, nparts, rng)
    part = _fm_refine(cur, part, nparts, max_imbalance=max_imbalance,
                      passes=refine_passes)

    for fine, cmap in reversed(levels):
        part = part[cmap]              # project to fine level
        part = _fm_refine(fine, part, nparts, max_imbalance=max_imbalance,
                          passes=refine_passes)
    part = _balance_repair(g, part, nparts, max_imbalance=max_imbalance)
    return evaluate(g, part, nparts)


# ------------------------------------------------------------ baselines
def partition_geometric(positions: np.ndarray, nparts: int,
                        weights: Optional[np.ndarray] = None) -> np.ndarray:
    """Recursive coordinate bisection — the 'traditional' geometric baseline
    the paper contrasts with (slab/grid cuts)."""
    n = len(positions)
    w = np.ones(n) if weights is None else weights
    out = np.zeros(n, dtype=np.int64)

    def rec(idx: np.ndarray, parts: int, base: int):
        if parts == 1 or len(idx) == 0:
            out[idx] = base
            return
        left_parts = parts // 2
        frac = left_parts / parts
        spans = positions[idx].max(axis=0) - positions[idx].min(axis=0)
        axis = int(np.argmax(spans))
        order = idx[np.argsort(positions[idx, axis], kind="stable")]
        cw = np.cumsum(w[order])
        split = int(np.searchsorted(cw, cw[-1] * frac))
        split = max(1, min(len(order) - 1, split))
        rec(order[:split], left_parts, base)
        rec(order[split:], parts - left_parts, base + left_parts)

    rec(np.arange(n), nparts, 0)
    return out
