"""Task graph with dependencies and conflicts (SWIFT §3.1, QuickSched model).

A computation is decomposed into :class:`Task` objects. Two relations are
tracked, exactly as in the paper:

* **dependency** — task A *depends on* task B: B must complete before A may
  start (data produced by B is consumed by A).
* **conflict** — tasks A and B require exclusive access to the same resource
  but in no particular order; a valid schedule must never run them
  concurrently.

On a TPU there is no runtime scheduler — the graph is *compiled* (see
``scheduler.py``) into a static wave schedule ahead of time. This module is
the pure data structure: construction, validation, topological utilities, and
the cell-graph projection used by the domain decomposition (SWIFT §3.2).
"""

from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Callable, Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple


class TaskGraphError(Exception):
    """Raised for structural errors (cycles, unknown ids, self-deps)."""


@dataclass(frozen=True)
class Task:
    """A single unit of work.

    Attributes
    ----------
    tid:        unique integer id within the graph.
    kind:       task type, e.g. ``"sort"``, ``"density_self"``,
                ``"density_pair"``, ``"ghost"``, ``"force_self"``,
                ``"force_pair"``, ``"kick"``, ``"send"``, ``"recv"``.
    resources:  ids of the resources (cells, tensors) the task touches.
                Tasks sharing a resource *with write intent* conflict.
    writes:     subset of ``resources`` written (exclusive access needed).
    cost:       estimated execution cost (arbitrary units; see cost_model).
    rank:       partition / rank the task is assigned to (-1 = unassigned).
    payload:    opaque metadata (e.g. cell indices for a pair task).
    active:     activation mask for hierarchical time-stepping: a task whose
                cells contain no particle due at the current time-bin level
                is *inactive* and is skipped by the wave scheduler and the
                executor simulation (see ``sph/timebins.py``).
    """

    tid: int
    kind: str
    resources: Tuple[int, ...] = ()
    writes: Tuple[int, ...] = ()
    cost: float = 1.0
    rank: int = -1
    payload: tuple = ()
    active: bool = True

    def __post_init__(self):
        for w in self.writes:
            if w not in self.resources:
                raise TaskGraphError(
                    f"task {self.tid}: write target {w} not in resources")


class TaskGraph:
    """Mutable task graph with dependencies and conflicts."""

    def __init__(self) -> None:
        self.tasks: Dict[int, Task] = {}
        # dependents[b] = set of tasks that depend on b (b -> a edges)
        self._dependents: Dict[int, Set[int]] = collections.defaultdict(set)
        # dependencies[a] = set of tasks a depends on
        self._dependencies: Dict[int, Set[int]] = collections.defaultdict(set)
        self._conflicts: Dict[int, Set[int]] = collections.defaultdict(set)
        self._next_id = 0

    # ------------------------------------------------------------------ build
    def add_task(self, kind: str, *, resources: Sequence[int] = (),
                 writes: Sequence[int] = (), cost: float = 1.0,
                 rank: int = -1, payload: tuple = (),
                 active: bool = True) -> int:
        tid = self._next_id
        self._next_id += 1
        self.tasks[tid] = Task(tid=tid, kind=kind,
                               resources=tuple(resources),
                               writes=tuple(writes), cost=float(cost),
                               rank=rank, payload=tuple(payload),
                               active=bool(active))
        return tid

    def add_dependency(self, task: int, depends_on: int) -> None:
        """``task`` may only run after ``depends_on`` has completed."""
        if task == depends_on:
            raise TaskGraphError(f"self-dependency on task {task}")
        self._check(task), self._check(depends_on)
        self._dependencies[task].add(depends_on)
        self._dependents[depends_on].add(task)

    def add_conflict(self, a: int, b: int) -> None:
        if a == b:
            raise TaskGraphError(f"self-conflict on task {a}")
        self._check(a), self._check(b)
        self._conflicts[a].add(b)
        self._conflicts[b].add(a)

    def auto_conflicts(self) -> int:
        """Derive conflicts from write-sets (two tasks writing one resource).

        Returns the number of conflict pairs added. Dependency-ordered pairs
        are skipped — ordering already serialises them.
        """
        by_resource: Dict[int, List[int]] = collections.defaultdict(list)
        for t in self.tasks.values():
            for w in t.writes:
                by_resource[w].append(t.tid)
        added = 0
        reach = None
        for tids in by_resource.values():
            if len(tids) < 2:
                continue
            if reach is None:
                reach = self._reachability()
            for i in range(len(tids)):
                for j in range(i + 1, len(tids)):
                    a, b = tids[i], tids[j]
                    if b in reach.get(a, ()) or a in reach.get(b, ()):
                        continue  # ordered by dependencies already
                    if b not in self._conflicts[a]:
                        self.add_conflict(a, b)
                        added += 1
        return added

    # ------------------------------------------------------------ inspection
    def dependencies(self, tid: int) -> FrozenSet[int]:
        return frozenset(self._dependencies.get(tid, ()))

    def dependents(self, tid: int) -> FrozenSet[int]:
        return frozenset(self._dependents.get(tid, ()))

    def conflicts(self, tid: int) -> FrozenSet[int]:
        return frozenset(self._conflicts.get(tid, ()))

    def __len__(self) -> int:
        return len(self.tasks)

    def __iter__(self):
        return iter(self.tasks.values())

    def total_cost(self) -> float:
        return sum(t.cost for t in self.tasks.values())

    def _check(self, tid: int) -> None:
        if tid not in self.tasks:
            raise TaskGraphError(f"unknown task id {tid}")

    # ----------------------------------------------------- activity masking
    def active_tasks(self) -> FrozenSet[int]:
        """Ids of tasks whose activation mask is set."""
        return frozenset(t.tid for t in self.tasks.values() if t.active)

    def set_active(self, predicate: Callable[["Task"], bool]) -> int:
        """Recompute every task's activation flag; returns #active.

        Used by the time-bin hierarchy: at sub-step level L only tasks whose
        cells hold particles in bins ≥ L are due, everything else is skipped
        by the scheduler (SWIFT runs "only the work that is due").
        """
        n = 0
        for tid, t in list(self.tasks.items()):
            a = bool(predicate(t))
            n += a
            if a != t.active:
                self.tasks[tid] = Task(tid=t.tid, kind=t.kind,
                                       resources=t.resources, writes=t.writes,
                                       cost=t.cost, rank=t.rank,
                                       payload=t.payload, active=a)
        return n

    def active_subgraph(self) -> "TaskGraph":
        """Project onto the active tasks (same task ids).

        Dependencies on inactive tasks are treated as already satisfied —
        an inactive density task belongs to a cell with nothing due, so the
        ghost/force chain of an *active* neighbour must not wait on it.
        Conflicts between two active tasks are preserved.
        """
        keep = {tid for tid, t in self.tasks.items() if t.active}
        g = TaskGraph()
        g.tasks = {tid: self.tasks[tid] for tid in keep}
        g._next_id = self._next_id
        for tid in keep:
            deps = {d for d in self._dependencies.get(tid, ()) if d in keep}
            if deps:
                g._dependencies[tid] = deps
                for d in deps:
                    g._dependents[d].add(tid)
            confl = {c for c in self._conflicts.get(tid, ()) if c in keep}
            if confl:
                g._conflicts[tid] = confl
        return g

    # ---------------------------------------------------------------- orders
    def toposort(self) -> List[int]:
        """Kahn topological order; raises on cycles."""
        indeg = {tid: len(self._dependencies.get(tid, ())) for tid in self.tasks}
        queue = collections.deque(sorted(t for t, d in indeg.items() if d == 0))
        order: List[int] = []
        while queue:
            tid = queue.popleft()
            order.append(tid)
            for dep in sorted(self._dependents.get(tid, ())):
                indeg[dep] -= 1
                if indeg[dep] == 0:
                    queue.append(dep)
        if len(order) != len(self.tasks):
            raise TaskGraphError("dependency cycle detected")
        return order

    def _reachability(self) -> Dict[int, Set[int]]:
        """reach[a] = all tasks transitively reachable from a via dependents."""
        order = self.toposort()
        reach: Dict[int, Set[int]] = {tid: set() for tid in self.tasks}
        for tid in reversed(order):
            for d in self._dependents.get(tid, ()):
                reach[tid].add(d)
                reach[tid] |= reach[d]
        return reach

    def critical_path(self) -> Tuple[float, List[int]]:
        """Longest cost-weighted path — the lower bound on parallel makespan."""
        order = self.toposort()
        best: Dict[int, float] = {}
        pred: Dict[int, Optional[int]] = {}
        for tid in order:
            deps = self._dependencies.get(tid, ())
            if deps:
                p = max(deps, key=lambda d: best[d])
                best[tid] = best[p] + self.tasks[tid].cost
                pred[tid] = p
            else:
                best[tid] = self.tasks[tid].cost
                pred[tid] = None
        end = max(best, key=lambda t: best[t])
        path = []
        cur: Optional[int] = end
        while cur is not None:
            path.append(cur)
            cur = pred[cur]
        return best[end], list(reversed(path))

    # -------------------------------------------------- cell-graph projection
    def cell_graph(self) -> Tuple[Dict[int, float], Dict[Tuple[int, int], float]]:
        """Project the task graph onto its resources (SWIFT §3.2).

        Returns ``(node_weights, edge_weights)`` where nodes are resource ids.
        A task touching one resource adds its cost to that node; a task
        touching two resources adds its cost to the edge between them (and
        half to each node, so node weights estimate per-cell work). Tasks
        touching >2 resources contribute cost to every pairwise edge scaled
        by 1/npairs (hyperedge approximation — in SWIFT each task references
        at most two cells so the graph is a plain cell graph).
        """
        nodes: Dict[int, float] = collections.defaultdict(float)
        edges: Dict[Tuple[int, int], float] = collections.defaultdict(float)
        for t in self.tasks.values():
            res = sorted(set(t.resources))
            if not res:
                continue
            if len(res) == 1:
                nodes[res[0]] += t.cost
                continue
            share = t.cost / len(res)
            for r in res:
                nodes[r] += share
            npairs = len(res) * (len(res) - 1) // 2
            for i in range(len(res)):
                for j in range(i + 1, len(res)):
                    edges[(res[i], res[j])] += t.cost / npairs
        return dict(nodes), dict(edges)

    # ------------------------------------------------------------- validation
    def validate_schedule(self, waves: Sequence[Sequence[int]]) -> None:
        """Check a wave schedule: every task exactly once; dependencies in
        strictly earlier waves; no intra-wave conflicts."""
        seen: Dict[int, int] = {}
        for w, wave in enumerate(waves):
            for tid in wave:
                self._check(tid)
                if tid in seen:
                    raise TaskGraphError(f"task {tid} scheduled twice")
                seen[tid] = w
        if len(seen) != len(self.tasks):
            missing = set(self.tasks) - set(seen)
            raise TaskGraphError(f"tasks never scheduled: {sorted(missing)[:8]}…")
        for tid, w in seen.items():
            for dep in self._dependencies.get(tid, ()):
                if seen[dep] >= w:
                    raise TaskGraphError(
                        f"task {tid} (wave {w}) depends on {dep} "
                        f"(wave {seen[dep]})")
        for w, wave in enumerate(waves):
            wset = set(wave)
            for tid in wave:
                bad = wset & self._conflicts.get(tid, set())
                bad.discard(tid)
                if bad:
                    raise TaskGraphError(
                        f"wave {w}: conflicting tasks {tid} and {sorted(bad)}")
