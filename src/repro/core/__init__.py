"""SWIFT's three contributions as a composable library.

C1: task-based parallelism  -> taskgraph, scheduler
C2: graph-partition domain decomposition -> partition, decompose
C3: fully asynchronous communication -> comm_planner (+ distributed/overlap)
"""

from .taskgraph import Task, TaskGraph, TaskGraphError
from .scheduler import (AsyncExecutorSim, SimResult, balance_wave,
                        makespan_lower_bound, wave_schedule)
from .partition import (Graph, PartitionResult, evaluate, partition_geometric,
                        partition_graph)
from .cost_model import (CostModel, LayerCost, attention_cost,
                         cell_activation_frequency, mamba_cost, mlp_cost,
                         moe_cost, model_flops_2nd, model_flops_6nd,
                         timebin_frequency)
from .comm_planner import (CommStats, HaloPlan, insert_comm_tasks,
                           pairwise_stats_from_partition, plan_halo_1d,
                           ppermute_rounds)
from .decompose import (Decomposition, assign_tasks, bin_occupancy_imbalance,
                        decompose_cells, decompose_layers,
                        decompose_with_comm, rank_bin_occupancy,
                        timebin_node_weights)

__all__ = [
    "Task", "TaskGraph", "TaskGraphError",
    "AsyncExecutorSim", "SimResult", "balance_wave", "makespan_lower_bound",
    "wave_schedule",
    "Graph", "PartitionResult", "evaluate", "partition_geometric",
    "partition_graph",
    "CostModel", "LayerCost", "attention_cost", "cell_activation_frequency",
    "mamba_cost", "mlp_cost", "moe_cost", "model_flops_2nd",
    "model_flops_6nd", "timebin_frequency",
    "CommStats", "HaloPlan", "insert_comm_tasks",
    "pairwise_stats_from_partition", "plan_halo_1d", "ppermute_rounds",
    "Decomposition", "assign_tasks", "bin_occupancy_imbalance",
    "decompose_cells", "decompose_layers", "decompose_with_comm",
    "rank_bin_occupancy", "timebin_node_weights",
]
