"""Wave scheduling and discrete-event simulation of the async executor.

On CPU clusters SWIFT's QuickSched picks runnable tasks dynamically. On a TPU
the program is static, so the graph is compiled ahead of time into **waves**:
maximal conflict-free antichains of ready tasks. Each wave lowers to one fused
XLA/Pallas op batched over all tasks of the same kind (see ``sph/engine.py``).

The :class:`AsyncExecutorSim` is a discrete-event simulator of the *paper's*
runtime (work-stealing threads + asynchronous sends/recvs with latency). It is
used by ``benchmarks/strong_scaling.py`` to reproduce the strong-scaling
figures (Figs 5, 6, 8): the simulated speed-up of the SWIFT schedule vs the
bulk-synchronous baseline is the paper's central claim, and it is a property
of the *schedule*, not of the hardware.
"""

from __future__ import annotations

import collections
import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .taskgraph import TaskGraph


# --------------------------------------------------------------------- waves
def wave_schedule(graph: TaskGraph, *, by_kind: bool = True,
                  active_only: bool = False) -> List[List[int]]:
    """Greedy maximal conflict-free antichain decomposition.

    Repeatedly take every task whose dependencies are all satisfied, then
    within the ready set drop tasks that conflict with an already-picked task
    of the same wave (greedy maximal independent set in the conflict graph,
    highest-cost-first so expensive tasks are scheduled early).

    With ``by_kind`` the ready set is additionally split per task kind so
    each wave lowers to a single homogeneous batched op.

    With ``active_only`` the schedule covers only tasks whose activation
    mask is set (hierarchical time-stepping: inactive tasks have nothing
    due at the current bin level). Dependencies on inactive tasks count as
    satisfied; the returned waves never contain an inactive task.
    """
    if active_only:
        graph = graph.active_subgraph()
    indeg = {tid: len(graph.dependencies(tid)) for tid in graph.tasks}
    ready = {tid for tid, d in indeg.items() if d == 0}
    waves: List[List[int]] = []
    while ready:
        pool = sorted(ready, key=lambda t: (-graph.tasks[t].cost, t))
        if by_kind:
            kinds = collections.Counter(graph.tasks[t].kind for t in pool)
            # schedule the kind with the largest ready population first
            kind = max(kinds, key=lambda k: (kinds[k], k))
            pool = [t for t in pool if graph.tasks[t].kind == kind]
        wave: List[int] = []
        picked: set = set()
        blocked: set = set()
        for tid in pool:
            if tid in blocked:
                continue
            wave.append(tid)
            picked.add(tid)
            blocked |= graph.conflicts(tid)
        waves.append(wave)
        for tid in wave:
            ready.discard(tid)
            for dep in graph.dependents(tid):
                indeg[dep] -= 1
                if indeg[dep] == 0:
                    ready.add(dep)
    graph.validate_schedule(waves)
    return waves


def balance_wave(costs: Sequence[float], num_bins: int) -> List[List[int]]:
    """Cost-balanced batching of one wave across ``num_bins`` executors.

    LPT (longest processing time) greedy: the AOT analogue of QuickSched's
    dynamic load balancing. Returns per-bin task-index lists.
    """
    order = sorted(range(len(costs)), key=lambda i: -costs[i])
    heap: List[Tuple[float, int]] = [(0.0, b) for b in range(num_bins)]
    heapq.heapify(heap)
    bins: List[List[int]] = [[] for _ in range(num_bins)]
    for i in order:
        load, b = heapq.heappop(heap)
        bins[b].append(i)
        heapq.heappush(heap, (load + costs[i], b))
    return bins


def makespan_lower_bound(graph: TaskGraph, workers: int) -> float:
    """max(critical path, total work / workers) — classic Graham bound."""
    cp, _ = graph.critical_path()
    return max(cp, graph.total_cost() / max(workers, 1))


# --------------------------------------------------- discrete-event simulator
@dataclass
class SimResult:
    makespan: float
    per_rank_busy: Dict[int, float]
    per_rank_idle: Dict[int, float]
    messages: int
    message_bytes: float
    ranks: int = 1
    threads: int = 1
    timeline: Optional[List[Tuple[float, float, int, int]]] = None  # (t0,t1,rank,tid)

    @property
    def efficiency(self) -> float:
        busy = sum(self.per_rank_busy.values())
        denom = self.makespan * max(self.ranks, 1) * max(self.threads, 1)
        return busy / denom if denom > 0 else 0.0


class AsyncExecutorSim:
    """Discrete-event simulation of SWIFT's async runtime.

    Ranks own tasks (``task.rank``); each rank has ``threads`` workers. A
    ``send``/``recv`` task pair models one MPI_Isend/Irecv: the send occupies
    its rank for ``send_overhead`` seconds (injection), then the matching recv
    completes ``latency + bytes/bandwidth`` later *without occupying a core* —
    this is the "fully asynchronous" part. Compute tasks become runnable when
    all dependencies are done; each worker greedily picks the costliest
    runnable local task (work-stealing within a rank is free on shared
    memory).

    For the bulk-synchronous baseline (``synchronous=True``) every task kind
    forms a barrier across all ranks, and communication happens in a separate
    phase where workers sit idle — the branch-and-bound model the paper
    argues against.
    """

    def __init__(self, graph: TaskGraph, *, ranks: int, threads: int = 1,
                 latency: float = 1e-6, bandwidth: float = 5e9,
                 send_overhead: float = 5e-7, synchronous: bool = False,
                 record_timeline: bool = False, active_only: bool = False):
        if active_only:
            # hierarchical time-stepping: simulate only the tasks that are
            # due at the current bin level (inactive deps pre-satisfied)
            graph = graph.active_subgraph()
        self.g = graph
        self.ranks = ranks
        self.threads = threads
        self.latency = latency
        self.bandwidth = bandwidth
        self.send_overhead = send_overhead
        self.synchronous = synchronous
        self.record_timeline = record_timeline

    def run(self) -> SimResult:
        g = self.g
        indeg = {tid: len(g.dependencies(tid)) for tid in g.tasks}
        ready: List[List[Tuple[float, int]]] = [[] for _ in range(self.ranks)]
        for tid, d in indeg.items():
            if d == 0:
                t = g.tasks[tid]
                heapq.heappush(ready[t.rank], (-t.cost, tid))

        # event heap: (time, seq, kind, payload)
        events: List[Tuple[float, int, str, tuple]] = []
        seq = 0
        free_workers = {r: self.threads for r in range(self.ranks)}
        busy = collections.defaultdict(float)
        done_time = 0.0
        messages = 0
        message_bytes = 0.0
        timeline: List[Tuple[float, float, int, int]] = []
        now = 0.0
        ndone = 0

        def message_size(task) -> float:
            # payload convention for send/recv: (peer_rank, nbytes)
            if len(task.payload) >= 2:
                return float(task.payload[1])
            return 4096.0

        def try_dispatch(rank: int):
            nonlocal seq, messages, message_bytes
            while free_workers[rank] > 0 and ready[rank]:
                if self.synchronous:
                    # BSP superstep: only tasks at the current barrier
                    # level may run (lock-step level-by-level execution —
                    # the branch-and-bound baseline of the paper)
                    kept = [(c, t) for (c, t) in ready[rank]
                            if depth[t] == barrier_level]
                    if not kept:
                        return
                    heapq.heapify(kept)
                    c, tid = heapq.heappop(kept)
                    rest = [(cc, tt) for (cc, tt) in ready[rank]
                            if tt != tid]
                    heapq.heapify(rest)
                    ready[rank][:] = rest
                else:
                    c, tid = heapq.heappop(ready[rank])
                task = g.tasks[tid]
                if task.kind == "send":
                    # occupies the core only for the injection overhead
                    free_workers[rank] -= 1
                    seq += 1
                    heapq.heappush(events, (now + self.send_overhead, seq,
                                            "worker_free", (rank,)))
                    nbytes = message_size(task)
                    messages += 1
                    message_bytes += nbytes
                    wire = self.latency + nbytes / self.bandwidth
                    seq += 1
                    heapq.heappush(events, (now + self.send_overhead + wire,
                                            seq, "task_done", (tid,)))
                    busy[rank] += self.send_overhead
                elif task.kind == "recv":
                    # recv is passive: completes instantly once its
                    # dependency (the matching send) is done.
                    seq += 1
                    heapq.heappush(events, (now, seq, "task_done", (tid,)))
                else:
                    free_workers[rank] -= 1
                    seq += 1
                    heapq.heappush(events, (now + task.cost, seq,
                                            "compute_done", (tid, rank, now)))
                    busy[rank] += task.cost

        depth: Dict[int, int] = {}
        remaining_by_level: Optional[collections.Counter] = None
        barrier_level = 0
        if self.synchronous:
            # level barriers: every task waits for the whole previous
            # topological level across all ranks — the bulk-synchronous
            # compute/communicate phase structure the paper argues against
            for tid in g.toposort():
                deps = g.dependencies(tid)
                depth[tid] = 1 + max((depth[d] for d in deps), default=-1)
            remaining_by_level = collections.Counter(depth.values())

        for r in range(self.ranks):
            try_dispatch(r)

        while events:
            now, _, ekind, payload = heapq.heappop(events)
            if ekind == "worker_free":
                (rank,) = payload
                free_workers[rank] += 1
                try_dispatch(rank)
                continue
            if ekind == "compute_done":
                tid, rank, t0 = payload
                free_workers[rank] += 1
                if self.record_timeline:
                    timeline.append((t0, now, rank, tid))
                seq += 1
                heapq.heappush(events, (now, seq, "task_done", (tid,)))
                try_dispatch(rank)
                continue
            # task_done: release dependents
            (tid,) = payload
            ndone += 1
            done_time = max(done_time, now)
            task = g.tasks[tid]
            if self.synchronous and remaining_by_level is not None:
                remaining_by_level[depth[tid]] -= 1
                advanced = False
                while remaining_by_level.get(barrier_level, 0) == 0 \
                        and barrier_level <= max(remaining_by_level):
                    barrier_level += 1
                    advanced = True
                if advanced:
                    for r in range(self.ranks):
                        try_dispatch(r)
            for dep in self.g.dependents(tid):
                indeg[dep] -= 1
                if indeg[dep] == 0:
                    t = self.g.tasks[dep]
                    heapq.heappush(ready[t.rank], (-t.cost, dep))
                    try_dispatch(t.rank)

        if ndone != len(g.tasks):
            raise RuntimeError(
                f"simulation deadlock: {ndone}/{len(g.tasks)} tasks done")
        idle = {r: done_time * self.threads - busy[r]
                for r in range(self.ranks)}
        return SimResult(makespan=done_time,
                         per_rank_busy=dict(busy), per_rank_idle=idle,
                         messages=messages, message_bytes=message_bytes,
                         ranks=self.ranks, threads=self.threads,
                         timeline=timeline if self.record_timeline else None)
