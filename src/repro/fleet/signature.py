"""Compiled-program signatures: which requests may share one batch.

SWIFT keeps the machine saturated by grouping *tasks* of the same kind into
one batched dispatch; the fleet layer does the same one level up, grouping
*simulations* whose compiled programs are interchangeable. Two requests can
ride the same vmapped/stacked entry point exactly when every property that
is baked into the compiled program agrees:

* the **quadrant** (integrator × backend) and its engine policy — transport,
  residency, rank count, halo flavour — select which programs exist at all;
* the **physics config** (:class:`~repro.sph.engine.SPHConfig`) is closed
  over by every jitted phase program (kernel choice, viscosity, γ, CFL,
  Pallas lowering), so differing values mean differing executables;
* the **scenario shape** — particle count, grid geometry, pair-list length —
  fixes every array shape. Scenario parameters that only change *values*
  (blast energy, shear velocity, RNG seed, …) deliberately do NOT enter the
  signature: a Sedov request with ``e0=1.0`` and one with ``e0=0.7`` are the
  same program over different data, which is precisely what batching wants.

The split between shape-affecting and value-only scenario parameters is
declared per scenario in :data:`SHAPE_PARAM_KEYS`; unknown scenarios fall
back to treating *every* parameter as shape-affecting (correct, never
batches wrongly — merely conservative).

``signature(spec)`` returns a hashable tuple; ``signature_key(spec)`` a
short stable hex digest for logs, program-cache keys and trace attrs.
"""

from __future__ import annotations

import hashlib
from typing import Any, Mapping, Tuple

# scenario-parameter names that change array shapes or compiled structure
# (anything not listed is value-only and batches freely). ``box`` changes
# the grid geometry; ``n_side``/``n`` the particle count; ``n_target`` the
# smoothing length and hence cell size via choose_grid.
SHAPE_PARAM_KEYS = {
    "uniform": ("n_side", "box", "n_target"),
    "sedov": ("n_side", "box", "n_target"),
    "kelvin_helmholtz": ("n_side", "box", "n_target"),
    "clustered": ("n", "box", "n_halos", "clustered_fraction", "n_target"),
}

# spec fields that never reach a compiled program: observability wiring is
# managed by the fleet itself and ``scenario_params`` is split separately.
_NON_PROGRAM_FIELDS = ("observe", "scenario_params")


def canonical(value: Any) -> Any:
    """Recursively convert ``value`` to a canonical hashable form.

    Mappings become sorted ``(key, value)`` tuples, sequences become
    tuples, numpy scalars collapse to Python scalars, arrays to
    (shape, dtype, bytes). Insertion order therefore never leaks into
    hashes or signatures.
    """
    if isinstance(value, Mapping):
        return tuple(sorted((str(k), canonical(v)) for k, v in value.items()))
    if isinstance(value, (list, tuple)):
        return tuple(canonical(v) for v in value)
    if isinstance(value, (set, frozenset)):
        return tuple(sorted(map(canonical, value), key=repr))
    if hasattr(value, "shape") and hasattr(value, "tobytes"):   # ndarray
        import numpy as np
        a = np.asarray(value)
        return ("ndarray", a.shape, str(a.dtype), a.tobytes())
    if hasattr(value, "item") and not isinstance(value, (int, float, str,
                                                         bool, bytes)):
        try:
            return value.item()                                 # np scalar
        except Exception:
            pass
    return value


def split_scenario_params(scenario: str, params: Mapping[str, Any]
                          ) -> Tuple[tuple, tuple]:
    """(shape_params, value_params) as canonical sorted tuples."""
    keys = SHAPE_PARAM_KEYS.get(scenario)
    items = sorted((str(k), canonical(v)) for k, v in dict(params).items())
    if keys is None:                 # unknown scenario: all shape-affecting
        return tuple(items), ()
    shape = tuple(kv for kv in items if kv[0] in keys)
    value = tuple(kv for kv in items if kv[0] not in keys)
    return shape, value


def signature(spec) -> tuple:
    """The compiled-program signature of a :class:`SimulationSpec`.

    Hashable, order-independent, equal for any two specs whose compiled
    entry points are interchangeable (same quadrant, physics, engine
    policy and scenario *shape*; value-only scenario params excluded).
    """
    import dataclasses
    fields = {}
    for f in dataclasses.fields(spec):
        if f.name in _NON_PROGRAM_FIELDS:
            continue
        fields[f.name] = canonical(getattr(spec, f.name))
    shape_params, _values = split_scenario_params(
        spec.scenario, spec.scenario_params)
    return (("quadrant", fields.pop("integrator"), fields.pop("backend")),
            ("scenario", fields.pop("scenario"), shape_params),
            tuple(sorted(fields.items())))


def signature_key(spec) -> str:
    """Short stable digest of :func:`signature` for logs and cache keys."""
    return hashlib.sha1(repr(signature(spec)).encode()).hexdigest()[:12]
