"""Request queue: admission control, inflight tracking, deadlines.

The fleet front door. A :class:`FleetRequest` is one user's simulation —
a frozen :class:`~repro.sph.api.SimulationSpec` plus how far to run it and
by when. The :class:`RequestQueue` is deliberately SWIFT-shaped: it never
blocks on any single request; it only ever answers "what work is ready
*right now*", grouped by compiled-program signature so the scheduler
(:mod:`repro.fleet.batcher`) can form shape-compatible batches, exactly the
way SWIFT's scheduler hands each core the next *ready* task rather than
walking a fixed order.

Admission is bounded (``max_inflight``): a full fleet rejects at the door
with :class:`AdmissionError` rather than queueing unboundedly — the caller
can retry, shed, or route elsewhere. Deadlines are wall-clock seconds from
submission; :meth:`RequestQueue.expire` sweeps overdue queued requests into
``EXPIRED`` (their callbacks fire with the error) so a stale burst cannot
occupy a batch slot that a live request needs.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Optional

from ..sph.api import SimulationSpec


class RequestState(Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    EXPIRED = "expired"


class AdmissionError(RuntimeError):
    """The fleet is at ``max_inflight``; the request was not admitted."""


@dataclass
class FleetResult:
    """What a finished request hands back.

    ``particles`` is the final state in the user's flat per-particle order
    (the ``unbin`` layout: pos/vel/mass/u/h arrays of shape (n, …)), the
    representation that is bitwise-comparable across execution strategies
    — batched, sequential, local, whatever — because it is independent of
    any engine's internal cell padding. ``energy``/``momentum`` are the
    standard diagnostics computed on host from exactly those arrays.
    """
    particles: Dict[str, Any]
    energy: float
    momentum: Any
    t: float
    steps: int
    wall: float                       # seconds inside the runner
    batched: bool                     # served by a batched entry point?
    batch_size: int = 1               # real members of the serving batch
    bucket: int = 1                   # padded batch bucket it rode in


@dataclass
class FleetRequest:
    """One admitted simulation request."""
    request_id: str
    spec: SimulationSpec
    n_steps: int
    deadline: Optional[float] = None        # seconds from submission
    callback: Optional[Callable[["FleetRequest"], None]] = None
    state: RequestState = RequestState.QUEUED
    submitted: float = field(default_factory=time.perf_counter)
    started: Optional[float] = None
    finished: Optional[float] = None
    result: Optional[FleetResult] = None
    error: Optional[BaseException] = None
    signature_key: str = ""
    row: int = 0                            # fleet trace row (timeline tid)

    @property
    def overdue(self) -> bool:
        return (self.deadline is not None
                and time.perf_counter() - self.submitted > self.deadline)

    @property
    def latency(self) -> Optional[float]:
        if self.finished is None:
            return None
        return self.finished - self.submitted

    def _finish(self, state: RequestState,
                result: Optional[FleetResult] = None,
                error: Optional[BaseException] = None) -> None:
        self.state = state
        self.result = result
        self.error = error
        self.finished = time.perf_counter()
        if self.callback is not None:
            self.callback(self)


class RequestQueue:
    """FIFO of queued requests with bounded admission and deadline sweeps."""

    def __init__(self, *, max_inflight: int = 1024):
        self.max_inflight = int(max_inflight)
        self._queued: List[FleetRequest] = []
        self._all: Dict[str, FleetRequest] = {}
        self._ids = itertools.count()
        self._rows = itertools.count()

    # ---------------------------------------------------------- admission
    def submit(self, spec: SimulationSpec, *, n_steps: int = 1,
               deadline: Optional[float] = None,
               request_id: Optional[str] = None,
               callback: Optional[Callable[[FleetRequest], None]] = None
               ) -> FleetRequest:
        # sweep first: a stale burst must not hold admission slots, and
        # its EXPIRED callbacks must fire even if nobody ever claims —
        # every front-door entry (submit/poll/claim) runs the sweep
        self.expire()
        if self.inflight >= self.max_inflight:
            raise AdmissionError(
                f"fleet at max_inflight={self.max_inflight}; request "
                f"rejected at admission")
        rid = request_id if request_id is not None \
            else f"req-{next(self._ids):04d}"
        if rid in self._all:
            raise ValueError(f"duplicate request_id {rid!r}")
        req = FleetRequest(request_id=rid, spec=spec, n_steps=int(n_steps),
                           deadline=deadline, callback=callback,
                           signature_key=spec.signature_key(),
                           row=next(self._rows))
        self._queued.append(req)
        self._all[rid] = req
        return req

    # ----------------------------------------------------------- tracking
    @property
    def inflight(self) -> int:
        return sum(1 for r in self._all.values()
                   if r.state in (RequestState.QUEUED, RequestState.RUNNING))

    def get(self, request_id: str) -> FleetRequest:
        return self._all[request_id]

    def expire(self) -> List[FleetRequest]:
        """Sweep overdue queued requests into EXPIRED; returns them."""
        dead = [r for r in self._queued if r.overdue]
        for r in dead:
            self._queued.remove(r)
            r._finish(RequestState.EXPIRED,
                      error=TimeoutError(
                          f"{r.request_id}: deadline {r.deadline}s passed "
                          f"before scheduling"))
        return dead

    def take_ready(self) -> List[FleetRequest]:
        """Claim every queued request (deadline sweep included), marking
        them RUNNING. Grouping into batches is the batcher's job."""
        self.expire()
        ready = self._queued
        self._queued = []
        now = time.perf_counter()
        for r in ready:
            r.state = RequestState.RUNNING
            r.started = now
        return ready

    def requeue(self, requests: List[FleetRequest]) -> None:
        """Return claimed requests to the head of the queue (a batch the
        runner could not place this round, e.g. a shape straggler)."""
        for r in requests:
            r.state = RequestState.QUEUED
            r.started = None
        self._queued[:0] = requests

    def complete(self, req: FleetRequest, result: FleetResult) -> None:
        req._finish(RequestState.DONE, result=result)

    def fail(self, req: FleetRequest, error: BaseException) -> None:
        req._finish(RequestState.FAILED, error=error)

    # ------------------------------------------------------------ reading
    def poll(self) -> Dict[str, int]:
        """Deadline sweep + queue stats: the non-claiming status check.

        Before this existed, sweeps ran only inside :meth:`take_ready` —
        a request with a passed deadline sat QUEUED forever (callback
        never fired) unless some *other* submission triggered a claim."""
        self.expire()
        return self.stats()

    def by_state(self, state: RequestState) -> List[FleetRequest]:
        return [r for r in self._all.values() if r.state is state]

    def stats(self) -> Dict[str, int]:
        out = {s.value: 0 for s in RequestState}
        for r in self._all.values():
            out[r.state.value] += 1
        out["total"] = len(self._all)
        return out
