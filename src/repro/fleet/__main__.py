"""``python -m repro.fleet`` — the serving entry point.

Drives a fleet of simulation requests through the batched runner:

    python -m repro.fleet --scenario sedov --requests 64
    python -m repro.fleet --scenario mixed --requests 8 \
        --check-parity --assert-compiles --trace-out fleet_trace.json

Requests are heterogeneous in *values* (seed, blast energy, shear speed —
the spec fields a program signature deliberately ignores) and homogeneous
in *shape* per scenario, so a mixed fleet exercises exactly the grouping
the subsystem exists for: one compiled program per (signature, batch
bucket), every request bitwise identical to running it alone.

``--waves`` splits the submissions into bursts with SWIFT-ishly wobbling
sizes so the no-shrink bucket policy is exercised; ``--check-parity``
re-runs every request on the single-simulation path and compares bitwise;
``--assert-compiles`` fails the process if any entry point compiled more
than once. Exit status is nonzero on any failed request or failed check —
this is the CI smoke contract.
"""

from __future__ import annotations

import argparse
import json
import sys


def _specs(args):
    from ..sph.api import SimulationSpec
    scenarios = {
        "sedov": lambda i: SimulationSpec(
            scenario="sedov",
            scenario_params={"n_side": args.n_side, "seed": i,
                             "e0": 1.0 + 0.1 * (i % 4)}),
        "kelvin_helmholtz": lambda i: SimulationSpec(
            scenario="kelvin_helmholtz",
            scenario_params={"n_side": args.n_side, "seed": i,
                             "v_shear": 0.4 + 0.05 * (i % 3)}),
    }
    if args.scenario == "mixed":
        names = sorted(scenarios)
        return [scenarios[names[i % len(names)]](i)
                for i in range(args.requests)]
    return [scenarios[args.scenario](i) for i in range(args.requests)]


def _waves(n, nwaves):
    """Split n submissions into nwaves bursts with wobbling sizes."""
    if nwaves <= 1:
        return [n]
    wobble = [3, 7, 5, 8]
    sizes, left, i = [], n, 0
    while left > 0 and len(sizes) < nwaves - 1:
        take = min(wobble[i % len(wobble)], left)
        sizes.append(take)
        left -= take
        i += 1
    if left:
        sizes.append(left)
    return sizes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description="Serve a fleet of SPH simulation requests as batched, "
                    "signature-grouped mesh programs.")
    ap.add_argument("--scenario", default="sedov",
                    choices=["sedov", "kelvin_helmholtz", "mixed"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--steps", type=int, default=4,
                    help="steps each request runs")
    ap.add_argument("--n-side", type=int, default=5,
                    help="IC lattice side (shape param: sets the signature)")
    ap.add_argument("--batch-max", type=int, default=64)
    ap.add_argument("--waves", type=int, default=1,
                    help="submit in this many wobbling-size bursts")
    ap.add_argument("--fleet-devices", type=int, default=None,
                    help="devices to shard the fleet axis over (default: "
                         "all local devices if a power of two, else 1)")
    ap.add_argument("--check-parity", action="store_true",
                    help="compare every request against the single-"
                         "simulation path: bitwise on the vmap path "
                         "(--fleet-devices 1), ulp tolerance when the "
                         "fleet axis is sharded (per-device program "
                         "partitioning reassociates reductions)")
    ap.add_argument("--assert-compiles", action="store_true",
                    help="fail if any entry point compiled more than once")
    ap.add_argument("--trace-out", default=None,
                    help="write the multi-request Chrome trace here")
    args = ap.parse_args(argv)

    from .queue import RequestState
    from .runner import FleetRunner, sequential_reference

    runner = FleetRunner(max_batch=args.batch_max,
                         fleet_devices=args.fleet_devices,
                         observe=args.trace_out is not None)
    specs = _specs(args)
    served = []
    it = iter(specs)
    for size in _waves(len(specs), args.waves):
        for _ in range(size):
            runner.submit(next(it), n_steps=args.steps)
        served.extend(runner.drain())

    failed = [r for r in served if r.state is not RequestState.DONE]
    for r in failed:
        print(f"FAILED {r.request_id}: {r.error!r}", file=sys.stderr)

    parity = None
    if args.check_parity:
        import numpy as np
        exact = runner.fleet_devices == 1
        parity = {"mode": "bitwise" if exact else "ulp",
                  "checked": 0, "mismatches": []}
        for r in served:
            if r.result is None or not r.result.particles:
                continue
            ref = sequential_reference(r.spec, r.n_steps)
            parity["checked"] += 1
            for k, a in r.result.particles.items():
                a, b = np.asarray(a), np.asarray(ref.particles[k])
                ok = np.array_equal(a, b) if exact \
                    else np.allclose(a, b, rtol=1e-4, atol=1e-5)
                if not ok:
                    parity["mismatches"].append(
                        {"request": r.request_id, "field": k,
                         "max_abs": float(np.max(np.abs(a - b)))})

    out = {
        "requests": len(specs),
        "scenario": args.scenario,
        "steps": args.steps,
        "stats": runner.stats(),
        "compile_counts": runner.compile_counts(),
        "latencies": {r.request_id: r.latency for r in served},
        "parity": parity,
    }
    if args.trace_out:
        import os
        parent = os.path.dirname(args.trace_out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        doc = runner.export_trace(args.trace_out)
        out["trace"] = {"path": args.trace_out,
                        "events": len(doc["traceEvents"])}
    json.dump(out, sys.stdout, indent=2, default=str)
    print()

    rc = 0
    if failed:
        rc = 1
    if parity is not None and (parity["mismatches"] or not parity["checked"]):
        print(f"PARITY FAILED: {parity}", file=sys.stderr)
        rc = 1
    if args.assert_compiles:
        try:
            runner.assert_compile_discipline()
        except AssertionError as e:
            print(str(e), file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
