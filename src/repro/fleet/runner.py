"""Fleet runner: many simulations as one batched, signature-grouped program.

SWIFT's scheduling insight applied one level up: the unit of work is a whole
*simulation request*, and the hardware stays saturated by always dispatching
the largest ready batch of shape-compatible requests as ONE compiled
program. The pieces:

* **Batched entry points.** Requests in the ``("global", "local")``
  quadrant are served by a single jitted program per (signature,
  shape, batch-bucket): the engine's ``step`` vmapped over a new leading
  **fleet axis**, and — when the process has a device mesh — wrapped in
  ``shard_map`` over that axis, so a batch of B independent simulations
  shards B/ndev-per-device across the mesh with zero cross-device traffic.
  Per-request CFL time-steps ride along as a ``(B,)`` vector. Entry points
  live in a :class:`~repro.distributed.transport.ProgramCache` and their
  compile counts are ledgered by :class:`CompileProbe` — at most one XLA
  compile per (signature, shape, bucket), no matter how arrival sizes
  wobble (the batcher's no-shrink buckets).
* **Lockstep semantics = sequential semantics.** Batched execution mirrors
  the single-run engine exactly: same eager per-member init, same host
  re-binning cadence (``rebin_every``), same CFL policy — so each lane of
  a vmapped batch (``fleet_devices=1``) is **bitwise identical** to the
  same spec run alone (``tests/test_fleet.py``). Sharding the fleet axis
  across devices keeps the math but not the bits: per-device SPMD
  partitioning reassociates the pair-sum reductions, so the sharded path's
  contract is ulp-level (``allclose``), asserted with a tight tolerance.
  A lane whose cell capacity diverges mid-run (rare re-bin overflow) falls
  off the batch and finishes sequentially; correctness is never traded for
  batching.
* **Sequential fallback.** Quadrants whose host control flow is
  data-dependent per request (time-bin ladders, distributed backends) are
  served one-by-one but still signature-grouped: the engine layer's shared
  jit programs (``engine.shared_step_program`` /
  ``timebins.shared_timebin_programs``) make N same-signature requests
  cost one compile, not N.
* **Pooled result transfers.** Finished lanes are pulled through a
  :class:`TransferBufferPool` (the SHARK-Engine idiom): bounded, reused
  host buffers per (shape, dtype) instead of a fresh allocation per
  request result.
* **Per-request tracing.** With ``observe=True`` every dispatch is
  recorded on each member request's own timeline row with a
  ``request_id`` attr, so one fleet trace shows every user's run on the
  shared Perfetto timeline (``export_trace``).
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..distributed.transport import CompileProbe, ProgramCache
from ..observability.tracer import NULL_TRACER, Tracer
from ..sph.api import SimulationSpec, build_simulation, make_ic
from .batcher import Batch, SignatureBatcher
from .queue import FleetRequest, FleetResult, RequestQueue, RequestState


# ------------------------------------------------------------- result pool
class TransferBufferPool:
    """Reusable host buffers for device→host result pulls.

    ``take(src)`` copies a device (or host) array into a pooled numpy
    buffer of matching (shape, dtype), allocating only on pool miss;
    ``give(buf)`` returns a buffer to its bucket. Serving keeps result
    memory bounded by the number of *inflight* results, not the number of
    requests ever served.
    """

    def __init__(self):
        self._free: Dict[Tuple[tuple, str], List[np.ndarray]] = {}
        self.hits = 0
        self.misses = 0

    def take(self, src) -> np.ndarray:
        a = np.asarray(src)
        key = (a.shape, str(a.dtype))
        bucket = self._free.get(key)
        if bucket:
            buf = bucket.pop()
            self.hits += 1
        else:
            buf = np.empty(a.shape, a.dtype)
            self.misses += 1
        np.copyto(buf, a)
        return buf

    def give(self, buf: np.ndarray) -> None:
        self._free.setdefault((buf.shape, str(buf.dtype)), []).append(buf)

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "resident": sum(len(v) for v in self._free.values())}


# ---------------------------------------------------------- batched members
@dataclass(eq=False)
class _Member:
    """One request's host-side engine bookkeeping inside a batch."""
    req: FleetRequest
    box: float
    n: int
    gspec: Any
    cells: Any
    pairs: Any
    perm: np.ndarray
    state: Any                      # SPHState (host-side numpy leaves ok)
    steps_done: int = 0
    steps_since_rebin: int = 0
    done: bool = False

    @property
    def shape_key(self) -> tuple:
        return (self.gspec.ncells_side, self.cells.mass.shape[1],
                float(self.box), int(np.asarray(self.pairs.ci).shape[0]))


def _build_member(req: FleetRequest) -> _Member:
    """Host-side admission of one request: IC → grid → cells → initial
    state, exactly the single-run engine's construction path (eager
    ``init_state`` so lane 0 of a batch is bitwise the single run)."""
    from ..sph.cellgrid import bin_particles, build_pair_list, choose_grid
    from ..sph.engine import init_state
    spec = req.spec
    ic = make_ic(spec.scenario, **dict(spec.scenario_params))
    box = float(ic["box"])
    n = len(ic["pos"])
    gspec = choose_grid(box, float(np.max(ic["h"])), n,
                        capacity_margin=spec.capacity_margin)
    cells, perm = bin_particles(gspec, np.asarray(ic["pos"]),
                                np.asarray(ic["vel"]), np.asarray(ic["mass"]),
                                np.asarray(ic["u"]), np.asarray(ic["h"]))
    if cells.mass.shape[1] != gspec.capacity:
        object.__setattr__(gspec, "capacity", cells.mass.shape[1])
    pairs = build_pair_list(gspec)
    state = init_state(cells, pairs, spec.physics)
    return _Member(req=req, box=box, n=n, gspec=gspec, cells=cells,
                   pairs=pairs, perm=perm, state=state)


def _rebin_member(m: _Member) -> None:
    """The engine's host re-bin: unbin → re-bin → fresh eager init."""
    from ..sph.cellgrid import bin_particles, build_pair_list, unbin
    from ..sph.engine import init_state
    flat = unbin(m.state.cells, m.perm, m.n)
    m.cells, m.perm = bin_particles(m.gspec, flat["pos"], flat["vel"],
                                    flat["mass"], flat["u"], flat["h"])
    if m.cells.mass.shape[1] != m.gspec.capacity:
        object.__setattr__(m.gspec, "capacity", m.cells.mass.shape[1])
    m.pairs = build_pair_list(m.gspec)
    fresh = init_state(m.cells, m.pairs, m.req.spec.physics)
    m.state = fresh._replace(time=m.state.time)
    m.steps_since_rebin = 0


def _flat_result(state_cells, perm: np.ndarray, n: int, time: float,
                 steps: int, wall: float, *, batched: bool,
                 batch_size: int = 1, bucket: int = 1,
                 pool: Optional[TransferBufferPool] = None) -> FleetResult:
    """Final state → user-facing flat particle arrays + host diagnostics."""
    from ..sph.cellgrid import unbin
    flat = unbin(state_cells, perm, n)
    if pool is not None:
        flat = {k: (pool.take(v) if isinstance(v, np.ndarray) else v)
                for k, v in flat.items()}
    m = flat["mass"]
    v = flat["vel"]
    ke = 0.5 * float(np.sum(m * np.sum(v * v, axis=-1)))
    ie = float(np.sum(m * flat["u"]))
    mom = np.sum(m[:, None] * v, axis=0)
    return FleetResult(particles=flat, energy=ke + ie, momentum=mom,
                       t=float(time), steps=steps, wall=wall,
                       batched=batched, batch_size=batch_size, bucket=bucket)


# ------------------------------------------------------------------ runner
class FleetRunner:
    """Request-driven serving loop over signature-grouped batches."""

    def __init__(self, *, max_batch: int = 64, max_inflight: int = 1024,
                 fleet_devices: Optional[int] = None, observe: bool = False,
                 flight_dir: Optional[str] = None):
        import jax
        if fleet_devices is None:
            ndev = len(jax.devices())
            # the fleet axis must divide every power-of-two bucket
            fleet_devices = ndev if ndev & (ndev - 1) == 0 else 1
        self.fleet_devices = int(fleet_devices)
        self.queue = RequestQueue(max_inflight=max_inflight)
        self.batcher = SignatureBatcher(max_batch=max_batch,
                                        min_bucket=self.fleet_devices)
        self.probe = CompileProbe()
        self.programs = ProgramCache(self.probe)
        self.pool = TransferBufferPool()
        self.tracer: Tracer = Tracer() if observe else NULL_TRACER
        self.row_names: Dict[int, str] = {}
        self.batches_run = 0
        self.sequential_runs = 0
        self.particle_steps = 0         # Σ particles × steps actually served
        # per-request terminal-status counter: every request the runner
        # retires lands here exactly once (done/failed/expired) — the
        # metric that makes dead lanes visible, not just absent
        self.terminal_status: Dict[str, int] = {}
        # where expired-sweep post-mortem bundles go (None = no dumps)
        self.flight_dir = flight_dir
        self.flight_dumps: List[str] = []

    # ----------------------------------------------------------- frontend
    def submit(self, spec: SimulationSpec, *, n_steps: int = 1,
               deadline: Optional[float] = None,
               request_id: Optional[str] = None,
               callback: Optional[Callable[[FleetRequest], None]] = None
               ) -> FleetRequest:
        # visible sweep before admission: expired requests get their
        # terminal count / timeline span / flight bundle here, not only
        # when a later drain() claims (queue.submit also sweeps, but this
        # runs first so the runner's accounting sees every expiry)
        self._sweep_expired(self.queue.expire())
        req = self.queue.submit(spec, n_steps=n_steps, deadline=deadline,
                                request_id=request_id, callback=callback)
        self.row_names[req.row] = req.request_id
        return req

    def poll(self) -> Dict[str, Any]:
        """Deadline sweep + fleet stats without claiming any work."""
        self._sweep_expired(self.queue.expire())
        return self.stats()

    def drain(self) -> List[FleetRequest]:
        """Serve until the queue is empty; returns the finished requests.

        The deadline sweep runs *visibly*: expired requests get a terminal
        status count, a zero-length ``expired`` span on their own timeline
        row, and (when ``flight_dir`` is set) a post-mortem bundle — a
        dead lane must show up in the metrics, not just go missing."""
        served: List[FleetRequest] = []
        while True:
            self._sweep_expired(self.queue.expire())
            ready = self.queue.take_ready()
            if not ready:
                break
            for batch in self.batcher.form(ready):
                self._run_batch(batch)
                served.extend(batch.requests)
                for r in batch.requests:
                    self._count_terminal(r)
        return served

    def _count_terminal(self, req: FleetRequest) -> None:
        key = req.state.value
        self.terminal_status[key] = self.terminal_status.get(key, 0) + 1

    def _sweep_expired(self, expired: List[FleetRequest]) -> None:
        if not expired:
            return
        tr = self.tracer
        now = tr.now() if tr.enabled else 0.0
        for r in expired:
            self._count_terminal(r)
            if tr.enabled:
                tr.record("expired", r.row, now, now,
                          request_id=r.request_id, deadline=r.deadline,
                          error=str(r.error))
        if self.flight_dir is not None:
            from ..observability.flight import FlightRecorder
            path = FlightRecorder().dump(
                self.flight_dir,
                reason=f"expired-{expired[0].request_id}",
                cycle=self.batches_run,
                spans=self.tracer.spans[-256:],
                row_names=self.row_names,
                extra={"expired": [r.request_id for r in expired]})
            self.flight_dumps.append(path)

    # ---------------------------------------------------------- dispatch
    def _run_batch(self, batch: Batch) -> None:
        spec = batch.requests[0].spec
        quadrant = (spec.integrator, spec.backend)
        try:
            if quadrant == ("global", "local") and not spec.physics.use_pallas:
                self._run_batched_global(batch)
            else:
                self._run_sequential(batch)
        except Exception as e:
            for r in batch.requests:
                if r.state is RequestState.RUNNING:
                    self.queue.fail(r, e)
            raise
        finally:
            self.batches_run += 1

    # ----------------------------------------------- batched global×local
    def _ndev_for(self, bucket: int) -> int:
        """Devices the fleet axis shards over for this bucket (1 = vmap)."""
        if bucket % self.fleet_devices == 0 and bucket >= self.fleet_devices:
            return self.fleet_devices
        return 1

    def _shard_fleet(self, tree, ndev: int):
        """Pin the stacked state to the fleet-axis sharding the entry
        points expect — from the *first* call, so a state that stays
        device-resident between steps (rebin_every > 1) presents one input
        sharding to the jit cache, not unsharded-then-sharded (which would
        compile every program twice)."""
        if ndev <= 1:
            return tree
        import jax
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        from ..distributed.mesh_utils import ranks_mesh
        mesh = ranks_mesh(ndev, axis="fleet")
        return jax.device_put(tree, NamedSharding(mesh, P("fleet")))

    def _entry_points(self, sig_key: str, shape_key: tuple, bucket: int,
                      spec: SimulationSpec):
        """(step, cfl) programs for one (signature, shape, bucket) cell."""
        import jax
        import jax.numpy as jnp
        from ..sph.engine import cfl_timestep_particles, step
        ndev = self._ndev_for(bucket)
        box = float(shape_key[2])
        cfg = spec.physics

        def build_step():
            f = jax.vmap(functools.partial(step, box=box, cfg=cfg),
                         in_axes=(0, None, 0))
            if ndev > 1:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P
                from ..distributed.mesh_utils import ranks_mesh
                mesh = ranks_mesh(ndev, axis="fleet")
                f = shard_map(f, mesh=mesh,
                              in_specs=(P("fleet"), P(), P("fleet")),
                              out_specs=P("fleet"))
            return jax.jit(f)

        def build_cfl():
            def one(state):
                return jnp.min(cfl_timestep_particles(state, cfg))
            f = jax.vmap(one)
            if ndev > 1:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P
                from ..distributed.mesh_utils import ranks_mesh
                mesh = ranks_mesh(ndev, axis="fleet")
                f = shard_map(f, mesh=mesh, in_specs=(P("fleet"),),
                              out_specs=P("fleet"))
            return jax.jit(f)

        step_fn = self.programs.get(
            ("fleet_step", sig_key, shape_key, bucket, ndev), build_step)
        cfl_fn = self.programs.get(
            ("fleet_cfl", sig_key, shape_key, bucket, ndev), build_cfl)
        return step_fn, cfl_fn

    def _run_batched_global(self, batch: Batch) -> None:
        """Serve a ("global", "local") batch as one vmapped/sharded program.

        Splits by concrete shape key (members whose grid/capacity differ
        cannot stack); each shape group gets its own bucket from the
        no-shrink policy and its own cached entry points.
        """
        members = [_build_member(r) for r in batch.requests]
        groups: Dict[tuple, List[_Member]] = {}
        for m in members:
            groups.setdefault(m.shape_key, []).append(m)
        for shape_key, group in groups.items():
            if len(groups) == 1:
                bucket = batch.bucket            # the batcher's sizing holds
            else:
                bucket = self.batcher.policy.fit(
                    (batch.signature_key, shape_key), len(group))
            self._run_shape_group(batch.signature_key, shape_key, bucket,
                                  group)

    def _stack(self, group: List[_Member], bucket: int):
        """Members' states → one stacked pytree with a leading fleet axis
        (padding lanes replicate member 0; their outputs are discarded)."""
        import jax
        import jax.numpy as jnp
        idx = list(range(len(group))) + [0] * (bucket - len(group))
        return jax.tree_util.tree_map(
            lambda *leaves: jnp.stack([jnp.asarray(leaves[i]) for i in idx]),
            *[m.state for m in group])

    def _run_shape_group(self, sig_key: str, shape_key: tuple, bucket: int,
                         group: List[_Member]) -> None:
        import jax
        import jax.numpy as jnp
        tr = self.tracer
        spec = group[0].req.spec
        step_fn, cfl_fn = self._entry_points(sig_key, shape_key, bucket, spec)
        ndev = self._ndev_for(bucket)
        stacked = self._shard_fleet(self._stack(group, bucket), ndev)
        pairs = group[0].pairs
        max_steps = max(m.req.n_steps for m in group)
        t_start = time.perf_counter()
        for n in range(max_steps):
            t0 = tr.now() if tr.enabled else time.perf_counter()
            if spec.dt is not None:
                dts = jnp.full((bucket,), float(spec.dt),
                               stacked.cells.pos.dtype)
            else:
                dts = cfl_fn(stacked).astype(stacked.cells.pos.dtype)
            stacked = step_fn(stacked, pairs, dts)
            if tr.enabled:
                tr.fence(stacked.cells.pos)
                for m in group:
                    if not m.done:
                        tr.record("fleet_step", m.req.row, t0,
                                  request_id=m.req.request_id,
                                  signature=sig_key, step=n, batch=len(group),
                                  bucket=bucket)
            self.particle_steps += sum(m.n for m in group if not m.done)
            # lockstep host bookkeeping, mirroring engine.Simulation.run
            finish, rebin = [], False
            for i, m in enumerate(group):
                if m.done:
                    continue
                m.steps_done += 1
                m.steps_since_rebin += 1
                if m.steps_done >= m.req.n_steps:
                    finish.append(i)
                elif m.steps_since_rebin >= m.req.spec.rebin_every:
                    rebin = True
            if finish or (rebin and n < max_steps - 1):
                # pull lanes to host once; finish and/or re-bin from it
                host = jax.tree_util.tree_map(np.asarray, stacked)
                for i in finish:
                    m = group[i]
                    m.done = True
                    lane = jax.tree_util.tree_map(lambda a, i=i: a[i], host)
                    wall = time.perf_counter() - t_start
                    res = _flat_result(
                        lane.cells, m.perm, m.n, lane.time, m.steps_done,
                        wall, batched=True, batch_size=len(group),
                        bucket=bucket, pool=self.pool)
                    self.queue.complete(m.req, res)
                if rebin and n < max_steps - 1:
                    for i, m in enumerate(group):
                        if m.done:
                            continue
                        m.state = jax.tree_util.tree_map(
                            lambda a, i=i: a[i], host)
                        if m.steps_since_rebin >= m.req.spec.rebin_every:
                            _rebin_member(m)
                            if m.shape_key != shape_key:
                                # capacity grew: this lane can no longer
                                # stack — finish it off-batch, correctness
                                # over batching
                                self._finish_member_sequentially(m)
                    if any(not m.done for m in group):
                        stacked = self._shard_fleet(
                            self._stack_mixed(group, bucket), ndev)
            if all(m.done for m in group):
                break

    def _stack_mixed(self, group: List[_Member], bucket: int):
        """Re-stack after a host pull/re-bin: live lanes carry their member
        state (re-binned, or as pulled), done/fallen lanes pad with a live
        lane's state (their outputs are never read again)."""
        import jax
        import jax.numpy as jnp
        states = [None if m.done else m.state for m in group]
        anchor = next(s for s in states if s is not None)
        lanes = [s if s is not None else anchor for s in states]
        lanes += [anchor] * (bucket - len(lanes))
        return jax.tree_util.tree_map(
            lambda *leaves: jnp.stack([jnp.asarray(x) for x in leaves]),
            *lanes)

    def _finish_member_sequentially(self, m: _Member) -> None:
        """A lane that fell off its batch (shape divergence) finishes on the
        shared single-run engine path from its current exact state."""
        import jax.numpy as jnp
        from ..sph.engine import cfl_timestep, shared_step_program
        tr = self.tracer
        spec = m.req.spec
        step_fn = shared_step_program(m.box, spec.physics)
        t_start = time.perf_counter()
        while m.steps_done < m.req.n_steps:
            if spec.dt is not None:
                dt = float(spec.dt)
            else:
                dt = float(cfl_timestep(m.state, spec.physics))
            t0 = tr.now() if tr.enabled else 0.0
            m.state = step_fn(m.state, m.pairs,
                              jnp.asarray(dt, m.state.cells.pos.dtype))
            if tr.enabled:
                tr.fence(m.state.cells.pos)
                tr.record("fleet_step", m.req.row, t0,
                          request_id=m.req.request_id, sequential=1)
            m.steps_done += 1
            m.steps_since_rebin += 1
            self.particle_steps += m.n
            if m.steps_since_rebin >= spec.rebin_every \
                    and m.steps_done < m.req.n_steps:
                _rebin_member(m)
        m.done = True
        self.sequential_runs += 1
        res = _flat_result(m.state.cells, m.perm, m.n, m.state.time,
                           m.steps_done, time.perf_counter() - t_start,
                           batched=False, pool=self.pool)
        self.queue.complete(m.req, res)

    # -------------------------------------------------- sequential fallback
    def _run_sequential(self, batch: Batch) -> None:
        """Quadrants without a batched lowering (time-bin ladders,
        distributed backends): serve per request, signature-grouped so the
        shared engine programs compile once for the whole group."""
        tr = self.tracer
        for req in batch.requests:
            t_start = time.perf_counter()
            t0 = tr.now() if tr.enabled else 0.0
            try:
                sim = build_simulation(req.spec)
                for _ in range(req.n_steps):
                    sim.step()
                res = self._sequential_result(
                    sim, req, time.perf_counter() - t_start)
            except Exception as e:
                self.queue.fail(req, e)
                continue
            if tr.enabled:
                tr.record("fleet_run", req.row, t0,
                          request_id=req.request_id,
                          signature=batch.signature_key,
                          quadrant=f"{req.spec.integrator}/"
                                   f"{req.spec.backend}")
            self.sequential_runs += 1
            self.queue.complete(req, res)

    def _sequential_result(self, sim, req: FleetRequest,
                           wall: float) -> FleetResult:
        eng = getattr(sim, "engine", sim)
        state = getattr(eng, "state", None)
        cells = getattr(state, "cells", None)
        perm = getattr(eng, "perm", None)
        n = getattr(eng, "n", None)
        self.particle_steps += (n or 0) * req.n_steps
        if cells is not None and perm is not None and n is not None:
            return _flat_result(cells, perm, n, sim.time, req.n_steps, wall,
                                batched=False, pool=self.pool)
        e, p = sim.diagnostics()
        return FleetResult(particles={}, energy=e, momentum=p, t=sim.time,
                           steps=req.n_steps, wall=wall, batched=False)

    # ------------------------------------------------------------- reading
    def compile_counts(self) -> Dict[str, int]:
        return self.probe.counts()

    def assert_compile_discipline(self) -> None:
        """≤1 XLA compile per (signature, shape, bucket) entry point."""
        bad = {k: c for k, c in self.probe.counts().items() if c > 1}
        if bad:
            raise AssertionError(
                f"fleet entry points recompiled: {bad} — batch bucketing "
                f"or shape keying is leaking shapes")

    def stats(self) -> Dict[str, Any]:
        return {"queue": self.queue.stats(),
                "terminal_status": dict(self.terminal_status),
                "flight_dumps": list(self.flight_dumps),
                "batches": self.batches_run,
                "sequential_runs": self.sequential_runs,
                "particle_steps": self.particle_steps,
                "programs": len(self.programs.keys),
                "compiles": self.probe.total_compiles(),
                "buckets": dict(self.batcher.policy._bucket),
                "pool": self.pool.stats(),
                "fleet_devices": self.fleet_devices}

    def export_trace(self, path: str) -> Dict[str, Any]:
        """Chrome-trace of the fleet timeline: one row per request, every
        span attributed to its ``request_id``."""
        from ..observability.sinks import write_chrome_trace
        return write_chrome_trace(path, self.tracer.spans,
                                  self.tracer.t_origin,
                                  process_name="repro.fleet",
                                  row_names=self.row_names)


def sequential_reference(spec: SimulationSpec, n_steps: int) -> FleetResult:
    """The single-simulation serving path for parity checks and baselines:
    ``build_simulation`` + ``step()`` × n, result in the same flat layout
    as the fleet's (bitwise-comparable per request)."""
    t0 = time.perf_counter()
    sim = build_simulation(spec)
    for _ in range(n_steps):
        sim.step()
    eng = sim.engine
    return _flat_result(eng.state.cells, eng.perm, eng.n, sim.time, n_steps,
                        time.perf_counter() - t0, batched=False)
