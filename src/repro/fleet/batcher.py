"""Signature grouping + batch-size bucketing: never recompile for a wobble.

The fleet's analogue of the transport layer's bucketed exchange buffers
(:class:`~repro.distributed.transport.BucketPolicy`): request arrival rates
wobble, and a compiled entry point per *exact* batch size would put the XLA
compiler on the serving hot path — the SHARK-Engine exemplar solves this
with one pre-compiled entry point per batch size; we solve it the
transport's way, padding each batch up to a power-of-two **batch bucket**
with a no-shrink policy (a serving process that has once seen a batch of 8
keeps the bucket-8 program forever; compiled programs are cheap to keep and
ruinous to rebuild). Arrival sizes 3, 7, 5, 8 therefore compile exactly two
programs (buckets 4 and 8), not four — asserted by ``CompileProbe`` in
``tests/test_fleet.py``.

Batches are formed per signature in admission order, capped at
``max_batch``, and the bucket is always divisible by the fleet mesh size
(``min_bucket``) so a batch can be sharded along the fleet axis without a
remainder lane.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from ..distributed.transport import BucketPolicy
from .queue import FleetRequest

# a bucket that has grown never shrinks: recompiling a serving entry point
# costs more than any padded lane ever will
NO_SHRINK = 10 ** 9


@dataclass
class Batch:
    """Same-signature requests to be served by one stacked program."""
    signature_key: str
    requests: List[FleetRequest]
    bucket: int                       # padded batch size (power of two)

    @property
    def size(self) -> int:
        return len(self.requests)

    @property
    def pad(self) -> int:
        return self.bucket - len(self.requests)


class SignatureBatcher:
    """Group ready requests by signature; bucket each group's batch size."""

    def __init__(self, *, max_batch: int = 64, min_bucket: int = 1):
        self.max_batch = int(max_batch)
        self.policy = BucketPolicy(min_bucket=min_bucket,
                                   shrink_patience=NO_SHRINK)

    def form(self, ready: List[FleetRequest]) -> List[Batch]:
        """Admission-ordered batches: one per (signature, ≤max_batch chunk).

        Groups keep arrival order (first request of a signature anchors its
        group's position) so no signature can be starved by a busier one.
        """
        groups: Dict[str, List[FleetRequest]] = {}
        order: List[str] = []
        for r in ready:
            if r.signature_key not in groups:
                groups[r.signature_key] = []
                order.append(r.signature_key)
            groups[r.signature_key].append(r)
        batches: List[Batch] = []
        for key in order:
            reqs = groups[key]
            for lo in range(0, len(reqs), self.max_batch):
                chunk = reqs[lo:lo + self.max_batch]
                bucket = self.policy.fit(key, len(chunk))
                batches.append(Batch(signature_key=key, requests=chunk,
                                     bucket=bucket))
        return batches
