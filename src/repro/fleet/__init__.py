"""Fleet execution: serve many simulations as one batched mesh program.

The serving subsystem (ISSUE: "fleet"): a request stream of frozen
:class:`~repro.sph.api.SimulationSpec` s is admitted by
:class:`~repro.fleet.queue.RequestQueue`, grouped by compiled-program
signature (:mod:`repro.fleet.signature`) into no-shrink batch buckets
(:mod:`repro.fleet.batcher`), and each batch is dispatched by
:class:`~repro.fleet.runner.FleetRunner` as ONE stacked program — vmapped
over a fleet axis, sharded across the device mesh when one is present.

``python -m repro.fleet --scenario sedov --requests 64`` is the serving
entry point (it replaces the LM-zoo era ``repro.launch.serve``).

Import discipline: :mod:`repro.sph.api` lazily imports
:mod:`repro.fleet.signature` (spec canonicalisation + signatures), and
:mod:`repro.fleet.queue` imports the spec back — so this package must not
eagerly import its queue/batcher/runner modules. They load on attribute
access.
"""

from __future__ import annotations

from . import signature as signature                       # cycle-free
from .signature import SHAPE_PARAM_KEYS, signature_key, split_scenario_params

_LAZY = {
    "RequestQueue": "queue",
    "FleetRequest": "queue",
    "FleetResult": "queue",
    "RequestState": "queue",
    "AdmissionError": "queue",
    "SignatureBatcher": "batcher",
    "Batch": "batcher",
    "FleetRunner": "runner",
    "TransferBufferPool": "runner",
    "sequential_reference": "runner",
}

__all__ = ["SHAPE_PARAM_KEYS", "signature", "signature_key",
           "split_scenario_params", *sorted(_LAZY)]


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
