"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
        --steps 100 --batch 8 --seq 256 --ckpt /tmp/ckpt

Runs the fault-tolerant loop (checkpoint/restart, NaN guard, straggler
accounting) on whatever devices exist: the host mesh for local runs, or the
production mesh under a real multi-chip runtime. On the assigned cluster the
same entrypoint is launched per-host with jax.distributed initialisation.
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test scale config")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (custom scale runs)")
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--mesh", default="host", choices=["host", "production"])
    ap.add_argument("--compression", default=None,
                    choices=[None, "int8", "topk"])
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from ..configs import get_config
    from ..distributed import ShardingRules
    from ..train import (AdamConfig, Checkpointer, DataConfig,
                         FaultTolerantLoop, LoopConfig, TokenStream,
                         TrainConfig, init_train_state, make_train_step)
    from .mesh import make_host_mesh, make_production_mesh

    cfg = get_config(args.arch, reduced=args.reduced)
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
    if args.n_layers:
        overrides["n_layers"] = args.n_layers
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if len(jax.devices()) == 1:
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)

    tcfg = TrainConfig(adam=AdamConfig(lr=args.lr, warmup_steps=10,
                                       total_steps=args.steps),
                       compression=args.compression)
    rules = None
    if args.mesh == "production":
        mesh = make_production_mesh()
        rules = ShardingRules(mesh, cfg, "train")
    elif len(jax.devices()) > 1:
        n = len(jax.devices())
        mesh = make_host_mesh((n, 1))
        rules = ShardingRules(mesh, cfg, "train")

    params, opt = init_train_state(cfg, jax.random.PRNGKey(0), tcfg, rules)
    n_params = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    step_fn = jax.jit(make_train_step(cfg, tcfg, rules))
    stream = TokenStream(DataConfig(vocab=cfg.vocab, seq=args.seq,
                                    batch=args.batch))
    ck = Checkpointer(args.ckpt, keep=3, async_save=True)
    loop = FaultTolerantLoop(
        train_step=step_fn, params=params, opt_state=opt, stream=stream,
        ckpt=ck, loop_cfg=LoopConfig(total_steps=args.steps,
                                     checkpoint_every=args.checkpoint_every,
                                     log_every=max(args.steps // 50, 1)))
    result = loop.run()
    for m in result["log"]:
        print(f"step {m['step']:6d}  loss {m['loss']:.4f}  "
              f"wall {m['wall'] * 1e3:.0f} ms")
    print(f"done: steps={result['final_step']} restores={result['restores']}"
          f" stragglers={result['stragglers']}")


if __name__ == "__main__":
    main()
