"""Serving driver: batched prefill + decode loop.

.. deprecated:: **Legacy (LM-zoo era).** Still runnable, but the repo's
   serving entry point is now the simulation fleet:
   ``PYTHONPATH=src python -m repro.fleet --scenario sedov --requests 64``
   (see :mod:`repro.fleet`). This LM driver stays as an exercise of the
   model zoo only.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from ..configs import get_config
    from ..models import init_params
    from ..serve.serve_step import decode_step, prefill

    cfg = get_config(args.arch, reduced=args.reduced)
    if len(jax.devices()) == 1:
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)

    B, S0, N = args.batch, args.prompt_len, args.new_tokens
    prompts = jax.random.randint(key, (B, S0), 0, cfg.vocab)
    kwargs = {}
    if cfg.is_encdec:
        kwargs["enc_inputs"] = jax.random.normal(
            key, (B, S0, cfg.d_model)) * 0.1
    if cfg.vlm_patches:
        kwargs["patch_embeds"] = jax.random.normal(
            key, (B, cfg.vlm_patches, cfg.d_model)) * 0.1
    extra = cfg.vlm_patches or 0

    t0 = time.perf_counter()
    logits, caches, rolling = prefill(params, cfg, prompts,
                                      cache_len=S0 + N + extra, **kwargs)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {B}×{S0} tokens in {t_prefill*1e3:.0f} ms "
          f"({B*S0/t_prefill:.0f} tok/s)")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.asarray(S0 + extra, jnp.int32)
    t0 = time.perf_counter()
    outs = [tok]
    for _ in range(N - 1):
        logits, caches = decode_step(params, cfg, tok, caches, pos,
                                     rolling=rolling)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(tok)
        pos = pos + 1
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    total = B * (N - 1)
    print(f"decode: {total} tokens in {t_decode*1e3:.0f} ms "
          f"({total/max(t_decode,1e-9):.0f} tok/s, "
          f"{t_decode/(N-1)*1e3:.1f} ms/step)")
    sample = jnp.concatenate(outs, 1)[0, :16]
    print("sample tokens:", list(map(int, sample)))


if __name__ == "__main__":
    main()
