import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the real jitted step (train_step / prefill /
decode_step) against ShapeDtypeStruct inputs on the production mesh,
compiles it, and records:

* ``memory_analysis()``  — per-device argument/output/temp bytes (fits?),
* ``cost_analysis()``    — per-device HLO FLOPs and bytes accessed,
* collective traffic     — parsed from the post-SPMD optimized HLO,
* the three roofline terms + MODEL_FLOPS ratio (§Roofline).

Results are cached as JSON under ``benchmarks/results/dryrun/`` so repeated
invocations only compile missing cells.

Usage:
    python -m repro.launch.dryrun --arch qwen1.5-32b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --multi-pod both
    python -m repro.launch.dryrun --list
"""

import argparse
import dataclasses
import gc
import json
import time
import traceback
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..analysis.hlo_parse import collective_summary
from ..analysis.roofline import Roofline, model_flops, remat_overhead
from ..configs import ARCH_NAMES, SHAPES, applicable, get_config
from ..distributed.sharding_rules import ShardingRules
from ..models.model import forward, init_params, make_caches, rolling_map
from ..serve.serve_step import decode_step
from ..train.optimizer import adam_init
from ..train.train_step import TrainConfig, make_train_step
from .mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "benchmarks", "results",
                           "dryrun")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(arch: str, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.batch, shape.seq
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        toks = S
        if cfg.vlm_patches:
            toks = S - cfg.vlm_patches
        out["tokens"] = _sds((B, toks), jnp.int32)
        out["targets"] = _sds((B, toks), jnp.int32)
        if cfg.is_encdec:
            out["enc_inputs"] = _sds((B, S, cfg.d_model), cfg.dtype)
        if cfg.vlm_patches:
            out["patch_embeds"] = _sds((B, cfg.vlm_patches, cfg.d_model),
                                       cfg.dtype)
    elif shape.kind == "prefill":
        toks = S - cfg.vlm_patches if cfg.vlm_patches else S
        out["tokens"] = _sds((B, toks), jnp.int32)
        if cfg.is_encdec:
            out["enc_inputs"] = _sds((B, S, cfg.d_model), cfg.dtype)
        if cfg.vlm_patches:
            out["patch_embeds"] = _sds((B, cfg.vlm_patches, cfg.d_model),
                                       cfg.dtype)
    else:                                   # decode: 1 new token, KV = S
        out["token"] = _sds((B, 1), jnp.int32)
        out["pos"] = _sds((), jnp.int32)
        enc_len = S if cfg.is_encdec else 0
        out["caches"] = jax.eval_shape(
            lambda: make_caches(cfg, B, S, enc_len=enc_len,
                                stacked=False)[0])
    return out


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape_name: str, mesh, *,
               variant: str = "baseline"):
    """→ (fn, example_args tuple, in_shardings tuple)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    specs = input_specs(arch, shape_name)
    mode = "train" if shape.kind == "train" else "serve"
    rules = ShardingRules(mesh, cfg, mode)
    rules = apply_variant(variant, cfg, rules)
    cfg = rules.cfg

    params_shapes = jax.eval_shape(
        lambda k: init_params(cfg, k), _sds((2,), jnp.uint32))
    params_sh = _named(mesh, rules.params_pspec(params_shapes))
    bp = rules.tokens_pspec(shape.batch)

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(adam_init, params_shapes)
        opt_sh = _named(mesh, rules.opt_pspec(params_shapes))
        batch = {k: v for k, v in specs.items()}
        batch_sh = {}
        for k, v in batch.items():
            nd = v.ndim
            batch_sh[k] = NamedSharding(mesh, P(*( [bp[0] if bp else None]
                                                   + [None] * (nd - 1))))
        step = make_train_step(cfg, TrainConfig(), rules)
        # donate params+opt (in-place update); metrics sharding unspecified
        return step, (params_shapes, opt_shapes, batch), dict(
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1)), cfg

    if shape.kind == "prefill":
        rmap = rolling_map(cfg, shape.seq)

        def fn(params, batch):
            res = forward(params, cfg, batch["tokens"], mode="prefill",
                          rolling=rmap,
                          enc_inputs=batch.get("enc_inputs"),
                          patch_embeds=batch.get("patch_embeds"),
                          constrain=rules.constrain)
            return res.logits[:, -1], res.caches

        batch = dict(specs)
        batch_sh = {k: NamedSharding(
            mesh, P(*([bp[0] if bp else None] + [None] * (v.ndim - 1))))
            for k, v in batch.items()}
        with mesh:
            out_shapes = jax.eval_shape(fn, params_shapes, batch)
        logits_sh = NamedSharding(mesh, P(bp[0] if bp else None, None))
        caches_out_sh = _named(mesh, rules.caches_pspec(out_shapes[1]))
        return fn, (params_shapes, batch), dict(
            in_shardings=(params_sh, batch_sh),
            out_shardings=(logits_sh, caches_out_sh)), cfg

    # decode
    rmap = rolling_map(cfg, shape.seq)
    caches = specs["caches"]
    caches_sh = _named(mesh, rules.caches_pspec(caches))

    def fn(params, token, caches, pos):
        return decode_step(params, cfg, token, caches, pos, rolling=rmap,
                           constrain=rules.constrain)

    tok_sh = NamedSharding(mesh, P(*(list(bp)[:1] + [None])))
    pos_sh = NamedSharding(mesh, P())
    logits_sh = NamedSharding(mesh, P(bp[0] if bp else None, None))
    # donate the caches: decode is an in-place cache update
    return fn, (params_shapes, specs["token"], caches, specs["pos"]), dict(
        in_shardings=(params_sh, tok_sh, caches_sh, pos_sh),
        out_shardings=(logits_sh, caches_sh),
        donate_argnums=(2,)), cfg


# ----------------------------------------------------------------- variants
def apply_variant(name: str, cfg, rules: ShardingRules) -> ShardingRules:
    """Sharding/config variants for §Perf hillclimbing."""
    if name == "baseline":
        rules.cfg = cfg
        return rules
    from . import variants                  # registered separately
    return variants.apply(name, cfg, rules)


# ------------------------------------------------------------------- runner
def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = RESULTS_DIR, force: bool = False,
             variant: str = "baseline") -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}__{mesh_name}__{variant}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    ok, why = applicable(cfg, shape_name)
    if not ok:
        res = {"tag": tag, "status": "skipped", "reason": why}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        return res

    res: Dict[str, Any] = {"tag": tag, "arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "variant": variant}
    try:
        from . import variants as variants_mod
        mesh = variants_mod.mesh_override(variant, multi_pod) \
            or make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        fn, args, jit_kwargs, eff_cfg = build_cell(arch, shape_name, mesh,
                                                   variant=variant)
        cfg = eff_cfg            # variant-modified config (remat flags etc.)
        t0 = time.time()
        with mesh:
            lowered = jax.jit(fn, **jit_kwargs).lower(*args)
            res["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            res["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        res["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_estimate_bytes": int(mem.argument_size_in_bytes
                                       + mem.output_size_in_bytes
                                       + mem.temp_size_in_bytes
                                       - mem.alias_size_in_bytes),
            "hbm_per_chip": 16 * 1024 ** 3,
        }
        ca = compiled.cost_analysis()
        res["cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed",
                                                      0.0))}
        txt = compiled.as_text()
        res["hlo_chars"] = len(txt)
        res["collectives"] = collective_summary(txt)
        del txt
        mf = model_flops(cfg, shape, chips=chips)
        rf = Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name,
            flops_per_chip=res["cost"]["flops"],
            bytes_per_chip=res["cost"]["bytes_accessed"],
            collective_bytes_per_chip=res["collectives"]["traffic_bytes"],
            model_flops_per_chip=mf,
            executed_flops_per_chip=mf * remat_overhead(cfg, shape))
        res["roofline"] = rf.row()
        res["status"] = "ok"
    except Exception as e:
        res["status"] = "error"
        res["error"] = f"{type(e).__name__}: {e}"
        res["traceback"] = traceback.format_exc()[-4000:]
    finally:
        gc.collect()

    with open(path, "w") as f:
        json.dump(res, f, indent=1, default=str)
    return res


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_NAMES} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.multi_pod]

    if args.list:
        for a in archs:
            for s in shapes:
                ok, why = applicable(get_config(a), s)
                print(f"{a:25s} {s:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return

    for a in archs:
        for s in shapes:
            for mp in meshes:
                r = run_cell(a, s, multi_pod=mp, out_dir=args.out,
                             force=args.force, variant=args.variant)
                status = r.get("status")
                extra = ""
                if status == "ok":
                    ro = r["roofline"]
                    extra = (f"bottleneck={ro['bottleneck']} "
                             f"frac={ro['roofline_fraction']:.3f} "
                             f"compile={r.get('compile_s')}s")
                elif status == "error":
                    extra = r.get("error", "")[:120]
                print(f"[{r['tag']}] {status} {extra}", flush=True)


if __name__ == "__main__":
    main()
