"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips — the ``pod`` axis is the
slow (DCN) dimension; batch shards over (pod, data).
"""

from __future__ import annotations

import math

import jax
from jax.sharding import Mesh

from ..distributed.mesh_utils import mesh_with_auto_axes


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"before importing jax (dryrun.py does this)")
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return mesh_with_auto_axes(dev_array, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = math.prod(shape)
    import numpy as np
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return mesh_with_auto_axes(dev_array, axes)
