"""Sharding/config variants for §Perf hillclimbing.

Each variant transforms (cfg, rules) before the cell is lowered. The dry-run
records results per variant, so baseline vs optimized stay separately
visible in EXPERIMENTS.md (paper-faithful floor vs beyond-paper gains).
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import PartitionSpec as P

from ..distributed.mesh_utils import batch_pref, valid_spec
from ..distributed.sharding_rules import ShardingRules


def apply(name: str, cfg, rules: ShardingRules) -> ShardingRules:
    if name not in VARIANTS:
        raise ValueError(f"unknown variant {name!r}; have {list(VARIANTS)}")
    return VARIANTS[name](cfg, rules)


def _seq_shard(cfg, rules: ShardingRules) -> ShardingRules:
    """Sequence-parallel activations: residual stream sharded over 'model'
    between blocks (Megatron-SP). Cuts layer-boundary residual memory and
    turns the TP all-reduce into reduce-scatter + all-gather pairs."""
    base_constrain = rules.constrain

    def constrain(x, kind=None):
        if x.ndim == 3 and x.shape[1] % rules.mesh.shape["model"] == 0:
            bp = batch_pref(rules.mesh)
            spec = valid_spec(x.shape, [bp, ["model"], []], rules.mesh)
            return jax.lax.with_sharding_constraint(x, spec)
        return base_constrain(x, kind)

    new = dataclasses.replace(rules)
    new.constrain = constrain
    new.cfg = cfg
    return new


def _scan_group(n):
    def f(cfg, rules: ShardingRules) -> ShardingRules:
        rules.cfg = dataclasses.replace(cfg, scan_group=n)
        return rules
    return f


def _ssm_chunk(n):
    def f(cfg, rules: ShardingRules) -> ShardingRules:
        rules.cfg = dataclasses.replace(cfg, ssm_chunk=n)
        return rules
    return f


def _ssm_bf16(cfg, rules: ShardingRules) -> ShardingRules:
    rules.cfg = dataclasses.replace(cfg, ssm_bf16=True)
    return rules


def _seq_shard_no_block_remat(cfg, rules: ShardingRules) -> ShardingRules:
    rules = _seq_shard(cfg, rules)
    rules.cfg = dataclasses.replace(rules.cfg, block_remat=False)
    return rules


def _seq_nbr_g2(cfg, rules: ShardingRules) -> ShardingRules:
    rules = _seq_shard_no_block_remat(cfg, rules)
    rules.cfg = dataclasses.replace(rules.cfg, scan_group=2)
    return rules


def _no_block_remat(cfg, rules: ShardingRules) -> ShardingRules:
    """Drop the per-block remat level (keep group-level sqrt remat):
    executed flops 10/6 → 8/6 of MODEL — viable once banded attention has
    freed the S×S activation memory."""
    rules.cfg = dataclasses.replace(cfg, block_remat=False)
    return rules


def _moe_group(n):
    def f(cfg, rules: ShardingRules) -> ShardingRules:
        rules.cfg = dataclasses.replace(cfg)
        object.__setattr__(rules.cfg, "_moe_group", n)   # read by moe()
        return rules
    return f


def _identity(cfg, rules):
    rules.cfg = cfg
    return rules


def _moe_ep(cfg, rules: ShardingRules) -> ShardingRules:
    """Expert parallelism: experts sharded over the model axis (requires an
    EP-compatible mesh, see ``mesh_override``) — routing becomes all-to-all,
    expert FFNs run collective-free."""
    rules.moe_ep = True
    rules.cfg = cfg
    return rules


VARIANTS = {
    "baseline": _identity,
    "seq_shard": _seq_shard,
    "scan_group8": _scan_group(8),
    "scan_group2": _scan_group(2),
    "ssm_chunk64": _ssm_chunk(64),
    "ssm_chunk32": _ssm_chunk(32),
    "ssm_chunk256": _ssm_chunk(256),
    "ep8": _moe_ep,
    "no_block_remat": _no_block_remat,
    "ssm_bf16": _ssm_bf16,
    "seq_nbr": _seq_shard_no_block_remat,
    "seq_nbr_g2": _seq_nbr_g2,
}

# variants that need a different production mesh factorisation (same chip
# count): ep8 reshapes a pod to (data=32, model=8) so 8 experts divide the
# model axis
MESH_OVERRIDES = {
    "ep8": {False: ((32, 8), ("data", "model")),
            True: ((2, 32, 8), ("pod", "data", "model"))},
}


def mesh_override(name: str, multi_pod: bool):
    """Return a Mesh for variants that refactor the pod, else None."""
    if name not in MESH_OVERRIDES:
        return None
    import math
    import numpy as np
    import jax
    from ..distributed.mesh_utils import mesh_with_auto_axes
    shape, axes = MESH_OVERRIDES[name][multi_pod]
    n = math.prod(shape)
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return mesh_with_auto_axes(dev, axes)
