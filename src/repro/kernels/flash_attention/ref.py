"""Pure-jnp oracle for the flash attention kernel.

Materialises the full (S, T) score matrix — the thing the Pallas kernel
exists to avoid — with causal + sliding-window masking and GQA head
grouping. Ground truth for tests/test_kernel_flash_attention.py.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None):
    """q (B, S, H, hd); k/v (B, T, K, hd) with H = K·G. → (B, S, H, hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    qpos = jnp.arange(S)
    kpos = jnp.arange(T)
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None] + (T - S)
    if window is not None:
        ok &= kpos[None, :] > qpos[:, None] + (T - S) - window
    scores = jnp.where(ok[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, S, H, hd)
