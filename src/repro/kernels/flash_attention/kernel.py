"""Flash attention (online softmax) Pallas TPU kernel.

Grid: (batch, q_head, q_blocks). Each program owns one (Bq × hd) query tile
in VMEM and streams KV in (Bk × hd) tiles with the online-softmax
rescaling recurrence (running max m, normaliser l, accumulator acc), so the
(S × T) score matrix never exists — per-program VMEM is
O(Bq·hd + Bk·hd + Bq·Bk).

Structure notes (TPU):
* q tile × k tileᵀ is an MXU matmul (hd = contraction dim, multiple of 128
  in the production configs); rescale/exp are VPU ops.
* Causal + sliding-window masking is positional arithmetic on block
  offsets; fully-masked KV tiles are skipped by clamping the streamed
  range (`lo`, `hi`) — the paper's "don't compute what the mask kills".
* GQA: the kv-head index map collapses G consecutive q heads onto one KV
  head, so no KV duplication is materialised.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool,
                  window: Optional[int], block_k: int, q_len: int,
                  kv_len: int):
    qb = q_ref[0, :, 0, :]                       # (Bq, hd)
    Bq, hd = qb.shape
    scale = 1.0 / math.sqrt(hd)
    iq = pl.program_id(2)
    q0 = iq * Bq + (kv_len - q_len)              # global key-offset of row 0

    nk = kv_len // block_k
    # streamed kv range: skip tiles that are fully masked
    hi = nk
    if causal:
        hi = jnp.minimum(nk, (q0 + Bq - 1) // block_k + 1)
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (q0 - window + 1) // block_k)

    def body(j, carry):
        m, l, acc = carry
        kb = k_ref[0, pl.ds(j * block_k, block_k), 0, :]   # (Bk, hd)
        vb = v_ref[0, pl.ds(j * block_k, block_k), 0, :]
        s = jnp.dot(qb, kb.T,
                    preferred_element_type=jnp.float32) * scale  # (Bq, Bk)
        qpos = q0 + jnp.arange(Bq)[:, None]
        kpos = j * block_k + jnp.arange(block_k)[None, :]
        ok = jnp.ones((Bq, block_k), bool)
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p.astype(vb.dtype), vb, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((Bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Bq,), jnp.float32)
    acc0 = jnp.zeros((Bq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q (B, S, H, hd); k/v (B, T, K, hd), H = K·G. → (B, S, H, hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, "seq lens must tile"

    grid = (B, H, S // bq)
    q_spec = pl.BlockSpec((1, bq, 1, hd), lambda b, h, i: (b, i, h, 0))
    kv_spec = pl.BlockSpec((1, T, 1, hd), lambda b, h, i: (b, 0, h // G, 0))
    o_spec = pl.BlockSpec((1, bq, 1, hd), lambda b, h, i: (b, i, h, 0))
    fn = functools.partial(_flash_kernel, causal=causal, window=window,
                           block_k=bk, q_len=S, kv_len=T)
    return pl.pallas_call(
        fn, grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
