"""Jit'd wrapper for the flash attention kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from .kernel import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True,
                       window: Optional[int] = None, block_q: int = 128,
                       block_k: int = 128, interpret: bool = True):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)
