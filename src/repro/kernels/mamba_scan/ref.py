"""Pure-jnp oracle for the selective-scan kernel.

Sequential ``lax.scan`` over time at the (B, d_inner, d_state) level —
the mathematically transparent form of Mamba-1's recurrence:

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t−1} + (Δ_t u_t) ⊗ B_t
    y_t = ⟨h_t, C_t⟩ + D ⊙ u_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(u, dt, A, Bm, Cm, D, *, h0=None):
    """u/dt (B, S, dI); A (dI, N); Bm/Cm (B, S, N); D (dI,).

    Returns (y (B, S, dI), h_final (B, dI, N)). All math in f32.
    """
    B_, S, dI = u.shape
    N = A.shape[1]
    uf = u.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    h = jnp.zeros((B_, dI, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        ut, dtt, bt, ct = inp
        dA = jnp.exp(dtt[:, :, None] * A[None])
        h = dA * h + (dtt * ut)[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h, ys = jax.lax.scan(
        step, h,
        (uf.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
         Bm.astype(jnp.float32).transpose(1, 0, 2),
         Cm.astype(jnp.float32).transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + uf * D[None, None, :]
    return y, h
