"""Selective-scan kernel (Mamba-1, VMEM-resident state)."""

from .kernel import selective_scan
from .ops import selective_scan_op
from .ref import selective_scan_ref

__all__ = ["selective_scan", "selective_scan_op", "selective_scan_ref"]
