"""Selective-scan (Mamba-1) Pallas TPU kernel.

Hardware adaptation of the CUDA selective-scan (DESIGN.md §8): the CUDA
kernel streams time through SRAM keeping (d_inner, d_state) state resident;
here each program owns a (channel-block × d_state) state tile in VMEM and
scans the full sequence for its (batch, channel-block) grid cell:

  grid = (B, d_inner // block_d)
  VMEM per program: u/dt (S, block_d), B/C (S, N), state (block_d, N),
                    y (S, block_d) — ~1.6 MB at S=1024, block_d=128, N=16.

The channel dimension is embarrassingly parallel for Mamba-1's diagonal A
(this is also why d_inner tensor-parallelism is clean — the same split,
across chips instead of across programs). Time stays sequential inside the
program (`lax.scan`), which is the honest dependency structure; HBM traffic
is one read of the inputs and one write of y — the (S, d_inner, d_state)
intermediate that a naive XLA lowering would materialise never leaves VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref,
                 y_ref, hout_ref):
    u = u_ref[0].astype(jnp.float32)          # (S, dblk)
    dt = dt_ref[0].astype(jnp.float32)        # (S, dblk)
    A = A_ref[...].astype(jnp.float32)        # (dblk, N)
    Bm = B_ref[0].astype(jnp.float32)         # (S, N)
    Cm = C_ref[0].astype(jnp.float32)         # (S, N)
    D = D_ref[...].astype(jnp.float32)        # (dblk,)
    h = h0_ref[0].astype(jnp.float32)         # (dblk, N)

    def step(h, inp):
        ut, dtt, bt, ct = inp                 # (dblk,),(dblk,),(N,),(N,)
        dA = jnp.exp(dtt[:, None] * A)        # (dblk, N)
        h = dA * h + (dtt * ut)[:, None] * bt[None, :]
        y = h @ ct                            # (dblk,)
        return h, y

    h, ys = jax.lax.scan(step, h, (u, dt, Bm, Cm))
    y_ref[0] = (ys + u * D[None, :]).astype(y_ref.dtype)
    hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan(u, dt, A, Bm, Cm, D, *, h0=None, block_d: int = 128,
                   interpret: bool = True):
    """u/dt (B, S, dI); A (dI, N); Bm/Cm (B, S, N); D (dI,).

    Returns (y (B, S, dI), h_final (B, dI, N)).
    """
    B_, S, dI = u.shape
    N = A.shape[1]
    bd = min(block_d, dI)
    assert dI % bd == 0, "d_inner must tile by block_d"
    if h0 is None:
        h0 = jnp.zeros((B_, dI, N), jnp.float32)

    grid = (B_, dI // bd)
    sd = pl.BlockSpec((1, S, bd), lambda b, j: (b, 0, j))
    sn = pl.BlockSpec((1, S, N), lambda b, j: (b, 0, 0))
    sA = pl.BlockSpec((bd, N), lambda b, j: (j, 0))
    sD = pl.BlockSpec((bd,), lambda b, j: (j,))
    sh = pl.BlockSpec((1, bd, N), lambda b, j: (b, j, 0))
    return pl.pallas_call(
        _scan_kernel, grid=grid,
        in_specs=[sd, sd, sA, sn, sn, sD, sh],
        out_specs=[sd, sh],
        out_shape=[jax.ShapeDtypeStruct((B_, S, dI), u.dtype),
                   jax.ShapeDtypeStruct((B_, dI, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, A, Bm, Cm, D, h0)
