"""Jit'd wrapper for the selective-scan kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import selective_scan


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def selective_scan_op(u, dt, A, Bm, Cm, D, *, block_d: int = 128,
                      interpret: bool = True):
    return selective_scan(u, dt, A, Bm, Cm, D, block_d=block_d,
                          interpret=interpret)
