"""Fused SSD (Mamba-2) kernel — the zamba2 §Perf fix (VMEM-resident block)."""

from .kernel import ssd_scan
from .ops import ssd_scan_op
from .ref import ssd_scan_ref

__all__ = ["ssd_scan", "ssd_scan_op", "ssd_scan_ref"]
