"""Jit'd wrapper for the fused SSD kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import ssd_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_op(u, dt, A, Bm, Cm, D, *, chunk: int = 64,
                interpret: bool = True):
    return ssd_scan(u, dt, A, Bm, Cm, D, chunk=chunk, interpret=interpret)
