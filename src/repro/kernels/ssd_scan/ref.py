"""Pure-jnp oracle for the fused SSD (Mamba-2) kernel.

Sequential per-step scan of the scalar-decay-per-head SSM:

    h_t = exp(Δ_t·A_h) · h_{t−1} + Δ_t · B_t ⊗ u_t
    y_t = C_t · h_t + D_h · u_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(u, dt, A, Bm, Cm, D, *, h0=None):
    """u (B, S, H, hp); dt (B, S, H); A/D (H,); Bm/Cm (B, S, N).

    Returns (y (B, S, H, hp), h_final (B, H, N, hp)). f32 math.
    """
    B_, S, H, hp = u.shape
    N = Bm.shape[-1]
    uf = u.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    h = jnp.zeros((B_, H, N, hp), jnp.float32) if h0 is None else h0

    def step(h, inp):
        ut, dtt, bt, ct = inp            # (B,H,hp), (B,H), (B,N), (B,N)
        da = jnp.exp(dtt * A[None, :])   # (B,H)
        h = h * da[:, :, None, None] \
            + (dtt[:, :, None] * ut)[:, :, None, :] * bt[:, None, :, None]
        y = jnp.einsum("bhnp,bn->bhp", h, ct) + ut * D[None, :, None]
        return h, y

    h, ys = jax.lax.scan(
        step, h,
        (uf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
         Bm.astype(jnp.float32).transpose(1, 0, 2),
         Cm.astype(jnp.float32).transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3), h
