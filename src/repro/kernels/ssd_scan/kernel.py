"""Fused SSD (Mamba-2) Pallas TPU kernel.

This is the fix identified by the zamba2 §Perf hillclimb: the pure-XLA SSD
block's HBM traffic is spread over dozens of (B, S, d_inner)-sized streams
at fusion boundaries (measured flat under chunk/precision changes —
EXPERIMENTS.md iteration Z1–Z3). The fused kernel keeps *everything*
between the input read and the y write resident in VMEM:

  grid = (B, H): one program owns one (batch, head) strip.
  VMEM per program @ S=4096, hp=64, N=64, Q=64:
      u (S, hp) 1 MB · B/C (S, N) 1 MB each · y (S, hp) 1 MB ·
      chunk temporaries (Q², Q·N, Q·hp ≤ 0.3 MB) · state (N, hp) 16 kB
  HBM traffic per layer = one read of (u, Δ, B, C) + one write of y —
  ~6 GB instead of ~80 GB for zamba2 train_4k (per-chip, per pass).

Within the program, time is processed in Q-length chunks with the SSD
matmul form (intra-chunk (Q×Q) decay-masked products on the MXU; scalar
per-head decay makes the inter-chunk state update one rank-1-ish einsum),
carried sequentially by `lax.scan` — the same dependency structure the
CUDA kernel implements with SRAM tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref,
                y_ref, hout_ref, *, chunk: int):
    u = u_ref[0, :, 0, :].astype(jnp.float32)        # (S, hp)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (S,)
    a = A_ref[0].astype(jnp.float32)                 # scalar
    Bm = B_ref[0].astype(jnp.float32)                # (S, N)
    Cm = C_ref[0].astype(jnp.float32)                # (S, N)
    d = D_ref[0].astype(jnp.float32)                 # scalar

    S, hp = u.shape
    N = Bm.shape[1]
    Q = min(chunk, S)
    T = S // Q

    la = dt * a                                      # (S,) log-decay
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_body(h, inp):
        uq, dtq, bq, cq, laq = inp                   # (Q,hp),(Q,),(Q,N)…
        Lc = jnp.cumsum(laq)                         # (Q,)
        # intra-chunk: y[t] = Σ_{s≤t} (C_t·B_s) exp(L_t−L_s) Δ_s u_s
        cb = jnp.dot(cq, bq.T,
                     preferred_element_type=jnp.float32)      # (Q,Q)
        diff = Lc[:, None] - Lc[None, :]
        decay = jnp.exp(jnp.where(causal, diff, NEG_INF))
        M = cb * decay                                        # (Q,Q)
        y_intra = jnp.dot(M * dtq[None, :], uq,
                          preferred_element_type=jnp.float32)  # (Q,hp)
        # inter-chunk: y += C_t exp(L_t) h_in
        y_inter = jnp.exp(Lc)[:, None] * jnp.dot(
            cq, h, preferred_element_type=jnp.float32)         # (Q,hp)
        # state update: h_out = exp(L_Q) h_in + Σ_s exp(L_Q−L_s) Δ_s B_s⊗u_s
        dec_end = jnp.exp(Lc[-1] - Lc)                         # (Q,)
        Sc = jnp.dot((bq * (dec_end * dtq)[:, None]).T, uq,
                     preferred_element_type=jnp.float32)       # (N,hp)
        h = jnp.exp(Lc[-1]) * h + Sc
        return h, y_intra + y_inter

    h0 = jnp.zeros((N, hp), jnp.float32)
    resh = lambda x: x.reshape((T, Q) + x.shape[1:])
    h_fin, yq = jax.lax.scan(chunk_body, h0,
                             (resh(u), resh(dt), resh(Bm), resh(Cm),
                              resh(la)))
    y = yq.reshape(S, hp) + u * d
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    hout_ref[0, 0] = h_fin.astype(hout_ref.dtype)


def ssd_scan(u, dt, A, Bm, Cm, D, *, chunk: int = 64,
             interpret: bool = True):
    """u (B, S, H, hp); dt (B, S, H); A/D (H,); Bm/Cm (B, S, N).

    Returns (y (B, S, H, hp), h_final (B, H, N, hp)).
    """
    B_, S, H, hp = u.shape
    N = Bm.shape[-1]
    assert S % min(chunk, S) == 0, "sequence must tile by chunk"

    grid = (B_, H)
    s_u = pl.BlockSpec((1, S, 1, hp), lambda b, h: (b, 0, h, 0))
    s_dt = pl.BlockSpec((1, S, 1), lambda b, h: (b, 0, h))
    s_sc = pl.BlockSpec((1,), lambda b, h: (h,))
    s_bc = pl.BlockSpec((1, S, N), lambda b, h: (b, 0, 0))
    s_h = pl.BlockSpec((1, 1, N, hp), lambda b, h: (b, h, 0, 0))
    fn = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        fn, grid=grid,
        in_specs=[s_u, s_dt, s_sc, s_bc, s_bc, s_sc],
        out_specs=[s_u, s_h],
        out_shape=[jax.ShapeDtypeStruct((B_, S, H, hp), u.dtype),
                   jax.ShapeDtypeStruct((B_, H, N, hp), jnp.float32)],
        interpret=interpret,
    )(u, dt, A, Bm, Cm, D)
