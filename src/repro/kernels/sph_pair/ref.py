"""Pure-jnp oracle for the sph_pair Pallas kernels.

Computes exactly what ``kernel.py`` computes — both directions of every
cell-pair interaction — by calling the physics blocks twice. Used by the
kernel tests (``tests/test_kernel_sph_pair.py``) and as the fallback path.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from ...sph.physics import density_block, force_block


def density_pair_ref(pos_i, h_i, m_i, mask_i, pos_j, h_j, m_j, mask_j,
                     *, kernel: str = "cubic"):
    """Both directions of the density interaction for batched pairs.

    All inputs carry a leading pairs dimension P; positions are (P, C, 3)
    with pos_j already image-shifted. Returns
    (rho_i, drho_i, nngb_i, rho_j, drho_j, nngb_j), each (P, C).
    """
    dens = functools.partial(density_block, kernel=kernel)
    dij = jax.vmap(dens)(pos_i, h_i, pos_j, m_j, mask_j)
    dji = jax.vmap(dens)(pos_j, h_j, pos_i, m_i, mask_i)
    return (dij.rho, dij.drho_dh, dij.nngb,
            dji.rho, dji.drho_dh, dji.nngb)


def force_pair_ref(pos_i, vel_i, h_i, P_i, rho_i, omega_i, cs_i, m_i, mask_i,
                   pos_j, vel_j, h_j, P_j, rho_j, omega_j, cs_j, m_j, mask_j,
                   *, kernel: str = "cubic", alpha_visc: float = 0.0):
    """Both directions of the force interaction for batched pairs.

    Returns (dv_i, du_i, dv_j, du_j): (P, C, 3), (P, C), (P, C, 3), (P, C).
    """
    force = functools.partial(force_block, kernel=kernel,
                              alpha_visc=alpha_visc)
    fij = jax.vmap(force)(pos_i, vel_i, h_i, P_i, rho_i, omega_i, cs_i,
                          pos_j, vel_j, h_j, P_j, rho_j, omega_j, cs_j,
                          m_j, mask_j)
    fji = jax.vmap(force)(pos_j, vel_j, h_j, P_j, rho_j, omega_j, cs_j,
                          pos_i, vel_i, h_i, P_i, rho_i, omega_i, cs_i,
                          m_i, mask_i)
    return fij.dv, fij.du, fji.dv, fji.du
