"""Pallas TPU kernels for SPH cell-pair interactions.

One grid program = one SWIFT pair task (``density_pair`` / ``force_pair``).
TPU-native design decisions (DESIGN.md §8.3):

* The (C × C) interaction matrix is the unit of work. Distances use the
  dot-product form r² = |xi|² + |xj|² − 2·xi·xjᵀ, so the inner op is a
  (C,3) @ (3,C) matmul feeding the MXU, followed by VPU element-wise kernel
  evaluation. C (cell capacity) is padded to a multiple of 8 and capped by
  VMEM: C=128 gives 64 kB per f32 (C,C) buffer.
* **Symmetry exploited** — both directions of the pair are produced in one
  program (row-reductions → i-side, column-reductions → j-side), reusing the
  distance matrix. The vmapped reference evaluates each direction separately;
  the kernel does the paper's "exploit symmetries in the particle
  interactions" optimisation.
* Periodic image shifts are applied by the host wrapper (ops.py), so the
  kernel body is branch-free Euclidean geometry.

Layout: positions/velocities are passed as (C, 3) blocks; the small
trailing dim lives in lanes only during the matmul and is irrelevant for
correctness in interpret mode. Per-pair scalar-ish fields (h, m, mask, …)
are (C,) blocks.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...sph.smoothing import get_kernel

EPS = 1e-12


def _two_sum(a, b):
    """Error-free f32 addition: returns (fl(a+b), rounding error)."""
    s = a + b
    bb = s - a
    err = (a - (s - bb)) + (b - bb)
    return s, err


def _two_prod(a, b):
    """Error-free f32 product via Dekker splitting (no FMA needed)."""
    p = a * b
    split = 4097.0          # 2**12 + 1 for float32 (24-bit significand)
    ca = split * a
    a_hi = ca - (ca - a)
    a_lo = a - a_hi
    cb = split * b
    b_hi = cb - (cb - b)
    b_lo = b - b_hi
    err = ((a_hi * b_hi - p) + a_hi * b_lo + a_lo * b_hi) + a_lo * b_lo
    return p, err


def _df_weighted_contract(w, g, rhat, axis):
    """Σ_axis w·g·r̂ in double-float precision, rounded once at the end.

    ``w`` broadcasts against the (C, C) matrix ``g``; ``rhat`` is (C, C, 3).
    Both directions of a pair task contract the *same* g and r̂ matrices, so
    computing the products and the reduction in double-float makes the pair's
    momentum exchange antisymmetric to the final-rounding floor — Newton's
    third law holds to ~1 ulp of each dv entry instead of drifting with the
    length of the f32 product/reduction chain.
    """
    p1, e1 = _two_prod(jnp.broadcast_to(w, g.shape), g)
    p2, e2 = _two_prod(p1[:, :, None], rhat)
    lo = e2 + e1[:, :, None] * rhat
    hi = jnp.moveaxis(p2, axis, 0)
    lo = jnp.moveaxis(lo, axis, 0)

    def body(k, carry):
        s_hi, s_lo = carry
        s, e = _two_sum(s_hi, hi[k])
        e = e + (s_lo + lo[k])
        s2 = s + e                      # renormalise the pair
        return s2, e - (s2 - s)

    init = (jnp.zeros_like(hi[0]), jnp.zeros_like(lo[0]))
    s_hi, s_lo = jax.lax.fori_loop(0, hi.shape[0], body, init)
    return s_hi + s_lo


def _r_and_rhat(xi, xj):
    """(C,C) distances, (C,C,3) displacement and unit displacement via the
    MXU dot form."""
    sq_i = jnp.sum(xi * xi, axis=-1)
    sq_j = jnp.sum(xj * xj, axis=-1)
    cross = jnp.dot(xi, xj.T, preferred_element_type=jnp.float32)
    r2 = jnp.maximum(sq_i[:, None] + sq_j[None, :] - 2.0 * cross, 0.0)
    r = jnp.sqrt(r2 + EPS)
    dx = xi[:, None, :] - xj[None, :, :]
    rhat = dx / r[:, :, None]
    return r2, r, dx, rhat


# ------------------------------------------------------------------ density
def _density_kernel(pos_i_ref, h_i_ref, m_i_ref, mask_i_ref,
                    pos_j_ref, h_j_ref, m_j_ref, mask_j_ref,
                    rho_i_ref, drho_i_ref, nngb_i_ref,
                    rho_j_ref, drho_j_ref, nngb_j_ref,
                    *, kernel: str):
    w_fn, dwdr_fn = get_kernel(kernel)
    xi = pos_i_ref[0]          # (C, 3)
    xj = pos_j_ref[0]
    hi = h_i_ref[0][:, None]   # (C, 1)
    hj = h_j_ref[0][None, :]   # (1, C)
    _r2, r, _dx, _rhat = _r_and_rhat(xi, xj)

    # i <- j (rows reduce over j)
    wi = w_fn(r, hi)
    mj = (m_j_ref[0] * mask_j_ref[0])[None, :]
    rho_i_ref[0] = jnp.sum(mj * wi, axis=1)
    dwdh_i = -(3.0 * wi + r * dwdr_fn(r, hi)) / hi
    drho_i_ref[0] = jnp.sum(mj * dwdh_i, axis=1)
    nngb_i_ref[0] = jnp.sum((wi > 0.0) * mask_j_ref[0][None, :], axis=1)

    # j <- i (columns reduce over i) — same r matrix, h_j kernel
    wj = w_fn(r, hj)
    mi = (m_i_ref[0] * mask_i_ref[0])[:, None]
    rho_j_ref[0] = jnp.sum(mi * wj, axis=0)
    dwdh_j = -(3.0 * wj + r * dwdr_fn(r, hj)) / hj
    drho_j_ref[0] = jnp.sum(mi * dwdh_j, axis=0)
    nngb_j_ref[0] = jnp.sum((wj > 0.0) * mask_i_ref[0][:, None], axis=0)


def density_pair_pallas(pos_i, h_i, m_i, mask_i, pos_j, h_j, m_j, mask_j,
                        *, kernel: str = "cubic", interpret: bool = True):
    """Batched cell-pair density, both directions per program.

    Shapes: pos (P, C, 3); h/m/mask (P, C). Returns six (P, C) arrays:
    (rho_i, drho_i, nngb_i, rho_j, drho_j, nngb_j).
    """
    P, C, _ = pos_i.shape
    f32 = pos_i.dtype
    vec = pl.BlockSpec((1, C, 3), lambda p: (p, 0, 0))
    sca = pl.BlockSpec((1, C), lambda p: (p, 0))
    out_shape = [jax.ShapeDtypeStruct((P, C), f32)] * 6
    out_specs = [sca] * 6
    fn = functools.partial(_density_kernel, kernel=kernel)
    return pl.pallas_call(
        fn,
        grid=(P,),
        in_specs=[vec, sca, sca, sca, vec, sca, sca, sca],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(pos_i, h_i, m_i, mask_i, pos_j, h_j, m_j, mask_j)


# -------------------------------------------------------------------- force
def _force_kernel(pos_i_ref, vel_i_ref, h_i_ref, P_i_ref, rho_i_ref,
                  om_i_ref, cs_i_ref, m_i_ref, mask_i_ref,
                  pos_j_ref, vel_j_ref, h_j_ref, P_j_ref, rho_j_ref,
                  om_j_ref, cs_j_ref, m_j_ref, mask_j_ref,
                  dv_i_ref, du_i_ref, dv_j_ref, du_j_ref,
                  *, kernel: str, alpha_visc: float):
    _w_fn, dwdr_fn = get_kernel(kernel)
    xi, xj = pos_i_ref[0], pos_j_ref[0]
    vi, vj = vel_i_ref[0], vel_j_ref[0]
    hi = h_i_ref[0][:, None]
    hj = h_j_ref[0][None, :]
    r2, r, dx, rhat = _r_and_rhat(xi, xj)

    dwi = dwdr_fn(r, hi)
    dwj = dwdr_fn(r, hj)
    ai = (P_i_ref[0] / (om_i_ref[0] * rho_i_ref[0] ** 2))[:, None]
    aj = (P_j_ref[0] / (om_j_ref[0] * rho_j_ref[0] ** 2))[None, :]
    fmag = ai * dwi + aj * dwj

    valid = (mask_i_ref[0][:, None] * mask_j_ref[0][None, :]
             * (r < jnp.maximum(hi, hj)) * (r2 > EPS))

    dvel = vi[:, None, :] - vj[None, :, :]
    vdotrhat = jnp.sum(dvel * rhat, axis=-1)

    du_visc_i = jnp.zeros(xi.shape[0], dtype=xi.dtype)
    du_visc_j = jnp.zeros(xj.shape[0], dtype=xj.dtype)
    if alpha_visc > 0.0:
        # match physics.force_block's rounding path exactly (vdotr from dx,
        # not vdotrhat*r) so the fused kernel keeps Newton's third law to
        # the same ulp as the two-sided reference
        vdotr = jnp.sum(dvel * dx, axis=-1)
        hbar = 0.5 * (hi + hj)
        rhobar = 0.5 * (rho_i_ref[0][:, None] + rho_j_ref[0][None, :])
        csbar = 0.5 * (cs_i_ref[0][:, None] + cs_j_ref[0][None, :])
        mu = hbar * vdotr / (r2 + 0.01 * hbar * hbar)
        mu = jnp.where(vdotr < 0.0, mu, 0.0)
        beta = 2.0 * alpha_visc
        piij = (-alpha_visc * csbar * mu + beta * mu * mu) / rhobar
        dwbar = 0.5 * (dwi + dwj)
        fmag = fmag + piij * dwbar
        mvisc_i = m_j_ref[0][None, :] * valid
        du_visc_i = 0.5 * jnp.sum(
            mvisc_i * piij * dwbar * (vdotr / r), axis=1)
        mvisc_j = (m_i_ref[0][:, None] * valid).T
        du_visc_j = 0.5 * jnp.sum(
            mvisc_j * piij.T * dwbar.T * (vdotr.T / r.T), axis=1)

    # Both directions contract the *same* (C, C) interaction matrix against
    # the same r̂, in double-float, so the pair's momentum exchange is
    # antisymmetric to the output-rounding floor (Newton's third law).
    g = jnp.where(valid > 0, fmag, 0.0) * valid
    dv_i_ref[0] = -_df_weighted_contract(m_j_ref[0][None, :], g, rhat, axis=1)
    dv_j_ref[0] = _df_weighted_contract(m_i_ref[0][:, None], g, rhat, axis=0)

    # energy eq. (4): per-side cutoff r < h_side
    valid_ui = mask_j_ref[0][None, :] * (r < hi) * (r2 > EPS)
    coef_i = P_i_ref[0] / (om_i_ref[0] * rho_i_ref[0] ** 2)
    du_i_ref[0] = coef_i * jnp.sum(
        m_j_ref[0][None, :] * valid_ui * vdotrhat * dwi, axis=1) + du_visc_i
    valid_uj = mask_i_ref[0][:, None] * (r < hj) * (r2 > EPS)
    coef_j = P_j_ref[0] / (om_j_ref[0] * rho_j_ref[0] ** 2)
    # v_ji·r̂_ji = (−dvel)·(−r̂) = vdotrhat
    du_j_ref[0] = coef_j * jnp.sum(
        m_i_ref[0][:, None] * valid_uj * vdotrhat * dwj, axis=0) + du_visc_j


def force_pair_pallas(pos_i, vel_i, h_i, press_i, rho_i, om_i, cs_i, m_i,
                      mask_i, pos_j, vel_j, h_j, press_j, rho_j, om_j, cs_j,
                      m_j, mask_j, *, kernel: str = "cubic",
                      alpha_visc: float = 0.0, interpret: bool = True):
    """Batched cell-pair forces, both directions per program.

    Returns (dv_i, du_i, dv_j, du_j): (P,C,3), (P,C), (P,C,3), (P,C).
    """
    P, C, _ = pos_i.shape
    f32 = pos_i.dtype
    vec = pl.BlockSpec((1, C, 3), lambda p: (p, 0, 0))
    sca = pl.BlockSpec((1, C), lambda p: (p, 0))
    out_shape = [jax.ShapeDtypeStruct((P, C, 3), f32),
                 jax.ShapeDtypeStruct((P, C), f32),
                 jax.ShapeDtypeStruct((P, C, 3), f32),
                 jax.ShapeDtypeStruct((P, C), f32)]
    out_specs = [vec, sca, vec, sca]
    fn = functools.partial(_force_kernel, kernel=kernel,
                           alpha_visc=alpha_visc)
    return pl.pallas_call(
        fn,
        grid=(P,),
        in_specs=[vec, vec, sca, sca, sca, sca, sca, sca, sca,
                  vec, vec, sca, sca, sca, sca, sca, sca, sca],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(pos_i, vel_i, h_i, press_i, rho_i, om_i, cs_i, m_i, mask_i,
      pos_j, vel_j, h_j, press_j, rho_j, om_j, cs_j, m_j, mask_j)
