"""Jit'd wrappers: gather pair blocks → Pallas kernel → scatter-accumulate.

The gather/scatter around the kernel is the wave execution of the task
graph: one ``density_pairs`` call executes *every* density task of the wave
as a single batched Pallas launch (DESIGN.md §2 C1).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernel import density_pair_pallas, force_pair_pallas


def _gather(cells, pairs):
    gi = lambda a: a[pairs.ci]
    gj = lambda a: a[pairs.cj]
    pos_i = gi(cells.pos)
    pos_j = gj(cells.pos) + pairs.shift[:, None, :]
    return gi, gj, pos_i, pos_j


def density_pairs(cells, pairs, *, kernel: str = "cubic",
                  interpret: bool = True, pair_mask=None):
    """All density_pair/density_self tasks → (rho, drho_dh, nngb).

    ``pair_mask`` (npairs,) zeroes masked pair tasks' contributions (padding
    used by the time-bin engine's fixed-shape level pair lists).
    """
    gi, gj, pos_i, pos_j = _gather(cells, pairs)
    rho_i, drho_i, nn_i, rho_j, drho_j, nn_j = density_pair_pallas(
        pos_i, gi(cells.h), gi(cells.mass), gi(cells.mask),
        pos_j, gj(cells.h), gj(cells.mass), gj(cells.mask),
        kernel=kernel, interpret=interpret)

    ncells, cap = cells.mass.shape
    notself = (pairs.ci != pairs.cj).astype(cells.pos.dtype)[:, None]
    live = jnp.ones_like(notself) if pair_mask is None else pair_mask[:, None]

    def scatter(a_ij, a_ji):
        out = jnp.zeros((ncells, cap), cells.pos.dtype)
        out = out.at[pairs.ci].add(a_ij * live)
        out = out.at[pairs.cj].add(a_ji * notself * live)
        return out

    return (scatter(rho_i, rho_j), scatter(drho_i, drho_j),
            scatter(nn_i, nn_j))


def force_pairs(cells, pairs, rho, press, omega, cs, *,
                kernel: str = "cubic", alpha_visc: float = 0.0,
                interpret: bool = True, pair_mask=None):
    """All force_pair/force_self tasks → (dv, du)."""
    gi, gj, pos_i, pos_j = _gather(cells, pairs)
    dv_i, du_i, dv_j, du_j = force_pair_pallas(
        pos_i, gi(cells.vel), gi(cells.h), gi(press), gi(rho), gi(omega),
        gi(cs), gi(cells.mass), gi(cells.mask),
        pos_j, gj(cells.vel), gj(cells.h), gj(press), gj(rho), gj(omega),
        gj(cs), gj(cells.mass), gj(cells.mask),
        kernel=kernel, alpha_visc=alpha_visc, interpret=interpret)

    ncells, cap = cells.mass.shape
    notself = (pairs.ci != pairs.cj).astype(cells.pos.dtype)
    live = jnp.ones_like(notself) if pair_mask is None else pair_mask

    dv = jnp.zeros((ncells, cap, 3), cells.pos.dtype)
    dv = dv.at[pairs.ci].add(dv_i * live[:, None, None])
    dv = dv.at[pairs.cj].add(dv_j * (notself * live)[:, None, None])
    du = jnp.zeros((ncells, cap), cells.pos.dtype)
    du = du.at[pairs.ci].add(du_i * live[:, None])
    du = du.at[pairs.cj].add(du_j * (notself * live)[:, None])
    return dv, du
