"""SPH cell-pair interaction kernels (Pallas TPU + jnp oracle)."""

from . import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
