"""Three-term roofline from the compiled dry-run artifact (TPU v5e target).

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_traffic_per_chip / link_bw

``cost_analysis()`` of an SPMD-partitioned executable reports per-partition
(= per-chip) FLOPs and bytes; the HLO parser likewise sums local shard
sizes, so all three terms are per-chip seconds directly (the spec's
"/(chips × bw)" with the totals already divided by chips).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (single-link assumption)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops_per_chip: float = 0.0
    executed_flops_per_chip: float = 0.0   # MODEL × remat overhead

    @property
    def t_compute(self) -> float:
        # XLA:CPU cost analysis undercounts FLOPs inside remat'd loop bodies
        # (observed: HLO < MODEL on train cells with double remat). Use the
        # max of reported-HLO and the analytic *executed* flops (MODEL ×
        # remat recompute factor) — never understate the compute term.
        return max(self.flops_per_chip, self.executed_flops_per_chip,
                   self.model_flops_per_chip) / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower bound on step time: max of the three terms (full overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / executed FLOPs — how much compute is useful
        (catches remat recompute, dispatch overhead, masking waste)."""
        executed = max(self.flops_per_chip, self.executed_flops_per_chip)
        if executed <= 0:
            return 1.0
        return min(self.model_flops_per_chip / executed, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute utilisation at the bound: what fraction of peak
        FLOP/s the chip would sustain if the step ran at t_bound."""
        if self.t_bound <= 0:
            return 0.0
        return (self.model_flops_per_chip / PEAK_FLOPS_BF16) / self.t_bound

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape, *, chips: int) -> float:
    """MODEL_FLOPS per chip: 6·N·D train, 2·N_active·D inference."""
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        total = 6.0 * n_act * tokens
    elif shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        total = 2.0 * n_act * tokens
    else:                                    # decode: one token per sequence
        total = 2.0 * n_act * shape.batch
    return total / chips


def remat_overhead(cfg, shape) -> float:
    """Executed/useful flops ratio from the remat policy.

    Train = fwd(2ND) + bwd(4ND) + one extra fwd per remat level: the
    group-level sqrt remat always recomputes once, ``block_remat`` adds a
    second recompute ⇒ (6 + 2·levels)/6.
    """
    if shape.kind != "train":
        return 1.0
    levels = 1 + (1 if getattr(cfg, "block_remat", False) else 0)
    return (6.0 + 2.0 * levels) / 6.0
