"""Rebuild the §Roofline table from cached dry-run JSONs — and render
SWIFT-style task timelines from observability traces.

Two modes:

* ``python -m repro.analysis.report`` (no positional arg): recomputes the
  roofline three-term table with the *current* formulas (so analysis fixes
  don't require recompiling 70 cells) and emits the markdown table for
  EXPERIMENTS.md plus per-cell one-liners on what would move the
  bottleneck.
* ``python -m repro.analysis.report trace.json [--metrics metrics.jsonl]``:
  renders the Chrome trace exported by a ``SimulationSpec(observe=True)``
  run as a text task plot — one row per rank, one character per time
  bucket, dominant task per bucket (the terminal rendition of SWIFT §4's
  task-timeline figures) — followed by the per-cycle imbalance/dead-time
  table and the measured-vs-modelled task-cost ratios from the metrics
  log.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from ..configs import SHAPES, get_config
from .roofline import Roofline, model_flops, remat_overhead

HBM = 16 * 1024 ** 3


def load_cells(results_dir: str, variant: str = "baseline") -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(results_dir,
                                           f"*__{variant}.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def rebuild_roofline(cell: Dict) -> Optional[Roofline]:
    if cell.get("status") != "ok":
        return None
    cfg = get_config(cell["arch"])
    if cell.get("variant") == "no_block_remat":
        import dataclasses
        cfg = dataclasses.replace(cfg, block_remat=False)
    shape = SHAPES[cell["shape"]]
    chips = 512 if cell["mesh"] == "multi" else 256
    mf = model_flops(cfg, shape, chips=chips)
    return Roofline(
        arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"],
        flops_per_chip=cell["cost"]["flops"],
        bytes_per_chip=cell["cost"]["bytes_accessed"],
        collective_bytes_per_chip=cell["collectives"]["traffic_bytes"],
        model_flops_per_chip=mf,
        executed_flops_per_chip=mf * remat_overhead(cfg, shape))


def advice(r: Roofline, cell: Dict) -> str:
    """One sentence: what would move the dominant term down."""
    peak_gb = cell["memory"]["peak_estimate_bytes"] / 2 ** 30
    if r.bottleneck == "compute":
        if r.useful_flops_ratio < 0.99 and r.shape == "train_4k":
            return ("remat recompute dominates waste — selective "
                    "checkpointing (save attn outputs) trims the extra "
                    "forward")
        return ("compute-bound at high useful ratio — larger per-chip batch "
                "or fewer chips raise MFU further")
    if r.bottleneck == "memory":
        if "decode" in r.shape or r.shape == "long_500k":
            return ("KV/state reads dominate — KV quantisation (int8) or "
                    "larger decode batch amortises the weight/cache sweep")
        if peak_gb > 16:
            return ("activation footprint exceeds HBM — fused (flash) "
                    "attention / sequence-parallel activations cut "
                    "intermediate traffic")
        return ("HBM traffic bound — fuse attention (no S×S spill) and "
                "keep activations bf16")
    return ("collective-bound — overlap TP collectives with compute "
            "(chunked allgather-matmul) or reshard to cut cross-chip bytes")


def markdown_table(results_dir: str, variant: str = "baseline",
                   mesh: str = "single") -> str:
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
        "useful | roofline frac | peak GB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for cell in load_cells(results_dir, variant):
        if cell.get("mesh") != mesh:
            continue
        if cell.get("status") == "skipped":
            lines.append(f"| {cell['tag'].split('__')[0]} "
                         f"| {cell['tag'].split('__')[1]} "
                         f"| — | — | — | skipped | — | — | — | — |")
            continue
        r = rebuild_roofline(cell)
        if r is None:
            lines.append(f"| {cell.get('arch')} | {cell.get('shape')} "
                         f"| ERROR {cell.get('error', '')[:40]} "
                         f"| | | | | | | |")
            continue
        peak = cell["memory"]["peak_estimate_bytes"] / 2 ** 30
        lines.append(
            f"| {r.arch} | {r.shape} | {r.t_compute:.3g} | {r.t_memory:.3g} "
            f"| {r.t_collective:.3g} | {r.bottleneck} "
            f"| {r.useful_flops_ratio:.2f} | {r.roofline_fraction:.3f} "
            f"| {peak:.1f} | {'✓' if peak <= 16 else '✗'} |")
    return "\n".join(lines)


def advice_list(results_dir: str, variant: str = "baseline",
                mesh: str = "single") -> str:
    lines = []
    for cell in load_cells(results_dir, variant):
        if cell.get("mesh") != mesh or cell.get("status") != "ok":
            continue
        r = rebuild_roofline(cell)
        lines.append(f"* **{r.arch} × {r.shape}** ({r.bottleneck}-bound): "
                     f"{advice(r, cell)}")
    return "\n".join(lines)


# ----------------------------------------------------- task-timeline report
def load_trace(path: str) -> Dict:
    with open(path) as fh:
        doc = json.load(fh)
    if isinstance(doc, list):                    # bare event-array flavour
        doc = {"traceEvents": doc}
    return doc


def _task_slices(doc: Dict) -> List[Dict]:
    from ..observability import UMBRELLA_SPANS
    return [e for e in doc.get("traceEvents", [])
            if e.get("ph") == "X" and e.get("name") not in UMBRELLA_SPANS]


def render_timeline(doc: Dict, width: int = 72) -> str:
    """One row per rank, one char per time bucket, dominant task wins.

    The terminal rendition of SWIFT's task plot: load imbalance shows as
    rows going quiet ('.') while others still work; communication-heavy
    stretches show as exchange characters lining up across rows.
    """
    xs = _task_slices(doc)
    if not xs:
        return "(no task slices in trace)"
    t0 = min(e["ts"] for e in xs)
    t1 = max(e["ts"] + e["dur"] for e in xs)
    span = max(t1 - t0, 1e-9)
    names = sorted({e["name"] for e in xs})
    chars: Dict[str, str] = {}
    used = set()
    for nm in names:
        for ch in (nm[:1].upper() + nm[1:] + "0123456789*#@"):
            ch = ch.upper()
            if ch not in used and not ch.isspace():
                chars[nm] = ch
                used.add(ch)
                break
    rows = sorted({e["tid"] for e in xs})
    # row labels come from the trace's thread_name metadata when present
    # (fleet traces name rows by request_id; rank traces by "rank N")
    row_names = {e.get("tid"): str(e.get("args", {}).get("name"))
                 for e in doc.get("traceEvents", [])
                 if isinstance(e, dict) and e.get("ph") == "M"
                 and e.get("name") == "thread_name"
                 and e.get("args", {}).get("name")}
    label_w = max([8] + [len(v) for v in row_names.values()])
    lines = [f"task timeline: {span / 1e6:.4f} s over {width} buckets "
             f"('.' = dead time)"]
    bw = span / width
    for r in rows:
        cover: List[Dict[str, float]] = [{} for _ in range(width)]
        for e in xs:
            if e["tid"] != r:
                continue
            e0, e1 = e["ts"] - t0, e["ts"] + e["dur"] - t0
            b0 = max(int(e0 / bw), 0)
            b1 = min(int(e1 / bw), width - 1)
            for b in range(b0, b1 + 1):
                ov = max(0.0, min(e1, (b + 1) * bw) - max(e0, b * bw))
                cover[b][e["name"]] = cover[b].get(e["name"], 0.0) + ov
        line = "".join(chars[max(c, key=c.get)] if c else "."
                       for c in cover)
        label = row_names.get(r, f"rank {r:>3}")
        lines.append(f"{label:>{label_w}} |{line}|")
    legend = "  ".join(f"{c}={n}"
                       for n, c in sorted(chars.items(), key=lambda kv: kv[1]))
    lines.append(f"legend: {legend}")
    # dead lanes must be loud: every expired-sweep marker called out by
    # request, not left as a zero-width slice nobody notices
    expired = [e for e in xs if e.get("name") == "expired"]
    if expired:
        lines.append(f"EXPIRED lanes ({len(expired)}):")
        for e in expired:
            a = e.get("args", {})
            who = a.get("request_id", row_names.get(e.get("tid"),
                                                    f"row {e.get('tid')}"))
            lines.append(f"  {who}: deadline={a.get('deadline')} "
                         f"({a.get('error', 'expired before scheduling')})")
    return "\n".join(lines)


def attribution_table(records: List[Dict]) -> str:
    """Per-rank × per-kind cost-attribution table from the last record's
    ``cell_work`` block (schema v3). Pre-v3 logs — upgraded records with
    ``cell_work: None`` — render every column as '-'."""
    if not records:
        return "(no metrics records)"
    from ..observability import upgrade_record
    last = upgrade_record(records[-1])
    cw = last.get("cell_work")
    cols = (cw or {}).get("columns") or ["drift", "density", "force",
                                         "exchange"]
    lines = ["per-rank cost attribution (work units by task kind, "
             "last cycle):",
             f"{'rank':>5} " + " ".join(f"{c:>12}" for c in cols)]
    if not cw:
        lines.append(f"{'-':>5} " + " ".join(f"{'-':>12}" for _ in cols))
        lines.append("(record predates schema v3 — no per-cell "
                     "attribution)")
        return "\n".join(lines)
    for r, row in enumerate(cw["per_rank"]):
        lines.append(f"{r:>5} " + " ".join(f"{v:>12.4g}" for v in row))
    lines.append(f"{'total':>5} "
                 + " ".join(f"{v:>12.4g}" for v in cw["totals"]))
    cal = last.get("cost_calibration")
    if cal and cal.get("kinds"):
        res = cal.get("residual")
        lines += ["", "calibrated per-kind rates (joint fit over "
                      f"{cal.get('nsamples', 0)} cycle samples, relative "
                      "residual "
                      f"{'-' if res is None else format(res, '.3f')}):",
                  f"{'kind':<12} {'rate (s/unit)':>14} {'confidence':>11}"]
        for k in sorted(cal["kinds"]):
            v = cal["kinds"][k]
            lines.append(f"{k:<12} {v['rate']:>14.4g} "
                         f"{v['confidence']:>11.3f}")
    return "\n".join(lines)


def advisor_trend(records: List[Dict]) -> str:
    """Repartition-advisor time-series: measured current vs advised
    imbalance per cycle (schema v3; '-' for records predating it)."""
    if not records:
        return "(no metrics records)"
    from ..observability import upgrade_record
    records = [upgrade_record(r) for r in records]
    lines = ["repartition advisor trend (measured per-rank load "
             "imbalance, max/mean):",
             f"{'cycle':>5} {'current':>9} {'advised':>9} "
             f"{'candidate':>10} {'accepted':>9}"]
    any_adv = False
    for r in records:
        adv = r.get("advisor")
        if adv is None:
            lines.append(f"{r.get('cycle', 0):>5} {'-':>9} {'-':>9} "
                         f"{'-':>10} {'-':>9}")
            continue
        any_adv = True
        lines.append(
            f"{r.get('cycle', 0):>5} "
            f"{adv['current_imbalance']:>9.3f} "
            f"{adv['advised_imbalance']:>9.3f} "
            f"{adv['candidate_imbalance']:>10.3f} "
            f"{'yes' if adv.get('accepted') else 'keep':>9}")
    if not any_adv:
        lines.append("(no advisor records — single rank, device metrics "
                     "off, or pre-v3 log)")
    return "\n".join(lines)


def metrics_summary(records: List[Dict]) -> str:
    """Per-cycle imbalance/dead-time table + measured-vs-modelled costs.

    Accepts schema-v1 (PR 5) through v3 records alike: every record is
    normalised through ``upgrade_record``, so the device-metrics and
    cost-attribution columns render as '-' for logs that predate them."""
    if not records:
        return "(no metrics records)"
    from ..observability import upgrade_record
    records = [upgrade_record(r) for r in records]
    lines = ["per-cycle summary:",
             f"{'cycle':>5} {'wall (s)':>10} {'imbalance':>10} "
             f"{'dev_imb':>8} {'health':>7} "
             f"{'dead_frac':>10} {'updates':>10} {'compiles':>9}"]
    for r in records:
        imb = r.get("imbalance")
        dead = r.get("dead_frac")
        dimb = r.get("device_imbalance")
        health = r.get("health")
        if health is None:
            hcol = "-"
        else:
            hcol = "TRIP" if health.get("tripped") else "ok"
        lines.append(
            f"{r.get('cycle', 0):>5} {r.get('wall', 0.0):>10.4f} "
            f"{'-' if imb is None else format(imb, '.3f'):>10} "
            f"{'-' if dimb is None else format(dimb, '.3f'):>8} "
            f"{hcol:>7} "
            f"{'-' if dead is None else format(dead, '.3f'):>10} "
            f"{r.get('updates', 0):>10} "
            f"{str(r.get('total_compiles', '-')):>9}")
    last = records[-1]
    du = last.get("device_phase_units")
    if du:
        lines += ["", "device-measured work units (last cycle, in-program "
                      "telemetry):",
                  "  " + "  ".join(f"{k}={v:.4g}"
                                   for k, v in sorted(du.items()))]
    dumps = [r["flight_dump"] for r in records if r.get("flight_dump")]
    if dumps:
        lines += ["", "flight-recorder dumps (sentinel trips):"]
        lines += [f"  {d}" for d in dumps]
    ratios = last.get("cost_ratios") or {}
    if ratios:
        units = last.get("observed_units") or {}
        lines += ["",
                  "measured vs modelled task cost (rate ratio; >1 = task "
                  "costlier per unit than the model assumed):",
                  f"{'task kind':<16} {'units':>12} {'ratio':>12}"]
        for k in sorted(ratios):
            lines.append(f"{k:<16} {units.get(k, 0):>12.4g} "
                         f"{ratios[k]:>12.4g}")
    lines += ["", attribution_table(records), "", advisor_trend(records)]
    return "\n".join(lines)


def trace_report(trace_path: str, metrics_path: Optional[str] = None,
                 width: int = 72) -> str:
    doc = load_trace(trace_path)
    parts = [render_timeline(doc, width=width)]
    if metrics_path:
        from ..observability import read_metrics_jsonl
        parts += ["", metrics_summary(read_metrics_jsonl(metrics_path))]
    return "\n".join(parts)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", nargs="?", default=None,
                    help="Chrome-trace JSON from an observe=True run; "
                         "when given, render the task timeline instead of "
                         "the roofline table")
    ap.add_argument("--metrics", default=None,
                    help="per-cycle metrics JSONL to summarise under the "
                         "timeline")
    ap.add_argument("--width", type=int, default=72)
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "benchmarks",
        "results", "dryrun"))
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--advice", action="store_true")
    args = ap.parse_args()
    if args.trace:
        print(trace_report(args.trace, args.metrics, width=args.width))
        return
    print(markdown_table(args.dir, args.variant, args.mesh))
    if args.advice:
        print()
        print(advice_list(args.dir, args.variant, args.mesh))


if __name__ == "__main__":
    main()
