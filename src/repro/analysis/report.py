"""Rebuild the §Roofline table from cached dry-run JSONs.

Recomputes the three terms with the *current* formulas (so analysis fixes
don't require recompiling 70 cells) and emits the markdown table for
EXPERIMENTS.md plus per-cell one-liners on what would move the bottleneck.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from ..configs import SHAPES, get_config
from .roofline import Roofline, model_flops, remat_overhead

HBM = 16 * 1024 ** 3


def load_cells(results_dir: str, variant: str = "baseline") -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(results_dir,
                                           f"*__{variant}.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def rebuild_roofline(cell: Dict) -> Optional[Roofline]:
    if cell.get("status") != "ok":
        return None
    cfg = get_config(cell["arch"])
    if cell.get("variant") == "no_block_remat":
        import dataclasses
        cfg = dataclasses.replace(cfg, block_remat=False)
    shape = SHAPES[cell["shape"]]
    chips = 512 if cell["mesh"] == "multi" else 256
    mf = model_flops(cfg, shape, chips=chips)
    return Roofline(
        arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"],
        flops_per_chip=cell["cost"]["flops"],
        bytes_per_chip=cell["cost"]["bytes_accessed"],
        collective_bytes_per_chip=cell["collectives"]["traffic_bytes"],
        model_flops_per_chip=mf,
        executed_flops_per_chip=mf * remat_overhead(cfg, shape))


def advice(r: Roofline, cell: Dict) -> str:
    """One sentence: what would move the dominant term down."""
    peak_gb = cell["memory"]["peak_estimate_bytes"] / 2 ** 30
    if r.bottleneck == "compute":
        if r.useful_flops_ratio < 0.99 and r.shape == "train_4k":
            return ("remat recompute dominates waste — selective "
                    "checkpointing (save attn outputs) trims the extra "
                    "forward")
        return ("compute-bound at high useful ratio — larger per-chip batch "
                "or fewer chips raise MFU further")
    if r.bottleneck == "memory":
        if "decode" in r.shape or r.shape == "long_500k":
            return ("KV/state reads dominate — KV quantisation (int8) or "
                    "larger decode batch amortises the weight/cache sweep")
        if peak_gb > 16:
            return ("activation footprint exceeds HBM — fused (flash) "
                    "attention / sequence-parallel activations cut "
                    "intermediate traffic")
        return ("HBM traffic bound — fuse attention (no S×S spill) and "
                "keep activations bf16")
    return ("collective-bound — overlap TP collectives with compute "
            "(chunked allgather-matmul) or reshard to cut cross-chip bytes")


def markdown_table(results_dir: str, variant: str = "baseline",
                   mesh: str = "single") -> str:
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
        "useful | roofline frac | peak GB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for cell in load_cells(results_dir, variant):
        if cell.get("mesh") != mesh:
            continue
        if cell.get("status") == "skipped":
            lines.append(f"| {cell['tag'].split('__')[0]} "
                         f"| {cell['tag'].split('__')[1]} "
                         f"| — | — | — | skipped | — | — | — | — |")
            continue
        r = rebuild_roofline(cell)
        if r is None:
            lines.append(f"| {cell.get('arch')} | {cell.get('shape')} "
                         f"| ERROR {cell.get('error', '')[:40]} "
                         f"| | | | | | | |")
            continue
        peak = cell["memory"]["peak_estimate_bytes"] / 2 ** 30
        lines.append(
            f"| {r.arch} | {r.shape} | {r.t_compute:.3g} | {r.t_memory:.3g} "
            f"| {r.t_collective:.3g} | {r.bottleneck} "
            f"| {r.useful_flops_ratio:.2f} | {r.roofline_fraction:.3f} "
            f"| {peak:.1f} | {'✓' if peak <= 16 else '✗'} |")
    return "\n".join(lines)


def advice_list(results_dir: str, variant: str = "baseline",
                mesh: str = "single") -> str:
    lines = []
    for cell in load_cells(results_dir, variant):
        if cell.get("mesh") != mesh or cell.get("status") != "ok":
            continue
        r = rebuild_roofline(cell)
        lines.append(f"* **{r.arch} × {r.shape}** ({r.bottleneck}-bound): "
                     f"{advice(r, cell)}")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "benchmarks",
        "results", "dryrun"))
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--advice", action="store_true")
    args = ap.parse_args()
    print(markdown_table(args.dir, args.variant, args.mesh))
    if args.advice:
        print()
        print(advice_list(args.dir, args.variant, args.mesh))


if __name__ == "__main__":
    main()
