"""Parse collective traffic out of optimized (post-SPMD) HLO text.

``compiled.as_text()`` on a partitioned executable names every collective
explicitly (`all-reduce`, `all-gather`, `reduce-scatter`, `all-to-all`,
`collective-permute`, async `-start` variants). Shapes in the text are
*per-device* (local shard) shapes, so summed bytes here are per-device
quantities — exactly what the roofline's per-chip terms need.

Per-op traffic model (ring algorithms, group size N):
    all-reduce          2·(N−1)/N · bytes(result)
    all-gather          (N−1)/N · bytes(result)
    reduce-scatter      (N−1)   · bytes(result)      (input = N·result)
    all-to-all          (N−1)/N · bytes(result)
    collective-permute  bytes(result)
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


@dataclasses.dataclass
class CollectiveOp:
    op: str
    bytes_result: float
    group_size: int
    traffic: float
    line: str


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def _traffic(op: str, nbytes: float, n: int) -> float:
    if n <= 1 and op != "collective-permute":
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n * nbytes
    if op == "all-gather":
        return (n - 1) / n * nbytes
    if op == "reduce-scatter":
        return (n - 1) * nbytes
    if op == "all-to-all":
        return (n - 1) / n * nbytes
    return nbytes                      # collective-permute


def parse_collectives(hlo_text: str, *, default_group: int = 1
                      ) -> List[CollectiveOp]:
    out: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("shape"))
        n = _group_size(line, default_group)
        out.append(CollectiveOp(op=op, bytes_result=nbytes, group_size=n,
                                traffic=_traffic(op, nbytes, n),
                                line=line.strip()[:200]))
    return out


def collective_summary(hlo_text: str, *, default_group: int = 1
                       ) -> Dict[str, float]:
    ops = parse_collectives(hlo_text, default_group=default_group)
    by_kind: Dict[str, float] = {}
    for o in ops:
        by_kind[o.op] = by_kind.get(o.op, 0.0) + o.traffic
    return {
        "count": float(len(ops)),
        "traffic_bytes": sum(o.traffic for o in ops),
        **{f"bytes_{k}": v for k, v in sorted(by_kind.items())},
    }
