"""Roofline analysis from compiled dry-run artifacts."""

from .hlo_parse import CollectiveOp, collective_summary, parse_collectives
from .roofline import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16, Roofline,
                       model_flops, remat_overhead)

__all__ = [
    "CollectiveOp", "collective_summary", "parse_collectives",
    "HBM_BW", "ICI_BW", "PEAK_FLOPS_BF16", "Roofline", "model_flops",
    "remat_overhead",
]
