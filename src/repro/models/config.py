"""Model configuration schema for the assigned architecture zoo."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention variants
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_base: float = 10000.0
    window: Optional[int] = None           # SWA for every attn layer (mixtral)
    attn_softcap: Optional[float] = None

    # gemma-isms
    act: str = "silu"                      # silu (SwiGLU) | gelu (GeGLU)
    rms_plus_one: bool = False             # (1 + w) RMSNorm scale
    embed_scale: bool = False              # x *= sqrt(d_model)
    tie_embeddings: bool = False

    # gemma3 local:global interleave
    local_global: Optional[Tuple[int, int]] = None     # e.g. (5, 1)
    local_window: int = 1024
    global_rope_base: float = 1.0e6

    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25

    # SSM
    ssm: Optional[str] = None              # mamba1 | mamba2
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_bf16: bool = False          # SSD einsum operands in bf16 (f32 accum)

    # hybrid (zamba2): shared attention block every N backbone layers
    shared_attn_every: int = 0
    shared_lora_rank: int = 32

    # enc-dec
    n_enc_layers: int = 0                  # >0 → encoder-decoder

    # vlm: number of image tokens whose embeddings arrive precomputed (stub)
    vlm_patches: int = 0

    # numerics / compile shape
    dtype: Any = jnp.bfloat16
    scan_group: int = 4                    # sqrt-remat group (layers per group)
    block_remat: bool = True               # remat each block (drop S×S resid)
    pad_vocab_multiple: int = 256          # shardable logits (production norm)

    @property
    def vocab_padded(self) -> int:
        m = self.pad_vocab_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def n_params(self) -> float:
        """Analytic parameter count (embeddings included once)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        H, K, hd = self.n_heads, self.n_kv, self.head_dim
        attn = d * (H * hd) + 2 * d * (K * hd) + (H * hd) * d
        mlp_p = 3 * d * ff
        per_layer = 0.0
        if self.ssm == "mamba1":
            R = max(d // 16, 1)
            dI = self.d_inner
            per_layer = d * 2 * dI + self.d_conv * dI + \
                dI * (R + 2 * self.d_state) + R * dI + dI * d
        elif self.ssm == "mamba2":
            dI = self.d_inner
            nh = dI // self.ssm_headdim
            conv_dim = dI + 2 * self.d_state
            per_layer = d * (2 * dI + 2 * self.d_state + nh) + \
                self.d_conv * conv_dim + dI * d
        elif self.n_experts:
            per_layer = attn + self.n_experts * mlp_p + d * self.n_experts
        else:
            per_layer = attn + mlp_p
        total = self.n_layers * per_layer
        if self.is_encdec:
            total += self.n_enc_layers * (attn + mlp_p) \
                + self.n_layers * attn          # cross-attention
        if self.shared_attn_every:
            d2 = 2 * d
            total += d2 * (H * hd) + 2 * d2 * (K * hd) + (H * hd) * d2 \
                + 3 * d2 * ff + d2 * d
        total += V * d * (1 if self.tie_embeddings else 2)
        return float(total)

    def n_active_params(self) -> float:
        """Per-token active params (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        mlp_p = 3 * d * ff
        total = self.n_params()
        total -= self.n_layers * (self.n_experts - self.top_k) * mlp_p
        return float(total)
