"""Mamba-1 (falcon-mamba-7b) and Mamba-2/SSD (zamba2) blocks.

Hardware adaptation notes (DESIGN.md §8): the CUDA selective-scan kernel
streams the (d_inner, d_state) state through SRAM. The TPU-native training
formulation here:

* **Mamba-1** — per-channel diagonal A forbids the quadratic (matmul)
  form, so training uses a two-level scan: an outer ``lax.scan`` over
  chunks (saving only the (B, d_inner, d_state) carry per chunk) with a
  ``jax.checkpoint``-ed inner scan over time steps — the classic sqrt-remat
  that keeps HBM residuals at O(S/Q · state) instead of O(S · state).
  ``repro.kernels.mamba_scan`` is the fused Pallas version (state lives in
  VMEM across a sequential grid).
* **Mamba-2 (SSD)** — scalar A per head admits the chunked matmul
  (attention-like) form: intra-chunk (Q×Q) masked-decay matmuls on the MXU
  plus a cheap inter-chunk state recurrence.

Decode keeps (conv_state, ssm_state) per layer and costs O(1) per token —
this is why the ``long_500k`` shape runs natively on the SSM archs.
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _rms(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv * w.astype(jnp.float32)).astype(x.dtype)


# =================================================================== Mamba-1
class Mamba1State(NamedTuple):
    conv: jax.Array     # (B, d_conv-1, d_inner)
    ssm: jax.Array      # (B, d_inner, d_state) — always f32


def init_mamba1(key, d_model: int, *, d_state: int = 16, d_conv: int = 4,
                expand: int = 2, dt_rank: Optional[int] = None,
                bcdt_rms: bool = False, dtype=jnp.float32) -> Params:
    dI = expand * d_model
    R = dt_rank or max(d_model // 16, 1)
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d_model)
    sI = 1.0 / math.sqrt(dI)
    p = {
        "in_proj": (jax.random.normal(ks[0], (d_model, 2 * dI)) * s
                    ).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, dI)) * 0.5).astype(dtype),
        "conv_b": jnp.zeros((dI,), dtype),
        "x_proj": (jax.random.normal(ks[2], (dI, R + 2 * d_state)) * sI
                   ).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (R, dI)) / math.sqrt(R)
                    ).astype(dtype),
        "dt_bias": (jnp.log(jnp.exp(
            jnp.exp(jax.random.uniform(ks[4], (dI,)) *
                    (math.log(0.1) - math.log(0.001)) + math.log(0.001))
            ) - 1.0 + 1e-6)).astype(jnp.float32),   # softplus-inverse init
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, d_state + 1, dtype=jnp.float32)[None], (dI, 1))),
        "D": jnp.ones((dI,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (dI, d_model)) * sI
                     ).astype(dtype),
    }
    if bcdt_rms:
        p["b_norm"] = jnp.ones((d_state,), jnp.float32)
        p["c_norm"] = jnp.ones((d_state,), jnp.float32)
        p["dt_norm"] = jnp.ones((R,), jnp.float32)
    return p


def _mamba1_inputs(p: Params, x, *, d_state: int, bcdt_rms: bool):
    """Shared projections: returns (xz-gated u, z, dt, B, C)."""
    B_, S, _ = x.shape
    dI = p["conv_w"].shape[1]
    xz = x @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)               # (B, S, dI) each
    return u, z


def _mamba1_ssm_params(p: Params, u, *, d_state: int, bcdt_rms: bool):
    R = p["dt_proj"].shape[0]
    proj = u @ p["x_proj"]                          # (B, S, R + 2N)
    dt_r, Bm, Cm = jnp.split(proj, [R, R + d_state], axis=-1)
    if bcdt_rms:
        dt_r = _rms(dt_r, p["dt_norm"])
        Bm = _rms(Bm, p["b_norm"])
        Cm = _rms(Cm, p["c_norm"])
    dt = jax.nn.softplus(
        (dt_r @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _causal_conv(p: Params, u, conv_state=None):
    """Depthwise causal conv along S. Returns (y, new_conv_state)."""
    K, dI = p["conv_w"].shape
    B_, S, _ = u.shape
    if conv_state is None:
        pad = jnp.zeros((B_, K - 1, dI), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)         # (B, S+K-1, dI)
    y = sum(ext[:, i:i + S, :] * p["conv_w"][i][None, None, :]
            for i in range(K))
    y = y + p["conv_b"]
    new_state = ext[:, -(K - 1):, :]
    return jax.nn.silu(y), new_state


def mamba1_forward(p: Params, x, *, d_state: int = 16,
                   chunk: int = 64, bcdt_rms: bool = False,
                   state: Optional[Mamba1State] = None,
                   return_state: bool = False
                   ) -> Tuple[jax.Array, Optional[Mamba1State]]:
    """Full-sequence Mamba-1. x (B, S, d) → (B, S, d)."""
    B_, S, d = x.shape
    dI = p["conv_w"].shape[1]
    u, z = _mamba1_inputs(p, x, d_state=d_state, bcdt_rms=bcdt_rms)
    conv_state = state.conv if state is not None else None
    u, new_conv = _causal_conv(p, u, conv_state)
    dt, Bm, Cm = _mamba1_ssm_params(p, u, d_state=d_state, bcdt_rms=bcdt_rms)
    A = -jnp.exp(p["A_log"])                        # (dI, N)
    uf = u.astype(jnp.float32)

    h0 = (state.ssm if state is not None
          else jnp.zeros((B_, dI, d_state), jnp.float32))

    # pad S to a multiple of chunk
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) *
                                 (a.ndim - 2))
        uf, dt, Bm, Cm = map(zpad, (uf, dt, Bm, Cm))
    T = uf.shape[1] // Q

    def chunk_body(h, inp):
        uq, dtq, bq, cq = inp                       # (B, Q, …)

        def step(hh, sinp):
            ut, dtt, bt, ct = sinp                  # (B, dI), (B,dI), (B,N)…
            dA = jnp.exp(dtt[:, :, None] * A[None])
            hh = dA * hh + (dtt * ut)[:, :, None] * bt[:, None, :]
            yt = jnp.einsum("bdn,bn->bd", hh, ct)
            return hh, yt

        stepped = jax.checkpoint(
            lambda hh, si: jax.lax.scan(step, hh, si))
        h, yq = stepped(h, (uq.transpose(1, 0, 2), dtq.transpose(1, 0, 2),
                            bq.transpose(1, 0, 2), cq.transpose(1, 0, 2)))
        return h, yq.transpose(1, 0, 2)             # (B, Q, dI)

    chunked = lambda a: a.reshape(B_, T, Q, -1).transpose(1, 0, 2, 3)
    h_fin, ys = jax.lax.scan(chunk_body, h0,
                             (chunked(uf), chunked(dt), chunked(Bm),
                              chunked(Cm)))
    y = ys.transpose(1, 0, 2, 3).reshape(B_, T * Q, dI)[:, :S]
    y = y + uf[:, :S] * p["D"][None, None, :]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_state = Mamba1State(new_conv, h_fin) if return_state else None
    return out, new_state


def mamba1_step(p: Params, x, state: Mamba1State, *, d_state: int = 16,
                bcdt_rms: bool = False) -> Tuple[jax.Array, Mamba1State]:
    """Single-token decode. x (B, 1, d)."""
    B_, S, d = x.shape
    K, dI = p["conv_w"].shape
    xz = x[:, 0] @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                # (B, dI)
    # conv via state
    ext = jnp.concatenate([state.conv.astype(u.dtype), u[:, None]], axis=1)
    y = jnp.einsum("bkd,kd->bd", ext, p["conv_w"]) + p["conv_b"]
    u = jax.nn.silu(y)
    new_conv = ext[:, 1:]

    dt, Bm, Cm = _mamba1_ssm_params(p, u[:, None], d_state=d_state,
                                    bcdt_rms=bcdt_rms)
    dt, Bm, Cm = dt[:, 0], Bm[:, 0], Cm[:, 0]
    A = -jnp.exp(p["A_log"])
    uf = u.astype(jnp.float32)
    dA = jnp.exp(dt[:, :, None] * A[None])
    h = dA * state.ssm + (dt * uf)[:, :, None] * Bm[:, None, :]
    yt = jnp.einsum("bdn,bn->bd", h, Cm) + uf * p["D"][None]
    yt = yt.astype(x.dtype) * jax.nn.silu(z)
    return (yt @ p["out_proj"])[:, None], Mamba1State(new_conv, h)


def make_mamba1_state(batch: int, d_model: int, *, d_state: int = 16,
                      d_conv: int = 4, expand: int = 2,
                      dtype=jnp.float32) -> Mamba1State:
    dI = expand * d_model
    return Mamba1State(jnp.zeros((batch, d_conv - 1, dI), dtype),
                       jnp.zeros((batch, dI, d_state), jnp.float32))


# =================================================================== Mamba-2
class Mamba2State(NamedTuple):
    conv: jax.Array     # (B, d_conv-1, conv_dim)
    ssm: jax.Array      # (B, H, headdim, d_state) f32


def init_mamba2(key, d_model: int, *, d_state: int = 64, d_conv: int = 4,
                expand: int = 2, headdim: int = 64,
                dtype=jnp.float32) -> Params:
    dI = expand * d_model
    H = dI // headdim
    conv_dim = dI + 2 * d_state
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    return {
        # projects to [u (dI), B (N), C (N), dt (H), z (dI)]
        "in_proj": (jax.random.normal(
            ks[0], (d_model, 2 * dI + 2 * d_state + H)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, conv_dim)) * 0.5
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((dI,), dtype),
        "out_proj": (jax.random.normal(ks[3], (dI, d_model))
                     / math.sqrt(dI)).astype(dtype),
    }


def mamba2_forward(p: Params, x, *, d_state: int = 64, headdim: int = 64,
                   chunk: int = 128, bf16_einsum: bool = False,
                   state: Optional[Mamba2State] = None,
                   return_state: bool = False
                   ) -> Tuple[jax.Array, Optional[Mamba2State]]:
    """SSD chunked matmul form. x (B, S, d) → (B, S, d).

    ``bf16_einsum`` casts the large einsum operands (decay/Q² tensors, u, B,
    C) to bf16 with f32 accumulation — halves the HBM traffic of the SSD
    block at bf16-roundoff cost (decays ≤ 1, products well-conditioned);
    the log-decay cumsum stays f32.
    """
    B_, S, d = x.shape
    conv_dim = p["conv_w"].shape[1]
    dI = p["out_proj"].shape[0]
    H = dI // headdim

    zxbcdt = x @ p["in_proj"]
    z, ubc, dt_raw = jnp.split(zxbcdt, [dI, dI + conv_dim - dI + 0
                                        + 2 * d_state + dI - dI], axis=-1) \
        if False else (zxbcdt[..., :dI],
                       zxbcdt[..., dI:dI + conv_dim],
                       zxbcdt[..., dI + conv_dim:])
    conv_state = state.conv if state is not None else None
    ubc, new_conv = _causal_conv({"conv_w": p["conv_w"],
                                  "conv_b": p["conv_b"]}, ubc, conv_state)
    # stream dtype: natively bf16 when bf16_einsum (halves the (B,S,·) HBM
    # traffic that dominates t_mem); log-decay/dt stay f32 always
    sd = x.dtype if bf16_einsum else jnp.float32
    u = ubc[..., :dI]
    Bm = ubc[..., dI:dI + d_state].astype(sd)               # (B,S,N)
    Cm = ubc[..., dI + d_state:].astype(sd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                 # (H,)

    uh = u.astype(sd).reshape(B_, S, H, headdim)
    la = dt * A[None, None, :]                               # log decay (B,S,H)

    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) *
                               (a.ndim - 2))
        uh2, Bm2, Cm2, la2, dt2 = (jnp.pad(uh, ((0, 0), (0, pad), (0, 0),
                                                (0, 0))),
                                   zp(Bm), zp(Cm), zp(la), zp(dt))
    else:
        uh2, Bm2, Cm2, la2, dt2 = uh, Bm, Cm, la, dt
    T = uh2.shape[1] // Q

    def tochunks(a):
        return a.reshape((B_, T, Q) + a.shape[2:])

    uc, bc, cc, lc, dc = map(tochunks, (uh2, Bm2, Cm2, la2, dt2))
    # cumulative log-decay within chunk
    Lc = jnp.cumsum(lc, axis=2)                              # (B,T,Q,H)

    # intra-chunk: y[t] = Σ_{s≤t} C_t·B_s exp(L_t−L_s) dt_s u_s
    # (mask in log space: exp(L_t−L_s) overflows for t<s before masking)
    et = jnp.bfloat16 if bf16_einsum else jnp.float32

    def cast(a):
        return a.astype(et)

    cb = jnp.einsum("btqn,btsn->btqs", cast(cc), cast(bc),
                    preferred_element_type=jnp.float32)      # (B,T,Q,Q)
    diff = Lc[:, :, :, None, :] - Lc[:, :, None, :, :]       # (B,T,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], diff, -jnp.inf))
    M = cast(cb[..., None]) * cast(decay)
    y_intra = jnp.einsum("btqsh,btsh,btshp->btqhp", M, cast(dc), cast(uc),
                         preferred_element_type=jnp.float32).astype(sd)

    # chunk states: S_c = Σ_s exp(L_Q − L_s) dt_s B_s ⊗ u_s
    dec_end = jnp.exp(Lc[:, :, -1:, :] - Lc)                 # (B,T,Q,H)
    Sc = jnp.einsum("btsh,btsh,btsn,btshp->bthnp",
                    cast(dec_end), cast(dc), cast(bc), cast(uc),
                    preferred_element_type=jnp.float32)      # (B,T,H,N,hp)

    # inter-chunk recurrence over T (tiny scan)
    chunk_decay = jnp.exp(Lc[:, :, -1, :])                   # (B,T,H)
    h0 = (state.ssm.transpose(0, 1, 3, 2) if state is not None
          else jnp.zeros((B_, H, d_state, headdim), jnp.float32))

    def inter(h, inp):
        sc, cd = inp                                         # (B,H,N,hp),(B,H)
        h_out = h                                            # state entering
        h = h * cd[:, :, None, None] + sc
        return h, h_out

    h_fin, h_in = jax.lax.scan(
        inter, h0, (Sc.transpose(1, 0, 2, 3, 4),
                    chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                     # (B,T,H,N,hp)

    # inter-chunk contribution: C_t exp(L_t) h_in
    y_inter = jnp.einsum("btqn,btqh,bthnp->btqhp",
                         cast(cc), cast(jnp.exp(Lc)), cast(h_in),
                         preferred_element_type=jnp.float32).astype(sd)
    y = (y_intra + y_inter).reshape(B_, T * Q, H, headdim)[:, :S]
    y = y + uh * p["D"][None, None, :, None].astype(sd)
    y = y.reshape(B_, S, dI).astype(x.dtype)
    y = _rms(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"]
    new_state = None
    if return_state:
        new_state = Mamba2State(new_conv, h_fin.transpose(0, 1, 3, 2))
    return out, new_state


def mamba2_step(p: Params, x, state: Mamba2State, *, d_state: int = 64,
                headdim: int = 64) -> Tuple[jax.Array, Mamba2State]:
    """Single-token decode. x (B, 1, d)."""
    B_, _, d = x.shape
    dI = p["out_proj"].shape[0]
    H = dI // headdim
    conv_dim = p["conv_w"].shape[1]
    zxbcdt = x[:, 0] @ p["in_proj"]
    z = zxbcdt[:, :dI]
    ubc = zxbcdt[:, dI:dI + conv_dim]
    dt_raw = zxbcdt[:, dI + conv_dim:]
    ext = jnp.concatenate([state.conv.astype(ubc.dtype), ubc[:, None]],
                          axis=1)
    yc = jnp.einsum("bkd,kd->bd", ext, p["conv_w"]) + p["conv_b"]
    ubc = jax.nn.silu(yc)
    new_conv = ext[:, 1:]
    u = ubc[:, :dI].astype(jnp.float32).reshape(B_, H, headdim)
    Bm = ubc[:, dI:dI + d_state].astype(jnp.float32)
    Cm = ubc[:, dI + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None])                               # (B,H)
    h = state.ssm * dA[:, :, None, None] \
        + (dt[:, :, None] * u)[:, :, :, None] * Bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Cm) + u * p["D"][None, :, None]
    y = y.reshape(B_, dI).astype(x.dtype)
    y = _rms(y * jax.nn.silu(z), p["norm"])
    return (y @ p["out_proj"])[:, None], Mamba2State(new_conv, h)


def make_mamba2_state(batch: int, d_model: int, *, d_state: int = 64,
                      d_conv: int = 4, expand: int = 2, headdim: int = 64,
                      dtype=jnp.float32) -> Mamba2State:
    dI = expand * d_model
    H = dI // headdim
    conv_dim = dI + 2 * d_state
    return Mamba2State(jnp.zeros((batch, d_conv - 1, conv_dim), dtype),
                       jnp.zeros((batch, H, headdim, d_state), jnp.float32))
