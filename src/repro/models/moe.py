"""Mixture-of-Experts layer (Mixtral: 8 experts, top-2 routing).

Dispatch follows the standard TPU formulation (GShard/Switch): tokens are
routed to per-expert capacity buffers with one-hot dispatch/combine einsums,
so the expert FFN is a dense batched (E, cap, d)×(E, d, ff) einsum — MXU
work, shardable over either the model axis (TP inside experts) or an expert
axis (EP with all-to-all). The *placement* of experts onto devices is where
the paper's C2 shows up: ``distributed/pipeline.py::place_experts`` balances
measured expert load via the graph partitioner.

Router stats (per-expert token counts) are returned for exactly that load
measurement — SWIFT's "effective cost after execution".
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def init_moe(key, d_model: int, d_ff: int, n_experts: int, *,
             dtype=jnp.float32) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    E = n_experts
    return {
        "router": (jax.random.normal(k1, (d_model, E)) * s_in).astype(dtype),
        "wi": (jax.random.normal(k2, (E, d_model, d_ff)) * s_in).astype(dtype),
        "wg": (jax.random.normal(k3, (E, d_model, d_ff)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k4, (E, d_ff, d_model)) * s_out).astype(dtype),
    }


class MoEStats(NamedTuple):
    tokens_per_expert: jax.Array    # (E,) float — the measured load signal
    aux_loss: jax.Array             # scalar load-balancing loss
    dropped_fraction: jax.Array     # scalar


def moe(p: Params, x, *, top_k: int = 2, capacity_factor: float = 1.25,
        group_size: int = 1024, act=jax.nn.silu
        ) -> Tuple[jax.Array, MoEStats]:
    """x (B, S, d) → (B, S, d), top-k routing with capacity buffers.

    Tokens are processed in groups of ``group_size`` (GShard): the dispatch
    one-hot is (G, group, E, cap) — kept small per group and contracted
    immediately, so the materialised footprint stays ~10 MB/group instead of
    O(N²/E).
    """
    B, S, d = x.shape
    E = p["router"].shape[1]
    N = B * S
    g = min(group_size, N)
    while N % g:
        g //= 2                    # N is a power-of-two times batch in practice
    G = N // g
    cap = int(math.ceil(top_k * g / E * capacity_factor))
    cap = max(cap, top_k)

    xt = x.reshape(G, g, d)
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, g, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (G, g, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # slot of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)    # (G, g, k, E)
    flat = onehot.reshape(G, g * top_k, E)
    pos = jnp.cumsum(flat, axis=1) * flat                    # 1-based
    pos = pos.reshape(G, g, top_k, E).sum(-1) - 1            # (G, g, k)
    keep = pos < cap

    # gather-based dispatch (no one-hot einsums: honest FLOPs, tiny memory)
    def gather_group(xg, e_idx, slot, ok):
        gk = g * top_k
        tok = jnp.arange(gk, dtype=jnp.int32) // top_k
        e_f = e_idx.reshape(gk)
        s_f = slot.reshape(gk)
        ok_f = ok.reshape(gk)
        dest = jnp.where(ok_f, e_f * cap + s_f, E * cap)     # drop overflow
        src = jnp.full((E * cap,), -1, jnp.int32).at[dest].set(
            tok, mode="drop")
        gathered = jnp.where((src >= 0)[:, None],
                             xg[jnp.maximum(src, 0)], 0.0)   # (E·cap, d)
        return gathered.reshape(E, cap, d)

    def combine_group(out_e, e_idx, slot, ok, gv):
        gk = g * top_k
        e_f = e_idx.reshape(gk)
        s_f = slot.reshape(gk)
        ok_f = ok.reshape(gk)
        back = out_e.reshape(E * cap, d)[jnp.where(ok_f, e_f * cap + s_f, 0)]
        back = back * (ok_f.astype(out_e.dtype)
                       * gv.reshape(gk).astype(out_e.dtype))[:, None]
        return back.reshape(g, top_k, d).sum(1)

    expert_in = jax.vmap(gather_group)(xt, gate_idx, pos, keep)  # (G,E,cap,d)
    # single batched FFN across all groups: the expert-weight gradient is
    # one contraction instead of G per-group cotangents (memory: O(E·d·ff),
    # not O(G·E·d·ff))
    e_in = expert_in.transpose(1, 0, 2, 3).reshape(E, G * cap, d)
    h = act(jnp.einsum("end,edf->enf", e_in, p["wg"].astype(xt.dtype))) \
        * jnp.einsum("end,edf->enf", e_in, p["wi"].astype(xt.dtype))
    out_flat = jnp.einsum("enf,efd->end", h, p["wo"].astype(xt.dtype))
    back = out_flat.reshape(E, G, cap, d).transpose(1, 0, 2, 3)
    out = jax.vmap(combine_group)(back, gate_idx, pos, keep, gate_vals)

    # stats: measured load (C2's cost signal) + Switch aux loss
    me = probs.mean((0, 1))                                  # (E,)
    ce = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32).mean((0, 1))
    aux = E * jnp.sum(me * ce)
    counts = flat.sum((0, 1)).astype(jnp.float32)
    dropped = 1.0 - keep.mean()
    return out.reshape(B, S, d), MoEStats(counts, aux,
                                          dropped.astype(jnp.float32))
