"""Model primitives shared by every assigned architecture.

Pure functions over parameter pytrees (plain nested dicts of jax arrays) —
no framework dependency. Every primitive supports three modes:

* train/prefill  — full sequence, optional causal/banded mask,
* prefill        — as train but returns a KV cache,
* decode         — q_len==1 against a cache (full or rolling window).

Variant knobs cover the zoo: GQA (n_kv < n_heads), QKV bias (qwen),
head_dim ≠ d_model/n_heads (gemma), sliding window (mixtral, gemma3 local
layers), per-layer RoPE base (gemma3 local vs global), QK-norm (gemma3),
logit soft-capping, GeGLU vs SwiGLU.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------- norms
def rmsnorm(x, w, *, eps: float = 1e-6, plus_one: bool = False):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (xf * inv * scale).astype(dt)


def layernorm(x, w, b, *, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------- rope
def rope_tables(positions, head_dim: int, base: float = 10000.0):
    """positions (…,) int → cos, sin of shape (…, head_dim/2), f32."""
    half = head_dim // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, S, H, hd); cos/sin (S, hd/2) or (B, S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    else:
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    c = c.astype(x.dtype)
    s = s.astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ------------------------------------------------------------ activations
def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(
        jax.nn.gelu, approximate=True), "relu": jax.nn.relu}[name]


# ------------------------------------------------------------------- MLP
def init_mlp(key, d_model: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "wi": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "wg": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }


def mlp(p: Params, x, *, act: str = "silu"):
    """Gated MLP: act(x·wg) ⊙ (x·wi) · wo  (SwiGLU for silu, GeGLU for gelu)."""
    g = _act(act)(x @ p["wg"])
    h = g * (x @ p["wi"])
    return h @ p["wo"]


# -------------------------------------------------------------- attention
@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    softcap: Optional[float] = None
    rope_base: float = 10000.0
    window: Optional[int] = None          # sliding-window size (None = full)
    causal: bool = True


def init_attention(key, spec: AttnSpec, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    d, H, K, hd = spec.d_model, spec.n_heads, spec.n_kv, spec.head_dim
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(ks[0], (d, H * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, K * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, K * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H * hd, d))
               / math.sqrt(H * hd)).astype(dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    if spec.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


class KVCache(NamedTuple):
    """Dense KV cache. ``rolling=True`` → size is the sliding window and
    writes wrap (only valid for window attention)."""
    k: jax.Array          # (B, S_cache, n_kv, hd)
    v: jax.Array          # (B, S_cache, n_kv, hd)
    pos: jax.Array        # scalar int32: #tokens already absorbed


def make_cache(batch: int, length: int, spec: AttnSpec, *,
               dtype=jnp.float32) -> KVCache:
    shape = (batch, length, spec.n_kv, spec.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def _banded_sdpa(q, k, v, window: int, *, softcap=None):
    """Block-banded causal sliding-window attention (XLA-native).

    Queries are tiled into window-aligned blocks; block i attends only to
    key blocks i−1 and i, so the score buffer is (…, S/W, W, 2W) — S·2W
    instead of S² (4× smaller for gemma3 train, 16× for mixtral prefill).
    The (W, 2W) relative mask is identical for every block (block-aligned
    banding), so it folds into one static constant.
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    W = window
    nb = S // W
    qb = q.reshape(B, nb, W, K, G, hd)
    kb = k.reshape(B, nb, W, K, hd)
    vb = v.reshape(B, nb, W, K, hd)
    zero = jnp.zeros_like(kb[:, :1])
    k2 = jnp.concatenate([jnp.concatenate([zero, kb[:, :-1]], 1), kb], 2)
    v2 = jnp.concatenate([jnp.concatenate([zero, vb[:, :-1]], 1), vb], 2)
    # relative mask: q at local a (global iW+a), key j of the 2W tile sits
    # at global (i−1)W + j ⇒ diff = W + a − j; valid iff 0 ≤ diff < W,
    # and tile positions j < W are invalid for block 0 (no previous block).
    a = jnp.arange(W)[:, None]
    j = jnp.arange(2 * W)[None, :]
    diff = W + a - j
    ok = (diff >= 0) & (diff < W)
    mask = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)      # (W, 2W)
    first = jnp.where(j < W, -1e30, 0.0).astype(jnp.float32)  # block 0 extra
    blk = jnp.arange(nb)[:, None, None]
    full_mask = mask[None] + jnp.where(blk == 0, first[None], 0.0)

    scores = jnp.einsum("bnakgh,bnjkh->bkgnaj", qb, k2
                        ).astype(jnp.float32) / math.sqrt(hd)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = scores + full_mask[None, None, None]
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgnaj,bnjkh->bnakgh", p, v2)
    return out.reshape(B, S, H * hd)


def _sdpa(q, k, v, mask, *, softcap=None):
    """q (B,S,H,hd), k/v (B,T,K,hd) with H = K·G. mask (B?,S,T) additive."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    q = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = scores + mask[:, None, None, :, :]
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, S, H * hd)


def _train_mask(q_pos, k_pos, *, causal: bool, window: Optional[int],
                valid=None):
    """Additive mask (S, T) from query/key absolute positions."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = jnp.ones(dq.shape[:1] + dk.shape[1:], bool)
    if causal:
        ok &= dk <= dq
    if window is not None:
        ok &= dk > dq - window
    m = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)
    if valid is not None:
        m = m + jnp.where(valid, 0.0, -1e30)[:, None, :].astype(jnp.float32)
        return m
    return m[None]


def attention(p: Params, x, spec: AttnSpec, *,
              cos=None, sin=None, cache: Optional[KVCache] = None,
              update_cache: bool = False, rolling: bool = False,
              kv_x=None, cross: bool = False,
              ) -> Tuple[jax.Array, Optional[KVCache]]:
    """Full-featured attention.

    Modes:
      * cache None, update False  — training forward (full sequence).
      * cache None, update True   — prefill: also return the built cache.
      * cache given, cross=False  — decode: append q_len tokens to the cache
                                    (wrap-around writes if ``rolling``).
      * cache given, cross=True   — decode cross-attention: read-only cache
                                    built from the encoder at prefill.
    ``kv_x`` — separate KV source (cross-attention prefill).
    """
    B, S, _ = x.shape
    H, K, hd = spec.n_heads, spec.n_kv, spec.head_dim

    q = x @ p["wq"]
    if spec.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)
    if spec.qk_norm:
        q = rmsnorm(q, p["q_norm"])

    if cross and cache is not None:
        # read-only cross-attention against the encoder cache (no RoPE)
        mask = jnp.zeros((1, S, cache.k.shape[1]), jnp.float32)
        out = _sdpa(q, cache.k, cache.v, mask, softcap=spec.softcap)
        return out @ p["wo"], cache

    src = x if kv_x is None else kv_x
    Skv = src.shape[1]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if spec.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(B, Skv, K, hd)
    v = v.reshape(B, Skv, K, hd)
    if spec.qk_norm:
        k = rmsnorm(k, p["k_norm"])
    if not cross:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)      # self-attn: S == Skv always

    new_cache = None
    if cache is not None:
        # decode: append k/v at cache.pos (wrapping if rolling)
        T = cache.k.shape[1]
        start = jnp.where(rolling, cache.pos % T, cache.pos)
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), start, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), start, axis=1)
        new_cache = KVCache(ck, cv, cache.pos + S)
        # absolute key positions held by each cache slot
        slot = jnp.arange(T)
        if rolling:
            cur = cache.pos + S - 1
            kpos = slot + ((cur - slot) // T) * T    # largest ≡slot ≤ cur
            kvalid = kpos >= 0
        else:
            kpos = slot
            kvalid = slot < cache.pos + S
        qpos = cache.pos + jnp.arange(S)
        mask = _train_mask(qpos, kpos, causal=spec.causal,
                           window=spec.window)[0]
        mask = mask + jnp.where(kvalid, 0.0, -1e30)[None, :]
        out = _sdpa(q, ck, cv, mask[None], softcap=spec.softcap)
    else:
        banded = (spec.window is not None and spec.causal and not cross
                  and S == Skv and S % spec.window == 0
                  and S // spec.window >= 2)
        if banded:
            out = _banded_sdpa(q, k, v, spec.window, softcap=spec.softcap)
        else:
            if cross or not spec.causal:
                mask = jnp.zeros((1, S, Skv), jnp.float32)
            else:
                pos = jnp.arange(S)
                mask = _train_mask(pos, pos, causal=True, window=spec.window)
            out = _sdpa(q, k, v, mask, softcap=spec.softcap)
        if update_cache:
            new_cache = KVCache(k, v, jnp.asarray(Skv, jnp.int32))

    return out @ p["wo"], new_cache
