"""LM model zoo for the assigned architectures."""

from .config import ModelConfig
from .layers import AttnSpec, KVCache, attention, mlp, rmsnorm, rope_tables
from .mamba import (Mamba1State, Mamba2State, mamba1_forward, mamba1_step,
                    mamba2_forward, mamba2_step)
from .moe import MoEStats, moe
from .model import (ForwardResult, forward, init_params, lm_loss, make_caches,
                    plan_segments)

__all__ = [
    "ModelConfig", "AttnSpec", "KVCache", "attention", "mlp", "rmsnorm",
    "rope_tables", "Mamba1State", "Mamba2State", "mamba1_forward",
    "mamba1_step", "mamba2_forward", "mamba2_step", "MoEStats", "moe",
    "ForwardResult", "forward", "init_params", "lm_loss", "make_caches",
    "plan_segments",
]
