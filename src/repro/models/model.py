"""Model assembly: blocks, segment machinery, forward / prefill / decode.

A model is a list of **segments** — (pattern, repeats) pairs where the
pattern is a static tuple of block kinds (e.g. gemma3's
``("local",)*5 + ("global",)``). Parameters and caches are stacked along the
repeat dimension, and each segment lowers to a two-level ``lax.scan``:

    outer scan over repeat groups  →  checkpointed inner scan over the group

which is the sqrt-remat that keeps layer-boundary residuals at
O(L/G · B·S·d) HBM while emitting one compact HLO body per segment (compile
time stays flat in depth — essential for the 40-cell dry run).

Block kinds:
  attn     dense pre-norm attention + gated MLP (qwen/gemma/granite/…)
  local    sliding-window attention + MLP (gemma3 local layers)
  global   full attention + MLP, long-RoPE (gemma3 global layers)
  moe      attention + top-k MoE FFN (mixtral)
  mamba1   Mamba-1 mixer (falcon-mamba)
  mamba2   Mamba-2/SSD mixer (zamba2 backbone)
  mamba2s  shared-attention block (+ per-invocation LoRA) then Mamba-2
           (zamba2's shared block, params reused across invocations)
  enc      bidirectional attention + MLP (encoder)
  dec      causal self-attn + cross-attn + MLP (decoder)
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (AttnSpec, KVCache, apply_rope, attention, init_attention,
                     init_mlp, layernorm, mlp, rmsnorm, rope_tables)
from .mamba import (Mamba1State, Mamba2State, init_mamba1, init_mamba2,
                    make_mamba1_state, make_mamba2_state, mamba1_forward,
                    mamba1_step, mamba2_forward, mamba2_step)
from .moe import MoEStats, init_moe, moe

Params = Dict[str, Any]


# ------------------------------------------------------------------ helpers
def _norm(cfg: ModelConfig, w, x):
    return rmsnorm(x, w, plus_one=cfg.rms_plus_one)


def attn_spec(cfg: ModelConfig, kind: str) -> AttnSpec:
    window = None
    base = cfg.rope_base
    if kind == "local":
        window = cfg.local_window
    elif kind == "global":
        base = cfg.global_rope_base
    elif cfg.window is not None and kind in ("attn", "moe"):
        window = cfg.window
    return AttnSpec(d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                    head_dim=cfg.head_dim, qkv_bias=cfg.qkv_bias,
                    qk_norm=cfg.qk_norm, softcap=cfg.attn_softcap,
                    rope_base=base, window=window, causal=(kind != "enc"))


def shared_attn_spec(cfg: ModelConfig) -> AttnSpec:
    """Zamba2's shared block runs at concat width 2·d_model."""
    return AttnSpec(d_model=2 * cfg.d_model, n_heads=cfg.n_heads,
                    n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                    rope_base=cfg.rope_base, causal=True)


def plan_segments(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """Decoder-side segments (encoder handled separately)."""
    L = cfg.n_layers
    if cfg.local_global is not None:
        loc, glob = cfg.local_global
        k = loc + glob
        segs: List[Tuple[Tuple[str, ...], int]] = []
        if L // k:
            segs.append((("local",) * loc + ("global",) * glob, L // k))
        if L % k:
            segs.append((("local",) * (L % k), 1))
        return segs
    if cfg.family == "moe":
        return [(("moe",), L)]
    if cfg.ssm == "mamba1":
        return [(("mamba1",), L)]
    if cfg.shared_attn_every:
        k = cfg.shared_attn_every
        segs = []
        if L // k:
            segs.append((("mamba2s",) + ("mamba2",) * (k - 1), L // k))
        if L % k:
            segs.append((("mamba2",) * (L % k), 1))
        return segs
    if cfg.ssm == "mamba2":
        return [(("mamba2",), L)]
    if cfg.is_encdec:
        return [(("dec",), L)]
    return [(("attn",), L)]


def _group(repeats: int, target: int) -> int:
    """Largest divisor of ``repeats`` that is ≤ target (≥1)."""
    g = 1
    for d in range(1, min(repeats, target) + 1):
        if repeats % d == 0:
            g = d
    return g


# ----------------------------------------------------------- block init
def init_block(key, cfg: ModelConfig, kind: str) -> Params:
    dt = cfg.dtype
    d = cfg.d_model
    if kind in ("attn", "local", "global", "enc", "moe", "dec"):
        k1, k2, k3 = jax.random.split(key, 3)
        p: Params = {
            "ln1": jnp.zeros((d,), dt) if cfg.rms_plus_one
            else jnp.ones((d,), dt),
            "ln2": jnp.zeros((d,), dt) if cfg.rms_plus_one
            else jnp.ones((d,), dt),
            "attn": init_attention(k1, attn_spec(cfg, kind), dtype=dt),
        }
        if kind == "moe":
            p["ffn"] = init_moe(k2, d, cfg.d_ff, cfg.n_experts, dtype=dt)
        else:
            p["ffn"] = init_mlp(k2, d, cfg.d_ff, dtype=dt)
        if kind == "dec":
            k4, k5 = jax.random.split(k3)
            p["ln_x"] = jnp.ones((d,), dt)
            p["xattn"] = init_attention(k4, attn_spec(cfg, "dec"), dtype=dt)
        return p
    if kind == "mamba1":
        k1, = jax.random.split(key, 1)
        return {
            "ln1": jnp.ones((d,), dt),
            "mix": init_mamba1(k1, d, d_state=cfg.d_state, d_conv=cfg.d_conv,
                               expand=cfg.expand, bcdt_rms=True, dtype=dt),
        }
    if kind in ("mamba2", "mamba2s"):
        k1, k2 = jax.random.split(key, 2)
        p = {
            "ln1": jnp.ones((d,), dt),
            "mix": init_mamba2(k1, d, d_state=cfg.d_state, d_conv=cfg.d_conv,
                               expand=cfg.expand, headdim=cfg.ssm_headdim,
                               dtype=dt),
        }
        if kind == "mamba2s":
            # per-invocation LoRA on the shared block's output projection
            r = cfg.shared_lora_rank
            ka, kb = jax.random.split(k2)
            p["lora_a"] = (jax.random.normal(ka, (2 * d, r))
                           / math.sqrt(2 * d)).astype(dt)
            p["lora_b"] = jnp.zeros((r, d), dt)
        return p
    raise ValueError(f"unknown block kind {kind!r}")


def init_shared_block(key, cfg: ModelConfig) -> Params:
    """Zamba2 shared transformer block at width 2·d_model, projecting to d."""
    dt = cfg.dtype
    d2 = 2 * cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((d2,), dt),
        "ln2": jnp.ones((d2,), dt),
        "attn": init_attention(k1, shared_attn_spec(cfg), dtype=dt),
        "ffn": init_mlp(k2, d2, cfg.d_ff, dtype=dt),
        "out": (jax.random.normal(k3, (d2, cfg.d_model))
                / math.sqrt(d2)).astype(dt),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 8)
    p: Params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_padded, cfg.d_model))
                  * 0.02).astype(cfg.dtype),
        "ln_f": jnp.zeros((cfg.d_model,), cfg.dtype) if cfg.rms_plus_one
        else jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(keys[1],
                                       (cfg.d_model, cfg.vocab_padded))
                     / math.sqrt(cfg.d_model)).astype(cfg.dtype)

    def stack_init(kind: str, n: int, key):
        ks = jax.random.split(key, n)
        return jax.vmap(lambda k: init_block(k, cfg, kind))(ks)

    segs = plan_segments(cfg)
    p["segments"] = []
    for si, (pattern, repeats) in enumerate(segs):
        kseg = jax.random.fold_in(keys[2], si)
        pos_params = []
        for pi, kind in enumerate(pattern):
            pos_params.append(stack_init(kind, repeats,
                                         jax.random.fold_in(kseg, pi)))
        p["segments"].append(pos_params)

    if cfg.shared_attn_every:
        p["shared"] = init_shared_block(keys[3], cfg)
    if cfg.is_encdec:
        enc_params = []
        ks = jax.random.split(keys[4], cfg.n_enc_layers)
        enc_params = jax.vmap(lambda k: init_block(k, cfg, "enc"))(ks)
        p["encoder"] = enc_params
        p["enc_ln_f"] = jnp.ones((cfg.d_model,), cfg.dtype)
    return p


# -------------------------------------------------------------------- caches
def rolling_map(cfg: ModelConfig, cache_len: int) -> Dict[str, bool]:
    """Which attention kinds use wrap-around (rolling) KV caches at this
    cache length — static metadata needed alongside abstract caches."""
    rolling: Dict[str, bool] = {}
    for pattern, _ in plan_segments(cfg):
        for kind in pattern:
            if kind in ("attn", "local", "global", "moe", "enc", "dec"):
                spec = attn_spec(cfg, kind)
                rolling[kind] = (spec.window is not None
                                 and cache_len > spec.window)
    return rolling


def make_caches(cfg: ModelConfig, batch: int, cache_len: int, *,
                enc_len: int = 0, stacked: bool = True
                ) -> Tuple[list, Dict[str, bool]]:
    """Zero caches for decode, sized per block kind. Returns (caches,
    rolling_map: kind → whether its KV cache wraps).

    ``stacked=True`` → leaves carry a leading repeats dim (scan layout,
    prefill). ``stacked=False`` → per-layer list (decode layout: the decode
    step unrolls layers so every cache update aliases in place instead of
    double-buffering through scan xs/ys — at 32k context the KV cache is
    the dominant HBM tenant and must not be copied)."""
    rolling: Dict[str, bool] = {}

    def kv_len(kind: str) -> int:
        spec = attn_spec(cfg, kind)
        if spec.window is not None and cache_len > spec.window:
            rolling[kind] = True
            return spec.window
        rolling.setdefault(kind, False)
        return cache_len

    def block_cache(kind: str):
        if kind in ("attn", "local", "global", "moe", "enc"):
            spec = attn_spec(cfg, kind)
            L = kv_len(kind)
            sh = (batch, L, spec.n_kv, spec.head_dim)
            return KVCache(jnp.zeros(sh, cfg.dtype), jnp.zeros(sh, cfg.dtype),
                           jnp.zeros((), jnp.int32))
        if kind == "dec":
            spec = attn_spec(cfg, kind)
            sh = (batch, kv_len(kind), spec.n_kv, spec.head_dim)
            self_c = KVCache(jnp.zeros(sh, cfg.dtype),
                             jnp.zeros(sh, cfg.dtype),
                             jnp.zeros((), jnp.int32))
            shx = (batch, enc_len, spec.n_kv, spec.head_dim)
            cross_c = KVCache(jnp.zeros(shx, cfg.dtype),
                              jnp.zeros(shx, cfg.dtype),
                              jnp.asarray(enc_len, jnp.int32))
            return (self_c, cross_c)
        if kind == "mamba1":
            return make_mamba1_state(batch, cfg.d_model, d_state=cfg.d_state,
                                     d_conv=cfg.d_conv, expand=cfg.expand,
                                     dtype=cfg.dtype)
        if kind == "mamba2":
            return make_mamba2_state(batch, cfg.d_model, d_state=cfg.d_state,
                                     d_conv=cfg.d_conv, expand=cfg.expand,
                                     headdim=cfg.ssm_headdim, dtype=cfg.dtype)
        if kind == "mamba2s":
            spec = shared_attn_spec(cfg)
            sh = (batch, cache_len, spec.n_kv, spec.head_dim)
            kvc = KVCache(jnp.zeros(sh, cfg.dtype), jnp.zeros(sh, cfg.dtype),
                          jnp.zeros((), jnp.int32))
            return (kvc,
                    make_mamba2_state(batch, cfg.d_model,
                                      d_state=cfg.d_state, d_conv=cfg.d_conv,
                                      expand=cfg.expand,
                                      headdim=cfg.ssm_headdim,
                                      dtype=cfg.dtype))
        raise ValueError(kind)

    caches = []
    for (pattern, repeats) in plan_segments(cfg):
        pos = []
        for kind in pattern:
            one = block_cache(kind)
            if stacked:
                pos.append(jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (repeats,) + a.shape),
                    one))
            else:
                pos.append([jax.tree.map(jnp.copy, one)
                            for _ in range(repeats)])
        caches.append(pos)
    return caches, rolling


# --------------------------------------------------------------- block apply
@dataclasses.dataclass
class BlockIO:
    cfg: ModelConfig
    mode: str                                  # train | prefill | decode
    rope: Dict[str, Tuple[jax.Array, jax.Array]]
    rolling: Dict[str, bool]
    enc_out: Optional[jax.Array] = None
    shared: Optional[Params] = None
    x0: Optional[jax.Array] = None             # zamba2: initial embedding
    constrain: Callable = lambda x, kind=None: x


def _zero_aux(cfg: ModelConfig):
    E = max(cfg.n_experts, 1)
    return (jnp.zeros((), jnp.float32), jnp.zeros((E,), jnp.float32))


def apply_block(p: Params, x, kind: str, io: BlockIO, cache):
    cfg = io.cfg
    aux = _zero_aux(cfg)
    decode = io.mode == "decode"
    prefill = io.mode == "prefill"

    if kind in ("attn", "local", "global", "enc", "moe", "dec"):
        spec = attn_spec(cfg, kind)
        cos, sin = io.rope["global" if kind == "global" else "default"]
        self_cache = cache[0] if kind == "dec" and cache is not None else cache
        h = _norm(cfg, p["ln1"], x)
        a, new_kv = attention(
            p["attn"], h, spec, cos=cos, sin=sin,
            cache=self_cache if decode else None,
            update_cache=prefill,
            rolling=io.rolling.get(kind, False) and decode)
        x = io.constrain(x + a)
        if kind == "dec":
            h = _norm(cfg, p["ln_x"], x)
            if decode:
                xa, new_cross = attention(p["xattn"], h, spec, cross=True,
                                          cache=cache[1])
            else:
                xa, new_cross = attention(p["xattn"], h, spec, cross=True,
                                          kv_x=io.enc_out,
                                          update_cache=prefill)
            x = io.constrain(x + xa)
        h = _norm(cfg, p["ln2"], x)
        if kind == "moe":
            m, stats = moe(p["ffn"], h, top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor)
            aux = (stats.aux_loss, stats.tokens_per_expert)
        else:
            m = mlp(p["ffn"], h, act=cfg.act)
        x = io.constrain(x + m)
        if kind == "dec":
            new_cache = ((new_kv if new_kv is not None else None,
                          new_cross if new_cross is not None else None)
                         if (decode or prefill) else None)
        else:
            new_cache = new_kv
        return x, new_cache, aux

    if kind == "mamba1":
        h = _norm(cfg, p["ln1"], x)
        if decode and x.shape[1] == 1:
            y, new_state = mamba1_step(p["mix"], h, cache,
                                       d_state=cfg.d_state, bcdt_rms=True)
        else:
            y, new_state = mamba1_forward(
                p["mix"], h, d_state=cfg.d_state, chunk=cfg.ssm_chunk,
                bcdt_rms=True, state=cache if decode else None,
                return_state=decode or prefill)
        return io.constrain(x + y), new_state, aux

    if kind in ("mamba2", "mamba2s"):
        if kind == "mamba2s":
            kv_cache = cache[0] if cache is not None else None
            ssm_cache = cache[1] if cache is not None else None
            sh = io.shared
            spec = shared_attn_spec(cfg)
            cos, sin = io.rope["default"]
            xc = jnp.concatenate([x, io.x0], axis=-1)
            h = _norm(cfg, sh["ln1"], xc)
            a, new_kv = attention(sh["attn"], h, spec, cos=cos, sin=sin,
                                  cache=kv_cache if decode else None,
                                  update_cache=prefill)
            xc = xc + a
            h2 = _norm(cfg, sh["ln2"], xc)
            xc = xc + mlp(sh["ffn"], h2, act=cfg.act)
            delta = xc @ sh["out"] + (xc @ p["lora_a"]) @ p["lora_b"]
            x = io.constrain(x + delta)
        else:
            ssm_cache = cache
            new_kv = None
        h = _norm(cfg, p["ln1"], x)
        if decode and x.shape[1] == 1:
            y, new_state = mamba2_step(p["mix"], h, ssm_cache,
                                       d_state=cfg.d_state,
                                       headdim=cfg.ssm_headdim)
        else:
            y, new_state = mamba2_forward(
                p["mix"], h, d_state=cfg.d_state, headdim=cfg.ssm_headdim,
                chunk=cfg.ssm_chunk, bf16_einsum=cfg.ssm_bf16,
                state=ssm_cache if decode else None,
                return_state=decode or prefill)
        x = io.constrain(x + y)
        if kind == "mamba2s":
            return x, ((new_kv, new_state)
                       if (decode or prefill) else None), aux
        return x, new_state, aux

    raise ValueError(f"unknown block kind {kind!r}")


# ------------------------------------------------------------ segment runner
def run_segment(seg_params: list, seg_caches: Optional[list], x,
                pattern: Tuple[str, ...], repeats: int, io: BlockIO):
    """Two-level scan over one segment. Returns (x, new_caches, aux)."""
    G = _group(repeats, io.cfg.scan_group)
    R = repeats

    def regroup(tree):
        return jax.tree.map(
            lambda a: a.reshape((R // G, G) + a.shape[1:]), tree)

    aux0 = _zero_aux(io.cfg)
    with_caches = seg_caches is not None
    want_caches = io.mode in ("prefill", "decode")

    # decode with per-layer (unstacked) caches: unrolled python loop so
    # every cache update lowers to an in-place dynamic-update-slice on the
    # donated buffer (scan would double-buffer the KV through xs/ys)
    if (io.mode == "decode" and with_caches
            and isinstance(seg_caches[0], list)):
        aux = aux0
        new_caches: list = [[None] * R for _ in pattern]
        for r in range(R):
            for i, kind in enumerate(pattern):
                p_i = jax.tree.map(lambda a: a[r], seg_params[i])
                c_i = seg_caches[i][r]
                x, nc, a = apply_block(p_i, x, kind, io, c_i)
                new_caches[i][r] = nc
                aux = (aux[0] + a[0], aux[1] + a[1])
        return x, [list(nc) for nc in new_caches], aux

    def make_block_fn(kind: str):
        fn = lambda p, x, c: apply_block(p, x, kind, io, c)
        if io.mode == "train" and io.cfg.block_remat:
            # second remat level: recompute block internals (incl. the S×S
            # softmax) in the backward pass — only block inputs persist
            return jax.checkpoint(fn)
        return fn

    block_fns = [make_block_fn(kind) for kind in pattern]

    def inner_body(carry, xs):
        x, aux = carry
        new_caches = []
        for i, _kind in enumerate(pattern):
            p_i = xs[0][i]
            c_i = xs[1][i] if with_caches else None
            x, nc, a = block_fns[i](p_i, x, c_i)
            new_caches.append(nc)
            aux = (aux[0] + a[0], aux[1] + a[1])
        ys = tuple(new_caches) if want_caches else 0
        return (x, aux), ys

    def outer_body(carry, xs):
        return jax.lax.scan(inner_body, carry, xs)

    if io.mode == "train":
        outer = jax.checkpoint(outer_body)
    else:
        outer = outer_body

    xs_params = regroup(tuple(seg_params))
    xs_caches = regroup(tuple(seg_caches)) if with_caches else None
    if with_caches:
        xs = (xs_params, xs_caches)
    else:
        xs = (xs_params, xs_params)        # dummy second slot (unused)

    def body(carry, g_xs):
        return outer(carry, (g_xs[0], g_xs[1]))

    (x, aux), ys = jax.lax.scan(body, (x, aux0), xs)
    new_caches = None
    if want_caches:
        new_caches = jax.tree.map(
            lambda a: a.reshape((R,) + a.shape[2:]), ys)
        new_caches = list(new_caches)
    return x, new_caches, aux


# ----------------------------------------------------------------- top level
def _rope_for(cfg: ModelConfig, positions) -> Dict[str, tuple]:
    out = {"default": rope_tables(positions, cfg.head_dim, cfg.rope_base)}
    if cfg.local_global is not None:
        out["global"] = rope_tables(positions, cfg.head_dim,
                                    cfg.global_rope_base)
    else:
        out["global"] = out["default"]
    return out


def _run_encoder(params: Params, cfg: ModelConfig, enc_in, io: BlockIO):
    """Encoder stack over precomputed frame embeddings (audio stub)."""
    x = enc_in.astype(cfg.dtype)
    enc_io = dataclasses.replace(
        io, mode="train", enc_out=None,
        rope=_rope_for(cfg, jnp.arange(x.shape[1])))
    x, _, _ = run_segment([params["encoder"]], None, x, ("enc",),
                          cfg.n_enc_layers, enc_io)
    return rmsnorm(x, params["enc_ln_f"])


def _embed(params: Params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    return x


def _logits(params: Params, cfg: ModelConfig, x):
    x = _norm(cfg, params["ln_f"], x)
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["head"]


class ForwardResult(NamedTuple):
    logits: jax.Array
    caches: Optional[list]
    aux_loss: jax.Array
    expert_counts: jax.Array


def forward(params: Params, cfg: ModelConfig, tokens, *,
            mode: str = "train", caches: Optional[list] = None,
            rolling: Optional[Dict[str, bool]] = None,
            positions=None, enc_inputs=None, patch_embeds=None,
            constrain: Callable = lambda x, kind=None: x) -> ForwardResult:
    """Unified forward.

    train:   tokens (B, S)                          → logits (B, S, V)
    prefill: as train, returns caches
    decode:  tokens (B, S_small) + caches + positions → logits + new caches
    enc-dec: enc_inputs (B, S_enc, d) precomputed embeddings (stub frontend)
    vlm:     patch_embeds (B, P, d) prepended to token embeddings
    """
    B, S = tokens.shape
    x = _embed(params, cfg, tokens)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(cfg.dtype), x], axis=1)
        S = x.shape[1]
    if positions is None:
        positions = jnp.arange(S)
    io = BlockIO(cfg=cfg, mode=mode, rope=_rope_for(cfg, positions),
                 rolling=rolling or {}, constrain=constrain)
    if cfg.shared_attn_every:
        io.shared = params["shared"]
        io.x0 = x
    if cfg.is_encdec:
        if mode == "decode":
            io.enc_out = None          # cross caches already built
        else:
            assert enc_inputs is not None, "enc-dec needs encoder inputs"
            io.enc_out = _run_encoder(params, cfg, enc_inputs, io)

    aux = _zero_aux(cfg)
    new_caches = [] if mode in ("prefill", "decode") else None
    for si, (pattern, repeats) in enumerate(plan_segments(cfg)):
        seg_c = caches[si] if caches is not None else None
        x, nc, a = run_segment(params["segments"][si], seg_c, x, pattern,
                               repeats, io)
        if new_caches is not None:
            new_caches.append(nc)
        aux = (aux[0] + a[0], aux[1] + a[1])

    logits = _logits(params, cfg, x)
    return ForwardResult(logits, new_caches, aux[0], aux[1])


def lm_loss(params: Params, cfg: ModelConfig, tokens, targets, *,
            aux_weight: float = 0.01, constrain=lambda x, kind=None: x,
            enc_inputs=None, patch_embeds=None):
    """Causal LM cross-entropy (+ MoE aux loss)."""
    res = forward(params, cfg, tokens, mode="train", constrain=constrain,
                  enc_inputs=enc_inputs, patch_embeds=patch_embeds)
    logits = res.logits
    if patch_embeds is not None:
        logits = logits[:, patch_embeds.shape[1]:]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32),
                             targets[..., None], axis=-1)[..., 0]
    loss = (lse - ll).mean()
    if cfg.n_experts:
        loss = loss + aux_weight * res.aux_loss
    return loss, res.expert_counts
