"""Task-based SPH engine (single host): the paper's Fig. 1 pipeline in JAX.

The computation is modelled as a :class:`~repro.core.TaskGraph` — sort /
density / ghost / force / kick tasks over cells and cell pairs with the
paper's dependency structure — and *compiled* into a static wave program
(DESIGN.md §2 C1): each wave lowers to one batched op over every task of the
wave's kind. The numerical payloads are ``physics.density_block`` /
``physics.force_block`` vmapped over the cell-pair list, or the Pallas TPU
kernels in ``repro.kernels.sph_pair`` when ``use_pallas=True``.

Host-side re-binning between jitted steps plays the role of SWIFT's particle
exchange ("particles were exchanged whenever they strayed too far beyond
their cells").
"""

from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..core import CostModel, TaskGraph
from ..observability.tracer import NULL_TRACER
from .cellgrid import GridSpec, PairList, ParticleCells, bin_particles, \
    build_pair_list, choose_grid, unbin
from .physics import GAMMA, DensityResult, ForceResult, cfl_timestep_block, \
    density_block, force_block, ghost_update, smoothing_length_update
from .smoothing import get_kernel


class SPHState(NamedTuple):
    cells: ParticleCells
    accel: jax.Array       # (ncells, C, 3)
    dudt: jax.Array        # (ncells, C)
    rho: jax.Array         # (ncells, C)
    time: jax.Array        # scalar


@dataclass(frozen=True)
class SPHConfig:
    kernel: str = "cubic"
    alpha_visc: float = 0.8
    gamma: float = GAMMA
    n_target: float = 48.0
    adapt_h: bool = False          # keep h fixed unless asked (conservation tests)
    cfl: float = 0.25
    use_pallas: bool = False


# --------------------------------------------------------------- wave passes
def _density_pass(cells: ParticleCells, pairs: PairList, cfg: SPHConfig,
                  pair_mask: Optional[jax.Array] = None):
    """All density_self/density_pair tasks as two batched ops.

    ``pair_mask`` (npairs,) zeroes the contributions of masked pair tasks —
    used by the time-bin engine, which pads level-restricted pair lists to
    fixed power-of-two lengths so sub-steps reuse compiled programs.
    """
    if cfg.use_pallas:
        from ..kernels.sph_pair import ops as pair_ops
        return pair_ops.density_pairs(cells, pairs, kernel=cfg.kernel,
                                      pair_mask=pair_mask)

    pos_i = cells.pos[pairs.ci]                        # (P, C, 3)
    pos_j = cells.pos[pairs.cj] + pairs.shift[:, None, :]
    h_i, h_j = cells.h[pairs.ci], cells.h[pairs.cj]
    m_i, m_j = cells.mass[pairs.ci], cells.mass[pairs.cj]
    k_i, k_j = cells.mask[pairs.ci], cells.mask[pairs.cj]

    dens = functools.partial(density_block, kernel=cfg.kernel)
    dij = jax.vmap(dens)(pos_i, h_i, pos_j, m_j, k_j)      # i <- j
    dji = jax.vmap(dens)(pos_j, h_j, pos_i, m_i, k_i)      # j <- i

    ncells, cap = cells.mass.shape
    notself = (pairs.ci != pairs.cj).astype(cells.pos.dtype)[:, None]
    live = jnp.ones_like(notself) if pair_mask is None else pair_mask[:, None]

    def scatter(field_ij, field_ji):
        out = jnp.zeros((ncells, cap), cells.pos.dtype)
        out = out.at[pairs.ci].add(field_ij * live)
        out = out.at[pairs.cj].add(field_ji * notself * live)
        return out

    rho = scatter(dij.rho, dji.rho)
    drho_dh = scatter(dij.drho_dh, dji.drho_dh)
    nngb = scatter(dij.nngb, dji.nngb)
    return rho, drho_dh, nngb


def _force_pass(cells: ParticleCells, pairs: PairList, rho, press, omega, cs,
                cfg: SPHConfig, pair_mask: Optional[jax.Array] = None):
    """All force_self/force_pair tasks as two batched ops."""
    if cfg.use_pallas:
        from ..kernels.sph_pair import ops as pair_ops
        return pair_ops.force_pairs(cells, pairs, rho, press, omega, cs,
                                    kernel=cfg.kernel,
                                    alpha_visc=cfg.alpha_visc,
                                    pair_mask=pair_mask)

    gi = lambda a: a[pairs.ci]
    gj = lambda a: a[pairs.cj]
    pos_i, pos_j = gi(cells.pos), gj(cells.pos) + pairs.shift[:, None, :]

    force = functools.partial(force_block, kernel=cfg.kernel,
                              alpha_visc=cfg.alpha_visc)
    fij = jax.vmap(force)(
        pos_i, gi(cells.vel), gi(cells.h), gi(press), gi(rho), gi(omega),
        gi(cs),
        pos_j, gj(cells.vel), gj(cells.h), gj(press), gj(rho), gj(omega),
        gj(cs), gj(cells.mass), gj(cells.mask))
    fji = jax.vmap(force)(
        pos_j, gj(cells.vel), gj(cells.h), gj(press), gj(rho), gj(omega),
        gj(cs),
        pos_i, gi(cells.vel), gi(cells.h), gi(press), gi(rho), gi(omega),
        gi(cs), gi(cells.mass), gi(cells.mask))

    ncells, cap = cells.mass.shape
    notself = (pairs.ci != pairs.cj).astype(cells.pos.dtype)
    live = jnp.ones_like(notself) if pair_mask is None else pair_mask

    dv = jnp.zeros((ncells, cap, 3), cells.pos.dtype)
    dv = dv.at[pairs.ci].add(fij.dv * live[:, None, None])
    dv = dv.at[pairs.cj].add(fji.dv * (notself * live)[:, None, None])
    du = jnp.zeros((ncells, cap), cells.pos.dtype)
    du = du.at[pairs.ci].add(fij.du * live[:, None])
    du = du.at[pairs.cj].add(fji.du * (notself * live)[:, None])
    return dv, du


def compute_accelerations(cells: ParticleCells, pairs: PairList,
                          cfg: SPHConfig):
    """density → ghost → force (the Fig. 1 dependency chain)."""
    rho, drho_dh, nngb = _density_pass(cells, pairs, cfg)
    # padded slots: keep safe values so downstream divisions stay finite
    rho = jnp.where(cells.mask > 0, rho, 1.0)
    drho_dh = jnp.where(cells.mask > 0, drho_dh, 0.0)
    press, omega, cs = ghost_update(rho, drho_dh, cells.u, cells.h,
                                    gamma=cfg.gamma)
    press = jnp.where(cells.mask > 0, press, 0.0)
    dv, du = _force_pass(cells, pairs, rho, press, omega, cs, cfg)
    mask3 = cells.mask[..., None]
    return dv * mask3, du * cells.mask, rho, nngb


def init_state(cells: ParticleCells, pairs: PairList,
               cfg: SPHConfig) -> SPHState:
    dv, du, rho, _ = compute_accelerations(cells, pairs, cfg)
    return SPHState(cells=cells, accel=dv, dudt=du, rho=rho,
                    time=jnp.zeros((), cells.pos.dtype))


def step(state: SPHState, pairs: PairList, dt, box: float,
         cfg: SPHConfig) -> SPHState:
    """One KDK leapfrog step (kick and drift are SWIFT's integrator tasks)."""
    cells = state.cells
    mask3 = cells.mask[..., None]
    # K: half kick with stored accelerations
    v_half = cells.vel + 0.5 * dt * state.accel
    u_half = jnp.maximum(cells.u + 0.5 * dt * state.dudt, 1e-12)
    # D: drift
    pos = jnp.mod(cells.pos + dt * v_half * mask3, box)
    cells = cells._replace(pos=pos, vel=v_half, u=u_half)
    # re-evaluate forces at the new positions
    dv, du, rho, nngb = compute_accelerations(cells, pairs, cfg)
    # K: second half kick
    v_new = cells.vel + 0.5 * dt * dv
    u_new = jnp.maximum(u_half + 0.5 * dt * du, 1e-12)
    h_new = cells.h
    if cfg.adapt_h:
        h_new = smoothing_length_update(cells.h, rho, cells.mass, nngb,
                                        n_target=cfg.n_target)
        h_new = jnp.where(cells.mask > 0, h_new, cells.h)
    cells = cells._replace(vel=v_new, u=u_new, h=h_new)
    return SPHState(cells=cells, accel=dv, dudt=du, rho=rho,
                    time=state.time + dt)


def cfl_timestep_particles(state: SPHState, cfg: SPHConfig) -> jax.Array:
    """Per-particle CFL dt (ncells, C); +inf on padded slots.

    The time-bin hierarchy quantises this field into power-of-two bins;
    the global-dt engine takes its minimum.
    """
    cells = state.cells
    return cfl_timestep_block(cells.h, cells.u, cells.vel, cells.mask,
                              gamma=cfg.gamma, cfl=cfg.cfl)


def cfl_timestep(state: SPHState, cfg: SPHConfig) -> jax.Array:
    """dt = C_CFL · min_i h_i / (c_i + |v_i|)."""
    return jnp.min(cfl_timestep_particles(state, cfg))


@functools.lru_cache(maxsize=None)
def shared_step_program(box: float, cfg: SPHConfig):
    """One jitted step program per (box, physics config), shared by every
    :class:`Simulation` instance. A per-instance ``jax.jit(partial(...))``
    gives each engine its own jit cache, so a fleet of same-signature
    requests would recompile the identical program once per request; the
    memo makes N engines of one signature cost one compile."""
    return jax.jit(functools.partial(step, box=box, cfg=cfg))


# -------------------------------------------------------------- task graph
def build_taskgraph(spec: GridSpec, pairs: PairList,
                    occupancy: np.ndarray,
                    cost_model: Optional[CostModel] = None, *,
                    cell_bins: Optional[np.ndarray] = None,
                    level: Optional[int] = None,
                    occupancy_by_bin: Optional[np.ndarray] = None,
                    time_average: bool = False) -> TaskGraph:
    """SWIFT's Fig. 1 task hierarchy for the current grid.

    Per cell: sort → … → ghost → … → kick; per pair (and per self-cell):
    density and force tasks with the dependencies of eqs. (2)–(4). Costs are
    the cost model's asymptotic estimates over the *actual* occupancies —
    the graph the domain decomposition partitions.

    Time-bin extensions (see ``timebins.py``):

    * ``cell_bins`` (ncells,) — each cell's deepest occupied time bin
      (−1 for empty cells). With ``level`` set, every task gets an
      *activation mask*: a per-cell task is active iff its cell holds a
      particle in a bin ≥ level; a pair task is active iff either cell
      does (an inactive neighbour still contributes to an active cell's
      sums, so the pair must run). ``wave_schedule(..., active_only=True)``
      then compiles a program over only the due work.
    * ``time_average`` with ``occupancy_by_bin`` (ncells, nbins) — task
      costs become cycle-averaged active work (bin b pays on a fraction
      2**(b−d) of sub-steps), so ``decompose_cells`` balances what
      actually runs rather than where particles merely sit.
    """
    cm = cost_model or CostModel(rates={})
    g = TaskGraph()
    nc = spec.ncells
    occ = np.asarray(occupancy, dtype=np.int64)
    if time_average and occupancy_by_bin is None:
        raise ValueError("time_average=True requires occupancy_by_bin")
    bins_arr = None
    if cell_bins is not None:
        bins_arr = np.asarray(cell_bins, dtype=np.int64)
    obb = None
    max_bin = 0
    if occupancy_by_bin is not None:
        obb = np.asarray(occupancy_by_bin, dtype=np.int64)
        max_bin = obb.shape[1] - 1
    elif bins_arr is not None:
        max_bin = int(bins_arr.max()) if bins_arr.size else 0

    def cell_active(c: int) -> bool:
        if bins_arr is None or level is None:
            return True
        return bool(bins_arr[c] >= level)

    def cell_cost(kind: str, c: int) -> float:
        if time_average:
            return cm.timebin_units(kind, obb[c], max_bin=max_bin)
        return cm.units(kind, max(int(occ[c]), 1))

    def inter_cost(kind: str, a: int, b: Optional[int] = None) -> float:
        if time_average:
            return cm.timebin_units(kind, obb[a],
                                    obb[b] if b is not None else None,
                                    max_bin=max_bin)
        if b is None:
            return cm.units(kind, int(occ[a]))
        return cm.units(kind, int(occ[a]), int(occ[b]))

    sort = [g.add_task("sort", resources=(c,), writes=(c,),
                       cost=cell_cost("sort", c), active=cell_active(c))
            for c in range(nc)]
    ghost = [g.add_task("ghost", resources=(c,), writes=(c,),
                        cost=cell_cost("ghost", c), active=cell_active(c))
             for c in range(nc)]
    kick = [g.add_task("kick", resources=(c,), writes=(c,),
                       cost=cell_cost("kick", c), active=cell_active(c))
            for c in range(nc)]
    ci = np.asarray(pairs.ci)
    cj = np.asarray(pairs.cj)
    for a, b in zip(ci, cj):
        a, b = int(a), int(b)
        if a == b:
            act = cell_active(a)
            d = g.add_task("density_self", resources=(a,), writes=(a,),
                           cost=inter_cost("density_self", a), active=act)
            f = g.add_task("force_self", resources=(a,), writes=(a,),
                           cost=inter_cost("force_self", a), active=act)
            res = (a,)
        else:
            act = cell_active(a) or cell_active(b)
            d = g.add_task("density_pair", resources=(a, b), writes=(a, b),
                           cost=inter_cost("density_pair", a, b), active=act)
            f = g.add_task("force_pair", resources=(a, b), writes=(a, b),
                           cost=inter_cost("force_pair", a, b), active=act)
            res = (a, b)
        for c in res:
            g.add_dependency(d, sort[c])     # density after sort
            g.add_dependency(ghost[c], d)    # ghost after every density
            g.add_dependency(f, ghost[c])    # force after ghost
            g.add_dependency(kick[c], f)     # kick after every force
    return g


# ------------------------------------------------------------------ driver
class Simulation:
    """Host-side driver: binning, jitted stepping, re-binning, diagnostics.

    .. deprecated:: constructing this directly is the legacy path; it is
       now the global×local *engine* behind ``repro.sph.build_simulation(
       SimulationSpec(integrator="global", backend="local"))``.
    """

    def __init__(self, pos, vel, mass, u, h, *, box: float,
                 cfg: SPHConfig = SPHConfig(),
                 capacity_margin: float = 3.0,
                 rebin_every: int = 1):
        if type(self) is Simulation:
            warnings.warn(
                "constructing repro.sph.Simulation directly is deprecated; "
                "use repro.sph.build_simulation(SimulationSpec(...)) "
                "(integrator='global', backend='local')",
                DeprecationWarning, stacklevel=2)
        self.box = float(box)
        self.cfg = cfg
        self.n = len(pos)
        self.rebin_every = rebin_every
        h_max = float(np.max(h))
        self.spec = choose_grid(self.box, h_max, self.n,
                                capacity_margin=capacity_margin)
        self._rebin(np.asarray(pos), np.asarray(vel), np.asarray(mass),
                    np.asarray(u), np.asarray(h))
        self._jit_step = shared_step_program(self.box, self.cfg)
        self.state = init_state(self.cells, self.pairs, self.cfg)
        self._steps_since_rebin = 0
        self.tracer = NULL_TRACER      # rebound when observe=True
        # device-metrics carry (single rank), filled by the api adapter
        self.device_metrics_enabled = False
        self.device_metrics_last = None
        self.device_metrics_pulls = 0
        self.device_cell_work_last = None

    def _rebin(self, pos, vel, mass, u, h):
        self.cells, self.perm = bin_particles(self.spec, pos, vel, mass, u, h)
        if self.cells.mass.shape[1] != self.spec.capacity:
            # capacity grew: record it so pair list block shapes stay valid
            object.__setattr__(self.spec, "capacity",
                               self.cells.mass.shape[1])
        self.pairs = build_pair_list(self.spec)

    def run(self, nsteps: int, dt: Optional[float] = None) -> Dict[str, list]:
        log: Dict[str, list] = {"t": [], "wall": [], "E": [], "px": []}
        for _ in range(nsteps):
            dt_step = dt if dt is not None else float(
                cfl_timestep(self.state, self.cfg))
            with self.tracer.timed("engine_step",
                                   pairs=int(self.pairs.ci.shape[0])) as sp:
                self.state = self._jit_step(self.state, self.pairs,
                                            jnp.asarray(
                                                dt_step,
                                                self.cells.pos.dtype))
                jax.block_until_ready(self.state.cells.pos)
            wall = sp.elapsed
            self._steps_since_rebin += 1
            if self._steps_since_rebin >= self.rebin_every:
                flat = unbin(self.state.cells, self.perm, self.n)
                self._rebin(flat["pos"], flat["vel"], flat["mass"],
                            flat["u"], flat["h"])
                accel0 = init_state(self.cells, self.pairs, self.cfg)
                self.state = accel0._replace(time=self.state.time)
                self._steps_since_rebin = 0
            log["t"].append(float(self.state.time))
            log["wall"].append(wall)
            e, p = self.diagnostics()
            log["E"].append(e)
            log["px"].append(p[0])
        return log

    def diagnostics(self) -> Tuple[float, np.ndarray]:
        """(total energy, total momentum) over real particles."""
        c = self.state.cells
        m = np.asarray(c.mass * c.mask)
        v = np.asarray(c.vel)
        u = np.asarray(c.u)
        ke = 0.5 * np.sum(m * np.sum(v * v, axis=-1))
        ie = np.sum(m * u)
        mom = np.sum(m[..., None] * v, axis=(0, 1))
        return float(ke + ie), mom
