"""Distributed hierarchical time-bin integration with activity-aware halos.

The missing quadrant of the {global-dt, time-bin} × {local, distributed}
matrix: per-particle power-of-two time-steps (``timebins.py``) over a
graph-partitioned cell decomposition (``core.decompose``), where halo
exchanges are **activity-aware** — at each sub-step only the cut cells with
bins active at that sub-step contribute to the export buffer. An inactive
boundary cell's replica stays valid on the importing rank because drift is
elementwise: the importer drifts its halo copies with exactly the owner's
arithmetic, so data only has to ship when a kick actually changes it.
This is the time-axis extension of SWIFT's halo protocol (§3.3): the
communication volume per sub-step tracks the *active* fraction of the cut,
not its size — on a Sedov blast the quiescent background's boundary cells
ship (almost) nothing between cycle synchronisation points.

Structure of one force sub-step on each rank (two comm phases, exactly as
the paper's step — positions are already local via replica drift):

1. density phase (``timebins._substep_density_phase``) over the rank's
   activity-restricted pair list → fresh rho/omega/press/cs for active
   particles;
2. **exchange 1**: owners ship (rho, omega, press, cs) of *active* cut
   cells — the importer's locally-computed values for those rows are
   partial sums and are overwritten;
3. force phase (``timebins._substep_force_phase``) → kick + bin deepening;
4. **exchange 2**: owners ship the kicked state (vel, u, bins, t_start,
   accel, dudt) of active cut cells so replicas stay current.

Cut pair tasks are duplicated on both ranks (the paper's Fig. 2 green
tasks): every rank's pair list covers all pairs touching its owned cells,
so owned active particles always receive complete interaction sums.

The wire is a pluggable **transport** (``transport="host" | "collective"``):
``HostTransport`` copies rows through numpy between the ranks' jitted phase
programs, while ``CollectiveTransport`` (``sph/collectives.py``) compiles
the same copies into one shard_map program — ``lax.ppermute`` rounds over
the comm planner's export edge schedule (``core.comm_planner.
ppermute_rounds``) with an ``all_gather`` fallback — over power-of-two-
bucketed export buffers, so the exchange program is compiled once and
reused for every sub-step regardless of how many cut-cell rows are active.
Both transports are pure row copies and therefore bit-for-bit identical
(asserted in ``tests/test_transport.py``). The density/force sub-step
programs are shared across ranks: every rank's pair subset is padded to one
common power-of-two bucket, so one compiled program per (phase, bucket)
serves the whole mesh; the :class:`~repro.distributed.transport.
CompileProbe` (``self.probe``) counts the real XLA compiles. With
``nranks=1`` the engine reduces to the single-host ladder bit-for-bit
(asserted in ``tests/test_api.py``).

Repartitioning uses per-rank **bin occupancy**: the decomposition is
retriggered when the time-averaged active work per rank
(``core.decompose.timebin_node_weights``) drifts out of balance, and the
new partition is computed from the cycle-averaged task costs
(``CostModel.timebin_units``), weighting send/recv by activation frequency.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import CostModel, decompose_cells
from ..core.decompose import timebin_node_weights
from ..distributed.transport import (BucketPolicy, CompileProbe, RESIDENCIES,
                                     ResidentBuffers, ShipSlots, TRANSPORTS,
                                     TransferProbe, make_transport, next_pow2,
                                     pack_allgather, pack_rounds)
from ..observability import device_metrics as dmetrics
from .cellgrid import PairList, ParticleCells
from .engine import SPHConfig, build_taskgraph
from .timebins import (STATE_AUX_FIELDS, STATE_CELL_FIELDS,
                       TimeBinSimulation, TimeBinState, _final_force_phase,
                       _substep_density_phase, _substep_force_phase,
                       active_level, cell_bin_histogram,
                       mass_weighted_mean_u, substep_active_mask,
                       trailing_zeros_table)

_PAD_H = 1e-6          # padded-slot smoothing length (division-safe)

# scalars shipped per particle slot in each exchange (for byte accounting):
# exchange 1: rho, omega, press, cs; exchange 2: vel(3), u, bins, t_start,
# accel(3), dudt
_EX1_FIELDS = 4
_EX2_FIELDS = 10


# ------------------------------------------------------------------ rank plan
@dataclass
class RankPlan:
    """Host-side plan of one decomposition: who owns what, who imports what.

    Extended row layout per rank: rows [0, K) hold owned cells (global cell
    order), rows [K, K+H) hold halo replicas; both padded uniformly so every
    rank shares one compiled program per pair-bucket size.
    """
    nranks: int
    K: int                              # owned rows per rank (padded max)
    H: int                              # halo rows per rank (padded max)
    assignment: np.ndarray              # (ncells,) -> rank
    owned: List[np.ndarray]             # per rank: global cell ids, in order
    halo: List[np.ndarray]              # per rank: imported global cell ids
    ext_row: np.ndarray                 # (nranks, ncells) cell -> ext row (-1)
    # cut cells: cell -> (owner rank, owner ext row, [(imp rank, imp row)])
    cut: Dict[int, Tuple[int, int, List[Tuple[int, int]]]] = \
        field(default_factory=dict)
    # per-rank global-pair membership and ext-index maps
    touch: List[np.ndarray] = field(default_factory=list)   # (npairs,) bool
    ci_ext: List[np.ndarray] = field(default_factory=list)  # (npairs,) int32
    cj_ext: List[np.ndarray] = field(default_factory=list)  # (npairs,) int32

    @property
    def cut_slots(self) -> int:
        """Total (cell, importer) slots across the cut = full-boundary
        export volume of one exchange."""
        return sum(len(imps) for _, _, imps in self.cut.values())

    def export_edges(self) -> List[Tuple[int, int]]:
        """Directed rank-to-rank edges of the cut (the comm planner's
        export edge list — input to ``ppermute_rounds``)."""
        edges = {(o, ir) for _, (o, _, imps) in self.cut.items()
                 for (ir, _) in imps}
        return sorted(edges)

    def ship_slots(self, cells_due: List[int]) -> ShipSlots:
        """This sub-step's exchange: owner row → importer rows per edge."""
        slots = ShipSlots()
        for c in cells_due:
            o, orow, imps = self.cut[c]
            for (ir, irow) in imps:
                slots.add(o, ir, orow, irow)
        return slots


def build_rank_plan(assignment: np.ndarray, ci: np.ndarray, cj: np.ndarray,
                    nranks: Optional[int] = None) -> RankPlan:
    """Ownership + halo-import plan over the global cell-pair list."""
    assignment = np.asarray(assignment, dtype=np.int64)
    ncells = len(assignment)
    if nranks is None:
        nranks = int(assignment.max()) + 1 if ncells else 1
    owned = [np.nonzero(assignment == r)[0] for r in range(nranks)]
    K = max((len(o) for o in owned), default=1)
    K = max(K, 1)

    imports: List[Dict[int, int]] = [dict() for _ in range(nranks)]
    for a, b in zip(np.asarray(ci), np.asarray(cj)):
        a, b = int(a), int(b)
        ra, rb = int(assignment[a]), int(assignment[b])
        if ra == rb:
            continue
        if b not in imports[ra]:
            imports[ra][b] = len(imports[ra])
        if a not in imports[rb]:
            imports[rb][a] = len(imports[rb])
    H = max((len(i) for i in imports), default=0)

    halo = []
    ext_row = np.full((nranks, ncells), -1, dtype=np.int64)
    for r in range(nranks):
        for slot, c in enumerate(owned[r]):
            ext_row[r, c] = slot
        hl = np.empty(len(imports[r]), dtype=np.int64)
        for c, idx in imports[r].items():
            hl[idx] = c
            ext_row[r, c] = K + idx
        halo.append(hl)

    cut: Dict[int, Tuple[int, int, List[Tuple[int, int]]]] = {}
    for r in range(nranks):
        for c, idx in imports[r].items():
            o = int(assignment[c])
            if c not in cut:
                cut[c] = (o, int(ext_row[o, c]), [])
            cut[c][2].append((r, K + idx))

    plan = RankPlan(nranks=nranks, K=K, H=H, assignment=assignment,
                    owned=owned, halo=halo, ext_row=ext_row, cut=cut)
    ci_np = np.asarray(ci, dtype=np.int64)
    cj_np = np.asarray(cj, dtype=np.int64)
    for r in range(nranks):
        touch = (assignment[ci_np] == r) | (assignment[cj_np] == r)
        cie = np.where(touch, ext_row[r, ci_np], 0).astype(np.int32)
        cje = np.where(touch, ext_row[r, cj_np], 0).astype(np.int32)
        plan.touch.append(touch)
        plan.ci_ext.append(cie)
        plan.cj_ext.append(cje)
    return plan


def halo_export_schedule(cell_bins: np.ndarray, plan: RankPlan, depth: int
                         ) -> Dict[str, np.ndarray]:
    """Static per-sub-step export volumes over one 2**depth cycle.

    ``cell_bins`` is each cell's deepest occupied bin (−1 empty). A cut cell
    ships to each of its importers when active (bin ≥ level of the
    sub-step); the full-boundary baseline ships every cut cell at every
    force sub-step. Pure host arithmetic — the fast check that
    activity-aware halos beat the baseline, without running the engine.
    """
    nsub = 1 << depth
    active_slots = np.zeros(nsub, dtype=np.int64)
    full_slots = np.zeros(nsub, dtype=np.int64)
    bins = np.asarray(cell_bins)
    for n in range(1, nsub + 1):
        level = 0 if n == nsub else active_level(n, depth)
        any_active = bool((bins >= level).any())
        if not any_active:
            continue
        full = plan.cut_slots
        act = sum(len(imps) for c, (_, _, imps) in plan.cut.items()
                  if bins[c] >= level)
        active_slots[n - 1] = act
        full_slots[n - 1] = full
    return {"active": active_slots, "full": full_slots}


# ------------------------------------------------------------------- driver
class DistTimeBinSimulation(TimeBinSimulation):
    """Rank-partitioned multi-dt driver (the distributed ``timebin`` engine).

    Inherits the cycle planner, bin math and host bookkeeping from
    :class:`TimeBinSimulation`; overrides the sub-step ladder to run on
    per-rank extended (owned ⊕ halo) states with the two activity-aware
    exchanges described in the module docstring. Export volumes are
    accumulated in ``halo_exported_slots`` / ``halo_full_slots``;
    ``halo_log`` holds the *latest cycle's* per-sub-step breakdown (reset
    each cycle so long runs stay bounded).
    """

    def __init__(self, pos, vel, mass, u, h, *, box: float,
                 cfg: SPHConfig = SPHConfig(),
                 nranks: int = 1,
                 activity_aware: bool = True,
                 repartition_threshold: float = 1.5,
                 cost_model: Optional[CostModel] = None,
                 seed: int = 0,
                 transport: str = "host",
                 transport_mode: str = "auto",
                 residency: str = "host",
                 schedule: str = "host",
                 segment_cycles: int = 1,
                 **kw):
        if transport not in TRANSPORTS:
            raise ValueError(f"transport must be one of {TRANSPORTS}, "
                             f"got {transport!r}")
        if residency not in RESIDENCIES:
            raise ValueError(f"residency must be one of {RESIDENCIES}, "
                             f"got {residency!r}")
        if residency == "device":
            if transport != "collective":
                raise ValueError(
                    "residency='device' fuses the exchange into the "
                    "sub-step programs and therefore requires "
                    "transport='collective' (the host wire has no device "
                    "mesh to keep the state resident on)")
            if cfg.use_pallas:
                raise ValueError(
                    "residency='device' compiles the vmap pair phases "
                    "into the fused shard_map programs; use_pallas=True "
                    "is not supported on this path yet")
        if schedule not in ("host", "device"):
            raise ValueError(f"schedule must be 'host' or 'device', "
                             f"got {schedule!r}")
        if schedule == "device" and residency != "device":
            raise ValueError(
                "schedule='device' derives the sub-step ladder inside the "
                "compiled segment program from the device-resident bins "
                "array and therefore requires residency='device'")
        if int(segment_cycles) < 1:
            raise ValueError("segment_cycles must be >= 1")
        if int(segment_cycles) > 1 and schedule != "device":
            raise ValueError(
                "segment_cycles > 1 fuses consecutive cycles into one "
                "device segment and requires schedule='device'")
        self.residency = residency
        self.schedule = schedule
        self.segment_cycles = int(segment_cycles)
        self.nranks = int(nranks)
        self.activity_aware = bool(activity_aware)
        self.repartition_threshold = float(repartition_threshold)
        self._cost_model = cost_model or CostModel(rates={})
        self._seed = seed
        self.transport_kind = transport
        super().__init__(pos, vel, mass, u, h, box=box, cfg=cfg, **kw)
        # the compile-count probe: every jitted program of this engine is
        # registered, so tests can assert the bucket discipline bounds
        # recompiles (one per (program, bucket), none per sub-step)
        self.probe = CompileProbe()
        self.probe.register("drift", self._jit_drift)
        self.probe.register("cycle_start", self._jit_start)
        self._jit_sub_density = self.probe.register("density", jax.jit(
            functools.partial(self._sub_density, cfg=cfg)))
        self._jit_sub_force = self.probe.register("force", jax.jit(
            functools.partial(_substep_force_phase, cfg=cfg)))
        self._jit_final_density = self.probe.register("final_density",
            jax.jit(functools.partial(self._final_density, cfg=cfg)))
        self._jit_final_force = self.probe.register("final_force", jax.jit(
            functools.partial(_final_force_phase, cfg=cfg)))
        self.program_keys: set = set()      # (program, level, bucket) seen
        self._transport = make_transport(transport, nranks=self.nranks,
                                         probe=self.probe,
                                         mode=transport_mode)
        self._plan_cache: Optional[RankPlan] = None
        self._plan_cache_key: Optional[bytes] = None
        self._assignment = self._initial_assignment()
        self.repartitions = 0
        self.halo_exported_slots = 0
        self.halo_full_slots = 0
        self.halo_log: List[Dict[str, float]] = []
        # residency="device": host↔device traffic ledger + mid-cycle bins
        # mirror refresh counter (one per deepening/wake event, the only
        # state-array readback the fused path ever performs)
        self.transfers = TransferProbe()
        self.bins_refreshes = 0
        # fused-program buckets never shrink: a whole-sub-step program is
        # orders of magnitude more expensive to compile than the padded
        # pair math an oversized bucket wastes, so demand dips must not
        # mint new shape signatures (growth still recompiles, once per
        # power-of-two crossing per stream)
        self._fused_buckets = BucketPolicy(min_bucket=8,
                                           shrink_patience=10 ** 9)
        # device telemetry: the fused programs always *compute* the
        # per-rank metrics row (see observability/device_metrics.py —
        # that's what keeps the instrumented program the only program);
        # this flag gates the once-per-cycle host pull + observer merge
        self.device_metrics_enabled = False
        self.device_metrics_last: Optional[Tuple[np.ndarray,
                                                 np.ndarray]] = None
        self.device_metrics_pulls = 0
        # per-cell attribution of the last pulled cycle (device-metrics
        # v2): {"columns", "cells" (ncells, C) float64, "per_rank"
        # (nranks, C)} or None — the TaskCostLedger / repartition-advisor
        # contract. Rides in the same once-per-cycle metrics transfer.
        self.device_cell_work_last: Optional[Dict] = None
        # schedule="device": whole K-cycle segments run as compiled
        # programs; run_cycle() pops one cycle's stats per call from this
        # queue. A segment aborts back to the host-scheduled ladder
        # (bitwise-recoverably) when a health sentinel or capacity/crossing
        # flag trips.
        self._segment_queue: List[Dict] = []
        self.segments = 0
        self.segment_aborts = 0

    # ------------------------------------------------------- jitted phases
    @staticmethod
    def _sub_density(state, pairs, pair_mask, level, wake_floor, *, cfg):
        active = substep_active_mask(state, level, wake_floor)
        rho, omega, press, cs = _substep_density_phase(
            state, pairs, pair_mask, active, cfg=cfg)
        return active, rho, omega, press, cs

    @staticmethod
    def _final_density(state, pairs, pair_mask, *, cfg):
        active = state.cells.mask
        return _substep_density_phase(state, pairs, pair_mask, active,
                                      cfg=cfg)

    # ---------------------------------------------------------- partitioning
    def _initial_assignment(self) -> np.ndarray:
        if self.nranks <= 1:
            return np.zeros(self.spec.ncells, dtype=np.int64)
        occ = np.asarray(self.state.cells.mask).sum(axis=1).astype(np.int64)
        g = build_taskgraph(self.spec, self.pairs, occ, self._cost_model)
        dec = decompose_cells(g, self.spec.ncells, self.nranks,
                              seed=self._seed)
        return np.asarray(dec.assignment, dtype=np.int64)

    def _maybe_repartition(self, bins_h: np.ndarray, mask_h: np.ndarray,
                           depth: int) -> None:
        """Per-rank bin-occupancy repartition trigger.

        The quantity balanced is the *time-averaged active work* per rank
        (``timebin_node_weights``): deep-bin (short-step) cells cost their
        rank every sub-step, shallow ones almost never. When the max/mean
        ratio exceeds the threshold, re-decompose with cycle-averaged task
        costs (``CostModel.timebin_units`` — send/recv weighted by
        activation frequency).
        """
        if self.nranks <= 1:
            return
        obb = cell_bin_histogram(bins_h, mask_h, depth + 1)
        w = timebin_node_weights(obb)
        rank_w = np.zeros(self.nranks)
        np.add.at(rank_w, self._assignment, w)
        mean = rank_w.mean()
        if mean <= 0 or rank_w.max() / mean <= self.repartition_threshold:
            return
        occ = (mask_h > 0).sum(axis=1).astype(np.int64)
        deep = (obb.shape[1] - 1 - np.argmax(obb[:, ::-1] > 0, axis=1))
        cb = np.where(obb.sum(axis=1) > 0, deep, -1)
        g = build_taskgraph(self.spec, self.pairs, occ, self._cost_model,
                            cell_bins=cb, occupancy_by_bin=obb,
                            time_average=True)
        dec = decompose_cells(g, self.spec.ncells, self.nranks,
                              seed=self._seed, occupancy_by_bin=obb)
        self._assignment = np.asarray(dec.assignment, dtype=np.int64)
        self.repartitions += 1

    # ------------------------------------------------------ scatter / gather
    def _scatter_state(self, plan: RankPlan) -> List[TimeBinState]:
        """Global mirror → per-rank extended TimeBinStates."""
        st = self.state
        fills = self._FILLS     # shared with _scatter_resident: the two
        states = []             # residencies must pad rows identically
        for r in range(plan.nranks):
            idx = np.concatenate([plan.owned[r], plan.halo[r]]).astype(int)
            split = len(plan.owned[r])
            nrows = plan.K + plan.H

            def ext(a, fill):
                a = np.asarray(a)
                out = np.full((nrows,) + a.shape[1:], fill, dtype=a.dtype)
                out[:split] = a[plan.owned[r]]
                out[plan.K:plan.K + len(plan.halo[r])] = a[plan.halo[r]]
                return jnp.asarray(out)

            cells = ParticleCells(
                pos=ext(st.cells.pos, fills["pos"]),
                vel=ext(st.cells.vel, fills["vel"]),
                mass=ext(st.cells.mass, fills["mass"]),
                u=ext(st.cells.u, fills["u"]),
                h=ext(st.cells.h, fills["h"]),
                mask=ext(st.cells.mask, fills["mask"]))
            states.append(TimeBinState(
                cells=cells,
                accel=ext(st.accel, fills["accel"]),
                dudt=ext(st.dudt, fills["dudt"]),
                rho=ext(st.rho, fills["rho"]),
                omega=ext(st.omega, fills["omega"]),
                bins=ext(st.bins, fills["bins"]),
                t_start=ext(st.t_start, fills["t_start"]),
                time=st.time))
        return states

    def _gather_state(self, plan: RankPlan, states: List[TimeBinState]
                      ) -> None:
        """Per-rank owned rows → global mirror (halo replicas discarded)."""
        st = self.state
        out = {name: np.asarray(getattr(st, name)).copy()
               for name in ("accel", "dudt", "rho", "omega", "bins",
                            "t_start")}
        cells_out = {name: np.asarray(getattr(st.cells, name)).copy()
                     for name in ("pos", "vel", "mass", "u", "h", "mask")}
        for r in range(plan.nranks):
            own = plan.owned[r]
            if not len(own):
                continue
            sr = states[r]
            for name in out:
                out[name][own] = np.asarray(getattr(sr, name))[:len(own)]
            for name in cells_out:
                cells_out[name][own] = np.asarray(
                    getattr(sr.cells, name))[:len(own)]
        self.state = TimeBinState(
            cells=ParticleCells(**{k: jnp.asarray(v)
                                   for k, v in cells_out.items()}),
            time=states[0].time,
            **{k: jnp.asarray(v) for k, v in out.items()})

    # ------------------------------------------------------------ rank plan
    def _get_plan(self) -> RankPlan:
        """The cycle's rank plan; cached per assignment (the pair list is
        static, so the plan only changes when the partition does)."""
        key = self._assignment.tobytes()
        if self._plan_cache is None or self._plan_cache_key != key:
            self._plan_cache = build_rank_plan(
                np.asarray(self._assignment), self._ci, self._cj,
                nranks=self.nranks)
            self._plan_cache_key = key
            self._transport.prepare(self._plan_cache.export_edges())
        return self._plan_cache

    # --------------------------------------------------------- pair subsets
    def _select_rank_pairs(self, plan: RankPlan,
                           active_cells: Optional[np.ndarray]
                           ) -> Tuple[List[np.ndarray], int]:
        """Per-rank live pair indices, in global pair order.

        The one selection rule (rank's touch set, optionally restricted to
        pairs touching an active cell) that both the host phase programs
        (:meth:`_rank_pair_subsets`) and the fused device tables
        (:meth:`_fused_tables`) build from — the bitwise-parity contract
        between the two residencies depends on it never forking.
        """
        idxs = []
        nmax = 1
        for r in range(plan.nranks):
            sel = plan.touch[r]
            if active_cells is not None:
                sel = sel & (active_cells[self._ci] | active_cells[self._cj])
            idx = np.nonzero(sel)[0]
            idxs.append(idx)
            nmax = max(nmax, len(idx))
        return idxs, nmax

    def _rank_pair_subsets(self, plan: RankPlan,
                           active_cells: Optional[np.ndarray]
                           ) -> Tuple[List[Tuple[PairList, jax.Array, int]],
                                      int]:
        """All ranks' pair subsets, padded to one **shared** power-of-two
        bucket (the max across ranks), so a single compiled phase program
        per (phase, bucket) serves every rank. Padded entries duplicate
        pair 0 with a zero mask and contribute exact +0.0 to every sum
        (the mask property test in ``tests/test_transport.py``)."""
        idxs, nmax = self._select_rank_pairs(plan, active_cells)
        npad = next_pow2(nmax)
        out = []
        for r in range(plan.nranks):
            idx = idxs[r]
            nlive = len(idx)
            idxp = np.concatenate(
                [idx, np.zeros(npad - nlive, dtype=idx.dtype)])
            pmask = np.zeros(npad, np.float32)
            pmask[:nlive] = 1.0
            sub = PairList(ci=jnp.asarray(plan.ci_ext[r][idxp]),
                           cj=jnp.asarray(plan.cj_ext[r][idxp]),
                           shift=jnp.asarray(self._shift[idxp]))
            out.append((sub, jnp.asarray(pmask), nlive))
        return out, npad

    # ------------------------------------------------------------ exchanges
    def _exchange_set(self, plan: RankPlan, active_cells: np.ndarray
                      ) -> List[int]:
        """Cut cells due for shipping this sub-step."""
        if not self.activity_aware:
            return list(plan.cut.keys())
        return [c for c in plan.cut if active_cells[c]]

    def transport_stats(self) -> Dict[str, object]:
        """Wire-level accounting of the active transport + compile probe."""
        out = dict(self._transport.stats())
        out["compiles"] = self.probe.counts()
        out["program_keys"] = len(self.program_keys)
        out["residency"] = self.residency
        out["transfers"] = self.transfers.stats()
        out["bins_refreshes"] = self.bins_refreshes
        return out

    # -------------------------------------------------------------- cycling
    def run_cycle(self) -> Dict[str, float]:
        tr = self.tracer
        if tr.enabled:
            tr.ctx["cycle"] = self.cycle_index
            tr.ctx.pop("substep", None)
        if self.schedule == "device":
            # device-scheduled: whole K-cycle segments run as compiled
            # programs; each run_cycle() call pops one cycle's stats
            if not self._segment_queue:
                with tr.timed("cycle") as seg:
                    self._segment_queue = self._run_segment()
                per_cycle_wall = seg.elapsed / max(len(self._segment_queue),
                                                   1)
                for s in self._segment_queue:
                    s["wall"] = per_cycle_wall
            stats = self._segment_queue.pop(0)
            if "_met" in stats:
                # the row travelled in the segment_stats boundary pull —
                # adopting it here is free (no extra transfer entry)
                self.device_metrics_last = stats.pop("_met")
                self.device_metrics_pulls += 1
                self.device_cell_work_last = stats.pop("_cellw", None)
            self.cycle_index += 1
            return stats
        with tr.timed("cycle") as cyc:
            ctx = self._cycle_prologue()
            if self.residency == "device":
                body = self._cycle_substeps_device(ctx)
            else:
                body = self._cycle_substeps_host(ctx)
            stats = self._cycle_epilogue(ctx, body)
        if tr.enabled:
            tr.ctx.pop("substep", None)
        self.cycle_index += 1
        stats["wall"] = cyc.elapsed
        return stats

    def _cycle_prologue(self) -> Dict[str, object]:
        """Plan the cycle and open it on the global mirror (host side)."""
        tr = self.tracer
        t0 = tr.now() if tr.enabled else 0.0
        dt_max_c, depth = self._plan_cycle()
        nsub = 1 << depth
        nreal = int(np.asarray(self.state.cells.mask).sum())
        bins_host = np.asarray(self.state.bins)
        mask_host = np.asarray(self.state.cells.mask)
        m_h = np.asarray(self.state.cells.mass * self.state.cells.mask)
        # fixed-shape tree fold (timebins.mass_weighted_mean_u): the same
        # reduction order the device plan program reproduces, so the
        # host- and device-derived schedules agree bit for bit
        u_floor = float(mass_weighted_mean_u(m_h,
                                             np.asarray(self.state.cells.u)))
        hist = np.bincount(bins_host[mask_host > 0], minlength=depth + 1)
        # opening half-kick on the global mirror, then scatter to ranks
        self.state = self._jit_start(self.state, jnp.float32(dt_max_c))
        plan = self._get_plan()
        if tr.enabled:
            tr.fence(self.state.cells.pos)
            # planning runs once on the host for everyone — one task on
            # every rank's row, like SWIFT's tree-build
            tr.record_all(range(plan.nranks), "plan", t0, units=nreal,
                          collective=1)
        return {"dt_max_c": dt_max_c, "depth": depth, "nsub": nsub,
                "dt_min": dt_max_c / nsub, "nreal": nreal,
                "bins_host": bins_host, "mask_host": mask_host,
                "u_floor": u_floor, "hist": hist, "plan": plan}

    def _cycle_epilogue(self, ctx: Dict[str, object],
                        body: Dict[str, int]) -> Dict[str, float]:
        """Close the cycle: repartition check, re-bin, counters, stats."""
        tr = self.tracer
        nsub, nreal = ctx["nsub"], ctx["nreal"]
        self._maybe_repartition(np.asarray(self.state.bins),
                                np.asarray(self.state.cells.mask),
                                ctx["depth"])
        if self.rebin_each_cycle:
            with tr.span("rebin", units=nreal):
                self._rebin_state()
        self.particle_updates += body["updates"]
        self.global_equiv_updates += nsub * nreal
        self.substeps += nsub
        self.halo_exported_slots += body["cycle_exported"]
        self.halo_full_slots += body["cycle_full"]
        return {
            "t": float(self.state.time),
            "dt_max": ctx["dt_max_c"],
            "depth": ctx["depth"],
            "substeps": nsub,
            "force_substeps": body["force_substeps"] + 1,
            "bin_hist": ctx["hist"],
            "updates": body["updates"],
            "global_equiv_updates": nsub * nreal,
            "pair_tasks": body["pair_tasks"],
            "global_equiv_pair_tasks": nsub * len(self._ci),
            "halo_exported_slots": body["cycle_exported"],
            "halo_full_slots": body["cycle_full"],
            "nranks": ctx["plan"].nranks,
            "residency": self.residency,
        }

    # ------------------------------------------------- device-metrics pull
    def _metrics_pull(self, counts, values, cells=None,
                      plan: Optional[RankPlan] = None) -> None:
        """Adopt one cycle's accumulated telemetry row: pull it to host —
        ONE ledgered boundary transfer per cycle (the acceptance bound
        ``benchmarks/observability_bench.py`` reports) — and expose it as
        ``device_metrics_last`` for the observer's end-of-cycle merge.
        The per-cell work buffer (``cells``, stacked device rows) rides in
        the same transfer and is folded onto global cells via the plan's
        row maps into ``device_cell_work_last``. Must run inside
        ``run_cycle`` so the transfer ledger the observer copies verbatim
        already contains this pull."""
        counts_h = np.asarray(counts)
        values_h = np.asarray(values)
        nbytes = counts_h.nbytes + values_h.nbytes
        if cells is not None and plan is not None:
            cells_h = np.asarray(cells)
            nbytes += cells_h.nbytes
            self.device_cell_work_last = dmetrics.fold_cell_rows(
                cells_h, plan.owned, plan.halo, self.spec.ncells, plan.K)
        self.transfers.record("metrics", nbytes, boundary=True)
        self.device_metrics_pulls += 1
        self.device_metrics_last = (counts_h, values_h)

    def _mirror_metrics_finish(self, plan: RankPlan, counts: np.ndarray,
                               values: np.ndarray) -> None:
        """Host-residency tail of the telemetry row: sentinel flags and
        per-rank state fingerprints from the gathered global mirror
        (whose rows the host path round-trips anyway)."""
        st = self.state
        mask = np.asarray(st.cells.mask)
        vel = np.asarray(st.cells.vel)
        u = np.asarray(st.cells.u)
        rho = np.asarray(st.rho)
        mass = np.asarray(st.cells.mass)
        for r in range(plan.nranks):
            own = plan.owned[r]
            if not len(own):
                continue
            dmetrics.state_health(mask[own], vel[own], u[own], rho[own],
                                  mass[own], counts, values, rank=r)

    def _cycle_substeps_host(self, ctx: Dict[str, object]) -> Dict[str, int]:
        """The host-orchestrated ladder: per-rank phase programs with the
        transport's exchanges (host or collective wire) in between."""
        plan: RankPlan = ctx["plan"]
        depth, nsub = ctx["depth"], ctx["nsub"]
        dt_max_c, dt_min = ctx["dt_max_c"], ctx["dt_min"]
        mask_host, u_floor = ctx["mask_host"], ctx["u_floor"]
        nreal = ctx["nreal"]
        tr = self.tracer
        t0 = tr.now() if tr.enabled else 0.0
        states = self._scatter_state(plan)
        if tr.enabled:
            tr.record_all(range(plan.nranks), "scatter", t0, collective=1)

        updates = 0
        pair_tasks = 0
        force_substeps = 0
        drifted_to = 0
        cycle_exported = 0
        cycle_full = 0
        self.halo_log = []          # latest cycle only (bounded memory)
        bins_h = ctx["bins_host"].copy()
        wake_floor = self._wake_floor(bins_h, mask_host)
        dm_on = self.device_metrics_enabled
        met_counts, met_values = dmetrics.zero_rows(plan.nranks)
        mCI, mVI = dmetrics.COUNT_INDEX, dmetrics.VALUE_INDEX
        alive_per_rank = [int((mask_host[plan.owned[r]] > 0).sum())
                          if len(plan.owned[r]) else 0
                          for r in range(plan.nranks)]
        # per-cell attribution (device-metrics v2): same owned-endpoint
        # rule as the device scatter, accumulated host-side from the pair
        # selections the ladder already computes. The per-rank exchange
        # column here is receiver-side truth (the value column's
        # ``nship // nranks`` split stays approximate on this path).
        cDI = dmetrics.CELL_INDEX
        cellw = cellw_rank = None
        if dm_on:
            cellw, cellw_rank = dmetrics.zero_cell_work(
                self.spec.ncells, plan.nranks)
            alive_cell = (mask_host > 0).sum(axis=1).astype(np.float64)

        def attribute_cells(idxs_r, ship_cells, nexch):
            for r in range(plan.nranks):
                gi = self._ci[idxs_r[r]]
                gj = self._cj[idxs_r[r]]
                tgt = np.where(self._assignment[gi] == r, gi, gj)
                np.add.at(cellw[:, cDI["density"]], tgt, 1.0)
                np.add.at(cellw[:, cDI["force"]], tgt, 1.0)
                cellw_rank[r, cDI["density"]] += len(tgt)
                cellw_rank[r, cDI["force"]] += len(tgt)
                own = plan.owned[r]
                if len(own):
                    cellw[own, cDI["drift"]] += alive_cell[own]
                cellw_rank[r, cDI["drift"]] += alive_per_rank[r]
            for c in ship_cells:
                _, _, imps = plan.cut[c]
                cellw[c, cDI["exchange"]] += nexch * len(imps)
                for (ir, _) in imps:
                    cellw_rank[ir, cDI["exchange"]] += nexch

        # per-cycle host caches: the extended wake floors are rebuilt only
        # when the wake floor itself changes (a wake-up or deepening), not
        # every sub-step
        wake_ext_cache: Dict[int, jax.Array] = {}

        def wake_ext(r):
            if r not in wake_ext_cache:
                wf = np.zeros(plan.K + plan.H, np.int32)
                wf[:len(plan.owned[r])] = wake_floor[plan.owned[r]]
                wf[plan.K:plan.K + len(plan.halo[r])] = \
                    wake_floor[plan.halo[r]]
                wake_ext_cache[r] = jnp.asarray(wf)
            return wake_ext_cache[r]

        for n in range(1, nsub):
            level = active_level(n, depth)
            active_p = ((bins_h >= level)
                        | (bins_h < wake_floor[:, None])) & (mask_host > 0)
            if not active_p.any():
                continue
            active_cells = active_p.any(axis=1)
            ship = self._exchange_set(plan, active_cells)
            slots = plan.ship_slots(ship) if ship else None
            nship = slots.total if slots else 0
            cycle_exported += nship
            cycle_full += plan.cut_slots
            self.halo_log.append({
                "substep": self.substeps + n, "level": level,
                "exported_slots": nship, "full_slots": plan.cut_slots})

            dt_d = jnp.float32((n - drifted_to) * dt_min)
            drifted_to = n
            if tr.enabled:
                tr.ctx["substep"] = n
                active_frac = float(active_p.sum()) / max(nreal, 1)
            subs, pair_bucket = self._rank_pair_subsets(plan, active_cells)
            self.program_keys.add(("density", level, pair_bucket))
            self.program_keys.add(("force", level, pair_bucket))
            phase1 = []
            for r in range(plan.nranks):
                with tr.span("drift", rank=r):
                    states[r] = self._jit_drift(states[r], dt_d)
                    if tr.enabled:
                        tr.fence(states[r].cells.pos)
                sub, pmask, nlive = subs[r]
                d_attrs = {}
                if tr.enabled:
                    d_attrs = dict(level=level, units=nlive, pairs=nlive,
                                   bucket=pair_bucket,
                                   active_frac=active_frac)
                with tr.span("density", rank=r, **d_attrs):
                    act, rho, om, pr, cs = self._jit_sub_density(
                        states[r], sub, pmask, jnp.int32(level), wake_ext(r))
                    if tr.enabled:
                        tr.fence(rho)
                phase1.append([sub, pmask, nlive, act, rho, om, pr, cs])
            # exchange 1: owner's fresh rho/omega/press/cs -> replicas
            if slots:
                fields = [[phase1[r][4 + f] for r in range(plan.nranks)]
                          for f in range(4)]
                fields = self._transport.exchange(slots, fields,
                                                  label="exchange1")
                for r in range(plan.nranks):
                    phase1[r][4:] = [fields[f][r] for f in range(4)]
            for r in range(plan.nranks):
                sub, pmask, nlive, act, rho, om, pr, cs = phase1[r]
                f_attrs = {}
                if tr.enabled:
                    f_attrs = dict(level=level, units=nlive, pairs=nlive,
                                   bucket=pair_bucket,
                                   active_frac=active_frac)
                with tr.span("force", rank=r, **f_attrs):
                    states[r], _ = self._jit_sub_force(
                        states[r], sub, pmask, act, rho, om, pr, cs,
                        wake_ext(r), jnp.float32(dt_max_c), jnp.int32(depth),
                        jnp.float32(u_floor))
                    if tr.enabled:
                        tr.fence(states[r].cells.vel)
            # exchange 2: kicked state of shipped cells -> replicas
            if slots:
                fields = [[getattr(states[r].cells, nm)
                           for r in range(plan.nranks)]
                          for nm in ("vel", "u")]
                fields += [[getattr(states[r], nm)
                            for r in range(plan.nranks)]
                           for nm in ("bins", "t_start", "accel", "dudt")]
                vel, uu, bb, ts, ac, dd = self._transport.exchange(
                    slots, fields, label="exchange2")
                for r in range(plan.nranks):
                    states[r] = states[r]._replace(
                        cells=states[r].cells._replace(
                            vel=vel[r], u=uu[r]),
                        bins=bb[r], t_start=ts[r], accel=ac[r], dudt=dd[r])
            # refresh the global bins mirror (deepening): only ranks whose
            # owned cells were active can have deepened; everyone else's
            # mirror rows are untouched — avoids re-materialising every
            # rank's bins array on every sub-step
            floor_dirty = False
            for r in range(plan.nranks):
                own = plan.owned[r]
                if not len(own) or not active_cells[own].any():
                    continue
                new_bins = np.asarray(states[r].bins)[:len(own)]
                if not np.array_equal(bins_h[own], new_bins):
                    if dm_on:
                        met_counts[r, mCI["deepen_events"]] += int(
                            (bins_h[own] != new_bins).sum())
                    bins_h[own] = new_bins
                    floor_dirty = True
            if floor_dirty:
                new_floor = self._wake_floor(bins_h, mask_host)
                if not np.array_equal(new_floor, wake_floor):
                    wake_floor = new_floor
                    wake_ext_cache.clear()     # invalidate on wake-up
            updates += int(active_p.sum())
            pair_tasks += int((active_cells[self._ci]
                               | active_cells[self._cj]).sum())
            force_substeps += 1
            if dm_on:
                sslots = nship // plan.nranks
                sbytes = sslots * mask_host.shape[1] * 4 \
                    * (_EX1_FIELDS + _EX2_FIELDS)
                for r in range(plan.nranks):
                    own = plan.owned[r]
                    act_r = int(active_p[own].sum()) if len(own) else 0
                    nlive = subs[r][2]
                    met_counts[r] += np.asarray(dmetrics.host_row(
                        substeps=1, drift_active=alive_per_rank[r],
                        density_active=act_r, force_active=act_r,
                        pair_int=nlive, exch_slots=2 * sslots,
                        exch_bytes=sbytes,
                        wake_events=int((bins_h[own]
                                         < wake_floor[own, None]).sum())
                        if len(own) else 0)[0])
                    met_values[r, mVI["density_units"]] += nlive
                    met_values[r, mVI["force_units"]] += nlive
                    met_values[r, mVI["exchange_units"]] += sslots
                    met_values[r, mVI["kick_units"]] += act_r
                attribute_cells(self._select_rank_pairs(plan,
                                                        active_cells)[0],
                                ship, 2.0)

        # final sync sub-step: everyone active, full pair lists, full cut
        dt_d = jnp.float32((nsub - drifted_to) * dt_min)
        if tr.enabled:
            tr.ctx["substep"] = nsub
        subs, pair_bucket = self._rank_pair_subsets(plan, None)
        self.program_keys.add(("final_density", 0, pair_bucket))
        self.program_keys.add(("final_force", 0, pair_bucket))
        phase1 = []
        for r in range(plan.nranks):
            with tr.span("drift", rank=r):
                states[r] = self._jit_drift(states[r], dt_d)
                if tr.enabled:
                    tr.fence(states[r].cells.pos)
            sub, pmask, nlive = subs[r]
            with tr.span("density", rank=r, units=nlive, pairs=nlive,
                         bucket=pair_bucket, active_frac=1.0):
                rho, om, pr, cs = self._jit_final_density(states[r], sub,
                                                          pmask)
                if tr.enabled:
                    tr.fence(rho)
            phase1.append([sub, pmask, nlive, rho, om, pr, cs])
        if plan.cut:
            ship = list(plan.cut.keys())
            slots = plan.ship_slots(ship)
            cycle_exported += slots.total
            cycle_full += plan.cut_slots
            fields = [[phase1[r][3 + f] for r in range(plan.nranks)]
                      for f in range(4)]
            fields = self._transport.exchange(slots, fields, stream="final",
                                              label="exchange_final")
            for r in range(plan.nranks):
                phase1[r][3:] = [fields[f][r] for f in range(4)]
        for r in range(plan.nranks):
            sub, pmask, nlive, rho, om, pr, cs = phase1[r]
            with tr.span("force", rank=r, units=nlive, pairs=nlive,
                         bucket=pair_bucket, active_frac=1.0):
                states[r] = self._jit_final_force(
                    states[r], sub, pmask, rho, om, pr, cs,
                    jnp.float32(dt_max_c))
                if tr.enabled:
                    tr.fence(states[r].cells.vel)
        jax.block_until_ready(states[-1].cells.pos)
        updates += nreal
        pair_tasks += len(self._ci)
        if dm_on:
            fslots = plan.cut_slots // plan.nranks if plan.cut else 0
            fbytes = fslots * mask_host.shape[1] * 4 * _EX1_FIELDS
            for r in range(plan.nranks):
                nlive = subs[r][2]
                met_counts[r] += np.asarray(dmetrics.host_row(
                    substeps=1, drift_active=alive_per_rank[r],
                    density_active=alive_per_rank[r],
                    force_active=alive_per_rank[r],
                    pair_int=nlive, exch_slots=fslots,
                    exch_bytes=fbytes)[0])
                met_values[r, mVI["density_units"]] += nlive
                met_values[r, mVI["force_units"]] += nlive
                met_values[r, mVI["exchange_units"]] += fslots
                met_values[r, mVI["kick_units"]] += alive_per_rank[r]
            attribute_cells(self._select_rank_pairs(plan, None)[0],
                            list(plan.cut) if plan.cut else [], 1.0)

        tg = tr.now() if tr.enabled else 0.0
        self._gather_state(plan, states)
        if tr.enabled:
            tr.record_all(range(plan.nranks), "gather", tg, collective=1)
        if dm_on:
            self._mirror_metrics_finish(plan, met_counts, met_values)
            self.device_cell_work_last = {
                "columns": list(dmetrics.CELL_COLUMNS),
                "cells": cellw, "per_rank": cellw_rank}
            self._metrics_pull(met_counts, met_values)
        else:
            self.device_metrics_last = None
            self.device_cell_work_last = None
        return {"updates": updates, "pair_tasks": pair_tasks,
                "force_substeps": force_substeps,
                "cycle_exported": cycle_exported,
                "cycle_full": cycle_full}

    # ------------------------------------------------- device-resident cycle
    _CELL_FIELDS = STATE_CELL_FIELDS
    _AUX_FIELDS = STATE_AUX_FIELDS
    _FILLS = {"pos": 0.0, "vel": 0.0, "mass": 0.0, "u": 0.0, "h": _PAD_H,
              "mask": 0.0, "accel": 0.0, "dudt": 0.0, "rho": 1.0,
              "omega": 1.0, "bins": 0, "t_start": 0.0}

    def _mesh_sharding(self) -> NamedSharding:
        t = self._transport
        return NamedSharding(t.mesh, P(t.axis))

    def _scatter_resident(self, plan: RankPlan) -> ResidentBuffers:
        """Global mirror → one stacked (nranks, K+H, …) sharded buffer per
        field, placed on the transport mesh for the whole cycle."""
        st = self.state
        sh = self._mesh_sharding()
        place = lambda a: jax.device_put(jnp.asarray(a), sh)
        nrows = plan.K + plan.H
        res = ResidentBuffers(self.transfers)

        def ext_stacked(a, fill):
            a = np.asarray(a)
            out = np.full((plan.nranks, nrows) + a.shape[1:], fill,
                          dtype=a.dtype)
            for r in range(plan.nranks):
                own, hal = plan.owned[r], plan.halo[r]
                out[r, :len(own)] = a[own]
                out[r, plan.K:plan.K + len(hal)] = a[hal]
            return out

        for name in self._CELL_FIELDS:
            res.put(name, ext_stacked(getattr(st.cells, name),
                                      self._FILLS[name]), place)
        for name in self._AUX_FIELDS:
            res.put(name, ext_stacked(getattr(st, name),
                                      self._FILLS[name]), place)
        time_h = np.full((plan.nranks,), float(st.time),
                         dtype=np.asarray(st.cells.pos).dtype)
        res.put("time", time_h, place)
        return res

    def _gather_resident(self, plan: RankPlan, res: ResidentBuffers) -> None:
        """Stacked owned rows → global mirror (halo replicas discarded)."""
        st = self.state
        out = {name: np.asarray(getattr(st, name)).copy()
               for name in self._AUX_FIELDS}
        cells_out = {name: np.asarray(getattr(st.cells, name)).copy()
                     for name in self._CELL_FIELDS}
        # only owned rows come home — halo replicas are discarded anyway,
        # so pulling them would pad the boundary ledger for nothing
        pulled = {name: res.pull(name, index=np.s_[:, :plan.K])
                  for name in self._CELL_FIELDS + self._AUX_FIELDS}
        for r in range(plan.nranks):
            own = plan.owned[r]
            if not len(own):
                continue
            for name in out:
                out[name][own] = pulled[name][r, :len(own)]
            for name in cells_out:
                cells_out[name][own] = pulled[name][r, :len(own)]
        time_h = res.pull("time")
        self.state = TimeBinState(
            cells=ParticleCells(**{k: jnp.asarray(v)
                                   for k, v in cells_out.items()}),
            time=jnp.asarray(time_h[0]),
            **{k: jnp.asarray(v) for k, v in out.items()})

    def _fused_tables(self, plan: RankPlan,
                      active_cells: Optional[np.ndarray], slots: ShipSlots,
                      stream: str, wake_stacked: Optional[np.ndarray],
                      level: int = 0) -> Tuple[Dict[str, jax.Array], Tuple]:
        """One sub-step's control tables for the fused program + the static
        shape signature that keys its compilation.

        The pair subset is built exactly as :meth:`_rank_pair_subsets`
        (shared power-of-two bucket, global pair order) and then split into
        interior / cut *positions* (a pair is cut iff it touches a halo row
        ≥ K); the exchange index tables come from the transport's round
        schedule and bucket policy. Everything here is control plane —
        int32 indices and masks — the only intra-cycle host→device traffic
        of the resident path.
        """
        t = self._transport
        nranks = plan.nranks
        nrows = plan.K + plan.H
        idxs, nmax = self._select_rank_pairs(plan, active_cells)
        splits = []
        imax, cmax = 1, 1
        for r in range(nranks):
            idx = idxs[r]
            halo_pair = ((plan.ci_ext[r][idx] >= plan.K)
                         | (plan.cj_ext[r][idx] >= plan.K))
            splits.append(halo_pair)
            imax = max(imax, int((~halo_pair).sum()))
            cmax = max(cmax, int(halo_pair.sum()))
        # pair buckets go through the engine's no-shrink policy, keyed per
        # (stream, level), so demand wobbling across cycles cannot mint
        # new fused-program shape signatures
        B = self._fused_buckets.fit((stream, "pairs", level), nmax)
        Bi = self._fused_buckets.fit((stream, "int", level), imax)
        Bc = self._fused_buckets.fit((stream, "cut", level), cmax)

        ci = np.zeros((nranks, B), np.int32)
        cj = np.zeros((nranks, B), np.int32)
        shift = np.zeros((nranks, B, 3), self._shift.dtype)
        pmask = np.zeros((nranks, B), np.float32)
        int_pos = np.zeros((nranks, Bi), np.int32)
        int_valid = np.zeros((nranks, Bi), np.float32)
        cut_pos = np.zeros((nranks, Bc), np.int32)
        cut_valid = np.zeros((nranks, Bc), np.float32)
        for r in range(nranks):
            idx, halo_pair = idxs[r], splits[r]
            nlive = len(idx)
            idxp = np.concatenate(
                [idx, np.zeros(B - nlive, dtype=idx.dtype)])
            ci[r] = plan.ci_ext[r][idxp]
            cj[r] = plan.cj_ext[r][idxp]
            shift[r] = self._shift[idxp]
            pmask[r, :nlive] = 1.0
            ipos = np.nonzero(~halo_pair)[0]
            cpos = np.nonzero(halo_pair)[0]
            int_pos[r, :len(ipos)] = ipos
            int_valid[r, :len(ipos)] = 1.0
            cut_pos[r, :len(cpos)] = cpos
            cut_valid[r, :len(cpos)] = 1.0

        tables = {"ci": ci, "cj": cj, "shift": shift, "pmask": pmask,
                  "int_pos": int_pos, "int_valid": int_valid,
                  "cut_pos": cut_pos, "cut_valid": cut_valid,
                  "wake": wake_stacked if wake_stacked is not None
                  else np.zeros((nranks, nrows), np.int32)}
        if t.mode == "ppermute":
            Be = self._fused_buckets.fit(("edge", stream),
                                         slots.max_edge_slots)
            pack, unpack, valid = pack_rounds(t.rounds, slots, nranks, Be)
            tables.update(e_pack=pack, e_unpack=unpack, e_valid=valid)
            exch_sig = ("ppermute", Be, t._perms_sig)
        else:
            Bo = self._fused_buckets.fit(("ag_out", stream),
                                         slots.max_rank_exports(nranks))
            Bn = self._fused_buckets.fit(("ag_in", stream),
                                         slots.max_rank_imports(nranks))
            pack, usrc, urows, valid = pack_allgather(slots, nranks, Bo, Bn)
            tables.update(e_pack=pack, e_usrc=usrc, e_urows=urows,
                          e_valid=valid)
            exch_sig = ("allgather", Bo, Bn)
        self.transfers.record(
            "tables", sum(a.nbytes for a in tables.values()), boundary=False)
        tables = {k: jnp.asarray(v) for k, v in tables.items()}
        sig = (nranks, nrows, plan.K, B, Bi, Bc, exch_sig)
        return tables, sig

    def _fused_program(self, sig: Tuple, *, final: bool):
        """Compiled fused sub-step program for this shape signature (one
        compile per (phase, bucket signature), cached with the transport's
        exchange programs so the probe counts every build)."""
        from .collectives import build_fused_substep_program
        t = self._transport
        nrows, K = sig[1], sig[2]
        key = ("fused_final" if final else "fused_force",) + sig + (t.mode,)
        return t.programs.get(key, lambda: build_fused_substep_program(
            t.mesh, t.axis, mode=t.mode, rounds=t.rounds, nrows=nrows, K=K,
            cfg=self.cfg, box=self.box, final=final))

    def _cycle_substeps_device(self, ctx: Dict[str, object]
                               ) -> Dict[str, int]:
        """The device-resident ladder: the stacked extended states stay on
        the mesh for the whole cycle; every force sub-step is one fused
        shard_map program (drift → density → exchange → split force →
        kick → exchange). Host traffic is control tables in, one changed
        flag out — plus a bins-mirror refresh per deepening/wake event."""
        plan: RankPlan = ctx["plan"]
        depth, nsub = ctx["depth"], ctx["nsub"]
        dt_max_c, dt_min = ctx["dt_max_c"], ctx["dt_min"]
        mask_host, u_floor = ctx["mask_host"], ctx["u_floor"]
        nreal = ctx["nreal"]
        tr = self.tracer
        t0 = tr.now() if tr.enabled else 0.0
        res = self._scatter_resident(plan)
        if tr.enabled:
            tr.fence(res["pos"])
            tr.record_all(range(plan.nranks), "scatter", t0, collective=1)

        updates = 0
        pair_tasks = 0
        force_substeps = 0
        drifted_to = 0
        cycle_exported = 0
        cycle_full = 0
        self.halo_log = []
        bins_h = ctx["bins_host"].copy()
        wake_floor = self._wake_floor(bins_h, mask_host)
        wake_stacked: Optional[np.ndarray] = None
        # cycle-scoped device plan: a sub-step's control tables depend only
        # on (level, bins mirror) — every sub-step of the same level reuses
        # the tables already sitting on the device; a deepening/wake event
        # invalidates the whole cache. A depth-d cycle uploads O(d) table
        # sets, not O(2**d).
        table_cache: Dict[int, Tuple] = {}

        def wake_tbl() -> np.ndarray:
            nonlocal wake_stacked
            if wake_stacked is None:
                w = np.zeros((plan.nranks, plan.K + plan.H), np.int32)
                for r in range(plan.nranks):
                    own, hal = plan.owned[r], plan.halo[r]
                    w[r, :len(own)] = wake_floor[own]
                    w[r, plan.K:plan.K + len(hal)] = wake_floor[hal]
                wake_stacked = w
            return wake_stacked

        def level_plan(level: int) -> Tuple:
            key = level
            if key not in table_cache:
                active_p = ((bins_h >= level)
                            | (bins_h < wake_floor[:, None])) \
                    & (mask_host > 0)
                if not active_p.any():
                    table_cache[key] = (active_p, None, None, None, None)
                else:
                    active_cells = active_p.any(axis=1)
                    ship = self._exchange_set(plan, active_cells)
                    slots = plan.ship_slots(ship) if ship else ShipSlots()
                    tables, sig = self._fused_tables(
                        plan, active_cells, slots, "fused_sub", wake_tbl(),
                        level=level)
                    table_cache[key] = (active_p, active_cells, slots,
                                        tables, sig)
            return table_cache[key]

        dm_on = self.device_metrics_enabled
        met_acc: List = []          # one (counts, values) device-ref cell
        cell_acc: List = []         # one stacked per-cell buffer device ref

        def run_fused(tables, sig, scalars, final):
            prog = self._fused_program(sig, final=final)
            state_in = {name: res[name] for name in
                        self._CELL_FIELDS + self._AUX_FIELDS + ("time",)}
            out_state, changed, met = prog(state_in, tables, scalars)
            res.update(out_state)
            if dm_on:
                row = (met["counts"], met["values"])
                if not met_acc:
                    met_acc.append(row)
                    cell_acc.append(met["cells"])
                else:
                    # eager device-side fold of the tiny rows: no host
                    # sync, no registered program, no extra compile
                    met_acc[0] = dmetrics.combine(met_acc[0], row, jnp)
                    cell_acc[0] = cell_acc[0] + met["cells"]
            return changed

        for n in range(1, nsub):
            level = active_level(n, depth)
            active_p, active_cells, slots, tables, sig = level_plan(level)
            if not active_p.any():
                continue
            cycle_exported += slots.total
            cycle_full += plan.cut_slots
            self.halo_log.append({
                "substep": self.substeps + n, "level": level,
                "exported_slots": slots.total,
                "full_slots": plan.cut_slots})

            dt_d = (n - drifted_to) * dt_min
            drifted_to = n
            if tr.enabled:
                tr.ctx["substep"] = n
            self.program_keys.add(("fused_force", level, sig[3]))
            scalars = {"dt_drift": jnp.float32(dt_d),
                       "level": jnp.int32(level),
                       "dt_max": jnp.float32(dt_max_c),
                       "depth": jnp.int32(depth),
                       "u_floor": jnp.float32(u_floor)}
            ts = tr.now() if tr.enabled else 0.0
            changed = run_fused(tables, sig, scalars, final=False)
            if tr.enabled:
                # the fused program is one task on every rank's row; fence
                # so its device time lands inside this span, not the next
                tr.fence(res["pos"])
                tr.record_all(
                    range(plan.nranks), "fused_substep", ts,
                    level=level, bucket=sig[3],
                    units=int((active_cells[self._ci]
                               | active_cells[self._cj]).sum()),
                    slots=slots.total,
                    active_frac=float(active_p.sum()) / max(nreal, 1),
                    collective=1)
            changed_h = np.asarray(changed)
            self.transfers.record("flags", changed_h.nbytes, boundary=False)
            if changed_h.any():
                # a deepening / wake-up: refresh the bins mirror for the
                # changed ranks only, then re-derive the wake floors —
                # the lone mid-cycle state-array readback, counted per
                # event by the transfer probe
                with tr.span("bins_refresh"):
                    for r in np.nonzero(changed_h)[0]:
                        own = plan.owned[int(r)]
                        if not len(own):
                            continue
                        row = res.pull("bins", boundary=False, index=int(r))
                        bins_h[own] = row[:len(own)]
                    self.bins_refreshes += 1
                    table_cache.clear()         # invalidate the level plans
                    new_floor = self._wake_floor(bins_h, mask_host)
                    if not np.array_equal(new_floor, wake_floor):
                        wake_floor = new_floor
                        wake_stacked = None     # invalidate on wake-up
            updates += int(active_p.sum())
            pair_tasks += int((active_cells[self._ci]
                               | active_cells[self._cj]).sum())
            force_substeps += 1

        # final sync sub-step: everyone active, full pair lists, full cut
        dt_d = (nsub - drifted_to) * dt_min
        slots = plan.ship_slots(list(plan.cut)) if plan.cut else ShipSlots()
        cycle_exported += slots.total
        if plan.cut:
            cycle_full += plan.cut_slots
        tables, sig = self._fused_tables(plan, None, slots, "fused_final",
                                         None)
        self.program_keys.add(("fused_final", 0, sig[3]))
        if tr.enabled:
            tr.ctx["substep"] = nsub
        scalars = {"dt_drift": jnp.float32(dt_d), "level": jnp.int32(0),
                   "dt_max": jnp.float32(dt_max_c),
                   "depth": jnp.int32(depth),
                   "u_floor": jnp.float32(u_floor)}
        ts = tr.now() if tr.enabled else 0.0
        run_fused(tables, sig, scalars, final=True)
        if tr.enabled:
            tr.fence(res["pos"])
            tr.record_all(range(plan.nranks), "fused_final", ts,
                          level=0, bucket=sig[3], units=len(self._ci),
                          slots=slots.total, active_frac=1.0, collective=1)
        updates += nreal
        pair_tasks += len(self._ci)

        if dm_on and met_acc:
            # one pull per cycle: the whole accumulated telemetry row
            # (per-cell buffer included — same single boundary transfer)
            self._metrics_pull(*met_acc[0], cells=cell_acc[0], plan=plan)
        elif not dm_on:
            self.device_metrics_last = None
            self.device_cell_work_last = None

        tg = tr.now() if tr.enabled else 0.0
        self._gather_resident(plan, res)
        if tr.enabled:
            tr.record_all(range(plan.nranks), "gather", tg, collective=1)
        return {"updates": updates, "pair_tasks": pair_tasks,
                "force_substeps": force_substeps,
                "cycle_exported": cycle_exported,
                "cycle_full": cycle_full}

    # ---------------------------------------------- device-scheduled segments
    def _segment_tables(self, plan: RankPlan
                        ) -> Tuple[Dict[str, jax.Array],
                                   Dict[str, jax.Array], Tuple]:
        """Static control tables of one device-scheduled segment.

        Unlike :meth:`_fused_tables` these are activity-*independent*: the
        full touch-pair set per rank (compacted in ascending global pair
        order — the same subsequence every per-level host table is a
        restriction of, so masked scatters fold identical contribution
        sequences), the full-cut exchange tables, and the schedule-deriving
        side tables (per-rank pair ownership for global pair counting, row
        cell ids for the crossing sentinel, the global row gather for
        u_floor). One upload per segment, ledgered as a *boundary*
        transfer: the scanned path has zero intra-segment entries by
        construction.
        """
        t = self._transport
        nranks, nrows = plan.nranks, plan.K + plan.H
        idxs, nmax = self._select_rank_pairs(plan, None)
        splits = []
        imax, cmax = 1, 1
        for r in range(nranks):
            idx = idxs[r]
            halo_pair = ((plan.ci_ext[r][idx] >= plan.K)
                         | (plan.cj_ext[r][idx] >= plan.K))
            splits.append(halo_pair)
            imax = max(imax, int((~halo_pair).sum()))
            cmax = max(cmax, int(halo_pair.sum()))
        # static demand (the full touch set) -> plain next_pow2 buckets;
        # the signature only moves when the partition does
        B, Bi, Bc = next_pow2(nmax), next_pow2(imax), next_pow2(cmax)

        ci = np.zeros((nranks, B), np.int32)
        cj = np.zeros((nranks, B), np.int32)
        shift = np.zeros((nranks, B, 3), self._shift.dtype)
        pmask = np.zeros((nranks, B), np.float32)
        own_pair = np.zeros((nranks, B), np.float32)
        int_pos = np.zeros((nranks, Bi), np.int32)
        int_valid = np.zeros((nranks, Bi), np.float32)
        cut_pos = np.zeros((nranks, Bc), np.int32)
        cut_valid = np.zeros((nranks, Bc), np.float32)
        rowcell = np.full((nranks, nrows), -1, np.int32)
        for r in range(nranks):
            idx, halo_pair = idxs[r], splits[r]
            nlive = len(idx)
            idxp = np.concatenate(
                [idx, np.zeros(B - nlive, dtype=idx.dtype)])
            ci[r] = plan.ci_ext[r][idxp]
            cj[r] = plan.cj_ext[r][idxp]
            shift[r] = self._shift[idxp]
            pmask[r, :nlive] = 1.0
            # a pair is counted by the rank owning its ci cell — a
            # partition of the global pair list, so the psum of live own
            # pairs equals the host's global live-pair count
            own_pair[r, :nlive] = (
                self._assignment[self._ci[idx]] == r).astype(np.float32)
            ipos = np.nonzero(~halo_pair)[0]
            cpos = np.nonzero(halo_pair)[0]
            int_pos[r, :len(ipos)] = ipos
            int_valid[r, :len(ipos)] = 1.0
            cut_pos[r, :len(cpos)] = cpos
            cut_valid[r, :len(cpos)] = 1.0
            own, hal = plan.owned[r], plan.halo[r]
            rowcell[r, :len(own)] = own
            rowcell[r, plan.K:plan.K + len(hal)] = hal

        tables = {"ci": ci, "cj": cj, "shift": shift, "pmask": pmask,
                  "own_pair": own_pair, "int_pos": int_pos,
                  "int_valid": int_valid, "cut_pos": cut_pos,
                  "cut_valid": cut_valid, "rowcell": rowcell}
        slots = plan.ship_slots(list(plan.cut)) if plan.cut else ShipSlots()
        if t.mode == "ppermute":
            Be = next_pow2(max(slots.max_edge_slots, 1))
            pack, unpack, valid = pack_rounds(t.rounds, slots, nranks, Be)
            tables.update(e_pack=pack, e_unpack=unpack, e_valid=valid)
            exch_sig = ("ppermute", Be, t._perms_sig)
        else:
            Bo = next_pow2(max(slots.max_rank_exports(nranks), 1))
            Bn = next_pow2(max(slots.max_rank_imports(nranks), 1))
            pack, usrc, urows, valid = pack_allgather(slots, nranks, Bo, Bn)
            tables.update(e_pack=pack, e_usrc=usrc, e_urows=urows,
                          e_valid=valid)
            exch_sig = ("allgather", Bo, Bn)
        # global cell c lives at flattened all_gather row
        # owner_rank * K + owner_row (the plan program's u_floor gather)
        gidx = np.zeros(self.spec.ncells, np.int32)
        for r in range(nranks):
            own = plan.owned[r]
            if len(own):
                gidx[own] = r * plan.K + np.arange(len(own), dtype=np.int32)
        consts = {"gather_idx": gidx}
        self.transfers.record(
            "segment_tables",
            sum(a.nbytes for a in tables.values()) + gidx.nbytes,
            boundary=True)
        sh = self._mesh_sharding()
        tables = {k: jax.device_put(jnp.asarray(v), sh)
                  for k, v in tables.items()}
        consts = {k: jnp.asarray(v) for k, v in consts.items()}
        sig = (nranks, nrows, plan.K, B, Bi, Bc, exch_sig)
        return tables, consts, sig

    def _cycle_scan_program(self, sig: Tuple, nsub_static: int):
        from .collectives import build_cycle_scan_program
        t = self._transport
        nrows, K = sig[1], sig[2]
        key = ("cycle_scan", nsub_static, self.activity_aware) + sig \
            + (t.mode,)
        return t.programs.get(key, lambda: build_cycle_scan_program(
            t.mesh, t.axis, mode=t.mode, rounds=t.rounds, nrows=nrows, K=K,
            cfg=self.cfg, box=self.box, nsub_static=nsub_static,
            bin_delta=self.bin_delta,
            activity_aware=self.activity_aware))

    def _plan_program(self, sig: Tuple, nsub_static: int):
        from .collectives import build_plan_program
        t = self._transport
        nrows, K = sig[1], sig[2]
        key = ("segment_plan", nsub_static, self.dt_max) + sig + (t.mode,)
        return t.programs.get(key, lambda: build_plan_program(
            t.mesh, t.axis, mode=t.mode, rounds=t.rounds, nrows=nrows, K=K,
            cfg=self.cfg, box=self.box,
            ncells_side=self.spec.ncells_side, max_depth=self.max_depth,
            bin_delta=self.bin_delta, depth_headroom=self.depth_headroom,
            nsub_static=nsub_static, dt_max_static=self.dt_max))

    def _place_scalars(self, vals: Dict[str, np.ndarray]
                       ) -> Dict[str, jax.Array]:
        sh = self._mesh_sharding()
        self.transfers.record(
            "segment_tables",
            sum(np.asarray(v).nbytes for v in vals.values()), boundary=True)
        return {k: jax.device_put(jnp.asarray(v), sh)
                for k, v in vals.items()}

    def _run_segment(self) -> List[Dict]:
        """Run one device-scheduled segment of ``segment_cycles`` cycles.

        Cycle 1 is planned by the host prologue (it also sizes the static
        scan ladder); each further cycle is planned *on device* by the
        plan program, its scalars flowing device-to-device. Between the
        initial scatter and the final gather the host moves zero state or
        schedule bytes — one boundary upload of the static tables, one
        boundary pull of the per-cycle counters/flags at the end
        (``TransferProbe`` shows no intra-segment entries at all). If a
        health sentinel (NaN/Inf/neg-rho), a cell crossing or a
        capacity-overflow flag tripped, the pre-segment state is restored
        and the segment replays on the host-scheduled ladder —
        bitwise-recoverable by the residency conformance contract.
        """
        K_cycles = self.segment_cycles
        stash = self.state
        ctx = self._cycle_prologue()
        plan: RankPlan = ctx["plan"]
        nsub_static = ctx["nsub"]
        res = self._scatter_resident(plan)
        tables, consts, sig = self._segment_tables(plan)
        cyc_prog = self._cycle_scan_program(sig, nsub_static)
        self.program_keys.add(("cycle_scan", ctx["depth"], sig[3]))
        plan_prog = self._plan_program(sig, nsub_static) \
            if K_cycles > 1 else None
        if plan_prog is not None:
            self.program_keys.add(("segment_plan", ctx["depth"], sig[3]))
        scalars = self._place_scalars({
            "dt_max": np.full(plan.nranks, ctx["dt_max_c"], np.float32),
            "depth": np.full(plan.nranks, ctx["depth"], np.int32),
            "nsub": np.full(plan.nranks, ctx["nsub"], np.int32),
            "u_floor": np.full(plan.nranks, ctx["u_floor"], np.float32)})
        names = self._CELL_FIELDS + self._AUX_FIELDS + ("time",)
        per_cnt, per_met, per_scal, per_flags = [], [], [scalars], []
        for j in range(K_cycles):
            if j > 0:
                state_in = {nm: res[nm] for nm in names}
                upd, scalars, flags = plan_prog(state_in, tables, consts)
                res.update(upd)
                per_scal.append(scalars)
                per_flags.append(flags)
            state_in = {nm: res[nm] for nm in names}
            out_state, cnt, met = cyc_prog(state_in, tables, scalars)
            res.update(out_state)
            per_cnt.append(cnt)
            per_met.append(met)
        # ---- ONE boundary pull: every cycle's counters, metrics rows,
        # device-planned scalars and sentinel flags
        pulled_cnt = [{k: np.asarray(v) for k, v in c.items()}
                      for c in per_cnt]
        pulled_met = [(np.asarray(m["counts"]), np.asarray(m["values"]),
                       np.asarray(m["cells"])) for m in per_met]
        pulled_scal = [{k: np.asarray(v) for k, v in s.items()}
                       for s in per_scal]
        pulled_flags = [{k: np.asarray(v) for k, v in f.items()}
                        for f in per_flags]
        nbytes = sum(a.nbytes for grp in pulled_cnt for a in grp.values())
        nbytes += sum(c.nbytes + v.nbytes + w.nbytes
                      for c, v, w in pulled_met)
        nbytes += sum(a.nbytes for grp in pulled_scal for a in grp.values())
        nbytes += sum(a.nbytes for grp in pulled_flags
                      for a in grp.values())
        self.transfers.record("segment_stats", nbytes, boundary=True)
        self.segments += 1

        mci = dmetrics.COUNT_INDEX
        sentinels = sum(
            int(c[:, mci["flag_nan"]].sum() + c[:, mci["flag_inf"]].sum()
                + c[:, mci["flag_neg_rho"]].sum())
            for c, _, _ in pulled_met)
        crossed = sum(int(f["crossed"][0]) for f in pulled_flags)
        over = sum(int(f["capacity"][0]) for f in pulled_flags)
        if sentinels or crossed or over:
            # sentinel trip: discard the segment (the flagged program's
            # interior state is garbage by contract), restore the
            # pre-segment state and replay host-scheduled — bitwise
            # identical to the reference ladder, NaNs included
            self.segment_aborts += 1
            self.state = stash
            return self._replay_segment_host(K_cycles)

        self._gather_resident(plan, res)
        depth_last = int(pulled_scal[-1]["depth"][0])
        self._maybe_repartition(np.asarray(self.state.bins),
                                np.asarray(self.state.cells.mask),
                                depth_last)
        if self.rebin_each_cycle:
            with self.tracer.span("rebin", units=ctx["nreal"]):
                self._rebin_state()

        nreal = ctx["nreal"]
        cut_slots = plan.cut_slots
        self.halo_log = []      # per-sub-step log is host-side only
        dm_on = self.device_metrics_enabled
        stats_list: List[Dict] = []
        for j in range(K_cycles):
            cnt, scal = pulled_cnt[j], pulled_scal[j]
            dt_max_j = float(scal["dt_max"][0])
            depth_j = int(scal["depth"][0])
            nsub_j = int(scal["nsub"][0])
            updates_j = int(cnt["updates"].sum())
            pair_j = int(cnt["pair_tasks"].sum())
            fs_j = int(cnt["force_substeps"][0])
            exported_j = int(cnt["exported"].sum())
            full_j = int(cnt["live_trips"][0]) * cut_slots
            self.particle_updates += updates_j
            self.global_equiv_updates += nsub_j * nreal
            self.substeps += nsub_j
            self.halo_exported_slots += exported_j
            self.halo_full_slots += full_j
            if j == 0:
                hist_j = ctx["hist"]
            else:
                hist_j = pulled_flags[j - 1]["hist"][0, :depth_j + 1]
            stats = {
                "t": float(cnt["t_end"][0]),
                "dt_max": dt_max_j,
                "depth": depth_j,
                "substeps": nsub_j,
                "force_substeps": fs_j + 1,
                "bin_hist": np.asarray(hist_j),
                "updates": updates_j,
                "global_equiv_updates": nsub_j * nreal,
                "pair_tasks": pair_j,
                "global_equiv_pair_tasks": nsub_j * len(self._ci),
                "halo_exported_slots": exported_j,
                "halo_full_slots": full_j,
                "nranks": plan.nranks,
                "residency": self.residency,
                "schedule": "device",
                "segment_cycles": K_cycles,
            }
            if dm_on:
                stats["_met"] = pulled_met[j][:2]
                stats["_cellw"] = dmetrics.fold_cell_rows(
                    pulled_met[j][2], plan.owned, plan.halo,
                    self.spec.ncells, plan.K)
            stats_list.append(stats)
        if not dm_on:
            self.device_metrics_last = None
            self.device_cell_work_last = None
        return stats_list

    def _replay_segment_host(self, K_cycles: int) -> List[Dict]:
        """Abort path: re-run the segment's cycles on the host-scheduled
        device-resident ladder (the conformance-pinned reference path)."""
        out = []
        for _ in range(K_cycles):
            ctx = self._cycle_prologue()
            body = self._cycle_substeps_device(ctx)
            stats = self._cycle_epilogue(ctx, body)
            stats["schedule"] = "device"
            stats["segment_cycles"] = K_cycles
            stats["replayed"] = True
            out.append(stats)
        return out
