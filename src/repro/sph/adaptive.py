"""Recursive cell splitting (paper §3.1).

    "These initial interaction tasks are then refined by recursively
    splitting cells that contain more than a certain number of particles
    and replacing tasks that span a pair of split cells with tasks spanning
    the neighbouring sub-cells."

Clustered ICs put thousands of particles in a handful of cells; without
splitting, a single cell's O(occ²) self-task exceeds the per-rank budget
and no partition can balance it (observed directly in
``benchmarks/partition_quality.py``). This module builds the *refined* cell
graph: cells over ``threshold`` particles are split into 8 children (with
their true sub-occupancies, recursively up to ``max_levels``), and pair
tasks are re-derived between spatially adjacent leaves of mixed levels.

The output is the (node_weights, edges, meta) cost graph the domain
decomposition partitions — granularity restored exactly the way SWIFT
does it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class LeafCell:
    level: int                 # 0 = base grid
    idx: Tuple[int, int, int]  # grid index at that level
    occupancy: int

    def bounds(self, box: float, base_side: int):
        side = base_side * (2 ** self.level)
        size = box / side
        lo = np.array(self.idx, dtype=np.float64) * size
        return lo, lo + size


def _touching(a: LeafCell, b: LeafCell, box: float, base_side: int) -> bool:
    """Periodic box-touch test (face/edge/corner adjacency)."""
    lo_a, hi_a = a.bounds(box, base_side)
    lo_b, hi_b = b.bounds(box, base_side)
    eps = 1e-9 * box
    for d in range(3):
        direct = max(0.0, max(lo_a[d], lo_b[d]) - min(hi_a[d], hi_b[d]))
        wrapped = max(0.0, (box - max(hi_a[d], hi_b[d])) + min(lo_a[d],
                                                               lo_b[d]))
        if min(direct, wrapped) > eps:
            return False
    return True


def split_cells(pos: np.ndarray, box: float, base_side: int, *,
                threshold: int = 64, max_levels: int = 3
                ) -> List[LeafCell]:
    """Recursively split overloaded cells; returns the leaf set."""
    pos = np.mod(np.asarray(pos, dtype=np.float64), box)

    def occupancy_at(level: int) -> Dict[Tuple[int, int, int], int]:
        side = base_side * (2 ** level)
        idx = np.clip((pos / box * side).astype(np.int64), 0, side - 1)
        out: Dict[Tuple[int, int, int], int] = {}
        for i in map(tuple, idx):
            out[i] = out.get(i, 0) + 1
        return out

    occ_by_level = [occupancy_at(l) for l in range(max_levels + 1)]
    leaves: List[LeafCell] = []

    def recurse(level: int, idx: Tuple[int, int, int]):
        occ = occ_by_level[level].get(idx, 0)
        if occ > threshold and level < max_levels:
            i, j, k = idx
            for di in (0, 1):
                for dj in (0, 1):
                    for dk in (0, 1):
                        child = (2 * i + di, 2 * j + dj, 2 * k + dk)
                        if occ_by_level[level + 1].get(child, 0) > 0:
                            recurse(level + 1, child)
            return
        leaves.append(LeafCell(level, idx, occ))

    for i in range(base_side):
        for j in range(base_side):
            for k in range(base_side):
                recurse(0, (i, j, k))
    return leaves


def refined_cell_graph(pos: np.ndarray, box: float, base_side: int, *,
                       threshold: int = 64, max_levels: int = 3,
                       n_ngb: float = 48.0, include_empty: bool = False
                       ) -> Tuple[np.ndarray, Dict[Tuple[int, int], float],
                                  List[LeafCell]]:
    """(node_weights, edge_weights, leaves) of the refined task graph.

    Cost model matches *adaptive* SPH: each particle interacts with
    ≈ ``n_ngb`` neighbours regardless of local density (h shrinks where it
    is dense), so a task over occupancies (a, b) costs
    min(a·b, n_ngb·min(a, b)) interactions — never the naive a·b, which
    would overweight dense cells the smoothing length has already shrunk
    away from. Two phases (density + force) per step.
    """
    leaves = [l for l in split_cells(pos, box, base_side,
                                     threshold=threshold,
                                     max_levels=max_levels)
              if include_empty or l.occupancy > 0]
    n = len(leaves)

    def self_cost(occ: float) -> float:
        return min(0.5 * occ * occ, n_ngb * occ)

    def pair_cost(a: float, b: float) -> float:
        return min(a * b, n_ngb * min(a, b))

    node_w = np.array([2.0 * self_cost(l.occupancy) + 3.0 * l.occupancy
                       for l in leaves], dtype=np.float64)
    edges: Dict[Tuple[int, int], float] = {}
    for a in range(n):
        for b in range(a + 1, n):
            if leaves[a].occupancy == 0 or leaves[b].occupancy == 0:
                continue
            if _touching(leaves[a], leaves[b], box, base_side):
                edges[(a, b)] = 2.0 * pair_cost(leaves[a].occupancy,
                                                leaves[b].occupancy)
    return node_w, edges, leaves
