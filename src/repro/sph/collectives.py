"""Device-collective transport: shard_map/ppermute halo exchange programs.

The wire lowering of the distributed time-bin engine's two per-sub-step
exchanges (``sph/dist_timebins.py``). Where :class:`~repro.distributed.
transport.HostTransport` copies rows through numpy, this module compiles the
same copies into one XLA program over a rank mesh:

* every rank packs the rows it owes its neighbours into a
  **power-of-two-bucketed export buffer** (mask-padded, so the program's
  shapes — and therefore its compilation — are independent of how many
  cut-cell rows are active at this sub-step);
* the buffers move either through ``lax.ppermute`` rounds — the
  neighbour-to-neighbour schedule derived from the comm planner's export
  edge list (``core.comm_planner.ppermute_rounds``) — or through one
  ``lax.all_gather`` (the fallback when the edge colouring needs more
  rounds than a gather is worth);
* each rank scatters the received slots into its halo replica rows;
  invalid (padding) slots are routed to a scratch row that is sliced off, so
  padded slots provably leave the state untouched.

Exchanges are pure row copies — the collective transport is bit-for-bit
identical to the host transport by construction, which the parity tests in
``tests/test_transport.py`` assert on 1 and 4 (emulated) devices.

Compiled programs are cached by their static signature (bucket, rounds,
field shapes) in a :class:`~repro.distributed.transport.ProgramCache`, and
every build is registered with the engine's :class:`~repro.distributed.
transport.CompileProbe` — the bucket hysteresis guarantees the cache stays
small across sub-steps and cycles.

**Fused sub-step programs** (:func:`build_fused_substep_program`): the
device-resident lowering goes further and compiles a *whole force sub-step*
— drift, density phase, exchange 1, force phase, kick and exchange 2 — into
one shard_map program over the stacked per-rank extended states, so the
state never leaves the mesh between cycle boundaries. The force pair pass
is split into **interior** pairs (both rows owned — their inputs cannot be
touched by exchange 1, so their per-pair math is scheduled against the
exchange rounds instead of behind them) and **cut** pairs (one row is a
halo replica — they wait for the exchanged densities); the two subsets'
contributions are re-assembled *in original pair-list order* and applied in
a single scatter, which keeps the fused program bit-for-bit identical to
the unsplit host-wire phases (:func:`_split_force_pass`).
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.comm_planner import ppermute_rounds
from ..distributed.mesh_utils import ranks_mesh
from ..distributed.transport import (BucketPolicy, CompileProbe, ProgramCache,
                                     ShipSlots, Transport, pack_allgather,
                                     pack_rounds)
from ..observability import device_metrics as dmetrics
from .cellgrid import PairList, ParticleCells
from .physics import force_block, sound_speed
from .timebins import (STATE_AUX_FIELDS, STATE_CELL_FIELDS, TimeBinState,
                       _apply_final_kick, _apply_force_kick, _cycle_start,
                       _drift, _substep_density_phase, assign_bins,
                       mass_weighted_mean_u, speed_norm,
                       substep_active_mask, trailing_zeros_table)


# ------------------------------------------------------- in-block row copies
def _permute_copy(loc, pack, unpack, valid, perms, axis: str, nrows: int):
    """ppermute-rounds copy of one field inside a shard_map block.

    ``loc`` (nrows, …) is this rank's field; ``pack``/``unpack``/``valid``
    are its (R, bucket) index tables. Padding slots land on a scratch row
    that is sliced off, so invalid slots provably never touch the state.
    """
    scratch = jnp.zeros((1,) + loc.shape[1:], loc.dtype)
    loc = jnp.concatenate([loc, scratch], axis=0)
    for t in range(len(perms)):
        buf = loc[pack[t]]                               # (bucket, …)
        got = jax.lax.ppermute(buf, axis, perms[t])
        keep = valid[t] > 0
        safe = jnp.where(keep, unpack[t], nrows)
        loc = loc.at[safe].set(got)
    return loc[:nrows]


def _allgather_copy(loc, pack, unpack_src, unpack_rows, valid, axis: str,
                    nrows: int):
    """all-gather fallback copy of one field inside a shard_map block."""
    scratch = jnp.zeros((1,) + loc.shape[1:], loc.dtype)
    loc = jnp.concatenate([loc, scratch], axis=0)
    buf = loc[pack]                                      # (bucket_out, …)
    g = jax.lax.all_gather(buf, axis)                    # (nranks, Bo, …)
    flat = g.reshape((-1,) + g.shape[2:])
    got = flat[unpack_src]                               # (bucket_in, …)
    keep = valid > 0
    safe = jnp.where(keep, unpack_rows, nrows)
    loc = loc.at[safe].set(got)
    return loc[:nrows]


def build_permute_program(mesh, axis: str,
                          rounds: Sequence[Sequence[Tuple[int, int]]],
                          nrows: int, bucket: int, nfields: int):
    """Compile one ppermute-rounds exchange over ``nfields`` stacked fields.

    Inputs (global shapes): ``pack``/``unpack`` (nranks, R, bucket) int32,
    ``valid`` (nranks, R, bucket) float, then each field
    (nranks, nrows, …). Returns the fields with every valid received slot
    written into its destination row; everything else bit-identical.
    """
    perms = [list(rnd) for rnd in rounds]

    def body(pack, unpack, valid, *fields):
        return tuple(
            _permute_copy(f[0], pack[0], unpack[0], valid[0], perms, axis,
                          nrows)[None]
            for f in fields)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis),) * (3 + nfields),
                   out_specs=(P(axis),) * nfields)
    return jax.jit(fn)


def build_allgather_program(mesh, axis: str, nrows: int, bucket_out: int,
                            bucket_in: int, nfields: int):
    """Compile the all-gather fallback exchange.

    Inputs: ``pack`` (nranks, bucket_out) int32, ``unpack_src``/
    ``unpack_rows`` (nranks, bucket_in) int32, ``valid`` (nranks,
    bucket_in) float, then the stacked fields.
    """

    def body(pack, unpack_src, unpack_rows, valid, *fields):
        return tuple(
            _allgather_copy(f[0], pack[0], unpack_src[0], unpack_rows[0],
                            valid[0], axis, nrows)[None]
            for f in fields)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis),) * (4 + nfields),
                   out_specs=(P(axis),) * nfields)
    return jax.jit(fn)


# ------------------------------------------------- interior/cut force split
def _split_force_pass(cells: ParticleCells, pairs: PairList, pair_mask,
                      pre, post, int_pos, int_valid, cut_pos, cut_valid,
                      *, cfg):
    """``engine._force_pass`` with the interior/cut work split.

    ``pre``/``post`` are (rho, press, omega, cs) before/after exchange 1.
    ``int_pos``/``cut_pos`` partition the live pair positions of ``pairs``
    into interior pairs (both rows owned) and cut pairs (one row a halo
    replica), each padded to its own bucket with ``*_valid`` zeros.

    Interior pairs read only owned rows, which exchange 1 never writes, so
    their per-pair contributions are computed from the *pre*-exchange
    fields — with no data dependency on the wire, XLA is free to schedule
    them against the exchange rounds. Cut pairs wait for the exchanged
    densities. Both subsets are then scattered back into **original
    pair-list position** (padding routed to a scratch slot) and applied in
    the same two accumulation ops as ``_force_pass``, so every row folds
    the same contributions in the same order — bit-for-bit identical to
    the unsplit pass over the ``post`` fields.
    """
    B = pairs.ci.shape[0]
    force = functools.partial(force_block, kernel=cfg.kernel,
                              alpha_visc=cfg.alpha_visc)

    def subset(fieldset, pos):
        rho, press, omega, cs = fieldset
        p = jnp.clip(pos, 0, max(B - 1, 0))
        ci, cj = pairs.ci[p], pairs.cj[p]
        shift = pairs.shift[p]
        gi = lambda a: a[ci]
        gj = lambda a: a[cj]
        pos_i = gi(cells.pos)
        pos_j = gj(cells.pos) + shift[:, None, :]
        fij = jax.vmap(force)(
            pos_i, gi(cells.vel), gi(cells.h), gi(press), gi(rho),
            gi(omega), gi(cs),
            pos_j, gj(cells.vel), gj(cells.h), gj(press), gj(rho),
            gj(omega), gj(cs), gj(cells.mass), gj(cells.mask))
        fji = jax.vmap(force)(
            pos_j, gj(cells.vel), gj(cells.h), gj(press), gj(rho),
            gj(omega), gj(cs),
            pos_i, gi(cells.vel), gi(cells.h), gi(press), gi(rho),
            gi(omega), gi(cs), gi(cells.mass), gi(cells.mask))
        return fij, fji

    fij_int, fji_int = subset(pre, int_pos)
    fij_cut, fji_cut = subset(post, cut_pos)

    safe_int = jnp.where(int_valid > 0, int_pos, B)
    safe_cut = jnp.where(cut_valid > 0, cut_pos, B)

    def assemble(int_vals, cut_vals):
        full = jnp.zeros((B + 1,) + int_vals.shape[1:], int_vals.dtype)
        full = full.at[safe_int].set(int_vals)
        full = full.at[safe_cut].set(cut_vals)
        return full[:B]

    dv_ij = assemble(fij_int.dv, fij_cut.dv)
    du_ij = assemble(fij_int.du, fij_cut.du)
    dv_ji = assemble(fji_int.dv, fji_cut.dv)
    du_ji = assemble(fji_int.du, fji_cut.du)

    ncells, cap = cells.mass.shape
    notself = (pairs.ci != pairs.cj).astype(cells.pos.dtype)
    live = jnp.ones_like(notself) if pair_mask is None else pair_mask
    dv = jnp.zeros((ncells, cap, 3), cells.pos.dtype)
    dv = dv.at[pairs.ci].add(dv_ij * live[:, None, None])
    dv = dv.at[pairs.cj].add(dv_ji * (notself * live)[:, None, None])
    du = jnp.zeros((ncells, cap), cells.pos.dtype)
    du = du.at[pairs.ci].add(du_ij * live[:, None])
    du = du.at[pairs.cj].add(du_ji * (notself * live)[:, None])
    return dv, du


# --------------------------------------------------- fused sub-step programs
def build_fused_substep_program(mesh, axis: str, *, mode: str,
                                rounds: Sequence[Sequence[Tuple[int, int]]],
                                nrows: int, K: int, cfg, box: float,
                                final: bool = False):
    """Compile one whole force sub-step as a single shard_map program.

    The device-resident engine's unit of work: drift → density phase →
    exchange 1 (rho, omega, press, cs) → split force pass → kick/deepen →
    exchange 2 (vel, u, bins, t_start, accel, dudt), all over the stacked
    per-rank extended states, which stay on the mesh. With ``final=True``
    the program is the cycle-closing boundary instead: every particle
    active, closing kick only, no exchange 2.

    Inputs are three pytrees — ``state`` (stacked per-rank field dict,
    sharded over ``axis`` and donated so buffers are reused in place),
    ``tables`` (pair lists, interior/cut split positions, wake floors and
    exchange index tables for this sub-step) and ``scalars`` (replicated
    dt/level/…). Returns the updated state dict, a per-rank ``changed``
    flag (1 iff any owned row's bin deepened — the only signal the host
    needs mid-cycle: it triggers a bins-mirror refresh; the dynamical
    state never leaves the device until the cycle gather), and a per-rank
    :mod:`~repro.observability.device_metrics` row — the in-program
    telemetry counters. The row is an **unconditional** third output:
    its reductions only add consumers to values the physics already
    computes (never producers), so instrumented and uninstrumented runs
    share this one compiled program per signature (zero extra compiles)
    and the state output is bitwise unchanged — both conformance-pinned.
    """
    perms = [list(rnd) for rnd in rounds]

    def xchg(tables, fields):
        if mode == "ppermute":
            return [_permute_copy(f, tables["e_pack"], tables["e_unpack"],
                                  tables["e_valid"], perms, axis, nrows)
                    for f in fields]
        return [_allgather_copy(f, tables["e_pack"], tables["e_usrc"],
                                tables["e_urows"], tables["e_valid"],
                                axis, nrows) for f in fields]

    def body(state, tables, scalars):
        blk = {k: v[0] for k, v in state.items()}
        tbl = {k: v[0] for k, v in tables.items()}
        st = TimeBinState(
            cells=ParticleCells(pos=blk["pos"], vel=blk["vel"],
                                mass=blk["mass"], u=blk["u"], h=blk["h"],
                                mask=blk["mask"]),
            accel=blk["accel"], dudt=blk["dudt"], rho=blk["rho"],
            omega=blk["omega"], bins=blk["bins"], t_start=blk["t_start"],
            time=blk["time"])
        st = _drift(st, scalars["dt_drift"], box=box)
        pairs = PairList(ci=tbl["ci"], cj=tbl["cj"], shift=tbl["shift"])
        pmask = tbl["pmask"]

        if final:
            active = st.cells.mask
        else:
            active = substep_active_mask(st, scalars["level"], tbl["wake"])
        rho, om, pr, cs = _substep_density_phase(st, pairs, pmask, active,
                                                 cfg=cfg)
        rho2, om2, pr2, cs2 = xchg(tbl, [rho, om, pr, cs])
        dv, du = _split_force_pass(
            st.cells, pairs, pmask, (rho, pr, om, cs),
            (rho2, pr2, om2, cs2), tbl["int_pos"], tbl["int_valid"],
            tbl["cut_pos"], tbl["cut_valid"], cfg=cfg)
        if final:
            st = _apply_final_kick(st, dv, du, rho2, om2,
                                   scalars["dt_max"], cfg=cfg)
            changed = jnp.zeros((1,), jnp.int32)
            kicked = jnp.sum((active > 0) & (st.cells.mask > 0))
            deepened = jnp.zeros((), jnp.int32)
            woken = jnp.zeros((), jnp.int32)
            nexch = 1
        else:
            st, kicked = _apply_force_kick(st, active, dv, du, rho2, om2,
                                           tbl["wake"], scalars["dt_max"],
                                           scalars["depth"],
                                           scalars["u_floor"], cfg=cfg)
            vel, uu, bb, ts, ac, dd = xchg(
                tbl, [st.cells.vel, st.cells.u, st.bins, st.t_start,
                      st.accel, st.dudt])
            deepened = jnp.sum(bb[:K] != blk["bins"][:K]
                               ).astype(jnp.int32)
            changed = (deepened > 0).astype(jnp.int32)[None]
            woken = jnp.sum(tbl["wake"] > scalars["level"]
                            ).astype(jnp.int32)
            st = st._replace(cells=st.cells._replace(vel=vel, u=uu),
                             bins=bb, t_start=ts, accel=ac, dudt=dd)
            nexch = 2
        # per-slot wire bytes are static: exchange 1 ships 4 (cap,)
        # fields; exchange 2 ships vel/accel (cap, 3) + u/bins/t_start/
        # dudt (cap,)
        cap = int(st.cells.mass.shape[1])
        slot_bytes = 4 * cap * 4
        if nexch == 2:
            slot_bytes += 10 * cap * 4
        nslots = jnp.sum(tbl["e_valid"] > 0).astype(jnp.int32)
        # telemetry covers the K *owned* rows only — halo mirrors belong
        # to their owner's row, so per-rank work and the summed energy
        # fingerprint match the host-path (no-halo) semantics exactly
        met_counts, met_values = dmetrics.measure_substep(
            mask=st.cells.mask[:K], active=active[:K],
            vel=st.cells.vel[:K], u=st.cells.u[:K],
            mass=st.cells.mass[:K], rho=st.rho[:K],
            live_pairs=jnp.sum(pmask),
            pair_int=jnp.sum(tbl["int_valid"] > 0),
            pair_cut=jnp.sum(tbl["cut_valid"] > 0),
            exch_slots=nslots * nexch, exch_bytes=nslots * slot_bytes,
            deepened=deepened, woken=woken, kicked=kicked)
        # per-cell attribution rides in the same unconditional output
        # pytree (new dict key, same out_specs): owned-row sums equal the
        # drift/density/force columns above, all-row exchange sums equal
        # exchange_units — the identities the 4-rank acceptance pins
        met_cells = dmetrics.measure_cells(
            nrows=nrows, K=K, mask=st.cells.mask[:K], pmask=pmask,
            ci=tbl["ci"], cj=tbl["cj"],
            exch_rows=(tbl["e_unpack"] if mode == "ppermute"
                       else tbl["e_urows"]),
            exch_valid=tbl["e_valid"], nexch=nexch)
        met = {"counts": met_counts[None], "values": met_values[None],
               "cells": met_cells[None]}
        out = {k: getattr(st.cells, k) for k in STATE_CELL_FIELDS}
        out.update({k: getattr(st, k) for k in STATE_AUX_FIELDS})
        out["time"] = st.time
        return {k: v[None] for k, v in out.items()}, changed, met

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis), P()),
                   out_specs=(P(axis), P(axis), P(axis)))
    return jax.jit(fn, donate_argnums=(0,))


# ------------------------------------------------ device-scheduled segments
# neutral element for integer scatter-max over possibly-empty stencils
# (same value the host planners use in timebins.limit_neighbour_bins)
_NEG_INF_BIN = -10 ** 6
_SCAN_UNROLL = False


def build_cycle_scan_program(mesh, axis: str, *, mode: str,
                             rounds: Sequence[Sequence[Tuple[int, int]]],
                             nrows: int, K: int, cfg, box: float,
                             nsub_static: int, bin_delta: int,
                             activity_aware: bool = True):
    """Compile one WHOLE cycle — every sub-step — as a single lax.scan.

    The device-scheduled lowering (``schedule="device"``): where
    :func:`build_fused_substep_program` compiles one sub-step and leaves the
    ladder bookkeeping (active levels, pair subsets, ship sets, wake floors)
    to a host loop, this program derives the entire schedule *inside* the
    compiled program from the device-resident ``bins`` array, so the host
    dispatches one call per cycle and reads nothing back until the segment
    boundary.

    Per scan trip n = 1..``nsub_static`` (the static ladder length;
    ``scalars["nsub"]`` may select a shorter dynamic ladder, later trips are
    dead):

    * the active level is ``max(depth − tz[n], 0)`` via a static
      trailing-zeros table;
    * the wake floor is recomputed from the live bins by pair scatter-max
      (the host recomputes it only on deepen events; the per-trip recompute
      reaches the same fixpoint values) and exchanged to halo rows over the
      full cut, so replica activity masks agree with their owners;
    * the pair subset is the *static full-touch table* gated by a dynamic
      mask — a pair is live iff it touches an active cell, exactly the host
      selection rule — and exchange validity is the static full-cut table
      gated by receiver-row activity (activity-aware shipping);
    * trips where no particle is active anywhere (``psum`` of the owned
      active counts) are *dead*: every state field keeps its carry via a
      ``where``, matching the host loop's ``continue`` (lazy drift
      included — the drift span accumulates in a ``drifted_to`` carry);
    * the final trip (n == nsub) runs the cycle-closing kick; interior and
      final updates are computed side by side and merged with a ``where``,
      so one compiled body serves both.

    Padded pair slots contribute exact ±0.0 through the same masked
    scatters as the host-scheduled fused path — the bitwise contract is
    ``assert_array_equal`` (±0.0 and NaN compare equal), identical to the
    existing residency conformance pin.

    The scan is **fully unrolled** (``unroll=nsub_static``): XLA:CPU's
    while-loop lowering of a rolled scan changes the force-reduction
    codegen by ~1 ulp versus the straight-line per-sub-step programs,
    which would break the bitwise contract. Unrolling recovers the exact
    straight-line HLO; the ladder is short (2^depth trips), so program
    size stays modest. ``_SCAN_UNROLL`` is a debug hook that swaps in a
    literal Python loop over trips to separate scan-lowering effects from
    body bugs.

    Outputs: the updated state dict (donated buffers), a per-rank counter
    dict (owned active updates, owned live pair tasks, live interior trips,
    exported slots, live trips, end-of-cycle time) and the cycle's
    accumulated device-metrics row — counters and health sentinels
    (NaN/Inf/neg-rho flags) included, so the segment driver's one boundary
    pull sees everything.
    """
    perms = [list(rnd) for rnd in rounds]
    tz_np = trailing_zeros_table(nsub_static)
    v_acc = np.asarray(dmetrics._V_ACCUM)
    v_sum = jnp.asarray(v_acc == "sum")
    v_last = jnp.asarray(v_acc == "last")
    v_max = jnp.asarray(v_acc == "max")

    def xchg(tbl, fields, valid):
        if mode == "ppermute":
            return [_permute_copy(f, tbl["e_pack"], tbl["e_unpack"], valid,
                                  perms, axis, nrows) for f in fields]
        return [_allgather_copy(f, tbl["e_pack"], tbl["e_usrc"],
                                tbl["e_urows"], valid, axis, nrows)
                for f in fields]

    def recv_valid(tbl, row_act, is_final):
        """Receiver-side slot validity: full cut on the final trip, active
        rows only in between (the packed send side always ships the whole
        static bucket — validity decides what lands)."""
        full = tbl["e_valid"]
        if not activity_aware:
            return full
        rows = tbl["e_unpack"] if mode == "ppermute" else tbl["e_urows"]
        return jnp.where(is_final, full, full * row_act[rows])

    def fold_values(acc, row, live):
        """Live-gated fold of one metrics value row per ``_V_ACCUM``
        (dmetrics.combine is unconditional — a dead trip's garbage row
        must not leak into last/max/min columns)."""
        upd_sum = acc + jnp.where(live, row, 0.0)
        upd_last = jnp.where(live, row, acc)
        upd_max = jnp.maximum(acc, jnp.where(live, row, -jnp.inf))
        upd_min = jnp.minimum(acc, jnp.where(live, row, jnp.inf))
        return jnp.where(v_sum, upd_sum,
                         jnp.where(v_last, upd_last,
                                   jnp.where(v_max, upd_max, upd_min)))

    def body(state, tables, scalars):
        blk = {k: v[0] for k, v in state.items()}
        tbl = {k: v[0] for k, v in tables.items()}
        dt_max = scalars["dt_max"][0]
        depth = scalars["depth"][0]
        nsub_dyn = scalars["nsub"][0]
        u_floor = scalars["u_floor"][0]
        # dt_min = dt_max / 2**depth: exact power-of-two scaling, so the
        # traced product k·dt_min below is the correctly-rounded f32 of the
        # host's f64 computation (nsub is a power of two)
        dt_min = dt_max * jnp.exp2(-depth.astype(jnp.float32))
        tz = jnp.asarray(tz_np)
        ci, cj, pmask = tbl["ci"], tbl["cj"], tbl["pmask"]
        pairs = PairList(ci=ci, cj=cj, shift=tbl["shift"])
        cap = int(blk["mass"].shape[1])
        fdt = blk["pos"].dtype

        st0 = TimeBinState(
            cells=ParticleCells(pos=blk["pos"], vel=blk["vel"],
                                mass=blk["mass"], u=blk["u"], h=blk["h"],
                                mask=blk["mask"]),
            accel=blk["accel"], dudt=blk["dudt"], rho=blk["rho"],
            omega=blk["omega"], bins=blk["bins"], t_start=blk["t_start"],
            time=blk["time"])
        cnt0 = {k: jnp.zeros((), jnp.int32)
                for k in ("updates", "pair_tasks", "force_substeps",
                          "exported", "live_trips")}
        met_c0 = jnp.zeros((len(dmetrics.COUNT_COLUMNS),), jnp.int32)
        met_v0 = jnp.zeros((len(dmetrics.VALUE_COLUMNS),), jnp.float32)
        met_v0 = met_v0.at[dmetrics.VALUE_INDEX["min_rho"]].set(jnp.inf)
        met_w0 = jnp.zeros((nrows, dmetrics.N_CELL_COLS), jnp.float32)

        def trip(carry, n):
            st, drifted_to, cnt, met_c, met_v, met_w = carry
            mask = st.cells.mask
            maskb = mask > 0
            level = jnp.maximum(depth - tz[n], 0)
            is_final = n == nsub_dyn
            # ---- wake floor from the live bins (host _wake_floor)
            deep = jnp.max(jnp.where(maskb, st.bins, _NEG_INF_BIN), axis=1)
            nb = deep
            nb = nb.at[ci].max(jnp.where(pmask > 0, deep[cj], _NEG_INF_BIN))
            nb = nb.at[cj].max(jnp.where(pmask > 0, deep[ci], _NEG_INF_BIN))
            wake_own = jnp.maximum(nb - bin_delta, 0).astype(jnp.int32)
            # full-cut exchange: halo rows take their owner's wake floor
            # (owned rows' stencils are complete — every pair touching an
            # owned cell is in the touch table)
            (wake,) = xchg(tbl, [wake_own], tbl["e_valid"])
            # ---- activity (host substep_active_mask / final mask)
            sub_act = ((st.bins >= level) | (st.bins < wake[:, None])
                       ) & maskb
            active = jnp.where(is_final, mask, sub_act.astype(fdt))
            row_act = jnp.any(sub_act, axis=1).astype(fdt)
            glob_act = jax.lax.psum(jnp.sum(sub_act[:K]).astype(jnp.int32),
                                    axis)
            live = ((glob_act > 0) | is_final) & (n <= nsub_dyn)
            # ---- lazy drift of everything since the last live trip
            kdt = (n - drifted_to).astype(jnp.float32) * dt_min
            std = _drift(st, kdt, box=box)
            # ---- density + exchange 1 + split force (as the fused path,
            # with the static tables gated by this trip's activity)
            pm = jnp.where(is_final, pmask,
                           pmask * jnp.maximum(row_act[ci], row_act[cj]))
            rho, om, pr, cs = _substep_density_phase(std, pairs, pm,
                                                     active, cfg=cfg)
            ev = recv_valid(tbl, row_act, is_final)
            rho2, om2, pr2, cs2 = xchg(tbl, [rho, om, pr, cs], ev)
            dv, du = _split_force_pass(
                std.cells, pairs, pm, (rho, pr, om, cs),
                (rho2, pr2, om2, cs2), tbl["int_pos"], tbl["int_valid"],
                tbl["cut_pos"], tbl["cut_valid"], cfg=cfg)
            # ---- interior and final kicks, merged by where
            stF, kickedF = _apply_force_kick(
                std, sub_act.astype(fdt), dv, du, rho2, om2, wake, dt_max,
                depth, u_floor, cfg=cfg)
            stL = _apply_final_kick(std, dv, du, rho2, om2, dt_max, cfg=cfg)
            stK = jax.tree_util.tree_map(
                lambda a, b: jnp.where(is_final, a, b), stL, stF)
            # ---- exchange 2: kicked state -> replicas. Unlike the host
            # ladder this also runs on the final trip (full validity), so
            # halo replicas enter the next cycle of a K>1 segment current;
            # owned rows are untouched by construction.
            vel, uu, bb, ts, ac, dd = xchg(
                tbl, [stK.cells.vel, stK.cells.u, stK.bins, stK.t_start,
                      stK.accel, stK.dudt], ev)
            stN = stK._replace(cells=stK.cells._replace(vel=vel, u=uu),
                               bins=bb, t_start=ts, accel=ac, dudt=dd)
            # ---- counters (owned partial sums; the driver psums on host)
            live32 = live.astype(jnp.int32)
            n_upd = jnp.where(is_final, jnp.sum(maskb[:K]),
                              jnp.sum(sub_act[:K])).astype(jnp.int32)
            n_pair = jnp.sum((pm > 0) & (tbl["own_pair"] > 0)
                             ).astype(jnp.int32)
            n_slots = jnp.sum(ev > 0).astype(jnp.int32)
            cnt_new = {
                "updates": cnt["updates"] + live32 * n_upd,
                "pair_tasks": cnt["pair_tasks"] + live32 * n_pair,
                "force_substeps": cnt["force_substeps"]
                + (live & ~is_final).astype(jnp.int32),
                "exported": cnt["exported"] + live32 * n_slots,
                "live_trips": cnt["live_trips"] + live32,
            }
            # ---- telemetry row (mirrors build_fused_substep_program)
            deepened = jnp.where(is_final, 0,
                                 jnp.sum(bb[:K] != st.bins[:K])
                                 ).astype(jnp.int32)
            woken = jnp.where(is_final, 0, jnp.sum(wake > level)
                              ).astype(jnp.int32)
            nexch = jnp.where(is_final, 1, 2)
            slot_bytes = jnp.where(is_final, 4 * cap * 4,
                                   (4 + 10) * cap * 4)
            kicked = jnp.where(
                is_final,
                jnp.sum((active > 0) & maskb).astype(jnp.int32), kickedF)
            mrow_c, mrow_v = dmetrics.measure_substep(
                mask=stN.cells.mask[:K], active=active[:K],
                vel=stN.cells.vel[:K], u=stN.cells.u[:K],
                mass=stN.cells.mass[:K], rho=stN.rho[:K],
                live_pairs=jnp.sum(pm),
                pair_int=jnp.sum(jnp.where(tbl["int_valid"] > 0,
                                           pm[tbl["int_pos"]], 0.0)
                                 ).astype(jnp.int32),
                pair_cut=jnp.sum(jnp.where(tbl["cut_valid"] > 0,
                                           pm[tbl["cut_pos"]], 0.0)
                                 ).astype(jnp.int32),
                exch_slots=n_slots * nexch,
                exch_bytes=n_slots * slot_bytes,
                deepened=deepened, woken=woken, kicked=kicked)
            mrow_w = dmetrics.measure_cells(
                nrows=nrows, K=K, mask=stN.cells.mask[:K], pmask=pm,
                ci=ci, cj=cj,
                exch_rows=(tbl["e_unpack"] if mode == "ppermute"
                           else tbl["e_urows"]),
                exch_valid=ev, nexch=nexch)
            met_c_new = met_c + jnp.where(live, mrow_c, 0)
            met_v_new = fold_values(met_v, mrow_v, live)
            met_w_new = met_w + jnp.where(live, mrow_w, 0.0)
            # ---- dead trips keep every carry bit-identical
            stO = jax.tree_util.tree_map(
                lambda new, old: jnp.where(live, new, old), stN, st)
            drifted_new = jnp.where(live, n, drifted_to)
            return (stO, drifted_new, cnt_new, met_c_new, met_v_new,
                    met_w_new), None

        xs = jnp.arange(1, nsub_static + 1, dtype=jnp.int32)
        carry0 = (st0, jnp.int32(0), cnt0, met_c0, met_v0, met_w0)
        if _SCAN_UNROLL:        # debug hook: straight-line trips
            carry = carry0
            for n in range(1, nsub_static + 1):
                carry, _ = trip(carry, jnp.int32(n))
            stE, _, cnt, met_c, met_v, met_w = carry
        else:
            (stE, _, cnt, met_c, met_v, met_w), _ = jax.lax.scan(
                trip, carry0, xs, unroll=nsub_static)
        out = {k: getattr(stE.cells, k) for k in STATE_CELL_FIELDS}
        out.update({k: getattr(stE, k) for k in STATE_AUX_FIELDS})
        out["time"] = stE.time
        cnt_out = {k: v[None] for k, v in cnt.items()}
        cnt_out["t_end"] = stE.time[None]
        met = {"counts": met_c[None], "values": met_v[None],
               "cells": met_w[None]}
        return ({k: v[None] for k, v in out.items()}, cnt_out, met)

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis), P(axis)),
                   out_specs=(P(axis), P(axis), P(axis)), check_rep=False)
    return jax.jit(fn, donate_argnums=(0,))


def build_plan_program(mesh, axis: str, *, mode: str,
                       rounds: Sequence[Sequence[Tuple[int, int]]],
                       nrows: int, K: int, cfg, box: float,
                       ncells_side: int, max_depth: int, bin_delta: int,
                       depth_headroom: int, nsub_static: int,
                       dt_max_static: Optional[float] = None):
    """Compile the between-cycles prologue of a K>1 device segment.

    Everything ``TimeBinSimulation._plan_cycle`` + the distributed
    prologue do on the host — signal-velocity CFL field, bin assignment,
    neighbour-limiter fixpoint, cycle depth, u_floor, opening half-kick —
    expressed over the resident extended blocks, plus the two segment
    sentinels the scanned path needs:

    * ``crossed``: any owned particle's cell id (identical f32 op sequence
      as ``cellgrid.bin_particles``) differs from its resident row's cell —
      the host epilogue's re-bin would have changed the layout, so the
      segment must abort and replay host-scheduled;
    * ``capacity``: the new cycle wants more sub-steps than the compiled
      scan's static ladder (deepening beyond headroom) — same abort.

    Bitwise notes: every reduction is either order-free (min/max/compare)
    or the pinned tree fold (u_floor via all_gather + a static global
    row-gather), and the scalar chain reproduces the host's f32 rounding
    (verified by the conformance rows). The limiter runs as a
    ``while_loop`` Jacobi iteration with a full-cut exchange and a psum'd
    convergence test per sweep — the same monotone fixpoint the host
    reaches. Not donated: only four fields come back, the rest of the
    resident buffers stay live.
    """
    perms = [list(rnd) for rnd in rounds]
    cell_size = box / ncells_side

    def xchg_full(tbl, fields):
        if mode == "ppermute":
            return [_permute_copy(f, tbl["e_pack"], tbl["e_unpack"],
                                  tbl["e_valid"], perms, axis, nrows)
                    for f in fields]
        return [_allgather_copy(f, tbl["e_pack"], tbl["e_usrc"],
                                tbl["e_urows"], tbl["e_valid"], axis,
                                nrows) for f in fields]

    def body(state, tables, consts):
        blk = {k: v[0] for k, v in state.items()}
        tbl = {k: v[0] for k, v in tables.items()}
        gidx = consts["gather_idx"]
        pos, vel, mass = blk["pos"], blk["vel"], blk["mass"]
        u, h, mask = blk["u"], blk["h"], blk["mask"]
        maskb = mask > 0
        cap = int(mass.shape[1])
        ci, cj, pmask = tbl["ci"], tbl["cj"], tbl["pmask"]

        # ---- crossing sentinel (cellgrid.bin_particles' id math)
        posw = jnp.mod(pos, box)
        idx3 = jnp.floor(posw / cell_size).astype(jnp.int32)
        idx3 = jnp.clip(idx3, 0, ncells_side - 1)
        cellid = (idx3[..., 0] * ncells_side + idx3[..., 1]) * ncells_side \
            + idx3[..., 2]
        crossed = jax.lax.psum(
            jnp.sum((cellid[:K] != tbl["rowcell"][:K, None]) & maskb[:K]
                    ).astype(jnp.int32), axis)

        # ---- signal-velocity CFL field (timebins._signal_speeds)
        cs = sound_speed(jnp.ones_like(u), u, cfg.gamma)
        v = speed_norm(vel)
        speed = jnp.where(maskb, cs + v, 0.0)
        s_cell = jnp.max(speed, axis=1)
        s_nb = s_cell
        s_nb = s_nb.at[ci].max(jnp.where(pmask > 0, s_cell[cj], 0.0))
        s_nb = s_nb.at[cj].max(jnp.where(pmask > 0, s_cell[ci], 0.0))
        dts = cfg.cfl * h / jnp.maximum(s_nb[:, None], 1e-12)
        dts = jnp.where(maskb, dts, jnp.inf)
        dt_min_req = jax.lax.pmin(jnp.min(dts[:K]), axis)
        if dt_max_static is not None:
            dt_max_c0 = jnp.float32(dt_max_static)
        else:
            dt_max_c0 = jax.lax.pmax(
                jnp.max(jnp.where(maskb[:K], dts[:K], -jnp.inf)), axis)
        dt_max_c = jnp.minimum(jnp.float32(dt_max_c0),
                               jnp.float32(dt_min_req)
                               * jnp.float32(2.0 ** max_depth))

        # ---- bin assignment + neighbour limiter fixpoint
        bins0 = assign_bins(dts, dt_max_c, max_depth)
        bins0 = jnp.where(maskb, bins0, 0).astype(jnp.int32)
        deep0 = jnp.max(jnp.where(maskb, bins0, _NEG_INF_BIN), axis=1)
        # halo rows' locally-computed deep/bins are incomplete (their
        # stencil is only complete on their owner); exchange before and
        # inside every sweep so halos always mirror owners
        (deep0,) = xchg_full(tbl, [deep0])

        def lim_cond(sv):
            i, _, ch = sv
            return (i < 256) & (ch > 0)

        def lim_step(sv):
            i, deep, _ = sv
            nb = deep
            nb = nb.at[ci].max(jnp.where(pmask > 0, deep[cj],
                                         _NEG_INF_BIN))
            nb = nb.at[cj].max(jnp.where(pmask > 0, deep[ci],
                                         _NEG_INF_BIN))
            new = jnp.maximum(deep, nb - bin_delta)
            (newx,) = xchg_full(tbl, [new])
            ch = jax.lax.psum(jnp.sum((newx[:K] != deep[:K])
                                      ).astype(jnp.int32), axis)
            return (i + 1, newx, ch)

        _, deep, _ = jax.lax.while_loop(
            lim_cond, lim_step, (jnp.int32(0), deep0, jnp.int32(1)))
        nb = deep
        nb = nb.at[ci].max(jnp.where(pmask > 0, deep[cj], _NEG_INF_BIN))
        nb = nb.at[cj].max(jnp.where(pmask > 0, deep[ci], _NEG_INF_BIN))
        floor = jnp.clip(nb - bin_delta, 0, max_depth)
        bins1 = jnp.where(maskb, jnp.maximum(bins0, floor[:, None]), bins0)
        bins1 = jnp.where(maskb, bins1, 0).astype(jnp.int32)
        (bins,) = xchg_full(tbl, [bins1])

        occ = jnp.maximum(jax.lax.pmax(
            jnp.max(jnp.where(maskb[:K], bins[:K], _NEG_INF_BIN)), axis), 0)
        depth = jnp.minimum(occ + depth_headroom, max_depth
                            ).astype(jnp.int32)
        nsub = jnp.left_shift(jnp.int32(1), depth)
        over = (nsub > nsub_static).astype(jnp.int32)
        # owned-bin histogram, psum'd: the host-side cycle stats' bin_hist
        # without pulling the bins array
        levels = jnp.arange(max_depth + 1, dtype=jnp.int32)
        hist = jax.lax.psum(
            jnp.sum((bins[:K][..., None] == levels) & maskb[:K][..., None],
                    axis=(0, 1)).astype(jnp.int32), axis)

        # ---- u_floor: pinned tree fold over the global (ncells, cap)
        # reconstruction (all_gather + static row gather), bitwise equal
        # to the host prologue's mass_weighted_mean_u
        mm = (mass * mask)[:K]
        gm = jax.lax.all_gather(mm, axis).reshape(-1, cap)[gidx]
        gu = jax.lax.all_gather(u[:K], axis).reshape(-1, cap)[gidx]
        u_floor = mass_weighted_mean_u(gm, gu)

        # ---- opening half-kick with the new bins (timebins._cycle_start)
        st = TimeBinState(
            cells=ParticleCells(pos=pos, vel=vel, mass=mass, u=u, h=h,
                                mask=mask),
            accel=blk["accel"], dudt=blk["dudt"], rho=blk["rho"],
            omega=blk["omega"], bins=bins, t_start=blk["t_start"],
            time=blk["time"])
        st2 = _cycle_start(st, dt_max_c, cfg=cfg)

        upd = {"bins": bins[None], "vel": st2.cells.vel[None],
               "u": st2.cells.u[None], "t_start": st2.t_start[None]}
        scal = {"dt_max": dt_max_c[None], "depth": depth[None],
                "nsub": nsub[None], "u_floor": jnp.float32(u_floor)[None]}
        flags = {"crossed": crossed[None], "capacity": over[None],
                 "hist": hist[None]}
        return upd, scal, flags

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis), P()),
                   out_specs=(P(axis), P(axis), P(axis)), check_rep=False)
    return jax.jit(fn)


class CollectiveTransport(Transport):
    """shard_map/ppermute lowering of the halo exchange.

    Holds the rank mesh, the round schedule of the current decomposition,
    the bucket policy and the compiled-program cache. ``prepare(edges)`` is
    called whenever the decomposition (and hence the export edge list)
    changes; ``exchange`` runs one compiled collective step.
    """

    kind = "collective"

    def __init__(self, *, nranks: int, probe: Optional[CompileProbe] = None,
                 mode: str = "auto", axis: str = "ranks",
                 min_bucket: int = 8, shrink_patience: int = 4):
        if mode not in ("auto", "ppermute", "allgather"):
            raise ValueError(f"mode must be auto|ppermute|allgather, "
                             f"got {mode!r}")
        self.nranks = int(nranks)
        self.axis = axis
        self.mesh = ranks_mesh(self.nranks, axis=axis)
        self.mode_requested = mode
        self.buckets = BucketPolicy(min_bucket=min_bucket,
                                    shrink_patience=shrink_patience)
        self.programs = ProgramCache(probe)
        self.rounds: List[List[Tuple[int, int]]] = []
        self._perms_sig: Tuple = ()
        self._edges: Optional[Tuple[Tuple[int, int], ...]] = None
        self.exchanges = 0
        self.shipped_rows = 0
        self.host_bytes = 0

    # ------------------------------------------------------------- planning
    def prepare(self, edges: Sequence[Tuple[int, int]]) -> None:
        edges_t = tuple(sorted({(int(s), int(d)) for s, d in edges}))
        if edges_t == self._edges:
            return
        self._edges = edges_t
        self.rounds = ppermute_rounds(edges_t, self.nranks)
        self._perms_sig = tuple(tuple(rnd) for rnd in self.rounds)

    @property
    def mode(self) -> str:
        if self.mode_requested != "auto":
            return self.mode_requested
        # neighbour-to-neighbour rounds beat a gather while the edge
        # colouring stays within the ring bound; degenerate cuts (more
        # rounds than ranks) fall back to one all_gather
        return "ppermute" if len(self.rounds) < self.nranks else "allgather"

    # ------------------------------------------------------------- exchange
    def exchange(self, slots: ShipSlots, fields: List[List],
                 stream: str = "substep",
                 label: Optional[str] = None) -> List[List]:
        if self._edges is None:
            raise RuntimeError("CollectiveTransport.exchange before "
                               "prepare(edges)")
        tr = self.tracer
        t0 = tr.now() if tr.enabled else 0.0
        nranks = self.nranks
        nrows = int(np.shape(fields[0][0])[0])
        meta = tuple((tuple(np.shape(f[0])[1:]),
                      np.dtype(jnp.asarray(f[0]).dtype).name)
                     for f in fields)
        stacked = [jnp.stack([jnp.asarray(fr) for fr in f]) for f in fields]
        if self.mode == "ppermute":
            B = self.buckets.fit(("edge", stream), slots.max_edge_slots)
            pack, unpack, valid = pack_rounds(self.rounds, slots, nranks, B)
            key = ("ppermute", nranks, nrows, B, self._perms_sig, meta)
            prog = self.programs.get(key, lambda: build_permute_program(
                self.mesh, self.axis, self.rounds, nrows, B, len(fields)))
            outs = prog(jnp.asarray(pack), jnp.asarray(unpack),
                        jnp.asarray(valid), *stacked)
            bkt = B
        else:
            Bo = self.buckets.fit(("ag_out", stream),
                                  slots.max_rank_exports(nranks))
            Bi = self.buckets.fit(("ag_in", stream),
                                  slots.max_rank_imports(nranks))
            pack, usrc, urows, valid = pack_allgather(slots, nranks, Bo, Bi)
            key = ("allgather", nranks, nrows, Bo, Bi, meta)
            prog = self.programs.get(key, lambda: build_allgather_program(
                self.mesh, self.axis, nrows, Bo, Bi, len(fields)))
            outs = prog(jnp.asarray(pack), jnp.asarray(usrc),
                        jnp.asarray(urows), jnp.asarray(valid), *stacked)
            bkt = max(Bo, Bi)
        self.exchanges += 1
        self.shipped_rows += slots.total
        # normalise placement: slicing a mesh-sharded output yields arrays
        # committed to individual devices, which would make every
        # downstream phase program recompile per device. Round-tripping
        # through host memory (what the host transport does anyway) keeps
        # the phase programs' compile count identical across transports.
        # This round trip — device→host→device of every full field — is
        # exactly the residual overhead the fused device-resident path
        # (residency="device") removes; host_bytes measures it.
        outs_h = [np.asarray(out) for out in outs]
        self.host_bytes += 2 * sum(o.nbytes for o in outs_h)
        if tr.enabled:
            # outs_h materialisation above is the sync point: the whole
            # collective (pack + wire + scatter) has completed by now, so
            # the span covers the one program as a task on every rank's row
            tr.record_all(range(nranks), label or "exchange", t0,
                          stream=stream, mode=self.mode, bucket=bkt,
                          units=slots.total, kind="collective", collective=1)
        return [[jnp.asarray(o[r]) for r in range(nranks)] for o in outs_h]

    def stats(self) -> Dict[str, object]:
        return {"kind": self.kind, "mode": self.mode,
                "rounds": len(self.rounds), "exchanges": self.exchanges,
                "shipped_rows": self.shipped_rows,
                "host_bytes": self.host_bytes,
                "programs": self.programs.builds,
                "bucket_events": list(self.buckets.events)}
