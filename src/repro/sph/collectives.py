"""Device-collective transport: shard_map/ppermute halo exchange programs.

The wire lowering of the distributed time-bin engine's two per-sub-step
exchanges (``sph/dist_timebins.py``). Where :class:`~repro.distributed.
transport.HostTransport` copies rows through numpy, this module compiles the
same copies into one XLA program over a rank mesh:

* every rank packs the rows it owes its neighbours into a
  **power-of-two-bucketed export buffer** (mask-padded, so the program's
  shapes — and therefore its compilation — are independent of how many
  cut-cell rows are active at this sub-step);
* the buffers move either through ``lax.ppermute`` rounds — the
  neighbour-to-neighbour schedule derived from the comm planner's export
  edge list (``core.comm_planner.ppermute_rounds``) — or through one
  ``lax.all_gather`` (the fallback when the edge colouring needs more
  rounds than a gather is worth);
* each rank scatters the received slots into its halo replica rows;
  invalid (padding) slots are routed to a scratch row that is sliced off, so
  padded slots provably leave the state untouched.

Exchanges are pure row copies — the collective transport is bit-for-bit
identical to the host transport by construction, which the parity tests in
``tests/test_transport.py`` assert on 1 and 4 (emulated) devices.

Compiled programs are cached by their static signature (bucket, rounds,
field shapes) in a :class:`~repro.distributed.transport.ProgramCache`, and
every build is registered with the engine's :class:`~repro.distributed.
transport.CompileProbe` — the bucket hysteresis guarantees the cache stays
small across sub-steps and cycles.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.comm_planner import ppermute_rounds
from ..distributed.mesh_utils import ranks_mesh
from ..distributed.transport import (BucketPolicy, CompileProbe, ProgramCache,
                                     ShipSlots, Transport, pack_allgather,
                                     pack_rounds)


def build_permute_program(mesh, axis: str,
                          rounds: Sequence[Sequence[Tuple[int, int]]],
                          nrows: int, bucket: int, nfields: int):
    """Compile one ppermute-rounds exchange over ``nfields`` stacked fields.

    Inputs (global shapes): ``pack``/``unpack`` (nranks, R, bucket) int32,
    ``valid`` (nranks, R, bucket) float, then each field
    (nranks, nrows, …). Returns the fields with every valid received slot
    written into its destination row; everything else bit-identical.
    """
    perms = [list(rnd) for rnd in rounds]

    def body(pack, unpack, valid, *fields):
        outs = []
        for f in fields:
            loc = f[0]                                   # (nrows, …)
            scratch = jnp.zeros((1,) + loc.shape[1:], loc.dtype)
            loc = jnp.concatenate([loc, scratch], axis=0)
            for t in range(len(perms)):
                buf = loc[pack[0, t]]                    # (bucket, …)
                got = jax.lax.ppermute(buf, axis, perms[t])
                keep = valid[0, t] > 0
                # padding slots land on the scratch row (sliced off below)
                safe = jnp.where(keep, unpack[0, t], nrows)
                loc = loc.at[safe].set(got)
            outs.append(loc[:nrows][None])
        return tuple(outs)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis),) * (3 + nfields),
                   out_specs=(P(axis),) * nfields)
    return jax.jit(fn)


def build_allgather_program(mesh, axis: str, nrows: int, bucket_out: int,
                            bucket_in: int, nfields: int):
    """Compile the all-gather fallback exchange.

    Inputs: ``pack`` (nranks, bucket_out) int32, ``unpack_src``/
    ``unpack_rows`` (nranks, bucket_in) int32, ``valid`` (nranks,
    bucket_in) float, then the stacked fields.
    """

    def body(pack, unpack_src, unpack_rows, valid, *fields):
        outs = []
        for f in fields:
            loc = f[0]
            scratch = jnp.zeros((1,) + loc.shape[1:], loc.dtype)
            loc = jnp.concatenate([loc, scratch], axis=0)
            buf = loc[pack[0]]                           # (bucket_out, …)
            g = jax.lax.all_gather(buf, axis)            # (nranks, Bo, …)
            flat = g.reshape((-1,) + g.shape[2:])
            got = flat[unpack_src[0]]                    # (bucket_in, …)
            keep = valid[0] > 0
            safe = jnp.where(keep, unpack_rows[0], nrows)
            loc = loc.at[safe].set(got)
            outs.append(loc[:nrows][None])
        return tuple(outs)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis),) * (4 + nfields),
                   out_specs=(P(axis),) * nfields)
    return jax.jit(fn)


class CollectiveTransport(Transport):
    """shard_map/ppermute lowering of the halo exchange.

    Holds the rank mesh, the round schedule of the current decomposition,
    the bucket policy and the compiled-program cache. ``prepare(edges)`` is
    called whenever the decomposition (and hence the export edge list)
    changes; ``exchange`` runs one compiled collective step.
    """

    kind = "collective"

    def __init__(self, *, nranks: int, probe: Optional[CompileProbe] = None,
                 mode: str = "auto", axis: str = "ranks",
                 min_bucket: int = 8, shrink_patience: int = 4):
        if mode not in ("auto", "ppermute", "allgather"):
            raise ValueError(f"mode must be auto|ppermute|allgather, "
                             f"got {mode!r}")
        self.nranks = int(nranks)
        self.axis = axis
        self.mesh = ranks_mesh(self.nranks, axis=axis)
        self.mode_requested = mode
        self.buckets = BucketPolicy(min_bucket=min_bucket,
                                    shrink_patience=shrink_patience)
        self.programs = ProgramCache(probe)
        self.rounds: List[List[Tuple[int, int]]] = []
        self._perms_sig: Tuple = ()
        self._edges: Optional[Tuple[Tuple[int, int], ...]] = None
        self.exchanges = 0
        self.shipped_rows = 0

    # ------------------------------------------------------------- planning
    def prepare(self, edges: Sequence[Tuple[int, int]]) -> None:
        edges_t = tuple(sorted({(int(s), int(d)) for s, d in edges}))
        if edges_t == self._edges:
            return
        self._edges = edges_t
        self.rounds = ppermute_rounds(edges_t, self.nranks)
        self._perms_sig = tuple(tuple(rnd) for rnd in self.rounds)

    @property
    def mode(self) -> str:
        if self.mode_requested != "auto":
            return self.mode_requested
        # neighbour-to-neighbour rounds beat a gather while the edge
        # colouring stays within the ring bound; degenerate cuts (more
        # rounds than ranks) fall back to one all_gather
        return "ppermute" if len(self.rounds) < self.nranks else "allgather"

    # ------------------------------------------------------------- exchange
    def exchange(self, slots: ShipSlots, fields: List[List],
                 stream: str = "substep") -> List[List]:
        if self._edges is None:
            raise RuntimeError("CollectiveTransport.exchange before "
                               "prepare(edges)")
        nranks = self.nranks
        nrows = int(np.shape(fields[0][0])[0])
        meta = tuple((tuple(np.shape(f[0])[1:]),
                      np.dtype(jnp.asarray(f[0]).dtype).name)
                     for f in fields)
        stacked = [jnp.stack([jnp.asarray(fr) for fr in f]) for f in fields]
        if self.mode == "ppermute":
            B = self.buckets.fit(("edge", stream), slots.max_edge_slots)
            pack, unpack, valid = pack_rounds(self.rounds, slots, nranks, B)
            key = ("ppermute", nranks, nrows, B, self._perms_sig, meta)
            prog = self.programs.get(key, lambda: build_permute_program(
                self.mesh, self.axis, self.rounds, nrows, B, len(fields)))
            outs = prog(jnp.asarray(pack), jnp.asarray(unpack),
                        jnp.asarray(valid), *stacked)
        else:
            Bo = self.buckets.fit(("ag_out", stream),
                                  slots.max_rank_exports(nranks))
            Bi = self.buckets.fit(("ag_in", stream),
                                  slots.max_rank_imports(nranks))
            pack, usrc, urows, valid = pack_allgather(slots, nranks, Bo, Bi)
            key = ("allgather", nranks, nrows, Bo, Bi, meta)
            prog = self.programs.get(key, lambda: build_allgather_program(
                self.mesh, self.axis, nrows, Bo, Bi, len(fields)))
            outs = prog(jnp.asarray(pack), jnp.asarray(usrc),
                        jnp.asarray(urows), jnp.asarray(valid), *stacked)
        self.exchanges += 1
        self.shipped_rows += slots.total
        # normalise placement: slicing a mesh-sharded output yields arrays
        # committed to individual devices, which would make every
        # downstream phase program recompile per device. Round-tripping
        # through host memory (what the host transport does anyway) keeps
        # the phase programs' compile count identical across transports.
        outs_h = [np.asarray(out) for out in outs]
        return [[jnp.asarray(o[r]) for r in range(nranks)] for o in outs_h]

    def stats(self) -> Dict[str, object]:
        return {"kind": self.kind, "mode": self.mode,
                "rounds": len(self.rounds), "exchanges": self.exchanges,
                "shipped_rows": self.shipped_rows,
                "programs": self.programs.builds,
                "bucket_events": list(self.buckets.events)}
