"""Hierarchical time-bin integration: per-particle time-steps (1807.01341).

Real simulations have a dynamic range of ~10^4 in stable time-step size;
integrating every particle at the global minimum wastes almost the whole
machine. Following Borrow et al. (arXiv:1807.01341) and SWIFT's time
integration (arXiv:2305.13380), each particle is assigned to a power-of-two
**time bin**: bin b steps with dt = dt_max / 2**b, so bin 0 carries the
longest step and deeper bins subdivide it exactly. One *cycle* spans dt_max
and consists of 2**depth sub-steps of the finest dt, where
depth = max occupied bin.

At sub-step n the **active** bins are those whose step boundary divides n:
bins b ≥ depth − tz(n) (tz = trailing zeros; n = 0 starts every bin). Active
particles get the full density → ghost → force → kick treatment; inactive
particles are *drifted* — position-only prediction at their last kicked
velocity — and contribute to their active neighbours' sums through the
drifted positions and their stored density/pressure. Kicks are synchronised
at bin boundaries: the KDK ladder of 1807.01341 Fig. 1, which reduces to the
global-dt engine's leapfrog when depth = 0.

The task-graph side lives in ``engine.build_taskgraph(cell_bins=…,
level=…)`` + ``core.scheduler`` (activation masks, active-only wave
schedules) and ``core.cost_model.timebin_units`` / ``core.decompose.
timebin_node_weights`` (cycle-averaged work for the partitioner).

Sub-step programs are jitted with level-restricted pair lists padded to
power-of-two lengths, so the number of distinct compiled programs is
O(log npairs) per cycle, not O(2**depth).
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..observability import device_metrics as dmetrics
from ..observability.tracer import NULL_TRACER
from .cellgrid import GridSpec, PairList, ParticleCells, bin_particles, \
    build_pair_list, choose_grid, unbin
from .engine import SPHConfig, _density_pass, _force_pass
from .physics import cfl_timestep_block, ghost_update

MAX_DEPTH_DEFAULT = 10      # ≥3 decades of dt spread (2**10 = 1024)
_U_FLOOR = 1e-12
_DU_SAFETY = 0.25           # dt ≤ κ·u/|du/dt| — strong-shock heating limit


def particle_timesteps(cells: ParticleCells, dudt, *, gamma: float,
                       cfl: float, du_safety: float = _DU_SAFETY,
                       u_floor=0.0):
    """Per-particle dt: CFL ∧ internal-energy criterion.

    The CFL term alone is blind to a shock front heating cold gas — u can
    e-fold in far less than h/c of the *pre-shock* sound speed. The
    κ·(u + u_floor)/|du/dt| term (SWIFT carries a similar guard) keeps the
    thermal update resolved where it is dynamically significant. The
    ``u_floor`` (typically the mass-weighted mean u) anchors "significant"
    to the problem's thermal scale: without it, numerically-cold background
    gas (u ~ 0) would be pinned onto the deepest bins by noise-level
    heating and the multi-dt advantage would evaporate.
    """
    dt = cfl_timestep_block(cells.h, cells.u, cells.vel, cells.mask,
                            gamma=gamma, cfl=cfl)
    xp = jnp if isinstance(dt, jax.Array) else np
    dt_u = du_safety * (cells.u + u_floor) / xp.maximum(xp.abs(dudt), 1e-30)
    dt_u = xp.where(cells.mask > 0, dt_u, xp.inf)
    return xp.minimum(dt, dt_u)


# ------------------------------------------------------------------ bin math
# Quantisation thresholds for assign_bins: ratio > _BIN_THRESHOLDS[k-1] puts
# a particle at least in bin k. Precomputed in float64 and rounded once to
# float32 so the decision is a pure f32 comparison — numpy and XLA disagree
# in the last ulp of log2 (the original formulation), and a bin flipping
# between host- and device-computed plans breaks the bitwise-parity contract
# of the device-scheduled path. The 1e-6 slack keeps the historical
# behaviour that dt == dt_max/2**k lands exactly in bin k.
BIN_LADDER_MAX = 24
_BIN_THRESHOLDS = np.asarray(
    2.0 ** (np.arange(BIN_LADDER_MAX) + 1e-6), np.float32)


def assign_bins(dt, dt_max, max_bin):
    """Quantise per-particle time-steps onto the power-of-two ladder.

    Returns the smallest b with dt_max / 2**b ≤ dt (so the bin step never
    exceeds the CFL step), clipped to [0, max_bin]. Works on numpy and jax
    arrays (``dt_max``/``max_bin`` may be traced scalars); +inf entries
    (padded slots) land in bin 0. Implemented as a comparison ladder
    against f32 thresholds so numpy and XLA agree bit-for-bit; bins beyond
    ``BIN_LADDER_MAX`` are unreachable (max_depth is validated against it).
    """
    xp = jnp if isinstance(dt, jax.Array) else np
    ratio = dt_max / xp.maximum(dt, 1e-30)
    thr = _BIN_THRESHOLDS if xp is np else jnp.asarray(_BIN_THRESHOLDS)
    b = (ratio[..., None] > thr).sum(axis=-1).astype(xp.int32)
    return xp.minimum(b, max_bin).astype(xp.int32)


def bin_timestep(dt_max: float, bins):
    """dt of each bin: dt_max / 2**b (exact in float — power-of-two scale)."""
    xp = jnp if isinstance(bins, jax.Array) else np
    return dt_max * xp.exp2(-bins.astype(xp.float32))


def active_level(n: int, depth: int) -> int:
    """Lowest active bin at sub-step ``n`` of a 2**depth cycle.

    Bins b ≥ active_level(n, depth) start/end a step at sub-step n. n = 0
    (cycle start) activates every bin.
    """
    if n == 0:
        return 0
    tz = (n & -n).bit_length() - 1
    return max(depth - tz, 0)


def trailing_zeros_table(nsub: int) -> np.ndarray:
    """tz(n) for n = 0..nsub as an int32 table (tz(0) := 0).

    The device-scheduled cycle program derives the active level of a traced
    sub-step index n as max(depth − tz_table[n], 0) — the same integer math
    as :func:`active_level`, with the bit-twiddling hoisted into a static
    lookup table.
    """
    return np.asarray(
        [0] + [(n & -n).bit_length() - 1 for n in range(1, nsub + 1)],
        np.int32)


# ---------------------------------------------------- reproducible reductions
def tree_sum(x):
    """Sum by fixed binary fold (pad to a power of two, halve repeatedly).

    ``xp.sum`` accumulation order is backend-defined — numpy uses pairwise
    blocks, XLA whatever the reduce lowering picks — so the same f32 data
    can sum to different last ulps on host and device. Every quantity that
    must agree bitwise between a host-computed and a device-computed cycle
    plan (u_floor) goes through this fold instead, on both sides.
    """
    xp = jnp if isinstance(x, jax.Array) else np
    x = xp.ravel(x)
    n = x.shape[0]
    p = 1
    while p < max(n, 1):
        p *= 2
    if p != n:
        x = xp.concatenate([x, xp.zeros((p - n,), x.dtype)])
    while x.shape[0] > 1:
        h = x.shape[0] // 2
        x = x[:h] + x[h:]
    return x[0]


def mass_weighted_mean_u(mass_masked, u):
    """u_floor of :func:`particle_timesteps`: Σ m·u / Σ m via tree_sum.

    Shared by the host planners and the device plan program so the floor —
    and therefore every deepening decision downstream of it — is bitwise
    identical regardless of where the plan was computed.
    """
    xp = jnp if isinstance(u, jax.Array) else np
    num = tree_sum(mass_masked * u)
    den = xp.maximum(tree_sum(mass_masked), 1e-30)
    return num / den


def speed_norm(vel):
    """|v| with a pinned evaluation order: sqrt((v0² + v1²) + v2²) in f32.

    np.linalg.norm's reduction strategy is not contractually ordered;
    spelling the three-term sum out keeps host- and device-computed signal
    speeds bit-identical.
    """
    xp = jnp if isinstance(vel, jax.Array) else np
    v0, v1, v2 = vel[..., 0], vel[..., 1], vel[..., 2]
    return xp.sqrt((v0 * v0 + v1 * v1) + v2 * v2)


def cell_max_bins(bins: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Deepest occupied bin per cell, −1 for empty cells: (ncells,)."""
    b = np.where(np.asarray(mask) > 0, np.asarray(bins), -1)
    return b.max(axis=1).astype(np.int64)


def cell_bin_histogram(bins: np.ndarray, mask: np.ndarray,
                       nbins: int) -> np.ndarray:
    """(ncells, nbins) occupancy histogram over time bins."""
    bins = np.asarray(bins)
    mask = np.asarray(mask) > 0
    ncells = bins.shape[0]
    out = np.zeros((ncells, nbins), dtype=np.int64)
    for c in range(ncells):
        bc = bins[c][mask[c]]
        if len(bc):
            out[c] = np.bincount(np.clip(bc, 0, nbins - 1), minlength=nbins)
    return out


def limit_neighbour_bins(bins: np.ndarray, mask: np.ndarray,
                         ci: np.ndarray, cj: np.ndarray, *,
                         delta: int = 2, max_bin: int,
                         max_iter: int = 256) -> np.ndarray:
    """Neighbour time-step limiter (Saitoh–Makino, at cell granularity).

    A particle on a long step sitting next to one on a very short step is
    the classic block-time-step failure mode: a shock arrives and dumps an
    enormous acceleration into a particle that then coasts on it for its
    whole long step. SWIFT limits neighbouring time bins to differ by at
    most ``delta``; here the constraint is applied per cell pair — every
    particle's bin is floored at (deepest bin among its own and neighbouring
    cells) − delta — and iterated to the fixpoint so the constraint
    propagates outwards from deep-bin regions.
    """
    mask = np.asarray(mask) > 0
    bins = np.asarray(bins)
    deep = np.where(mask, bins, -10 ** 6).max(axis=1)
    ci = np.asarray(ci)
    cj = np.asarray(cj)
    for _ in range(max_iter):
        nb = deep.copy()
        np.maximum.at(nb, ci, deep[cj])
        np.maximum.at(nb, cj, deep[ci])
        new_deep = np.maximum(deep, nb - delta)
        if (new_deep == deep).all():
            break
        deep = new_deep
    nb = deep.copy()
    np.maximum.at(nb, ci, deep[cj])
    np.maximum.at(nb, cj, deep[ci])
    floor = np.clip(nb - delta, 0, max_bin)
    out = np.maximum(bins, floor[:, None])
    return np.where(mask, out, bins).astype(np.int32)


# -------------------------------------------------------------------- state
# the state layout, as field-name tuples: the single source of truth for
# every code path that scatters/gathers/stacks TimeBinState field-by-field
# (dist_timebins' resident buffers, collectives' fused-program outputs)
STATE_CELL_FIELDS = ("pos", "vel", "mass", "u", "h", "mask")
STATE_AUX_FIELDS = ("accel", "dudt", "rho", "omega", "bins", "t_start")


class TimeBinState(NamedTuple):
    """Multi-dt engine state: the global-dt state plus per-particle bins and
    the stored thermodynamics inactive particles expose to their active
    neighbours (rho, omega at their last active update). ``t_start`` is
    each particle's current step-start time: closing kicks are computed as
    (t − t_start) − dt_bin/2, which stays consistent even when a particle
    is *woken* mid-step by the neighbour limiter and restarts off the
    global bin alignment."""
    cells: ParticleCells
    accel: jax.Array       # (ncells, C, 3)
    dudt: jax.Array        # (ncells, C)
    rho: jax.Array         # (ncells, C)
    omega: jax.Array       # (ncells, C)
    bins: jax.Array        # (ncells, C) int32
    t_start: jax.Array     # (ncells, C)
    time: jax.Array        # scalar


# ------------------------------------------------------------- jitted steps
def _active_accelerations(cells: ParticleCells, pairs: PairList, pair_mask,
                          active, rho_prev, omega_prev, cfg: SPHConfig):
    """density → ghost → force over a level-restricted pair list.

    The pair list covers every pair touching an active cell, so *active*
    particles receive complete sums; inactive particles in those cells get
    partial sums which are discarded in favour of their stored rho/omega
    (their pressure and sound speed are re-derived from stored rho and
    current u — the position-only prediction of 1807.01341).
    """
    mask = cells.mask
    rho_new, drho_dh, nngb = _density_pass(cells, pairs, cfg,
                                           pair_mask=pair_mask)
    rho_new = jnp.where(mask > 0, rho_new, 1.0)
    drho_dh = jnp.where(mask > 0, drho_dh, 0.0)
    rho = jnp.where(active > 0, rho_new, rho_prev)
    press, omega_new, cs = ghost_update(rho, drho_dh, cells.u, cells.h,
                                        gamma=cfg.gamma)
    omega = jnp.where(active > 0, omega_new, omega_prev)
    press = jnp.where(mask > 0, press, 0.0)
    dv, du = _force_pass(cells, pairs, rho, press, omega, cs, cfg,
                         pair_mask=pair_mask)
    mask3 = mask[..., None]
    return dv * mask3, du * mask, rho, omega


def timebin_init(cells: ParticleCells, pairs: PairList,
                 cfg: SPHConfig) -> TimeBinState:
    """Full (every-particle) force evaluation → synchronised initial state."""
    ones = cells.mask
    dv, du, rho, omega = _active_accelerations(
        cells, pairs, None, ones, jnp.ones_like(cells.u),
        jnp.ones_like(cells.u), cfg)
    return TimeBinState(cells=cells, accel=dv, dudt=du, rho=rho, omega=omega,
                        bins=jnp.zeros(cells.mass.shape, jnp.int32),
                        t_start=jnp.zeros(cells.mass.shape, cells.pos.dtype),
                        time=jnp.zeros((), cells.pos.dtype))


def _kick(cells: ParticleCells, accel, dudt, active, half_dt
          ) -> ParticleCells:
    """Half-kick of the active particles (their own bin's dt)."""
    active3 = active[..., None]
    v = cells.vel + half_dt[..., None] * accel * active3
    u = jnp.where(active > 0,
                  jnp.maximum(cells.u + half_dt * dudt, _U_FLOOR), cells.u)
    return cells._replace(vel=v, u=u)


def _cycle_start(state: TimeBinState, dt_max, *, cfg: SPHConfig
                 ) -> TimeBinState:
    """Opening half-kick: every bin starts its first step at n = 0."""
    active = state.cells.mask
    half_dt = 0.5 * bin_timestep(dt_max, state.bins)
    cells = _kick(state.cells, state.accel, state.dudt, active, half_dt)
    t_start = jnp.full_like(state.t_start, state.time)
    return state._replace(cells=cells, t_start=t_start)


def _drift(state: TimeBinState, dt_min, *, box: float) -> TimeBinState:
    """Drift *all* particles: position-only prediction for inactive ones."""
    cells = state.cells
    pos = jnp.mod(cells.pos + dt_min * cells.vel * cells.mask[..., None], box)
    return state._replace(cells=cells._replace(pos=pos),
                          time=state.time + dt_min)


def _substep_density_phase(state: TimeBinState, pairs: PairList, pair_mask,
                           active, *, cfg: SPHConfig):
    """Density half of a bin-boundary update (the paper's first comm phase).

    Computes fresh rho/omega for the ``active`` particles (stored values are
    kept elsewhere) and derives press/cs for *every* particle — inactive
    neighbours expose their stored rho through the equation of state. The
    distributed engine inserts the rho/press halo exchange between this
    phase and :func:`_substep_force_phase`; the single-host engine composes
    them back-to-back inside one jitted program.
    """
    cells = state.cells
    mask = cells.mask
    rho_new, drho_dh, nngb = _density_pass(cells, pairs, cfg,
                                           pair_mask=pair_mask)
    rho_new = jnp.where(mask > 0, rho_new, 1.0)
    drho_dh = jnp.where(mask > 0, drho_dh, 0.0)
    rho = jnp.where(active > 0, rho_new, state.rho)
    press, omega_new, cs = ghost_update(rho, drho_dh, cells.u, cells.h,
                                        gamma=cfg.gamma)
    omega = jnp.where(active > 0, omega_new, state.omega)
    press = jnp.where(mask > 0, press, 0.0)
    return rho, omega, press, cs


def _apply_force_kick(state: TimeBinState, active, dv, du, rho, omega,
                      wake_floor, dt_max, depth, u_floor, *, cfg: SPHConfig
                      ) -> Tuple[TimeBinState, jax.Array]:
    """Close/deepen/re-open the active bins given raw force-pass sums.

    The elementwise tail of a bin-boundary update, split from the pair pass
    so the distributed fused programs can compute the pair sums with the
    halo exchange interleaved (``sph/collectives.py``) and still share this
    exact update; :func:`_substep_force_phase` composes the two unchanged.
    """
    cells = state.cells
    mask = cells.mask
    mask3 = mask[..., None]
    dv, du = dv * mask3, du * mask
    accel = jnp.where(active[..., None] > 0, dv, state.accel)
    dudt = jnp.where(active > 0, du, state.dudt)
    # close the ending step: v is at t_start + dt_bin/2, bring it to `t`
    elapsed = state.time - state.t_start
    close = elapsed - 0.5 * bin_timestep(dt_max, state.bins)
    cells = _kick(cells, accel, dudt, active, close)
    # deepen where the new CFL/heating step (or the wake floor) demands it
    dt_need = particle_timesteps(cells, dudt, gamma=cfg.gamma, cfl=cfg.cfl,
                                 u_floor=u_floor)
    b_need = jnp.maximum(assign_bins(dt_need, dt_max, depth),
                         jnp.clip(wake_floor, 0, depth)[:, None])
    bins = jnp.where(active > 0, jnp.maximum(state.bins, b_need), state.bins)
    # open the next step
    half_new = 0.5 * bin_timestep(dt_max, bins)
    cells = _kick(cells, accel, dudt, active, half_new)
    t_start = jnp.where(active > 0, state.time, state.t_start)
    nact = jnp.sum(active).astype(jnp.int32)
    return state._replace(cells=cells, accel=accel, dudt=dudt, rho=rho,
                          omega=omega, bins=bins, t_start=t_start), nact


def _substep_force_phase(state: TimeBinState, pairs: PairList, pair_mask,
                         active, rho, omega, press, cs, wake_floor, dt_max,
                         depth, u_floor, *, cfg: SPHConfig
                         ) -> Tuple[TimeBinState, jax.Array]:
    """Force + kick half of a bin-boundary update (second comm phase)."""
    dv, du = _force_pass(state.cells, pairs, rho, press, omega, cs, cfg,
                         pair_mask=pair_mask)
    return _apply_force_kick(state, active, dv, du, rho, omega, wake_floor,
                             dt_max, depth, u_floor, cfg=cfg)


def substep_active_mask(state: TimeBinState, level, wake_floor) -> jax.Array:
    """Particles ending a step now: regular bin boundary (bins ≥ level) or
    woken by the neighbour limiter (their cell's wake_floor — deepest
    neighbourhood bin − delta — now exceeds their bin: a shock has arrived
    and coasting to the end of their long step would be unstable)."""
    at_boundary = state.bins >= level
    woken = state.bins < wake_floor[:, None]
    return ((at_boundary | woken)
            & (state.cells.mask > 0)).astype(state.cells.pos.dtype)


def _force_substep(state: TimeBinState, pairs: PairList, pair_mask, level,
                   wake_floor, dt_max, depth, u_floor, *, cfg: SPHConfig
                   ) -> Tuple[TimeBinState, jax.Array]:
    """Bin-boundary update at an interior sub-step.

    Two particle sets end a step here: bins ≥ level (their regular
    boundary) and particles *woken* by the neighbour limiter (see
    :func:`substep_active_mask`). Both are closed with a kick of
    (t − t_start) − dt_bin/2, which equals the regular half-kick for
    aligned particles and un-kicks the woken ones back to the current
    time. The closing particles may then *deepen* (their own new CFL /
    heating step, or the wake floor), and immediately open the next step
    with a first half-kick. Shallower bins wait for the cycle end.

    Composition of the density and force phases; the distributed time-bin
    engine runs the same two phases with an activity-aware halo exchange
    in between (``sph/dist_timebins.py``).
    """
    active = substep_active_mask(state, level, wake_floor)
    rho, omega, press, cs = _substep_density_phase(
        state, pairs, pair_mask, active, cfg=cfg)
    return _substep_force_phase(state, pairs, pair_mask, active, rho, omega,
                                press, cs, wake_floor, dt_max, depth,
                                u_floor, cfg=cfg)


def _apply_final_kick(state: TimeBinState, dv, du, rho, omega, dt_max,
                      *, cfg: SPHConfig) -> TimeBinState:
    """Closing kick of the cycle-ending boundary, given raw force sums."""
    cells = state.cells
    active = cells.mask
    mask3 = cells.mask[..., None]
    dv, du = dv * mask3, du * cells.mask
    elapsed = state.time - state.t_start
    close = elapsed - 0.5 * bin_timestep(dt_max, state.bins)
    cells = _kick(cells, dv, du, active, close)
    return state._replace(cells=cells, accel=dv, dudt=du, rho=rho,
                          omega=omega,
                          t_start=jnp.full_like(state.t_start, state.time))


def _final_force_phase(state: TimeBinState, pairs: PairList, pair_mask,
                       rho, omega, press, cs, dt_max, *, cfg: SPHConfig
                       ) -> TimeBinState:
    """Force + closing kick of the cycle-ending boundary."""
    dv, du = _force_pass(state.cells, pairs, rho, press, omega, cs, cfg,
                         pair_mask=pair_mask)
    return _apply_final_kick(state, dv, du, rho, omega, dt_max, cfg=cfg)


def _force_final(state: TimeBinState, pairs: PairList, pair_mask, dt_max,
                 *, cfg: SPHConfig) -> TimeBinState:
    """Cycle-closing boundary: every bin ends; no step is opened."""
    active = state.cells.mask
    rho, omega, press, cs = _substep_density_phase(
        state, pairs, pair_mask, active, cfg=cfg)
    return _final_force_phase(state, pairs, pair_mask, rho, omega, press,
                              cs, dt_max, cfg=cfg)


@functools.lru_cache(maxsize=None)
def shared_timebin_programs(box: float, cfg: SPHConfig) -> Dict[str, object]:
    """The five jitted ladder programs per (box, physics config), shared by
    every :class:`TimeBinSimulation` instance (same rationale as
    ``engine.shared_step_program``: a fleet of same-signature requests must
    compile the ladder once, not once per request)."""
    return {
        "init": jax.jit(functools.partial(timebin_init, cfg=cfg)),
        "start": jax.jit(functools.partial(_cycle_start, cfg=cfg)),
        "drift": jax.jit(functools.partial(_drift, box=box)),
        "sub": jax.jit(functools.partial(_force_substep, cfg=cfg)),
        "final": jax.jit(functools.partial(_force_final, cfg=cfg)),
    }


# ------------------------------------------------------------------- driver
class TimeBinSimulation:
    """Host driver of the sub-step hierarchy (multi-dt ``Simulation``).

    Per cycle: quantise per-particle CFL steps into bins, pick
    depth = deepest occupied bin (bounded by ``max_depth``), run the KDK
    ladder over 2**depth sub-steps activating only due bins, then
    re-synchronise, re-bin particles into cells and re-assign bins. The
    level-restricted pair lists (all pairs touching an active cell) are
    padded to power-of-two lengths so jit programs are reused across
    sub-steps and cycles.
    """

    def __init__(self, pos, vel, mass, u, h, *, box: float,
                 cfg: SPHConfig = SPHConfig(),
                 dt_max: Optional[float] = None,
                 max_depth: int = MAX_DEPTH_DEFAULT,
                 bin_delta: int = 2,
                 depth_headroom: int = 2,
                 capacity_margin: float = 3.0,
                 rebin_each_cycle: bool = True):
        if type(self) is TimeBinSimulation:
            import warnings
            warnings.warn(
                "constructing repro.sph.TimeBinSimulation directly is "
                "deprecated; use repro.sph.build_simulation("
                "SimulationSpec(...)) (integrator='timebin', "
                "backend='local')", DeprecationWarning, stacklevel=2)
        self.box = float(box)
        self.cfg = cfg
        self.n = len(pos)
        self.dt_max = dt_max
        if int(max_depth) > BIN_LADDER_MAX:
            raise ValueError(
                f"max_depth {max_depth} exceeds the assign_bins comparison "
                f"ladder ({BIN_LADDER_MAX} levels)")
        self.max_depth = int(max_depth)
        self.bin_delta = int(bin_delta)
        self.depth_headroom = int(depth_headroom)
        self.rebin_each_cycle = rebin_each_cycle
        h_max = float(np.max(h))
        self.spec = choose_grid(self.box, h_max, self.n,
                                capacity_margin=capacity_margin)
        self._rebin(np.asarray(pos), np.asarray(vel), np.asarray(mass),
                    np.asarray(u), np.asarray(h))
        progs = shared_timebin_programs(self.box, cfg)
        self._jit_init = progs["init"]
        self._jit_start = progs["start"]
        self._jit_drift = progs["drift"]
        self._jit_sub = progs["sub"]
        self._jit_final = progs["final"]
        # Cycle planning uses the signal-velocity CFL (see _signal_speeds);
        # the κ·u/|du/dt| heating guard applies only in mid-cycle deepening
        # (where it catches a shock front arriving at cold gas) — applying
        # it at planning time pins numerically-noisy cold background onto
        # deep bins and erases the multi-dt advantage.
        self.state = self._jit_init(self.cells, self.pairs)
        # counters for the speed-up accounting
        self.particle_updates = 0       # force evaluations actually received
        self.global_equiv_updates = 0   # what global-dt would have performed
        self.substeps = 0
        self.tracer = NULL_TRACER       # rebound when observe=True
        self.cycle_index = 0
        # device-metrics carry (single rank): rows built from the host
        # scalars the ladder already pulls (nact, nlive) — no extra sync
        self.device_metrics_enabled = False
        self.device_metrics_last: Optional[Tuple[np.ndarray,
                                                 np.ndarray]] = None
        self.device_metrics_pulls = 0
        # per-cell work attribution of the last cycle (device-metrics v2
        # contract shared with the distributed engines) or None
        self.device_cell_work_last: Optional[Dict] = None

    # ------------------------------------------------------------- plumbing
    def _rebin(self, pos, vel, mass, u, h):
        self.cells, self.perm = bin_particles(self.spec, pos, vel, mass, u, h)
        if self.cells.mass.shape[1] != self.spec.capacity:
            object.__setattr__(self.spec, "capacity",
                               self.cells.mass.shape[1])
        self.pairs = build_pair_list(self.spec)
        self._ci = np.asarray(self.pairs.ci)
        self._cj = np.asarray(self.pairs.cj)
        self._shift = np.asarray(self.pairs.shift)

    def _flatten_aux(self, arr, fill) -> np.ndarray:
        valid = self.perm >= 0
        idx = self.perm[valid]
        a = np.asarray(arr)
        out = np.full((self.n,) + a.shape[2:], fill, dtype=a.dtype)
        out[idx] = a[valid]
        return out

    def _rebin_state(self):
        """Re-bin particles into cells, carrying the full multi-dt state
        (no extra force pass: accel/rho/omega/bins ride along)."""
        st = self.state
        flat = unbin(st.cells, self.perm, self.n)
        aux = {
            "accel": self._flatten_aux(st.accel, 0.0),
            "dudt": self._flatten_aux(st.dudt, 0.0),
            "rho": self._flatten_aux(st.rho, 1.0),
            "omega": self._flatten_aux(st.omega, 1.0),
            "bins": self._flatten_aux(st.bins, 0),
            "t_start": self._flatten_aux(st.t_start, 0.0),
        }
        self._rebin(flat["pos"], flat["vel"], flat["mass"], flat["u"],
                    flat["h"])
        valid = self.perm >= 0
        idx = self.perm[valid]

        def take(a, fill):
            out = np.full(self.perm.shape + a.shape[1:], fill, dtype=a.dtype)
            out[valid] = a[idx]
            return out

        self.state = TimeBinState(
            cells=self.cells,
            accel=jnp.asarray(take(aux["accel"], 0.0)),
            dudt=jnp.asarray(take(aux["dudt"], 0.0)),
            rho=jnp.asarray(take(aux["rho"], 1.0)),
            omega=jnp.asarray(take(aux["omega"], 1.0)),
            bins=jnp.asarray(take(aux["bins"], 0)),
            t_start=jnp.asarray(take(aux["t_start"], 0.0)),
            time=st.time)

    def _pair_subset(self, active_cells: np.ndarray
                     ) -> Tuple[PairList, jax.Array, int]:
        """Pairs touching an active cell, padded to a power-of-two length."""
        sel = active_cells[self._ci] | active_cells[self._cj]
        idx = np.nonzero(sel)[0]
        nlive = len(idx)
        npad = 1
        while npad < max(nlive, 1):
            npad *= 2
        pad = np.zeros(npad - nlive, dtype=idx.dtype)
        idxp = np.concatenate([idx, pad])
        pmask = np.zeros(npad, np.float32)
        pmask[:nlive] = 1.0
        sub = PairList(ci=jnp.asarray(self._ci[idxp]),
                       cj=jnp.asarray(self._cj[idxp]),
                       shift=jnp.asarray(self._shift[idxp]))
        return sub, jnp.asarray(pmask), nlive

    def _wake_floor(self, bins_h: np.ndarray, mask_host: np.ndarray
                    ) -> np.ndarray:
        """Per-cell wake threshold: deepest bin in the 27-stencil − delta."""
        deep = np.where(mask_host > 0, bins_h, -10 ** 6).max(axis=1)
        nb = deep.copy()
        np.maximum.at(nb, self._ci, deep[self._cj])
        np.maximum.at(nb, self._cj, deep[self._ci])
        return np.maximum(nb - self.bin_delta, 0).astype(np.int32)

    # -------------------------------------------------------------- cycling
    def _signal_speeds(self, cells) -> np.ndarray:
        """Neighbourhood-max signal speed per cell (SWIFT's v_sig CFL).

        A cold particle at a hot interface has its force history driven by
        the *neighbour's* sound crossing, not its own — its dt must see
        max_j(c_j + |v_j|) over the interaction stencil, or the two sides
        of every interface pair integrate the shared force with mismatched
        quadratures and momentum leaks. Far from any contrast the stencil
        max equals the local value and long steps survive.
        """
        from .physics import sound_speed
        v = np.asarray(speed_norm(np.asarray(cells.vel)))
        cs = np.asarray(sound_speed(jnp.ones_like(cells.u), cells.u,
                                    self.cfg.gamma))
        speed = np.where(np.asarray(cells.mask) > 0, cs + v, 0.0)
        s_cell = speed.max(axis=1)
        s_nb = s_cell.copy()
        np.maximum.at(s_nb, self._ci, s_cell[self._cj])
        np.maximum.at(s_nb, self._cj, s_cell[self._ci])
        return s_nb

    def _plan_cycle(self) -> Tuple[float, int]:
        """Assign bins from the signal-velocity CFL field; returns
        (dt_max_cycle, depth)."""
        cells = self.state.cells
        s_nb = self._signal_speeds(cells)
        h = np.asarray(cells.h)
        dts = self.cfg.cfl * h / np.maximum(s_nb[:, None], 1e-12)
        mask = np.asarray(cells.mask) > 0
        dts = np.where(mask, dts, np.inf)
        live = dts[mask]
        dt_min_req = float(live.min())
        dt_max_c = self.dt_max if self.dt_max is not None else float(
            live.max())
        # never let the ladder exceed max_depth: shorten the cycle instead
        # of clamping fast particles onto too-long steps. The min is taken
        # in f32 so a device-computed plan (which has no f64 scalars) lands
        # on the same dt_max_c bit pattern.
        dt_max_c = float(min(np.float32(dt_max_c),
                             np.float32(dt_min_req)
                             * np.float32(2.0 ** self.max_depth)))
        bins = assign_bins(dts, dt_max_c, self.max_depth)
        bins = np.where(mask, bins, 0).astype(np.int32)
        bins = limit_neighbour_bins(bins, mask, self._ci, self._cj,
                                    delta=self.bin_delta,
                                    max_bin=self.max_depth)
        bins = np.where(mask, bins, 0).astype(np.int32)
        occupied = int(bins[mask].max()) if mask.any() else 0
        # headroom below the occupied bins: mid-cycle deepening (a shock
        # collapsing some particle's dt) has somewhere to go; empty finest
        # levels cost nothing thanks to lazy drift accumulation
        depth = min(occupied + self.depth_headroom, self.max_depth)
        self.state = self.state._replace(bins=jnp.asarray(bins))
        return dt_max_c, depth

    def run_cycle(self) -> Dict[str, float]:
        """One dt_max cycle of the KDK ladder; returns cycle stats."""
        tr = self.tracer
        if tr.enabled:
            tr.ctx["cycle"] = self.cycle_index
            tr.ctx.pop("substep", None)
        with tr.timed("cycle") as cyc:
            stats = self._run_cycle_body(tr)
        if tr.enabled:
            tr.ctx.pop("substep", None)
        self.cycle_index += 1
        stats["wall"] = cyc.elapsed
        return stats

    def _run_cycle_body(self, tr) -> Dict[str, float]:
        with tr.span("plan"):
            dt_max_c, depth = self._plan_cycle()
        nsub = 1 << depth
        dt_min = dt_max_c / nsub
        nreal = int(np.asarray(self.state.cells.mask).sum())
        bins_host = np.asarray(self.state.bins)
        mask_host = np.asarray(self.state.cells.mask)
        m_h = np.asarray(self.state.cells.mass * self.state.cells.mask)
        u_floor = float(mass_weighted_mean_u(
            m_h, np.asarray(self.state.cells.u)))
        hist = np.bincount(bins_host[mask_host > 0],
                           minlength=depth + 1)

        with tr.span("start", units=nreal):
            state = self._jit_start(self.state, jnp.float32(dt_max_c))
            if tr.enabled:
                tr.fence(state.cells.pos)
        updates = 0
        pair_tasks = 0
        force_substeps = 0
        drifted_to = 0          # sub-steps of drift applied so far
        # host caches — bins only change at force sub-steps (deepening)
        bins_h = np.asarray(state.bins)
        wake_floor = self._wake_floor(bins_h, mask_host)
        dm_on = self.device_metrics_enabled
        met_counts, met_values = dmetrics.zero_rows(1)
        mVI = dmetrics.VALUE_INDEX
        cellw = cellw_rank = None
        if dm_on:
            # per-cell attribution: single-rank flavour of the distributed
            # owned-endpoint rule — every pair charges its ci cell, drift
            # is the alive count per cell, exchange is zero (no halo)
            cellw, cellw_rank = dmetrics.zero_cell_work(self.spec.ncells, 1)
            cDI = dmetrics.CELL_INDEX
            alive_cell = (mask_host > 0).sum(axis=1).astype(np.float64)

            def attribute_cells(pair_idx):
                np.add.at(cellw[:, cDI["density"]], self._ci[pair_idx], 1.0)
                np.add.at(cellw[:, cDI["force"]], self._ci[pair_idx], 1.0)
                cellw[:, cDI["drift"]] += alive_cell
                cellw_rank[0, cDI["density"]] += len(pair_idx)
                cellw_rank[0, cDI["force"]] += len(pair_idx)
                cellw_rank[0, cDI["drift"]] += nreal
        for n in range(1, nsub):
            level = active_level(n, depth)
            active_p = ((bins_h >= level)
                        | (bins_h < wake_floor[:, None])) & (mask_host > 0)
            if not active_p.any():
                continue            # headroom level with nothing due
            if tr.enabled:
                tr.ctx["substep"] = n
            # lazily apply the accumulated drift up to time t0 + n·dt_min
            with tr.span("drift", units=nreal):
                state = self._jit_drift(
                    state, jnp.float32((n - drifted_to) * dt_min))
                if tr.enabled:
                    tr.fence(state.cells.pos)
            drifted_to = n
            sub, pmask, nlive = self._pair_subset(active_p.any(axis=1))
            sub_attrs = {}
            if tr.enabled:
                sub_attrs = dict(level=level, units=nlive, pairs=nlive,
                                 active_frac=float(active_p.sum())
                                 / max(nreal, 1))
            with tr.span("substep", **sub_attrs):
                state, nact = self._jit_sub(state, sub, pmask,
                                            jnp.int32(level),
                                            jnp.asarray(wake_floor),
                                            jnp.float32(dt_max_c),
                                            jnp.int32(depth),
                                            jnp.float32(u_floor))
                if tr.enabled:
                    tr.fence(state.cells.pos)
            updates += int(nact)
            pair_tasks += nlive
            force_substeps += 1
            # bins only change at force sub-steps (deepening / wake-up):
            # recompute the wake floors only when they actually did
            bins_new = np.asarray(state.bins)
            deepened = 0
            if not np.array_equal(bins_new, bins_h):
                deepened = int((bins_new != bins_h).sum())
                bins_h = bins_new
                wake_floor = self._wake_floor(bins_h, mask_host)
            if dm_on:
                met_counts[0] += dmetrics.host_row(
                    substeps=1, drift_active=nreal,
                    density_active=int(nact), force_active=int(nact),
                    pair_int=nlive, deepen_events=deepened,
                    wake_events=int(((bins_h < wake_floor[:, None])
                                     & (mask_host > 0)).sum()))[0]
                met_values[0, mVI["density_units"]] += nlive
                met_values[0, mVI["force_units"]] += nlive
                met_values[0, mVI["kick_units"]] += int(nact)
                acells = active_p.any(axis=1)
                attribute_cells(np.nonzero(acells[self._ci]
                                           | acells[self._cj])[0])
        if tr.enabled:
            tr.ctx["substep"] = nsub
        with tr.span("drift", units=nreal):
            state = self._jit_drift(
                state, jnp.float32((nsub - drifted_to) * dt_min))
            if tr.enabled:
                tr.fence(state.cells.pos)
        with tr.span("final", units=len(self._ci), pairs=len(self._ci),
                     active_frac=1.0):
            state = self._jit_final(state, self.pairs,
                                    jnp.ones(len(self._ci), jnp.float32),
                                    jnp.float32(dt_max_c))
            jax.block_until_ready(state.cells.pos)
        updates += nreal
        pair_tasks += len(self._ci)
        if dm_on:
            met_counts[0] += dmetrics.host_row(
                substeps=1, drift_active=nreal, density_active=nreal,
                force_active=nreal, pair_int=len(self._ci))[0]
            met_values[0, mVI["density_units"]] += len(self._ci)
            met_values[0, mVI["force_units"]] += len(self._ci)
            met_values[0, mVI["kick_units"]] += nreal
            attribute_cells(np.arange(len(self._ci)))
            c = state.cells
            dmetrics.state_health(np.asarray(c.mask), np.asarray(c.vel),
                                  np.asarray(c.u), np.asarray(state.rho),
                                  np.asarray(c.mass), met_counts,
                                  met_values, rank=0)
            self.device_metrics_last = (met_counts, met_values)
            self.device_metrics_pulls += 1
            self.device_cell_work_last = {
                "columns": list(dmetrics.CELL_COLUMNS),
                "cells": cellw, "per_rank": cellw_rank}
        else:
            self.device_metrics_last = None
            self.device_cell_work_last = None
        self.state = state
        if self.rebin_each_cycle:
            with tr.span("rebin", units=nreal):
                self._rebin_state()
        self.particle_updates += updates
        self.global_equiv_updates += nsub * nreal
        self.substeps += nsub
        return {
            "t": float(self.state.time),
            "dt_max": dt_max_c,
            "depth": depth,
            "substeps": nsub,
            "force_substeps": force_substeps + 1,   # interior + final
            "bin_hist": hist,
            "updates": updates,
            "global_equiv_updates": nsub * nreal,
            "pair_tasks": pair_tasks,
            "global_equiv_pair_tasks": nsub * len(self._ci),
        }

    def run(self, ncycles: int) -> Dict[str, list]:
        log: Dict[str, list] = {"t": [], "wall": [], "E": [], "px": [],
                                "depth": [], "updates": []}
        for _ in range(ncycles):
            stats = self.run_cycle()
            e, p = self.diagnostics()
            log["t"].append(stats["t"])
            log["wall"].append(stats["wall"])
            log["E"].append(e)
            log["px"].append(p[0])
            log["depth"].append(stats["depth"])
            log["updates"].append(stats["updates"])
        return log

    def diagnostics(self) -> Tuple[float, np.ndarray]:
        """(total energy, total momentum) over real particles."""
        c = self.state.cells
        m = np.asarray(c.mass * c.mask)
        v = np.asarray(c.vel)
        u = np.asarray(c.u)
        ke = 0.5 * np.sum(m * np.sum(v * v, axis=-1))
        ie = np.sum(m * u)
        mom = np.sum(m[..., None] * v, axis=(0, 1))
        return float(ke + ie), mom
