"""SPH smoothing kernels W(r, h) and derivatives (paper §2, eq. (1)).

Convention: the kernel has compact support of radius ``h`` — i.e. W(r,h) = 0
for r >= h, matching the paper's pair predicate ``r_ij < h_i``. All kernels
are 3-D and normalised so that ∫ W d³r = 1.

Derivatives provided:
  * ``grad_w``   — dW/dr (scalar radial derivative; ∇W = dW/dr · r̂)
  * ``dw_dh``    — ∂W/∂h, used for the Ω correction term
                   (∂W/∂h = −(3·W + r·dW/dr)/h for any 3-D scaling kernel)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_CUBIC_NORM_3D = 8.0 / jnp.pi     # × h⁻³, for q = r/h in [0, 1]
_WENDLAND_C2_NORM_3D = 21.0 / (2.0 * jnp.pi)


def w_cubic(r, h):
    """M4 cubic spline, support radius h."""
    q = r / h
    sigma = _CUBIC_NORM_3D / (h * h * h)
    w1 = 1.0 - 6.0 * q * q + 6.0 * q * q * q          # q <= 1/2
    w2 = 2.0 * (1.0 - q) ** 3                          # 1/2 < q <= 1
    w = jnp.where(q <= 0.5, w1, w2)
    return jnp.where(q < 1.0, sigma * w, 0.0)


def dwdr_cubic(r, h):
    q = r / h
    sigma = _CUBIC_NORM_3D / (h ** 4)
    d1 = -12.0 * q + 18.0 * q * q
    d2 = -6.0 * (1.0 - q) ** 2
    d = jnp.where(q <= 0.5, d1, d2)
    return jnp.where(q < 1.0, sigma * d, 0.0)


def w_wendland_c2(r, h):
    """Wendland C2, support radius h."""
    q = r / h
    sigma = _WENDLAND_C2_NORM_3D / (h * h * h)
    w = (1.0 - q) ** 4 * (4.0 * q + 1.0)
    return jnp.where(q < 1.0, sigma * w, 0.0)


def dwdr_wendland_c2(r, h):
    q = r / h
    sigma = _WENDLAND_C2_NORM_3D / (h ** 4)
    d = -20.0 * q * (1.0 - q) ** 3
    return jnp.where(q < 1.0, sigma * d, 0.0)


_KERNELS = {
    "cubic": (w_cubic, dwdr_cubic),
    "wendland_c2": (w_wendland_c2, dwdr_wendland_c2),
}


def get_kernel(name: str):
    """Return (W, dW/dr) callables."""
    try:
        return _KERNELS[name]
    except KeyError:
        raise ValueError(f"unknown kernel {name!r}; have {list(_KERNELS)}")


def dw_dh(r, h, name: str = "cubic"):
    """∂W/∂h = −(3W + r·dW/dr)/h (3-D scaling identity)."""
    w_fn, dwdr_fn = get_kernel(name)
    return -(3.0 * w_fn(r, h) + r * dwdr_fn(r, h)) / h
