"""Initial conditions.

The paper's tests resample z=0.5 EAGLE outputs — highly clustered particle
distributions whose densities span 8 orders of magnitude (Fig. 3). Without
the EAGLE data we generate a statistically similar proxy: a hierarchical
Gaussian-mixture clustering (halos with NFW-ish radial profiles placed on a
large-scale web) over a uniform background, which reproduces the *load
imbalance structure* the paper's decomposition is tested against. Uniform
ICs are provided for conservation tests.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np


def uniform_ic(n_side: int, *, box: float = 1.0, temperature: float = 1.0,
               jitter: float = 0.05, seed: int = 0,
               n_target: float = 48.0) -> Dict[str, np.ndarray]:
    """Jittered-lattice uniform gas at rest."""
    rng = np.random.default_rng(seed)
    g = (np.arange(n_side) + 0.5) / n_side
    pos = np.stack(np.meshgrid(g, g, g, indexing="ij"), -1).reshape(-1, 3)
    pos = (pos + jitter * rng.standard_normal(pos.shape) / n_side) % 1.0
    pos *= box
    n = len(pos)
    spacing = box / n_side
    h = np.full(n, spacing * (3.0 * n_target / (4.0 * np.pi)) ** (1 / 3))
    return {
        "pos": pos.astype(np.float32),
        "vel": np.zeros((n, 3), np.float32),
        "mass": np.full(n, (box ** 3) / n, np.float32),
        "u": np.full(n, temperature, np.float32),
        "h": h.astype(np.float32),
        "box": box,
    }


def sedov_ic(n_side: int, *, box: float = 1.0, e0: float = 1.0,
             u_background: float = 1e-6, r_inject: float | None = None,
             jitter: float = 0.02, seed: int = 0,
             n_target: float = 48.0) -> Dict[str, np.ndarray]:
    """Sedov–Taylor point explosion: cold uniform gas + central energy spike.

    The blast energy ``e0`` is deposited, kernel-weighted, into the
    particles within ``r_inject`` of the box centre. The resulting internal
    energy contrast (~``e0 / u_background`` per unit mass) drives a sound
    speed — and hence CFL time-step — contrast of order sqrt(contrast):
    with the defaults the central particles demand steps >3 decades shorter
    than the quiescent background, the scenario hierarchical time bins
    exist for. Energy conservation against the analytic Sedov solution is
    the standard accuracy check.
    """
    ic = uniform_ic(n_side, box=box, temperature=u_background,
                    jitter=jitter, seed=seed, n_target=n_target)
    pos = ic["pos"]
    centre = np.full(3, box / 2.0, np.float32)
    if r_inject is None:
        r_inject = 2.0 * box / n_side        # a couple of lattice spacings
    d = pos - centre
    d -= box * np.round(d / box)             # min-image
    r = np.linalg.norm(d, axis=1)
    sel = r < r_inject
    if not sel.any():
        sel = np.argsort(r)[:1]              # degenerate: nearest particle
        w = np.ones(1)
    else:
        w = 1.0 - (r[sel] / r_inject) ** 2   # smooth central weighting
    w = w / w.sum()
    u = ic["u"].astype(np.float64)
    u[sel] += e0 * w / ic["mass"][sel]
    ic["u"] = u.astype(np.float32)
    return ic


def kelvin_helmholtz_ic(n_side: int, *, box: float = 1.0,
                        v_shear: float = 0.5, u0: float = 1.0,
                        perturb: float = 0.05, modes: int = 2,
                        layer_width: float = 0.05, jitter: float = 0.02,
                        seed: int = 0,
                        n_target: float = 48.0) -> Dict[str, np.ndarray]:
    """Kelvin–Helmholtz shear layer: the classic mixing-instability test.

    A density-matched 3-D setup (equal-mass particles on one lattice, so no
    spurious surface tension from a density jump): the central slab
    |z − box/2| < box/4 streams at +v_shear in x, the outer gas at
    −v_shear, with a smooth tanh transition of width ``layer_width`` and a
    sinusoidal v_z seed perturbation localised at the two interfaces
    (Price 2008-style). Pressure is uniform (same u everywhere), so the
    only dynamics is the shear instability rolling up the interfaces —
    a scenario whose *activity structure* (interfaces deepen their time
    bins first) exercises the time-bin machinery differently from a
    point blast.
    """
    ic = uniform_ic(n_side, box=box, temperature=u0, jitter=jitter,
                    seed=seed, n_target=n_target)
    pos = ic["pos"]
    z = pos[:, 2] / box
    x = pos[:, 0] / box
    # smooth shear profile: +v in the central slab, -v outside
    d_lo = (z - 0.25) / max(layer_width, 1e-6)
    d_hi = (z - 0.75) / max(layer_width, 1e-6)
    profile = 0.5 * (np.tanh(d_lo) - np.tanh(d_hi)) * 2.0 - 1.0
    vx = v_shear * profile
    # interface-localised v_z seed (both interfaces, opposite phases)
    vz = perturb * v_shear * np.sin(2.0 * np.pi * modes * x) * (
        np.exp(-(d_lo ** 2)) + np.exp(-(d_hi ** 2)))
    vel = np.zeros_like(pos)
    vel[:, 0] = vx
    vel[:, 2] = vz
    ic["vel"] = vel.astype(np.float32)
    return ic


def clustered_ic(n: int, *, box: float = 1.0, n_halos: int = 32,
                 clustered_fraction: float = 0.8, seed: int = 0,
                 temperature: float = 1.0,
                 n_target: float = 48.0) -> Dict[str, np.ndarray]:
    """EAGLE-like clustered proxy: halos + filaments + uniform background.

    Halo masses follow a power law (few big, many small); particle radii
    within a halo follow r ~ U^2 (centrally concentrated), giving local
    densities spanning many orders of magnitude, as in the paper's Fig. 3.
    """
    rng = np.random.default_rng(seed)
    n_clust = int(n * clustered_fraction)
    n_bg = n - n_clust

    # halo centres on a rough filamentary web: random walk between anchors
    centres = rng.random((n_halos, 3)) * box
    mass_pl = rng.pareto(1.5, n_halos) + 1.0
    halo_p = mass_pl / mass_pl.sum()
    counts = rng.multinomial(n_clust, halo_p)
    scales = 0.02 * box * (mass_pl / mass_pl.max()) ** (1 / 3) + 0.004 * box

    chunks = []
    for c, cnt, s in zip(centres, counts, scales):
        if cnt == 0:
            continue
        r = s * rng.random(cnt) ** 2.0          # centrally concentrated
        d = rng.standard_normal((cnt, 3))
        d /= np.linalg.norm(d, axis=1, keepdims=True) + 1e-12
        chunks.append(c + r[:, None] * d)
    clustered = (np.concatenate(chunks, 0) if chunks
                 else np.empty((0, 3)))
    bg = rng.random((n_bg, 3)) * box
    pos = np.concatenate([clustered, bg], 0) % box
    n = len(pos)

    # per-particle h from local density estimate: kNN distance proxy via a
    # coarse grid count (cheap, only sets the *initial* h)
    gridn = max(int(np.ceil(n ** (1 / 3) / 2)), 4)
    idx = np.clip((pos / box * gridn).astype(int), 0, gridn - 1)
    flat = (idx[:, 0] * gridn + idx[:, 1]) * gridn + idx[:, 2]
    counts_g = np.bincount(flat, minlength=gridn ** 3)
    local = counts_g[flat] / (box / gridn) ** 3
    h = (3.0 * n_target / (4.0 * np.pi * np.maximum(local, 1e-12))) ** (1 / 3)
    h = np.clip(h, box / 512, box / 4)

    return {
        "pos": pos.astype(np.float32),
        "vel": np.zeros((n, 3), np.float32),
        "mass": np.full(n, (box ** 3) / n, np.float32),
        "u": np.full(n, temperature, np.float32),
        "h": h.astype(np.float32),
        "box": box,
    }
