"""SPH pair physics: density (eq. 2), forces (eq. 3), energy (eq. 4).

All functions here operate on *blocks* of particles — a receiver block
``i`` of shape (Ci, …) and a source block ``j`` of shape (Cj, …) — and are
the numerical payload of SWIFT's ``density_pair`` / ``force_pair`` tasks.
The engine vmaps them over the cell-pair list; ``kernels/sph_pair`` provides
the Pallas TPU version with these as the oracle.

Distances use the dot-product form |xi−xj|² = |xi|² + |xj|² − 2·xi·xjᵀ so the
inner operation is an MXU matmul. Periodic wrapping is handled *before* the
kernel by shifting the source block by the cell-pair's periodic image offset
(provided by the cell grid), so no per-element modulo is needed inside the
hot loop — a TPU-friendly restructuring of the usual min-image convention.

The optional Monaghan artificial viscosity (standard in SWIFT) is symmetric,
so momentum and total energy remain conserved.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .smoothing import get_kernel

GAMMA = 5.0 / 3.0      # adiabatic index (monatomic ideal gas)
EPS = 1e-12


def eos_pressure(rho, u, gamma: float = GAMMA):
    """P = (γ−1)·ρ·u."""
    return (gamma - 1.0) * rho * u


def sound_speed(rho, u, gamma: float = GAMMA):
    """c = sqrt(γ·P/ρ) = sqrt(γ(γ−1)u)."""
    return jnp.sqrt(jnp.maximum(gamma * (gamma - 1.0) * u, 0.0))


def pairwise_r2(pos_i, pos_j):
    """(Ci, Cj) squared distances via the MXU-friendly dot form."""
    sq_i = jnp.sum(pos_i * pos_i, axis=-1)          # (Ci,)
    sq_j = jnp.sum(pos_j * pos_j, axis=-1)          # (Cj,)
    cross = pos_i @ pos_j.T                         # (Ci, Cj) matmul
    r2 = sq_i[:, None] + sq_j[None, :] - 2.0 * cross
    return jnp.maximum(r2, 0.0)


class DensityResult(NamedTuple):
    rho: jax.Array        # (Ci,) Σ m_j W(r, h_i)
    drho_dh: jax.Array    # (Ci,) Σ m_j ∂W/∂h(r, h_i)
    nngb: jax.Array       # (Ci,) weighted neighbour count (for h iteration)


def density_block(pos_i, h_i, pos_j, m_j, mask_j, *,
                  kernel: str = "cubic") -> DensityResult:
    """Density contributions of source block j onto receiver block i (eq. 2).

    Includes the self term when the blocks alias (W(0, h) is finite).
    ``mask_j`` zeroes padded slots.
    """
    w_fn, dwdr_fn = get_kernel(kernel)
    r2 = pairwise_r2(pos_i, pos_j)
    r = jnp.sqrt(r2 + EPS)
    h = h_i[:, None]
    w = w_fn(r, h)
    mw = m_j[None, :] * mask_j[None, :] * w
    rho = jnp.sum(mw, axis=1)
    dwdh = -(3.0 * w + r * dwdr_fn(r, h)) / h
    drho_dh = jnp.sum(m_j[None, :] * mask_j[None, :] * dwdh, axis=1)
    nngb = jnp.sum((w > 0.0) * mask_j[None, :], axis=1)
    return DensityResult(rho, drho_dh, nngb)


class ForceResult(NamedTuple):
    dv: jax.Array      # (Ci, 3) acceleration contribution
    du: jax.Array      # (Ci,)  du/dt contribution


def force_block(pos_i, vel_i, h_i, P_i, rho_i, omega_i, cs_i,
                pos_j, vel_j, h_j, P_j, rho_j, omega_j, cs_j,
                m_j, mask_j, *, kernel: str = "cubic",
                alpha_visc: float = 0.0) -> ForceResult:
    """Force and energy contributions of block j onto block i (eqs. 3, 4).

    The pair predicate is r < max(h_i, h_j) for the momentum equation and
    r < h_i for the energy equation, exactly as in the paper.
    """
    _w_fn, dwdr_fn = get_kernel(kernel)
    r2 = pairwise_r2(pos_i, pos_j)
    r = jnp.sqrt(r2 + EPS)
    dx = pos_i[:, None, :] - pos_j[None, :, :]       # (Ci, Cj, 3)
    rhat = dx / r[:, :, None]

    hi = h_i[:, None]
    hj = h_j[None, :]
    dwi = dwdr_fn(r, hi)                              # ∇W(r, h_i) magnitude
    dwj = dwdr_fn(r, hj)                              # ∇W(r, h_j) magnitude

    # pressure term of eq. (3)
    ai = (P_i / (omega_i * rho_i ** 2))[:, None]      # (Ci, 1)
    aj = (P_j / (omega_j * rho_j ** 2))[None, :]      # (1, Cj)
    fmag = ai * dwi + aj * dwj                        # (Ci, Cj)

    valid = mask_j[None, :] * (r < jnp.maximum(hi, hj)) * (r2 > EPS)

    # artificial viscosity (Monaghan 1992), symmetric in (i, j)
    du_visc = jnp.zeros(pos_i.shape[0], dtype=pos_i.dtype)
    if alpha_visc > 0.0:
        dvel = vel_i[:, None, :] - vel_j[None, :, :]
        vdotr = jnp.sum(dvel * dx, axis=-1)
        hbar = 0.5 * (hi + hj)
        rhobar = 0.5 * (rho_i[:, None] + rho_j[None, :])
        csbar = 0.5 * (cs_i[:, None] + cs_j[None, :])
        mu = hbar * vdotr / (r2 + 0.01 * hbar * hbar)
        mu = jnp.where(vdotr < 0.0, mu, 0.0)
        beta = 2.0 * alpha_visc
        piij = (-alpha_visc * csbar * mu + beta * mu * mu) / rhobar
        dwbar = 0.5 * (dwi + dwj)
        fmag = fmag + piij * dwbar
        # viscous heating: ½ Σ m_j Π_ij v_ij·∇W̄ (symmetric split)
        mvisc = m_j[None, :] * valid
        du_visc = 0.5 * jnp.sum(
            mvisc * piij * dwbar * (vdotr / r), axis=1)

    mj = m_j[None, :] * valid
    fmag = jnp.where(valid > 0, fmag, 0.0)   # padded slots may hold non-finite
    dv = -jnp.sum((mj * fmag)[:, :, None] * rhat, axis=1)   # (Ci, 3)

    # eq. (4): du_i/dt = P_i/(Ω_i ρ_i²) Σ_j m_j (v_i − v_j)·∇W(r, h_i)
    dvel = vel_i[:, None, :] - vel_j[None, :, :]
    vdotrhat = jnp.sum(dvel * rhat, axis=-1)
    valid_u = mask_j[None, :] * (r < hi) * (r2 > EPS)
    du = (P_i / (omega_i * rho_i ** 2)) * jnp.sum(
        m_j[None, :] * valid_u * vdotrhat * dwi, axis=1)
    return ForceResult(dv, du + du_visc)


def cfl_timestep_block(h, u, vel, mask, *, gamma: float = GAMMA,
                       cfl: float = 0.25):
    """Per-particle CFL time-step: dt_i = C_CFL · h_i / (c_i + |v_i|).

    This is the quantity the time-bin hierarchy quantises into power-of-two
    bins (``timebins.assign_bins``): the dynamic range of dt_i across a
    clustered simulation reaches ~10^4, which is exactly why integrating
    everything at min_i dt_i wastes the machine. Padded slots get +inf so
    reductions and bin assignment ignore them.
    """
    cs = sound_speed(jnp.ones_like(u), u, gamma)   # c = sqrt(γ(γ−1)u)
    speed = jnp.linalg.norm(vel, axis=-1) + cs
    dt = cfl * h / jnp.maximum(speed, EPS)
    return jnp.where(mask > 0, dt, jnp.inf)


def ghost_update(rho, drho_dh, u, h, *, gamma: float = GAMMA
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The 'ghost' task (triangle in Fig. 1): close the density loop.

    Computes pressure, Ω correction (Ω = 1 + h/(3ρ)·∂ρ/∂h) and sound speed
    once every density contribution for a cell has been accumulated.
    """
    rho_safe = jnp.maximum(rho, EPS)
    omega = 1.0 + (h / (3.0 * rho_safe)) * drho_dh
    omega = jnp.where(jnp.abs(omega) < 1e-4, 1.0, omega)   # guard degenerate
    press = eos_pressure(rho_safe, u, gamma)
    cs = sound_speed(rho_safe, u, gamma)
    return press, omega, cs


def smoothing_length_update(h, rho, m, nngb, *, n_target: float = 48.0,
                            eta: float = 0.5, h_min: float = 1e-6,
                            h_max: float | None = None):
    """One fixed-point update of h towards ~constant neighbour number.

    SWIFT iterates h_i so each particle keeps ≈ n_target neighbours; a single
    damped fixed-point step per time-step tracks the compressible flow
    (smoothing lengths span orders of magnitude across the clustered IC).
    """
    ratio = (n_target / jnp.maximum(nngb, 1.0)) ** (1.0 / 3.0)
    h_new = h * (1.0 - eta + eta * ratio)
    if h_max is not None:
        h_new = jnp.minimum(h_new, h_max)
    return jnp.maximum(h_new, h_min)
