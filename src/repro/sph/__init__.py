"""SPH substrate: the paper's physics + the task-based engine.

New code should enter through the API layer — ``SimulationSpec`` +
``build_simulation`` (``repro.sph.api``) — which compiles a frozen spec
into any of the {global, timebin} × {local, distributed} engines. The
engine classes (``Simulation``, ``TimeBinSimulation``,
``distributed.DistSimulation``) remain importable as the engine layer /
legacy shims.
"""

from ..observability import ObserveSpec, RunObserver
from .api import (SCENARIOS, SimulationSpec, build_simulation, make_ic,
                  register_scenario)
from .api import Simulation as SimulationProtocol
from .cellgrid import (GridSpec, PairList, ParticleCells, bin_particles,
                       build_pair_list, choose_grid, unbin)
from .engine import (SPHConfig, SPHState, Simulation, build_taskgraph,
                     cfl_timestep, compute_accelerations, init_state, step)
from .engine import cfl_timestep_particles
from .ic import clustered_ic, kelvin_helmholtz_ic, sedov_ic, uniform_ic
from .physics import (GAMMA, cfl_timestep_block, density_block, eos_pressure,
                      force_block, ghost_update, smoothing_length_update,
                      sound_speed)
from .smoothing import dw_dh, get_kernel, w_cubic, w_wendland_c2
from .timebins import (TimeBinSimulation, TimeBinState, active_level,
                       assign_bins, bin_timestep, cell_bin_histogram,
                       cell_max_bins, timebin_init)
from .dist_timebins import (DistTimeBinSimulation, build_rank_plan,
                            halo_export_schedule)
from .collectives import (CollectiveTransport, build_allgather_program,
                          build_fused_substep_program, build_permute_program)

__all__ = [
    "SCENARIOS", "SimulationSpec", "SimulationProtocol", "build_simulation",
    "make_ic", "register_scenario", "ObserveSpec", "RunObserver",
    "GridSpec", "PairList", "ParticleCells", "bin_particles",
    "build_pair_list", "choose_grid", "unbin",
    "SPHConfig", "SPHState", "Simulation", "build_taskgraph", "cfl_timestep",
    "cfl_timestep_particles", "compute_accelerations", "init_state", "step",
    "clustered_ic", "kelvin_helmholtz_ic", "sedov_ic", "uniform_ic",
    "GAMMA", "cfl_timestep_block", "density_block", "eos_pressure",
    "force_block", "ghost_update", "smoothing_length_update", "sound_speed",
    "dw_dh", "get_kernel", "w_cubic", "w_wendland_c2",
    "TimeBinSimulation", "TimeBinState", "active_level", "assign_bins",
    "bin_timestep", "cell_bin_histogram", "cell_max_bins", "timebin_init",
    "DistTimeBinSimulation", "build_rank_plan", "halo_export_schedule",
    "CollectiveTransport", "build_allgather_program",
    "build_fused_substep_program", "build_permute_program",
]
