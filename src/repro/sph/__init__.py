"""SPH substrate: the paper's physics + the task-based engine."""

from .cellgrid import (GridSpec, PairList, ParticleCells, bin_particles,
                       build_pair_list, choose_grid, unbin)
from .engine import (SPHConfig, SPHState, Simulation, build_taskgraph,
                     cfl_timestep, compute_accelerations, init_state, step)
from .ic import clustered_ic, uniform_ic
from .physics import (GAMMA, density_block, eos_pressure, force_block,
                      ghost_update, smoothing_length_update, sound_speed)
from .smoothing import dw_dh, get_kernel, w_cubic, w_wendland_c2

__all__ = [
    "GridSpec", "PairList", "ParticleCells", "bin_particles",
    "build_pair_list", "choose_grid", "unbin",
    "SPHConfig", "SPHState", "Simulation", "build_taskgraph", "cfl_timestep",
    "compute_accelerations", "init_state", "step",
    "clustered_ic", "uniform_ic",
    "GAMMA", "density_block", "eos_pressure", "force_block", "ghost_update",
    "smoothing_length_update", "sound_speed",
    "dw_dh", "get_kernel", "w_cubic", "w_wendland_c2",
]
