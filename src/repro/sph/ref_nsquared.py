"""O(N²) brute-force SPH oracle (and bulk-synchronous baseline stand-in).

Direct evaluation of eqs. (2)–(4) over all particle pairs with periodic
minimum-image distances. This is the ground truth the cell/task engine and
the Pallas kernels are validated against, and doubles as the
"traditional code" baseline in ``benchmarks/baseline_compare.py`` (GADGET-2
fills that role in the paper; an O(N²)-masked dense evaluation is its
honest stand-in at test scale).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .physics import EPS, GAMMA, eos_pressure, sound_speed
from .smoothing import get_kernel


def _min_image(dx, box):
    return dx - box * jnp.round(dx / box)


def nsq_density(pos, mass, h, box, *, kernel: str = "cubic"):
    """rho, drho_dh, nngb for all particles, O(N²)."""
    w_fn, dwdr_fn = get_kernel(kernel)
    dx = _min_image(pos[:, None, :] - pos[None, :, :], box)
    r = jnp.sqrt(jnp.sum(dx * dx, axis=-1) + EPS)
    hi = h[:, None]
    w = w_fn(r, hi)
    rho = jnp.sum(mass[None, :] * w, axis=1)
    dwdh = -(3.0 * w + r * dwdr_fn(r, hi)) / hi
    drho_dh = jnp.sum(mass[None, :] * dwdh, axis=1)
    nngb = jnp.sum((w > 0.0), axis=1).astype(pos.dtype)
    return rho, drho_dh, nngb


def nsq_forces(pos, vel, mass, u, h, rho, omega, box, *,
               kernel: str = "cubic", alpha_visc: float = 0.0,
               gamma: float = GAMMA):
    """dv/dt and du/dt for all particles, O(N²) (eqs. 3, 4)."""
    _w_fn, dwdr_fn = get_kernel(kernel)
    press = eos_pressure(rho, u, gamma)
    cs = sound_speed(rho, u, gamma)
    dx = _min_image(pos[:, None, :] - pos[None, :, :], box)
    r2 = jnp.sum(dx * dx, axis=-1)
    r = jnp.sqrt(r2 + EPS)
    rhat = dx / r[:, :, None]
    hi, hj = h[:, None], h[None, :]
    dwi = dwdr_fn(r, hi)
    dwj = dwdr_fn(r, hj)
    ai = (press / (omega * rho ** 2))[:, None]
    aj = (press / (omega * rho ** 2))[None, :]
    fmag = ai * dwi + aj * dwj

    valid = (r < jnp.maximum(hi, hj)) & (r2 > EPS)

    du_visc = jnp.zeros_like(rho)
    if alpha_visc > 0.0:
        dvel = vel[:, None, :] - vel[None, :, :]
        vdotr = jnp.sum(dvel * dx, axis=-1)
        hbar = 0.5 * (hi + hj)
        rhobar = 0.5 * (rho[:, None] + rho[None, :])
        csbar = 0.5 * (cs[:, None] + cs[None, :])
        mu = hbar * vdotr / (r2 + 0.01 * hbar * hbar)
        mu = jnp.where(vdotr < 0.0, mu, 0.0)
        piij = (-alpha_visc * csbar * mu + 2.0 * alpha_visc * mu * mu) / rhobar
        dwbar = 0.5 * (dwi + dwj)
        fmag = fmag + piij * dwbar
        du_visc = 0.5 * jnp.sum(
            jnp.where(valid, mass[None, :] * piij * dwbar * (vdotr / r), 0.0),
            axis=1)

    fmag = jnp.where(valid, fmag, 0.0)
    mj = mass[None, :] * valid
    dv = -jnp.sum((mj * fmag)[:, :, None] * rhat, axis=1)

    dvel = vel[:, None, :] - vel[None, :, :]
    vdotrhat = jnp.sum(dvel * rhat, axis=-1)
    valid_u = (r < hi) & (r2 > EPS)
    du = (press / (omega * rho ** 2)) * jnp.sum(
        jnp.where(valid_u, mass[None, :] * vdotrhat * dwi, 0.0), axis=1)
    return dv, du + du_visc
